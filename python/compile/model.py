"""L2: the JAX transformer — per-layer fwd/bwd units the Rust FSDP engine drives.

FSDP's communication structure is per-layer: all-gather layer params before
forward, all-gather again + reduce-scatter grads in backward. To let the
Rust coordinator own those boundaries (and swap Collective <-> ODC there),
the model is exported as *per-layer* HLO modules operating on FLAT f32
parameter vectors (the FSDP flat-parameter representation the comm layer
shards):

  embed_fwd(emb_flat, tokens)            -> x                  [S, D]
  block_fwd(flat, x, seg)                -> y                  [S, D]
  block_bwd(flat, x, seg, dy)            -> (dx, dflat)        (recompute)
  loss_head(emb_flat, x, targets, mask)  -> (loss_sum, ntok, dx, demb_flat)
  embed_bwd(tokens, dx)                  -> demb_flat          (scatter-add)

block_bwd recomputes the forward from the saved layer *input* (per-layer
activation checkpointing), so the engine stores one [S, D] tensor per
layer per in-flight microbatch — the standard FSDP + checkpoint setup.
Attention inside block_fwd is the L1 Pallas kernel (custom_vjp, so
block_bwd's autodiff uses the Pallas backward kernels too).

The LM head is tied to the token embedding. loss_head returns the SUM of
masked token cross-entropies plus the token count; the engine aggregates
microbatch gradients with weights w_m = 1 (sum) and divides by the global
token count at the optimizer step — the paper's §2.1 aggregation policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.attention import flash_attention

LN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Flat-parameter packing
# ---------------------------------------------------------------------------


def unflatten_block(cfg: ModelConfig, flat: jax.Array) -> dict:
    """Split a flat f32[P_block] vector into the block's named tensors."""
    out = {}
    off = 0
    for name, shape in cfg.block_param_shapes():
        n = int(np.prod(shape))
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    assert off == cfg.block_params
    return out


def split_embed(cfg: ModelConfig, emb_flat: jax.Array):
    """emb_flat -> (token_emb [V, D], pos_emb [Smax, D])."""
    v, d, smax = cfg.vocab, cfg.d_model, cfg.max_seq
    tok = emb_flat[: v * d].reshape(v, d)
    pos = emb_flat[v * d :].reshape(smax, d)
    return tok, pos


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g + b


def embed_fwd(cfg: ModelConfig, emb_flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token + positional embedding lookup; tokens int32[S] -> f32[S, D]."""
    tok, pos = split_embed(cfg, emb_flat)
    s = tokens.shape[0]
    return tok[tokens] + pos[:s]


def embed_bwd(cfg: ModelConfig, tokens: jax.Array, dx: jax.Array) -> jax.Array:
    """Gradient of embed_fwd w.r.t. emb_flat (scatter-add + pos grad)."""
    v, d, smax = cfg.vocab, cfg.d_model, cfg.max_seq
    s = tokens.shape[0]
    dtok = jnp.zeros((v, d), jnp.float32).at[tokens].add(dx)
    dpos = jnp.zeros((smax, d), jnp.float32).at[:s].add(dx)
    return jnp.concatenate([dtok.reshape(-1), dpos.reshape(-1)])


def block_fwd(cfg: ModelConfig, flat: jax.Array, x: jax.Array, seg: jax.Array) -> jax.Array:
    """Pre-LN transformer block: attn(Pallas) + MLP, both residual."""
    p = unflatten_block(cfg, flat)
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    xn = layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = (xn @ p["wq"]).reshape(s, h, dh).transpose(1, 0, 2)
    k = (xn @ p["wk"]).reshape(s, h, dh).transpose(1, 0, 2)
    v = (xn @ p["wv"]).reshape(s, h, dh).transpose(1, 0, 2)
    attn = flash_attention(q, k, v, seg, cfg.block_q, cfg.block_k)
    attn = attn.transpose(1, 0, 2).reshape(s, d)
    x = x + attn @ p["wo"]

    xn = layer_norm(x, p["ln2_g"], p["ln2_b"])
    mlp = jax.nn.gelu(xn @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return x + mlp


def block_bwd(cfg: ModelConfig, flat: jax.Array, x: jax.Array, seg: jax.Array, dy: jax.Array):
    """VJP of block_fwd from the saved layer input (recompute inside)."""
    y, vjp = jax.vjp(lambda f, xx: block_fwd(cfg, f, xx, seg), flat, x)
    del y
    dflat, dx = vjp(dy)
    return dx, dflat


def loss_head(cfg: ModelConfig, emb_flat: jax.Array, x: jax.Array, targets: jax.Array, mask: jax.Array):
    """Tied-embedding LM head + masked cross-entropy (sum, not mean).

    Returns (loss_sum f32[], ntok f32[], dx f32[S,D], demb_flat).
    """

    def f(emb_flat_, x_):
        tok, _ = split_embed(cfg, emb_flat_)
        logits = x_ @ tok.T  # [S, V]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
        ce = lse - picked
        return jnp.sum(ce * mask)

    loss_sum, vjp = jax.vjp(f, emb_flat, x)
    demb, dx = vjp(jnp.float32(1.0))
    ntok = jnp.sum(mask)
    return loss_sum, ntok, dx, demb


# ---------------------------------------------------------------------------
# Whole-model reference (python-side tests + convergence cross-check)
# ---------------------------------------------------------------------------


def model_loss(cfg: ModelConfig, emb_flat, block_flats, tokens, seg, targets, mask):
    """Full forward pass composed from the per-layer units. Differentiable."""
    x = embed_fwd(cfg, emb_flat, tokens)
    for flat in block_flats:
        x = block_fwd(cfg, flat, x, seg)
    loss_sum, ntok, _, _ = loss_head(cfg, emb_flat, x, targets, mask)
    return loss_sum, ntok


def model_grads(cfg: ModelConfig, emb_flat, block_flats, tokens, seg, targets, mask):
    """Autodiff gradients of the summed loss — the engine-equivalence oracle."""

    def f(emb_flat_, blocks_):
        x = embed_fwd(cfg, emb_flat_, tokens)
        for flat in blocks_:
            x = block_fwd(cfg, flat, x, seg)
        loss_sum, _, _, _ = loss_head(cfg, emb_flat_, x, targets, mask)
        return loss_sum

    return jax.grad(f, argnums=(0, 1))(emb_flat, list(block_flats))


# ---------------------------------------------------------------------------
# Initialization (written to artifacts/<cfg>/init/*.bin at export time)
# ---------------------------------------------------------------------------


def init_embed(cfg: ModelConfig, rng: np.random.Generator) -> np.ndarray:
    d = cfg.d_model
    tok = rng.standard_normal((cfg.vocab, d), dtype=np.float32) * 0.02
    pos = rng.standard_normal((cfg.max_seq, d), dtype=np.float32) * 0.01
    return np.concatenate([tok.reshape(-1), pos.reshape(-1)])


def init_block(cfg: ModelConfig, rng: np.random.Generator) -> np.ndarray:
    """GPT-2-style init, flat-packed in block_param_shapes() order."""
    d = cfg.d_model
    parts = []
    for name, shape in cfg.block_param_shapes():
        if name in ("ln1_g", "ln2_g"):
            parts.append(np.ones(shape, np.float32))
        elif name in ("ln1_b", "ln2_b", "b1", "b2"):
            parts.append(np.zeros(shape, np.float32))
        elif name in ("wo", "w2"):
            # residual-path projections get the depth-scaled init
            scale = np.float32(0.02 / np.sqrt(2.0 * cfg.n_layers))
            parts.append(rng.standard_normal(shape, dtype=np.float32) * scale)
        else:
            parts.append(rng.standard_normal(shape, dtype=np.float32) * 0.02)
    return np.concatenate([p.reshape(-1) for p in parts])
