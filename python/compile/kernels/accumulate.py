"""L1: Pallas gradient-accumulation kernel (the scatter-accumulate op).

In the paper (Appendix B), a server receiving a *scatter-accumulate* push
runs a lightweight daemon that accumulates the incoming gradient into its
owned shard: acc <- acc + w * g. This is the daemon's compute kernel,
exported as a fixed-size chunk so the Rust engine can apply it to shards
of any length (last chunk zero-padded).

The adam kernel is the other server-side op: the owned shard's AdamW
update at the minibatch boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accum_kernel(acc_ref, g_ref, w_ref, out_ref):
    out_ref[...] = acc_ref[...] + w_ref[0] * g_ref[...]


def accumulate(acc: jax.Array, g: jax.Array, w: jax.Array, *, block: int = 65536) -> jax.Array:
    """acc + w * g over f32[n] via a tiled Pallas kernel.

    Args:
      acc, g: f32[n] with n % block == 0 (the AOT exporter pads).
      w: f32[1] scalar weight (the microbatch aggregation weight w_m).
    """
    n = acc.shape[0]
    b = min(block, n)
    assert n % b == 0, f"accumulate: n={n} not a multiple of block={b}"
    return pl.pallas_call(
        _accum_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(acc, g, w)


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, hp_ref, p_out, m_out, v_out):
    """AdamW on one chunk. hp = [lr, beta1, beta2, eps, wd, bc1, bc2].

    bc1/bc2 are the bias corrections (1 - beta^t) precomputed host-side so
    the kernel stays elementwise (no transcendental pow on the hot path).
    """
    lr, b1, b2, eps, wd = hp_ref[0], hp_ref[1], hp_ref[2], hp_ref[3], hp_ref[4]
    bc1, bc2 = hp_ref[5], hp_ref[6]
    g = g_ref[...]
    m2 = b1 * m_ref[...] + (1.0 - b1) * g
    v2 = b2 * v_ref[...] + (1.0 - b2) * (g * g)
    mhat = m2 / bc1
    vhat = v2 / bc2
    p_out[...] = p_ref[...] - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p_ref[...])
    m_out[...] = m2
    v_out[...] = v2


def adam_step(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    hparams: jax.Array,
    *,
    block: int = 65536,
):
    """Tiled AdamW step over f32[n] shards; hparams f32[7], see kernel."""
    n = p.shape[0]
    b = min(block, n)
    assert n % b == 0, f"adam_step: n={n} not a multiple of block={b}"
    vec = pl.BlockSpec((b,), lambda i: (i,))
    return pl.pallas_call(
        _adam_kernel,
        grid=(n // b,),
        in_specs=[vec, vec, vec, vec, pl.BlockSpec((7,), lambda i: (0,))],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(p, m, v, g, hparams)
