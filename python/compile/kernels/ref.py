"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. pytest + hypothesis sweep shapes/dtypes and
`assert_allclose` kernel-vs-ref; the JAX model (L2) can also be built
against these references to cross-check end-to-end numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


def attention_mask(segment_ids: jax.Array) -> jax.Array:
    """Causal block-diagonal mask for packed sequences.

    Token i may attend to token j iff j <= i (causal) and both belong to
    the same packed segment (no cross-contamination; Krell et al. 2021).
    The diagonal is always allowed, so rows are never fully masked.

    Args:
      segment_ids: int32[S]; padding shares segment id 0.

    Returns:
      bool[S, S], True where attention is allowed.
    """
    i = jnp.arange(segment_ids.shape[0])[:, None]
    j = jnp.arange(segment_ids.shape[0])[None, :]
    same_seg = segment_ids[:, None] == segment_ids[None, :]
    return (j <= i) & same_seg


def attention_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, segment_ids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Reference multi-head attention forward.

    Args:
      q, k, v: f32[H, S, Dh]
      segment_ids: int32[S]

    Returns:
      (out f32[H, S, Dh], lse f32[H, S]) — lse is the log-sum-exp of the
      scaled masked scores, saved for the flash backward pass.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("hsd,htd->hst", q, k) * scale
    mask = attention_mask(segment_ids)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("hst,htd->hsd", e / denom, v)
    lse = (m + jnp.log(denom))[..., 0]
    return out, lse


def attention(q, k, v, segment_ids):
    """Forward only (drops lse); differentiable by jax autodiff."""
    return attention_fwd(q, k, v, segment_ids)[0]


def attention_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    segment_ids: jax.Array,
    out: jax.Array,
    lse: jax.Array,
    dout: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference flash-style backward from saved (out, lse).

    Matches the math the Pallas backward kernels implement:
      p     = exp(scores - lse)
      dv    = p^T @ dout
      dp    = dout @ v^T
      delta = rowsum(dout * out)
      ds    = p * (dp - delta) * scale
      dq    = ds @ k ;  dk = ds^T @ q
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("hsd,htd->hst", q, k) * scale
    mask = attention_mask(segment_ids)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    p = jnp.exp(scores - lse[..., None])
    p = jnp.where(mask, p, 0.0)
    dv = jnp.einsum("hst,hsd->htd", p, dout)
    dp = jnp.einsum("hsd,htd->hst", dout, v)
    delta = jnp.sum(dout * out, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("hst,htd->hsd", ds, k)
    dk = jnp.einsum("hst,hsd->htd", ds, q)
    return dq, dk, dv


def accumulate(acc: jax.Array, g: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for the scatter-accumulate daemon op: acc + w * g."""
    return acc + w * g


def adam_step(p, m, v, g, lr, beta1, beta2, eps, wd, t):
    """Reference AdamW update (decoupled weight decay), step count t>=1."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * (g * g)
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2
