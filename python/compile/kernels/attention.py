"""L1: Pallas flash-attention for packed causal sequences (fwd + bwd).

This is the compute hot-spot of the paper's workload: long-sequence
attention whose O(s^2) cost is the source of the workload imbalance that
motivates ODC. The paper's own kernels are Triton/CUDA (warps, shared
memory); per DESIGN.md §Hardware-Adaptation we restructure the same
algorithm for the TPU model Pallas exposes:

  * the grid + BlockSpec describe the HBM->VMEM schedule (what CUDA does
    with threadblocks): queries are tiled into `block_q`-row tiles that
    stay resident, K/V stream through in `block_k` chunks;
  * tiles are MXU-friendly (multiples of 128 at production sizes) so the
    inner `q @ k^T` / `p @ v` products map onto the 128x128 systolic
    array in bf16/f32;
  * the online-softmax running (max, denom, acc) state is the VMEM
    scratch (here: fori_loop carry, which interpret mode keeps on-chip).

Kernels MUST run with interpret=True in this environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are verified against kernels/ref.py by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


def _pick_block(s: int, want: int) -> int:
    """Largest divisor of s that is <= want (block sizes must tile S)."""
    b = min(want, s)
    while s % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, seg_ref, o_ref, lse_ref, *, block_k: int, scale: float):
    """One (head, q-tile) program of the flash-attention forward.

    Streams K/V in `block_k` chunks, maintaining the online-softmax state
    (m, l, acc). Causality lets us stop streaming at the last K block that
    overlaps the query tile.
    """
    iq = pl.program_id(1)
    block_q = q_ref.shape[1]
    dh = q_ref.shape[2]
    s_total = k_ref.shape[1]

    q = q_ref[0, :, :] * scale  # [BQ, Dh]
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)  # [BQ]
    q_seg = pl.load(seg_ref, (pl.dslice(iq * block_q, block_q),))

    # Number of K blocks that can causally interact with this Q tile.
    n_kblocks = ((iq + 1) * block_q + block_k - 1) // block_k
    n_kblocks = jnp.minimum(n_kblocks, s_total // block_k)

    def body(ik, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        k_seg = pl.load(seg_ref, (pl.dslice(ik * block_k, block_k),))

        s = jnp.dot(q, k_blk.T)  # [BQ, BK] — MXU product
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_seg[None, :] == q_seg[:, None])
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v_blk)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    m_f, l_f, acc_f = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))

    o_ref[0, :, :] = acc_f / l_f[:, None]
    lse_ref[0, :] = m_f + jnp.log(l_f)


def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    segment_ids: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Pallas forward: (out f32[H,S,Dh], lse f32[H,S])."""
    h, s, dh = q.shape
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    scale = 1.0 / float(dh) ** 0.5

    grid = (h, s // bq)
    kernel = functools.partial(_fwd_kernel, block_k=bk, scale=scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((s,), lambda ih, iq: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, bq), lambda ih, iq: (ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, s), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(q, k, v, segment_ids)
    return out, lse


# ---------------------------------------------------------------------------
# Backward (flash-attention two-kernel backward: dq; then dk/dv)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, seg_ref, do_ref, lse_ref, delta_ref, dq_ref, *, block_k: int, scale: float):
    """dq for one (head, q-tile): dq = sum_k ds @ k, streaming K blocks."""
    iq = pl.program_id(1)
    block_q = q_ref.shape[1]
    dh = q_ref.shape[2]
    s_total = k_ref.shape[1]

    q = q_ref[0, :, :]
    do = do_ref[0, :, :]
    lse = lse_ref[0, :]
    delta = delta_ref[0, :]
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    q_seg = pl.load(seg_ref, (pl.dslice(iq * block_q, block_q),))

    n_kblocks = ((iq + 1) * block_q + block_k - 1) // block_k
    n_kblocks = jnp.minimum(n_kblocks, s_total // block_k)

    def body(ik, dq):
        k_blk = pl.load(k_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        k_seg = pl.load(seg_ref, (pl.dslice(ik * block_k, block_k),))

        s = jnp.dot(q, k_blk.T) * scale
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_seg[None, :] == q_seg[:, None])
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jnp.dot(do, v_blk.T)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jnp.dot(ds, k_blk)

    dq0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    dq_ref[0, :, :] = jax.lax.fori_loop(0, n_kblocks, body, dq0)


def _dkv_kernel(q_ref, k_ref, v_ref, seg_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, block_q: int, scale: float):
    """dk/dv for one (head, k-tile): streams causally-later Q blocks."""
    ik = pl.program_id(1)
    block_k = k_ref.shape[1]
    dh = k_ref.shape[2]
    s_total = q_ref.shape[1]

    k = k_ref[0, :, :]
    v = v_ref[0, :, :]
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    k_seg = pl.load(seg_ref, (pl.dslice(ik * block_k, block_k),))

    # Causality: only Q blocks whose last row is >= this K tile's first row.
    iq_start = (ik * block_k) // block_q
    n_qblocks = s_total // block_q

    def body(iq, carry):
        dk, dv = carry
        q_blk = pl.load(q_ref, (0, pl.dslice(iq * block_q, block_q), slice(None)))
        do_blk = pl.load(do_ref, (0, pl.dslice(iq * block_q, block_q), slice(None)))
        lse_blk = pl.load(lse_ref, (0, pl.dslice(iq * block_q, block_q)))
        delta_blk = pl.load(delta_ref, (0, pl.dslice(iq * block_q, block_q)))
        q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
        q_seg = pl.load(seg_ref, (pl.dslice(iq * block_q, block_q),))

        s = jnp.dot(q_blk, k.T) * scale  # [BQ, BK]
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_seg[None, :] == q_seg[:, None])
        p = jnp.where(mask, jnp.exp(s - lse_blk[:, None]), 0.0)
        dv_new = dv + jnp.dot(p.T, do_blk)
        dp = jnp.dot(do_blk, v.T)
        ds = p * (dp - delta_blk[:, None]) * scale
        dk_new = dk + jnp.dot(ds.T, q_blk)
        return dk_new, dv_new

    dk0 = jnp.zeros((block_k, dh), dtype=jnp.float32)
    dv0 = jnp.zeros((block_k, dh), dtype=jnp.float32)
    dk_f, dv_f = jax.lax.fori_loop(iq_start, n_qblocks, body, (dk0, dv0))
    dk_ref[0, :, :] = dk_f
    dv_ref[0, :, :] = dv_f


def flash_attention_bwd(
    q, k, v, segment_ids, out, lse, dout, *, block_q: int = 128, block_k: int = 128
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pallas backward from saved (out, lse): returns (dq, dk, dv)."""
    h, s, dh = q.shape
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    scale = 1.0 / float(dh) ** 0.5
    delta = jnp.sum(dout * out, axis=-1)  # [H, S]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_k=bk, scale=scale),
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((s,), lambda ih, iq: (0,)),
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, bq), lambda ih, iq: (ih, iq)),
            pl.BlockSpec((1, bq), lambda ih, iq: (ih, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
        interpret=True,
    )(q, k, v, segment_ids, dout, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=bq, scale=scale),
        grid=(h, s // bk),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda ih, ik: (ih, 0, 0)),
            pl.BlockSpec((1, bk, dh), lambda ih, ik: (ih, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda ih, ik: (ih, ik, 0)),
            pl.BlockSpec((s,), lambda ih, ik: (0,)),
            pl.BlockSpec((1, s, dh), lambda ih, ik: (ih, 0, 0)),
            pl.BlockSpec((1, s), lambda ih, ik: (ih, 0)),
            pl.BlockSpec((1, s), lambda ih, ik: (ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, dh), lambda ih, ik: (ih, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda ih, ik: (ih, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, segment_ids, dout, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper used by the L2 model
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def flash_attention(q, k, v, segment_ids, block_q: int = 128, block_k: int = 128):
    """Differentiable packed-causal flash attention (Pallas fwd AND bwd)."""
    out, _ = flash_attention_fwd(q, k, v, segment_ids, block_q=block_q, block_k=block_k)
    return out


def _vjp_fwd(q, k, v, segment_ids, block_q, block_k):
    out, lse = flash_attention_fwd(q, k, v, segment_ids, block_q=block_q, block_k=block_k)
    return out, (q, k, v, segment_ids, out, lse)


def _vjp_bwd(block_q, block_k, saved, dout):
    q, k, v, segment_ids, out, lse = saved
    dq, dk, dv = flash_attention_bwd(
        q, k, v, segment_ids, out, lse, dout, block_q=block_q, block_k=block_k
    )
    return dq, dk, dv, None


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
