"""AOT exporter: lower the L2/L1 stack to HLO text + manifest for Rust.

Runs ONCE at `make artifacts`; Python is never on the training hot path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly.

Output layout (per model preset):
  artifacts/<preset>/
    manifest.json             # shapes/dtypes/files — the rust runtime's index
    embed_fwd_s<S>.hlo.txt    # one per sequence bucket
    block_fwd_s<S>.hlo.txt
    block_bwd_s<S>.hlo.txt
    loss_head_s<S>.hlo.txt
    embed_bwd_s<S>.hlo.txt
    adam_chunk.hlo.txt        # sequence-independent shard ops
    accum_chunk.hlo.txt
    init/embed.bin            # f32-LE initial parameters
    init/block_<i>.bin
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import PRESETS, ModelConfig
from .kernels import accumulate as ACC


def to_hlo_text(lowered) -> str:
    """jax lowered -> XLA HLO text (see module docstring for why text)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def export_preset(cfg: ModelConfig, out_dir: str, seed: int = 0) -> dict:
    os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)
    pb, pe, c = cfg.block_params, cfg.embed_params, cfg.chunk
    d = cfg.d_model
    artifacts = {}

    def emit(key, fn, in_specs, inputs, outputs):
        fname = f"{key}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*in_specs))
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[key] = {"file": fname, "inputs": inputs, "outputs": outputs}
        print(f"  {key:<22} {len(text):>9} chars")

    for s in cfg.seq_buckets:
        emit(
            f"embed_fwd_s{s}",
            lambda e, t: M.embed_fwd(cfg, e, t),
            [_spec((pe,)), _spec((s,), jnp.int32)],
            [_io("emb_flat", (pe,)), _io("tokens", (s,), "i32")],
            [_io("x", (s, d))],
        )
        emit(
            f"block_fwd_s{s}",
            lambda f_, x, g: M.block_fwd(cfg, f_, x, g),
            [_spec((pb,)), _spec((s, d)), _spec((s,), jnp.int32)],
            [_io("flat", (pb,)), _io("x", (s, d)), _io("seg", (s,), "i32")],
            [_io("y", (s, d))],
        )
        emit(
            f"block_bwd_s{s}",
            lambda f_, x, g, dy: M.block_bwd(cfg, f_, x, g, dy),
            [_spec((pb,)), _spec((s, d)), _spec((s,), jnp.int32), _spec((s, d))],
            [_io("flat", (pb,)), _io("x", (s, d)), _io("seg", (s,), "i32"), _io("dy", (s, d))],
            [_io("dx", (s, d)), _io("dflat", (pb,))],
        )
        emit(
            f"loss_head_s{s}",
            lambda e, x, t, m: M.loss_head(cfg, e, x, t, m),
            [_spec((pe,)), _spec((s, d)), _spec((s,), jnp.int32), _spec((s,))],
            [_io("emb_flat", (pe,)), _io("x", (s, d)), _io("targets", (s,), "i32"), _io("mask", (s,))],
            [_io("loss_sum", ()), _io("ntok", ()), _io("dx", (s, d)), _io("demb_flat", (pe,))],
        )
        emit(
            f"embed_bwd_s{s}",
            lambda t, dx: M.embed_bwd(cfg, t, dx),
            [_spec((s,), jnp.int32), _spec((s, d))],
            [_io("tokens", (s,), "i32"), _io("dx", (s, d))],
            [_io("demb_flat", (pe,))],
        )

    emit(
        "accum_chunk",
        lambda a, g, w: ACC.accumulate(a, g, w, block=c),
        [_spec((c,)), _spec((c,)), _spec((1,))],
        [_io("acc", (c,)), _io("g", (c,)), _io("w", (1,))],
        [_io("out", (c,))],
    )
    emit(
        "adam_chunk",
        lambda p, m, v, g, hp: ACC.adam_step(p, m, v, g, hp, block=c),
        [_spec((c,))] * 4 + [_spec((7,))],
        [_io("p", (c,)), _io("m", (c,)), _io("v", (c,)), _io("g", (c,)), _io("hp", (7,))],
        [_io("p2", (c,)), _io("m2", (c,)), _io("v2", (c,))],
    )

    # Initial parameters (raw f32 little-endian).
    rng = np.random.default_rng(seed)
    M.init_embed(cfg, rng).tofile(os.path.join(out_dir, "init", "embed.bin"))
    for i in range(cfg.n_layers):
        M.init_block(cfg, rng).tofile(os.path.join(out_dir, "init", f"block_{i}.bin"))

    manifest = {
        "preset": cfg.name,
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers,
            "max_seq": cfg.max_seq,
            "block_params": pb,
            "embed_params": pe,
            "total_params": cfg.total_params,
        },
        "seq_buckets": list(cfg.seq_buckets),
        "chunk": c,
        "artifacts": artifacts,
        "init": {
            "embed": "init/embed.bin",
            "blocks": [f"init/block_{i}.bin" for i in range(cfg.n_layers)],
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", action="append", default=None, choices=sorted(PRESETS), help="model preset(s); default: tiny small")
    ap.add_argument("--out", default=None, help="artifacts root (default ../artifacts)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    presets = args.preset or ["tiny", "small"]
    root = args.out or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in presets:
        cfg = PRESETS[name]
        out_dir = os.path.join(root, name)
        print(f"[aot] exporting preset {name} ({cfg.total_params/1e6:.1f}M params) -> {out_dir}")
        export_preset(cfg, out_dir, seed=args.seed)
    print("[aot] done")


if __name__ == "__main__":
    main()
