"""Model configurations for the AOT exporter.

A config fully determines the artifact set: one HLO module per
(function, sequence-bucket) pair plus the sequence-independent chunk ops
(adam / scatter-accumulate). The Rust engine consumes the manifest and is
generic over configs.

Presets:
  tiny   — CI / pytest / rust integration tests (fast under interpret).
  small  — the end-to-end training example (~5M params, minutes on CPU).
  base   — ~25M params, used for longer validation runs.
  m100   — ~98M params: the "train a ~100M transformer" target; on this
           single-core CPU testbed it is exercised for a shorter run
           (see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    d_ff: int
    n_layers: int
    seq_buckets: tuple  # ascending sequence-length buckets (static HLO shapes)
    block_q: int = 128
    block_k: int = 128
    chunk: int = 65536  # element count for adam/accumulate chunk kernels

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def max_seq(self) -> int:
        return max(self.seq_buckets)

    def block_param_shapes(self) -> List[tuple]:
        """(name, shape) for one transformer block, flat-packing order."""
        d, f = self.d_model, self.d_ff
        return [
            ("ln1_g", (d,)),
            ("ln1_b", (d,)),
            ("wq", (d, d)),
            ("wk", (d, d)),
            ("wv", (d, d)),
            ("wo", (d, d)),
            ("ln2_g", (d,)),
            ("ln2_b", (d,)),
            ("w1", (d, f)),
            ("b1", (f,)),
            ("w2", (f, d)),
            ("b2", (d,)),
        ]

    @property
    def block_params(self) -> int:
        return sum(_prod(s) for _, s in self.block_param_shapes())

    @property
    def embed_params(self) -> int:
        """Token embedding + learned positional embedding, flat-packed."""
        return self.vocab * self.d_model + self.max_seq * self.d_model

    @property
    def total_params(self) -> int:
        return self.embed_params + self.n_layers * self.block_params


def _prod(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


PRESETS = {
    "tiny": ModelConfig(
        name="tiny", vocab=512, d_model=64, n_heads=4, d_ff=256, n_layers=2,
        seq_buckets=(32, 64), block_q=16, block_k=16, chunk=4096,
    ),
    "small": ModelConfig(
        name="small", vocab=4096, d_model=256, n_heads=8, d_ff=1024,
        n_layers=4, seq_buckets=(64, 128), block_q=32, block_k=32,
        chunk=65536,
    ),
    "base": ModelConfig(
        name="base", vocab=8192, d_model=384, n_heads=8, d_ff=1536,
        n_layers=6, seq_buckets=(128, 256), block_q=64, block_k=64,
        chunk=65536,
    ),
    "m100": ModelConfig(
        name="m100", vocab=16384, d_model=768, n_heads=12, d_ff=3072,
        n_layers=12, seq_buckets=(128,), block_q=128, block_k=128,
        chunk=65536,
    ),
}
