"""L2 correctness: per-layer units compose to the autodiff oracle.

The Rust engine drives embed_fwd -> block_fwd* -> loss_head -> block_bwd*
-> embed_bwd with gradient accumulation. These tests prove that chain is
exactly the gradient of the composed model (what FSDP computes), so any
engine/oracle mismatch later is a coordination bug, not a math bug.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import PRESETS

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    rng = np.random.default_rng(0)
    emb = jnp.asarray(M.init_embed(CFG, rng))
    blocks = [jnp.asarray(M.init_block(CFG, rng)) for _ in range(CFG.n_layers)]
    return emb, blocks


def mk_batch(s, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, s).astype(np.int32))
    targets = jnp.asarray(rng.integers(0, CFG.vocab, s).astype(np.int32))
    seg = jnp.asarray(np.concatenate([np.full(s // 2, 1), np.full(s - s // 2, 2)]).astype(np.int32))
    mask = jnp.asarray((np.arange(s) < s - 3).astype(np.float32))
    return tokens, seg, targets, mask


def test_block_shapes(params):
    _, blocks = params
    s = CFG.seq_buckets[0]
    x = jnp.ones((s, CFG.d_model), jnp.float32)
    seg = jnp.ones(s, jnp.int32)
    y = M.block_fwd(CFG, blocks[0], x, seg)
    assert y.shape == (s, CFG.d_model)
    dx, dflat = M.block_bwd(CFG, blocks[0], x, seg, jnp.ones_like(y))
    assert dx.shape == x.shape and dflat.shape == (CFG.block_params,)


def test_flat_roundtrip():
    rng = np.random.default_rng(1)
    flat = jnp.asarray(rng.standard_normal(CFG.block_params, dtype=np.float32))
    parts = M.unflatten_block(CFG, flat)
    rebuilt = jnp.concatenate([parts[n].reshape(-1) for n, _ in CFG.block_param_shapes()])
    np.testing.assert_array_equal(flat, rebuilt)


def test_per_layer_chain_equals_autodiff(params):
    """Manual fwd/bwd chain (what the Rust engine runs) == jax.grad."""
    emb, blocks = params
    s = CFG.seq_buckets[0]
    tokens, seg, targets, mask = mk_batch(s)

    # --- manual chain, exactly as the engine executes it ---
    acts = []
    x = M.embed_fwd(CFG, emb, tokens)
    for flat in blocks:
        acts.append(x)
        x = M.block_fwd(CFG, flat, x, seg)
    loss_sum, ntok, dx, demb_head = M.loss_head(CFG, emb, x, targets, mask)
    dblocks = []
    for flat, x_in in zip(reversed(blocks), reversed(acts)):
        dx, dflat = M.block_bwd(CFG, flat, x_in, seg, dx)
        dblocks.append(dflat)
    dblocks.reverse()
    demb = demb_head + M.embed_bwd(CFG, tokens, dx)

    # --- oracle ---
    o_demb, o_dblocks = M.model_grads(CFG, emb, blocks, tokens, seg, targets, mask)
    o_loss, o_ntok = M.model_loss(CFG, emb, blocks, tokens, seg, targets, mask)

    np.testing.assert_allclose(loss_sum, o_loss, rtol=1e-5)
    assert float(ntok) == float(o_ntok) == float(mask.sum())
    np.testing.assert_allclose(demb, o_demb, rtol=2e-4, atol=2e-4)
    for got, want in zip(dblocks, o_dblocks):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_loss_decreases_under_sgd(params):
    """A few steps of plain SGD on one batch reduce the loss."""
    emb, blocks = params
    s = CFG.seq_buckets[0]
    tokens, seg, targets, mask = mk_batch(s, seed=2)
    lr = 0.5

    def loss_fn(emb_, blocks_):
        ls, nt = M.model_loss(CFG, emb_, blocks_, tokens, seg, targets, mask)
        return ls / nt

    l0 = float(loss_fn(emb, blocks))
    for _ in range(5):
        demb, dblocks = M.model_grads(CFG, emb, blocks, tokens, seg, targets, mask)
        ntok = float(mask.sum())
        emb = emb - lr * demb / ntok
        blocks = [b - lr * g / ntok for b, g in zip(blocks, dblocks)]
    l1 = float(loss_fn(emb, blocks))
    assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"


def test_mask_zero_tokens_do_not_contribute(params):
    emb, blocks = params
    s = CFG.seq_buckets[0]
    tokens, seg, targets, _ = mk_batch(s, seed=3)
    half = jnp.asarray((np.arange(s) < s // 2).astype(np.float32))
    l_half, n_half = M.model_loss(CFG, emb, blocks, tokens, seg, targets, half)
    # flipping targets in the masked-out region must not change the loss
    targets2 = targets.at[s // 2 :].set((targets[s // 2 :] + 7) % CFG.vocab)
    l_half2, _ = M.model_loss(CFG, emb, blocks, tokens, seg, targets2, half)
    np.testing.assert_allclose(l_half, l_half2, rtol=1e-6)
    assert float(n_half) == s // 2


def test_embed_bwd_is_vjp_of_embed_fwd():
    rng = np.random.default_rng(4)
    s = CFG.seq_buckets[0]
    emb = jnp.asarray(M.init_embed(CFG, rng))
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, s).astype(np.int32))
    dx = jnp.asarray(rng.standard_normal((s, CFG.d_model), dtype=np.float32))
    _, vjp = jax.vjp(lambda e: M.embed_fwd(CFG, e, tokens), emb)
    (want,) = vjp(dx)
    got = M.embed_bwd(CFG, tokens, dx)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_init_sizes():
    rng = np.random.default_rng(0)
    assert M.init_embed(CFG, rng).size == CFG.embed_params
    assert M.init_block(CFG, rng).size == CFG.block_params
    assert CFG.total_params == CFG.embed_params + CFG.n_layers * CFG.block_params
