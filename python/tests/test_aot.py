"""AOT exporter: manifest consistency + HLO text sanity for the tiny preset."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_model_sizes(manifest):
    from compile.configs import PRESETS

    cfg = PRESETS["tiny"]
    m = manifest["model"]
    assert m["block_params"] == cfg.block_params
    assert m["embed_params"] == cfg.embed_params
    assert m["total_params"] == cfg.total_params
    assert manifest["seq_buckets"] == list(cfg.seq_buckets)


def test_every_artifact_file_exists_and_is_hlo(manifest):
    for key, art in manifest["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), key
        with open(path) as f:
            head = f.read(400)
        assert "HloModule" in head, f"{key} does not look like HLO text"


def test_expected_artifact_set(manifest):
    keys = set(manifest["artifacts"])
    for s in manifest["seq_buckets"]:
        for fn in ["embed_fwd", "block_fwd", "block_bwd", "loss_head", "embed_bwd"]:
            assert f"{fn}_s{s}" in keys
    assert "adam_chunk" in keys and "accum_chunk" in keys


def test_io_shapes_consistent(manifest):
    m = manifest["model"]
    d = m["d_model"]
    for s in manifest["seq_buckets"]:
        bf = manifest["artifacts"][f"block_fwd_s{s}"]
        assert bf["inputs"][0]["shape"] == [m["block_params"]]
        assert bf["inputs"][1]["shape"] == [s, d]
        assert bf["outputs"][0]["shape"] == [s, d]
        bb = manifest["artifacts"][f"block_bwd_s{s}"]
        assert bb["outputs"][1]["shape"] == [m["block_params"]]
        lh = manifest["artifacts"][f"loss_head_s{s}"]
        assert lh["outputs"][0]["shape"] == [] and lh["outputs"][1]["shape"] == []


def test_init_files(manifest):
    emb = os.path.join(ART, manifest["init"]["embed"])
    assert os.path.getsize(emb) == 4 * manifest["model"]["embed_params"]
    for b in manifest["init"]["blocks"]:
        assert os.path.getsize(os.path.join(ART, b)) == 4 * manifest["model"]["block_params"]


def test_no_custom_calls(manifest):
    """CPU PJRT cannot execute Mosaic custom-calls; interpret=True must
    have lowered everything to plain HLO."""
    for art in manifest["artifacts"].values():
        with open(os.path.join(ART, art["file"])) as f:
            assert "custom-call" not in f.read()
