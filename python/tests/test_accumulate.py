"""L1 correctness: scatter-accumulate + AdamW chunk kernels vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import accumulate as ACC
from compile.kernels import ref as R


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([64, 128, 256, 1024]),
    block=st.sampled_from([32, 64, 128]),
    w=st.floats(-4.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_accumulate_matches_ref(n, block, w, seed):
    if n % block != 0:
        block = n
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    g = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    got = ACC.accumulate(acc, g, jnp.array([w], jnp.float32), block=block)
    np.testing.assert_allclose(got, R.accumulate(acc, g, np.float32(w)), rtol=1e-6, atol=1e-6)


def test_accumulate_linearity():
    """accumulate(accumulate(a, g1, w1), g2, w2) == a + w1 g1 + w2 g2.

    This linearity is what makes the ODC scatter-accumulate daemon
    order-insensitive across microbatch pushes within one minibatch.
    """
    rng = np.random.default_rng(0)
    a, g1, g2 = [jnp.asarray(rng.standard_normal(128, dtype=np.float32)) for _ in range(3)]
    w1, w2 = jnp.array([0.3], jnp.float32), jnp.array([1.7], jnp.float32)
    ab = ACC.accumulate(ACC.accumulate(a, g1, w1, block=64), g2, w2, block=64)
    ba = ACC.accumulate(ACC.accumulate(a, g2, w2, block=64), g1, w1, block=64)
    np.testing.assert_allclose(ab, ba, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ab, a + 0.3 * g1 + 1.7 * g2, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 1000),
    lr=st.floats(1e-5, 1e-1),
    wd=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_matches_ref(t, lr, wd, seed):
    rng = np.random.default_rng(seed)
    n = 256
    p = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    m = jnp.asarray(rng.standard_normal(n, dtype=np.float32) * 0.1)
    v = jnp.asarray(np.abs(rng.standard_normal(n, dtype=np.float32)) * 0.01)
    g = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    b1, b2, eps = 0.9, 0.999, 1e-8
    hp = jnp.array([lr, b1, b2, eps, wd, 1 - b1**t, 1 - b2**t], jnp.float32)
    p2, m2, v2 = ACC.adam_step(p, m, v, g, hp, block=64)
    rp, rm, rv = R.adam_step(p, m, v, g, lr, b1, b2, eps, wd, float(t))
    # ref computes beta**t bias corrections in f64, the kernel takes them
    # precomputed in f32 — tolerate the mixed-precision delta.
    np.testing.assert_allclose(p2, rp, rtol=3e-4, atol=2e-5)
    np.testing.assert_allclose(m2, rm, rtol=1e-5, atol=5e-7)
    np.testing.assert_allclose(v2, rv, rtol=1e-5, atol=5e-7)
