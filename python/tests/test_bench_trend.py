"""Unit tests for scripts/bench_trend.py — the CI perf-trajectory gate.

The load-bearing cases are the baseline-side failure modes: a restored
cache that is empty (first run), lacks a file (brand-new BENCH key, e.g.
BENCH_wire.json the wire-calibration bench introduces), lacks a metric
(new key inside an existing file), or is outright corrupt (truncated
cache restore). All of those must SEED the trajectory, not fail the
gate — only the fresh side is load-bearing.
"""

import importlib.util
import json
import os

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "bench_trend.py")
_spec = importlib.util.spec_from_file_location("bench_trend", SCRIPT)
bt = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bt)


def hotpath(reduction_pct=40.0, gbps=5.0, wire_frac=0.5):
    return {
        "measured": True,
        "per_microbatch": {"reduction_pct": reduction_pct},
        "fold": {"gbps": gbps},
        "wire": {"bytes_reduction_fraction": wire_frac},
    }


def dispatch(margin=8.0, retained=0.9, shear=0.3, gain=0.12):
    return {
        "measured": True,
        "rows": [{"slowdown": 4.0, "static_bubble_time_s": margin + 2.0, "queue_bubble_time_s": 2.0}],
        "chaos": {"retained_throughput_fraction": retained},
        "seqsplit": {"makespan_reduction_fraction": shear},
        "async": {"throughput_gain_fraction": gain},
    }


def wire(alpha_us=2.0, beta_gbps=8.0):
    return {"measured": True, "transports": {"uds": {"alpha_us": alpha_us, "beta_gbps": beta_gbps}}}


def write(d, records):
    for fname, rec in records.items():
        with open(os.path.join(d, fname), "w") as f:
            json.dump(rec, f)


def fresh_full(d):
    write(d, {"BENCH_hotpath.json": hotpath(), "BENCH_dispatch.json": dispatch(), "BENCH_wire.json": wire()})


def run(prev, fresh, checks=None):
    msgs = []
    failures = bt.run_checks(str(prev), str(fresh), checks=checks or bt.CHECKS, out=msgs.append)
    return msgs, failures


def test_first_run_seeds_every_metric(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    fresh_full(fresh)
    msgs, failures = run(prev, fresh)
    assert failures == []
    assert len(msgs) == len(bt.CHECKS)
    assert all("seeding" in m for m in msgs)


def test_missing_baseline_file_seeds_only_that_file(tmp_path):
    # the wire-calibration record is brand new this cycle: the restored
    # baseline has hotpath + dispatch but no BENCH_wire.json
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    write(prev, {"BENCH_hotpath.json": hotpath(), "BENCH_dispatch.json": dispatch()})
    fresh_full(fresh)
    msgs, failures = run(prev, fresh)
    assert failures == []
    seeded = [m for m in msgs if "seeding" in m]
    assert len(seeded) == 2  # the two wire_calib checks only
    assert all("wire_calib" in m for m in seeded)


def test_corrupt_baseline_seeds_instead_of_crashing(tmp_path):
    # regression: a truncated cache restore used to raise out of
    # json.load and kill the whole gate
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    (prev / "BENCH_hotpath.json").write_text('{"measured": true, "per_micro')
    fresh_full(fresh)
    msgs, failures = run(prev, fresh)
    assert failures == []
    assert any("unreadable" in m for m in msgs)


def test_new_metric_in_existing_file_seeds(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    old_hot = hotpath()
    del old_hot["fold"]  # baseline predates the fold_kernel key
    write(prev, {"BENCH_hotpath.json": old_hot, "BENCH_dispatch.json": dispatch(), "BENCH_wire.json": wire()})
    fresh_full(fresh)
    msgs, failures = run(prev, fresh)
    assert failures == []
    assert any("no metric" in m and "fold" in m for m in msgs)


def test_higher_is_better_regression_fails(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    write(prev, {"BENCH_wire.json": wire(beta_gbps=10.0)})
    write(fresh, {"BENCH_wire.json": wire(beta_gbps=8.0)})  # -20% > 15% budget
    checks = [c for c in bt.CHECKS if c[1] == "wire_calib uds beta_gbps"]
    _, failures = run(prev, fresh, checks)
    assert len(failures) == 1 and "regressed" in failures[0]


def test_lower_is_better_direction_for_alpha(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    checks = [c for c in bt.CHECKS if c[1] == "wire_calib uds alpha_us"]
    # alpha DROPPED 20%: an improvement, must pass even though it moved
    # more than the tolerance
    write(prev, {"BENCH_wire.json": wire(alpha_us=2.5)})
    write(fresh, {"BENCH_wire.json": wire(alpha_us=2.0)})
    _, failures = run(prev, fresh, checks)
    assert failures == []
    # alpha ROSE 50%: a regression for a lower-is-better metric
    write(prev, {"BENCH_wire.json": wire(alpha_us=2.0)})
    write(fresh, {"BENCH_wire.json": wire(alpha_us=3.0)})
    _, failures = run(prev, fresh, checks)
    assert len(failures) == 1 and "regressed" in failures[0]


def test_within_tolerance_passes(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    write(prev, {"BENCH_hotpath.json": hotpath(reduction_pct=40.0)})
    write(fresh, {"BENCH_hotpath.json": hotpath(reduction_pct=36.0)})  # -10% < 15%
    checks = [c for c in bt.CHECKS if c[1] == "comm_path reduction_pct"]
    msgs, failures = run(prev, fresh, checks)
    assert failures == []
    assert any("OK" in m for m in msgs)


def test_fresh_side_is_load_bearing(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    # missing fresh record
    _, failures = run(prev, fresh, [c for c in bt.CHECKS if c[0] == "BENCH_wire.json"])
    assert failures and all("missing" in f for f in failures)
    # unmeasured fresh record (the committed placeholder)
    rec = wire()
    rec["measured"] = False
    write(fresh, {"BENCH_wire.json": rec})
    _, failures = run(prev, fresh, [c for c in bt.CHECKS if c[0] == "BENCH_wire.json"])
    assert failures and all("measured:false" in f for f in failures)
    # corrupt fresh record
    (fresh / "BENCH_wire.json").write_text("not json at all")
    _, failures = run(prev, fresh, [c for c in bt.CHECKS if c[0] == "BENCH_wire.json"])
    assert failures and all("unreadable" in f for f in failures)


def test_async_gain_regression_and_floor(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    checks = [c for c in bt.CHECKS if c[1] == "asyncps throughput gain fraction"]
    # the overlap win shrank 50%: a higher-is-better regression
    write(prev, {"BENCH_dispatch.json": dispatch(gain=0.12)})
    write(fresh, {"BENCH_dispatch.json": dispatch(gain=0.06)})
    _, failures = run(prev, fresh, checks)
    assert len(failures) == 1 and "regressed" in failures[0]
    # a negative gain (async slower than the barrier) trips the absolute
    # floor even on a seeding run with no baseline at all
    write(fresh, {"BENCH_dispatch.json": dispatch(gain=-0.02)})
    _, failures = run(tmp_path / "empty", fresh, checks)
    assert len(failures) == 1 and "absolute floor" in failures[0]
    # baseline predating the AsyncPS key seeds instead of failing
    old = dispatch()
    del old["async"]
    write(prev, {"BENCH_dispatch.json": old})
    write(fresh, {"BENCH_dispatch.json": dispatch(gain=0.12)})
    msgs, failures = run(prev, fresh, checks)
    assert failures == []
    assert any("no metric" in m for m in msgs)


def test_absolute_floor_applies_even_when_seeding(tmp_path):
    prev, fresh = tmp_path / "prev", tmp_path / "fresh"
    prev.mkdir(), fresh.mkdir()
    write(fresh, {"BENCH_dispatch.json": dispatch(shear=0.05)})  # below SEQSPLIT_FLOOR
    checks = [c for c in bt.CHECKS if c[1] == "seqsplit makespan reduction fraction"]
    _, failures = run(prev, fresh, checks)
    assert len(failures) == 1 and "absolute floor" in failures[0]
