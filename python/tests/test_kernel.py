"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

hypothesis sweeps shapes (heads, seq, head-dim, block sizes) and segment
layouts; every case asserts allclose against kernels/ref.py. This is the
CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def mk_qkv(rng, h, s, dh):
    return [jnp.asarray(rng.standard_normal((h, s, dh), dtype=np.float32)) for _ in range(3)]


def mk_segments(rng, s, max_segs):
    """Random packed layout: contiguous segments 1..n, trailing pad seg 0."""
    n_segs = int(rng.integers(1, max_segs + 1))
    cuts = np.sort(rng.choice(np.arange(1, s), size=n_segs - 1, replace=False)) if n_segs > 1 else np.array([], dtype=int)
    seg = np.zeros(s, dtype=np.int32)
    bounds = [0, *cuts.tolist(), s]
    for i in range(n_segs):
        seg[bounds[i] : bounds[i + 1]] = i + 1
    # random chance of trailing padding
    if rng.random() < 0.5 and s >= 8:
        pad = int(rng.integers(1, s // 4 + 1))
        seg[s - pad :] = 0
    return jnp.asarray(seg)


@settings(max_examples=12, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 32, 48, 64]),
    dh=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 64]),
    bk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_matches_ref(h, s, dh, bq, bk, seed):
    rng = np.random.default_rng(seed)
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = mk_segments(rng, s, 4)
    out, lse = A.flash_attention_fwd(q, k, v, seg, block_q=bq, block_k=bk)
    ro, rl = R.attention_fwd(q, k, v, seg)
    np.testing.assert_allclose(out, ro, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(lse, rl, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    h=st.sampled_from([1, 2]),
    s=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([8, 16]),
    bq=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_matches_ref(h, s, dh, bq, bk, seed):
    rng = np.random.default_rng(seed)
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = mk_segments(rng, s, 3)
    do = jnp.asarray(rng.standard_normal((h, s, dh), dtype=np.float32))
    out, lse = A.flash_attention_fwd(q, k, v, seg, block_q=bq, block_k=bk)
    dq, dk, dv = A.flash_attention_bwd(q, k, v, seg, out, lse, do, block_q=bq, block_k=bk)
    ro, rl = R.attention_fwd(q, k, v, seg)
    rdq, rdk, rdv = R.attention_bwd(q, k, v, seg, ro, rl, do)
    np.testing.assert_allclose(dq, rdq, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dk, rdk, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dv, rdv, rtol=3e-4, atol=3e-4)


def test_custom_vjp_matches_autodiff_of_ref():
    rng = np.random.default_rng(7)
    h, s, dh = 2, 32, 16
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = mk_segments(rng, s, 3)

    f = lambda q_, k_, v_: jnp.sum(A.flash_attention(q_, k_, v_, seg, 16, 16) ** 2)
    g = lambda q_, k_, v_: jnp.sum(R.attention(q_, k_, v_, seg) ** 2)
    ga = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_causality_no_future_leak():
    """Changing token j must not affect outputs at positions i < j."""
    rng = np.random.default_rng(3)
    h, s, dh = 2, 32, 8
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = jnp.ones(s, jnp.int32)
    out1, _ = A.flash_attention_fwd(q, k, v, seg, block_q=8, block_k=8)
    j = 20
    k2 = k.at[:, j:, :].set(99.0)
    v2 = v.at[:, j:, :].set(-99.0)
    out2, _ = A.flash_attention_fwd(q, k2, v2, seg, block_q=8, block_k=8)
    np.testing.assert_allclose(out1[:, :j], out2[:, :j], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, j:], out2[:, j:])


def test_segment_isolation_no_cross_contamination():
    """Per-segment outputs equal attention run on each segment alone."""
    rng = np.random.default_rng(11)
    h, s, dh = 2, 32, 8
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = jnp.asarray(np.array([1] * 12 + [2] * 20, np.int32))
    out, _ = A.flash_attention_fwd(q, k, v, seg, block_q=8, block_k=8)
    for lo, hi, sid in [(0, 12, 1), (12, 32, 2)]:
        sub_out = R.attention(q[:, lo:hi], k[:, lo:hi], v[:, lo:hi], jnp.full(hi - lo, sid, jnp.int32))
        np.testing.assert_allclose(out[:, lo:hi], sub_out, rtol=2e-5, atol=2e-5)


def test_all_pad_rows_are_finite():
    rng = np.random.default_rng(5)
    h, s, dh = 1, 16, 8
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = jnp.zeros(s, jnp.int32)  # everything is padding
    out, lse = A.flash_attention_fwd(q, k, v, seg, block_q=8, block_k=8)
    assert np.all(np.isfinite(out)) and np.all(np.isfinite(lse))


def test_block_size_invariance():
    """Result must not depend on the chosen tiling."""
    rng = np.random.default_rng(13)
    h, s, dh = 2, 64, 16
    q, k, v = mk_qkv(rng, h, s, dh)
    seg = mk_segments(rng, s, 4)
    ref_out, _ = A.flash_attention_fwd(q, k, v, seg, block_q=64, block_k=64)
    for bq, bk in [(8, 8), (16, 32), (32, 16), (64, 8)]:
        out, _ = A.flash_attention_fwd(q, k, v, seg, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref_out, rtol=2e-5, atol=2e-5)


def test_pick_block_divides():
    for s in [16, 48, 96, 128, 130]:
        for want in [8, 16, 128]:
            b = A._pick_block(s, want)
            assert s % b == 0 and 1 <= b <= min(want, s)
