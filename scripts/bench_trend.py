#!/usr/bin/env python3
"""Perf-trajectory gate: compare fresh BENCH_*.json records against the
previous CI run's records (restored via actions/cache).

Usage: bench_trend.py <prev_dir> <fresh_dir>

Tracked metrics (higher is better for all):
  * BENCH_hotpath.json  -> per_microbatch.reduction_pct
        (zero-copy vs seed comm-path win, %)
  * BENCH_hotpath.json  -> fold.gbps
        (chunk-parallel fold throughput, GB/s of folded source bytes;
        written by `cargo bench --bench fold_kernel`, which merges into
        the record comm_path writes — run it after comm_path)
  * BENCH_hotpath.json  -> wire.bytes_reduction_fraction
        (pushed-byte fraction bf16 payloads shed vs f32, measured from
        OdcComm hotpath counters; carries an ABSOLUTE floor of
        WIRE_FLOOR — halving the wire must always shed >=45%)
  * BENCH_dispatch.json -> static_bubble_time_s - queue_bubble_time_s
        at the 4x-slowdown row (bubble seconds the work queue removes)
  * BENCH_dispatch.json -> chaos.retained_throughput_fraction
        (throughput kept under the fixed lossy fault plan; a drop means
        retry/retransmission pricing got more expensive)
  * BENCH_dispatch.json -> seqsplit.makespan_reduction_fraction
        (dominant-corpus makespan fraction SeqSplit shears off; besides
        the trend comparison it carries an ABSOLUTE floor of
        SEQSPLIT_FLOOR — splitting must always remove at least 15% of
        the straggler-pinned makespan, even on a first/seeding run)

Exit codes: 0 = ok (including "no previous record yet" — the first run
seeds the trajectory), 1 = a metric regressed more than TOLERANCE, fell
below its absolute floor, or a fresh record is missing/measured:false.
"""

import json
import os
import sys

TOLERANCE = 0.15  # 15% relative regression budget
SEQSPLIT_FLOOR = 0.15  # absolute: split must shear >=15% off the dominant-corpus makespan
WIRE_FLOOR = 0.45  # absolute: bf16 payloads must shed >=45% of the f32 wire bytes


def load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def hot_metric(rec):
    try:
        v = rec["per_microbatch"]["reduction_pct"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def fold_metric(rec):
    try:
        v = rec["fold"]["gbps"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def wire_metric(rec):
    try:
        v = rec["wire"]["bytes_reduction_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def disp_metric(rec):
    try:
        for row in rec["rows"]:
            if float(row["slowdown"]) == 4.0:
                return float(row["static_bubble_time_s"]) - float(row["queue_bubble_time_s"])
    except (KeyError, TypeError, ValueError):
        return None
    return None


def chaos_metric(rec):
    try:
        v = rec["chaos"]["retained_throughput_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def seqsplit_metric(rec):
    try:
        v = rec["seqsplit"]["makespan_reduction_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def main():
    if len(sys.argv) != 3:
        print("usage: bench_trend.py <prev_dir> <fresh_dir>", file=sys.stderr)
        return 2
    prev_dir, fresh_dir = sys.argv[1], sys.argv[2]
    failures = []

    checks = [
        ("BENCH_hotpath.json", "comm_path reduction_pct", hot_metric, None),
        ("BENCH_hotpath.json", "fold_kernel fold.gbps", fold_metric, None),
        ("BENCH_hotpath.json", "bf16 wire bytes reduction fraction", wire_metric, WIRE_FLOOR),
        ("BENCH_dispatch.json", "ablation_dispatch 4x bubble margin", disp_metric, None),
        ("BENCH_dispatch.json", "chaos retained throughput fraction", chaos_metric, None),
        ("BENCH_dispatch.json", "seqsplit makespan reduction fraction", seqsplit_metric, SEQSPLIT_FLOOR),
    ]
    for fname, label, metric, abs_floor in checks:
        fresh = load(os.path.join(fresh_dir, fname))
        if fresh is None or not fresh.get("measured"):
            failures.append(f"{fname}: fresh record missing or still measured:false")
            continue
        cur = metric(fresh)
        if cur is None:
            failures.append(f"{fname}: fresh record has no {label} metric")
            continue
        if abs_floor is not None and cur < abs_floor:
            failures.append(f"{label} below absolute floor {abs_floor:.2f}: {cur:.4f}")
            continue
        prev = load(os.path.join(prev_dir, fname))
        if prev is None or not prev.get("measured"):
            print(f"{label}: no measured previous record — seeding the trajectory at {cur:.4f}")
            continue
        old = metric(prev)
        if old is None:
            print(f"{label}: previous record has no metric — seeding at {cur:.4f}")
            continue
        floor = old - abs(old) * TOLERANCE
        ok = cur >= floor
        print(
            f"{label}: previous {old:.4f} -> fresh {cur:.4f} "
            f"(floor {floor:.4f}) {'OK' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(f"{label} regressed >{TOLERANCE:.0%}: {old:.4f} -> {cur:.4f}")

    for msg in failures:
        print(f"::error::{msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
