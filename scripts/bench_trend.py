#!/usr/bin/env python3
"""Perf-trajectory gate: compare fresh BENCH_*.json records against the
previous CI run's records (restored via actions/cache).

Usage: bench_trend.py <prev_dir> <fresh_dir>

Tracked metrics (higher is better unless noted):
  * BENCH_hotpath.json  -> per_microbatch.reduction_pct
        (zero-copy vs seed comm-path win, %)
  * BENCH_hotpath.json  -> fold.gbps
        (chunk-parallel fold throughput, GB/s of folded source bytes;
        written by `cargo bench --bench fold_kernel`, which merges into
        the record comm_path writes — run it after comm_path)
  * BENCH_hotpath.json  -> wire.bytes_reduction_fraction
        (pushed-byte fraction bf16 payloads shed vs f32, measured from
        OdcComm hotpath counters; carries an ABSOLUTE floor of
        WIRE_FLOOR — halving the wire must always shed >=45%)
  * BENCH_dispatch.json -> static_bubble_time_s - queue_bubble_time_s
        at the 4x-slowdown row (bubble seconds the work queue removes)
  * BENCH_dispatch.json -> chaos.retained_throughput_fraction
        (throughput kept under the fixed lossy fault plan; a drop means
        retry/retransmission pricing got more expensive)
  * BENCH_dispatch.json -> seqsplit.makespan_reduction_fraction
        (dominant-corpus makespan fraction SeqSplit shears off; besides
        the trend comparison it carries an ABSOLUTE floor of
        SEQSPLIT_FLOOR — splitting must always remove at least 15% of
        the straggler-pinned makespan, even on a first/seeding run)
  * BENCH_dispatch.json -> async.throughput_gain_fraction
        (whole-run throughput AsyncPS bounded-staleness admission (k=2)
        gains over the synchronous barrier on the 4x-straggler Queue
        cell; carries an ABSOLUTE floor of ASYNC_FLOOR — overlapping the
        straggler must always gain SOMETHING, and a negative value means
        the admission schedule made the run slower than the barrier)
  * BENCH_wire.json     -> transports.uds.alpha_us   (LOWER is better:
        per-message setup cost of the socket transport)
  * BENCH_wire.json     -> transports.uds.beta_gbps
        (sustained socket-transport bandwidth, GB/s)

Baseline semantics: a metric or file that is missing, unmeasured, or
unreadable in the PREVIOUS record seeds the trajectory at the fresh
value instead of failing — brand-new BENCH keys (and a corrupted
restored cache) are first runs, not regressions. Only the FRESH side is
load-bearing: a fresh record that is missing, unmeasured, unparseable,
or lacking a tracked metric fails the gate.

Exit codes: 0 = ok (including any seeding), 1 = a metric regressed more
than TOLERANCE, crossed its absolute floor, or a fresh record is
missing/measured:false, 2 = usage error.
"""

import json
import os
import sys

TOLERANCE = 0.15  # 15% relative regression budget
SEQSPLIT_FLOOR = 0.15  # absolute: split must shear >=15% off the dominant-corpus makespan
WIRE_FLOOR = 0.45  # absolute: bf16 payloads must shed >=45% of the f32 wire bytes
ASYNC_FLOOR = 0.0005  # absolute: bounded-staleness admission must beat the barrier


def load(path):
    """Read a BENCH record: (record, None) on success, (None, reason)
    on a missing, unreadable, or unparseable file."""
    if not os.path.exists(path):
        return None, "missing"
    try:
        with open(path) as f:
            return json.load(f), None
    except (OSError, ValueError) as e:
        return None, f"unreadable ({e})"


def hot_metric(rec):
    try:
        v = rec["per_microbatch"]["reduction_pct"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def fold_metric(rec):
    try:
        v = rec["fold"]["gbps"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def wire_metric(rec):
    try:
        v = rec["wire"]["bytes_reduction_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def disp_metric(rec):
    try:
        for row in rec["rows"]:
            if float(row["slowdown"]) == 4.0:
                return float(row["static_bubble_time_s"]) - float(row["queue_bubble_time_s"])
    except (KeyError, TypeError, ValueError):
        return None
    return None


def chaos_metric(rec):
    try:
        v = rec["chaos"]["retained_throughput_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def seqsplit_metric(rec):
    try:
        v = rec["seqsplit"]["makespan_reduction_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def async_metric(rec):
    try:
        v = rec["async"]["throughput_gain_fraction"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def calib_alpha_metric(rec):
    try:
        v = rec["transports"]["uds"]["alpha_us"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


def calib_beta_metric(rec):
    try:
        v = rec["transports"]["uds"]["beta_gbps"]
        return float(v) if v is not None else None
    except (KeyError, TypeError, ValueError):
        return None


# (file, label, metric, absolute floor or None, higher_is_better)
CHECKS = [
    ("BENCH_hotpath.json", "comm_path reduction_pct", hot_metric, None, True),
    ("BENCH_hotpath.json", "fold_kernel fold.gbps", fold_metric, None, True),
    ("BENCH_hotpath.json", "bf16 wire bytes reduction fraction", wire_metric, WIRE_FLOOR, True),
    ("BENCH_dispatch.json", "ablation_dispatch 4x bubble margin", disp_metric, None, True),
    ("BENCH_dispatch.json", "chaos retained throughput fraction", chaos_metric, None, True),
    ("BENCH_dispatch.json", "seqsplit makespan reduction fraction", seqsplit_metric, SEQSPLIT_FLOOR, True),
    ("BENCH_dispatch.json", "asyncps throughput gain fraction", async_metric, ASYNC_FLOOR, True),
    ("BENCH_wire.json", "wire_calib uds alpha_us", calib_alpha_metric, None, False),
    ("BENCH_wire.json", "wire_calib uds beta_gbps", calib_beta_metric, None, True),
]


def run_checks(prev_dir, fresh_dir, checks=CHECKS, out=print):
    """Run every trend check; returns the list of failure messages."""
    failures = []
    for fname, label, metric, abs_floor, higher_is_better in checks:
        fresh, fresh_err = load(os.path.join(fresh_dir, fname))
        if fresh is None:
            failures.append(f"{fname}: fresh record {fresh_err}")
            continue
        if not fresh.get("measured"):
            failures.append(f"{fname}: fresh record missing or still measured:false")
            continue
        cur = metric(fresh)
        if cur is None:
            failures.append(f"{fname}: fresh record has no {label} metric")
            continue
        if abs_floor is not None and cur < abs_floor:
            failures.append(f"{label} below absolute floor {abs_floor:.2f}: {cur:.4f}")
            continue
        prev, prev_err = load(os.path.join(prev_dir, fname))
        if prev is None:
            # a missing OR corrupt baseline is a first run, not a
            # regression — the restored cache is advisory
            out(f"{label}: previous record {prev_err} — seeding the trajectory at {cur:.4f}")
            continue
        if not prev.get("measured"):
            out(f"{label}: no measured previous record — seeding the trajectory at {cur:.4f}")
            continue
        old = metric(prev)
        if old is None:
            # brand-new BENCH key (this metric didn't exist when the
            # baseline was written) — seed it
            out(f"{label}: previous record has no metric — seeding at {cur:.4f}")
            continue
        if higher_is_better:
            bound = old - abs(old) * TOLERANCE
            ok = cur >= bound
            kind = "floor"
        else:
            bound = old + abs(old) * TOLERANCE
            ok = cur <= bound
            kind = "ceiling"
        out(
            f"{label}: previous {old:.4f} -> fresh {cur:.4f} "
            f"({kind} {bound:.4f}) {'OK' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(f"{label} regressed >{TOLERANCE:.0%}: {old:.4f} -> {cur:.4f}")
    return failures


def main():
    if len(sys.argv) != 3:
        print("usage: bench_trend.py <prev_dir> <fresh_dir>", file=sys.stderr)
        return 2
    failures = run_checks(sys.argv[1], sys.argv[2])
    for msg in failures:
        print(f"::error::{msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
