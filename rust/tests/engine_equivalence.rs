//! Integration: the real FSDP engine, end to end through PJRT, on the
//! `tiny` preset.
//!
//! The paper's correctness claim (Appendix F / Fig 14) is that ODC
//! preserves training semantics exactly: same gradients, same updates,
//! same loss trajectory as collective FSDP. Here we assert it at small
//! scale across the full backend × balancer matrix — Hybrid (both group
//! shapes) vs ODC vs Collective vs a single-device run (the
//! data-parallel oracle) — all from identical seeds and plans. The
//! hybrid backend's deterministic fold order makes the single-group
//! case BIT-identical to the oracle (no tolerance).

use odc::balance::packers::Plan;
use odc::balance::SplitMap;
use odc::config::{Balancer, CommScheme};
use odc::engine::trainer::{plan_preview, plan_preview_split, train, TrainRun, TrainerConfig};
use std::path::{Path, PathBuf};

fn tiny_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have_artifacts() -> bool {
    tiny_dir().join("manifest.json").exists()
}

fn base_cfg() -> TrainerConfig {
    let mut c = TrainerConfig::new(tiny_dir());
    c.world = 2;
    c.minibs = 2;
    c.steps = 2;
    c.seed = 42;
    c
}

fn run(scheme: CommScheme, balancer: Balancer, world: usize) -> TrainRun {
    let mut c = base_cfg();
    c.scheme = scheme;
    c.balancer = balancer;
    c.world = world;
    train(&c).expect("training run")
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[test]
fn odc_matches_collective_exactly_in_semantics() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // NB: ODC runs with the minibatch-scoped gather cache enabled (the
    // TrainerConfig default), so this doubles as the cached-ODC vs
    // uncached-Collective equivalence proof.
    let col = run(CommScheme::Collective, Balancer::LbMicro, 2);
    let odc = run(CommScheme::Odc, Balancer::LbMicro, 2);

    // identical plans + identical math => loss curves match to float noise
    for (a, b) in col.logs.iter().zip(&odc.logs) {
        assert_eq!(a.tokens, b.tokens, "token counts must match");
        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "step {}: collective {} vs odc {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    // final parameters agree (accumulation order may differ => tiny noise)
    for (l, (pa, pb)) in col.final_params.iter().zip(&odc.final_params).enumerate() {
        let d = rel_l2(pb, pa);
        assert!(d < 1e-4, "layer {l}: rel L2 {d}");
    }
}

#[test]
fn multi_device_matches_single_device_oracle() {
    if !have_artifacts() {
        return;
    }
    // world=1 is plain training: DP with global-token-normalized grads
    // must produce the same updates for any world size — PROVIDED the
    // microbatch composition is identical (packing offsets select
    // positional embeddings, so grouping is semantically meaningful).
    // Pin the world=2 plan and replay it flattened onto one device.
    let mut multi_cfg = base_cfg();
    multi_cfg.scheme = CommScheme::Odc;
    multi_cfg.balancer = Balancer::LbMicro;
    let plans2 = odc::engine::trainer::plan_preview(&multi_cfg).unwrap();
    let flat: Vec<odc::balance::packers::Plan> = plans2
        .iter()
        .map(|p| odc::balance::packers::Plan {
            micro: vec![p.micro.iter().flatten().filter(|m| !m.is_empty()).cloned().collect()],
        })
        .collect();

    let mut solo_cfg = base_cfg();
    solo_cfg.world = 1;
    solo_cfg.minibs = 4; // 1×4 == 2×2 samples per optimizer step
    solo_cfg.scheme = CommScheme::Odc;
    solo_cfg.balancer = Balancer::LbMicro;
    solo_cfg.plan_override = Some(flat);
    let solo = train(&solo_cfg).unwrap();
    let multi = run(CommScheme::Odc, Balancer::LbMicro, 2);
    for (a, b) in solo.logs.iter().zip(&multi.logs) {
        assert_eq!(a.tokens, b.tokens);
        assert!((a.loss - b.loss).abs() < 1e-4, "step {}: {} vs {}", a.step, a.loss, b.loss);
    }
    for (l, (pa, pb)) in solo.final_params.iter().zip(&multi.final_params).enumerate() {
        let d = rel_l2(pb, pa);
        assert!(d < 1e-4, "layer {l}: rel L2 {d}");
    }
}

#[test]
fn initial_loss_is_near_uniform_entropy() {
    if !have_artifacts() {
        return;
    }
    // Cross-language sanity: random init => per-token CE ~= ln(vocab).
    // tiny preset vocab = 512 => ln(512) = 6.24.
    let r = run(CommScheme::Odc, Balancer::LbMini, 2);
    let l0 = r.logs[0].loss;
    assert!((5.2..7.3).contains(&l0), "initial loss {l0} should be near ln(512)=6.24");
}

#[test]
fn loss_decreases_over_steps() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.steps = 4;
    c.minibs = 2;
    c.adam.lr = 3e-3;
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::LbMini;
    let r = train(&c).unwrap();
    let first = r.logs.first().unwrap().loss;
    let last = r.logs.last().unwrap().loss;
    assert!(last < first, "loss should descend: {first} -> {last}");
}

#[test]
fn lb_mini_rejected_under_collective() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::LbMini;
    assert!(train(&c).is_err());
}

#[test]
fn gather_cache_bit_identical_to_seed_gather_path() {
    if !have_artifacts() {
        return;
    }
    // Params are immutable within a minibatch, so gathering once per
    // minibatch (cache on) instead of twice per microbatch (seed path,
    // cache off) must produce BIT-IDENTICAL training — assert_eq, no
    // tolerance. Pinned to world=1: a single client gives the daemon a
    // deterministic accumulation order, isolating exactly the variable
    // under test (every other source of float noise is absent).
    let mut cached = base_cfg();
    cached.world = 1;
    cached.minibs = 4;
    cached.scheme = CommScheme::Odc;
    cached.balancer = Balancer::LbMicro;
    cached.gather_cache = true;
    let mut uncached = cached.clone();
    uncached.gather_cache = false;
    let a = train(&cached).unwrap();
    let b = train(&uncached).unwrap();
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}: cached vs uncached loss must be bit-identical", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: cached vs uncached params must be bit-identical");
    }
}

#[test]
fn gather_cache_equivalent_multi_device() {
    if !have_artifacts() {
        return;
    }
    // Same comparison at world=2. Since the id-keyed fold landed, ODC's
    // daemon folds in canonical plan order regardless of arrival, so
    // even the multi-client run is BIT-comparable — assert_eq, no
    // tolerance (the seed version of this test allowed 1e-4 because the
    // fold order was arrival-dependent).
    let mut cached = base_cfg();
    cached.scheme = CommScheme::Odc;
    cached.balancer = Balancer::LbMicro;
    cached.gather_cache = true;
    let mut uncached = cached.clone();
    uncached.gather_cache = false;
    let a = train(&cached).unwrap();
    let b = train(&uncached).unwrap();
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}: {} vs {}", x.step, x.loss, y.loss);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: cached vs uncached must be bit-identical");
    }
}

/// Run the trainer, treating the in-tree PJRT stub as a skip — the
/// documented contract: artifact-gated tests stay green until the real
/// `xla` crate is wired in (see `runtime::xla_stub`). Any other failure
/// is a hard error.
fn try_train(cfg: &TrainerConfig) -> Option<TrainRun> {
    match train(cfg) {
        Ok(r) => Some(r),
        Err(e) if format!("{e:#}").contains("PJRT backend unavailable") => {
            eprintln!("skipping: {e:#}");
            None
        }
        Err(e) => panic!("training run: {e:#}"),
    }
}

/// The pinned world=2 LB-Micro plans plus the single-device oracle run
/// replaying them flattened (device 0's microbatches then device 1's) —
/// identical microbatch composition, one device, DP-equivalent updates.
/// `None` when the PJRT stub is active (skip).
fn pinned_plans_and_oracle() -> Option<(Vec<Plan>, TrainRun)> {
    let mut pin = base_cfg();
    pin.scheme = CommScheme::Odc;
    pin.balancer = Balancer::LbMicro;
    let plans2 = plan_preview(&pin).unwrap();
    let flat: Vec<Plan> = plans2
        .iter()
        .map(|p| Plan { micro: vec![p.micro.iter().flatten().filter(|m| !m.is_empty()).cloned().collect()] })
        .collect();
    let mut solo_cfg = base_cfg();
    solo_cfg.world = 1;
    solo_cfg.minibs = 4; // 1×4 == 2×2 samples per optimizer step
    solo_cfg.scheme = CommScheme::Odc;
    solo_cfg.balancer = Balancer::LbMicro;
    solo_cfg.plan_override = Some(flat);
    let solo = try_train(&solo_cfg)?;
    Some((plans2, solo))
}

/// Backend × balancer matrix against the single-device oracle: every
/// world-2 backend must reproduce the oracle's loss trajectory and
/// parameters on the SAME pinned plan.
#[test]
fn backend_matrix_matches_single_device_oracle() {
    if !have_artifacts() {
        return;
    }
    let Some((plans2, solo)) = pinned_plans_and_oracle() else { return };
    for (scheme, dpn, label) in [
        (CommScheme::Collective, 0, "collective"),
        (CommScheme::Odc, 0, "odc"),
        (CommScheme::Hybrid, 0, "hybrid/single-group"),
        (CommScheme::Hybrid, 1, "hybrid/per-device-groups"),
    ] {
        let mut c = base_cfg();
        c.scheme = scheme;
        c.balancer = Balancer::LbMicro;
        c.devices_per_node = dpn;
        c.plan_override = Some(plans2.clone());
        let Some(r) = try_train(&c) else { return };
        for (a, b) in solo.logs.iter().zip(&r.logs) {
            assert_eq!(a.tokens, b.tokens, "{label} step {}", a.step);
            assert!(
                (a.loss - b.loss).abs() < 1e-4,
                "{label} step {}: oracle {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        for (l, (pa, pb)) in solo.final_params.iter().zip(&r.final_params).enumerate() {
            let d = rel_l2(pb, pa);
            assert!(d < 1e-4, "{label} layer {l}: rel L2 {d}");
        }
    }
}

/// The acceptance-criterion case: a single-group hybrid run folds its
/// gradient pieces in exactly the oracle's flattened order (client asc,
/// push order), so the shard states are BIT-identical to the
/// single-device oracle — assert_eq, no tolerance.
#[test]
fn hybrid_single_group_bit_identical_to_oracle() {
    if !have_artifacts() {
        return;
    }
    let Some((plans2, solo)) = pinned_plans_and_oracle() else { return };
    let mut c = base_cfg();
    c.scheme = CommScheme::Hybrid;
    c.devices_per_node = 0; // 0 = one group spanning the world
    c.balancer = Balancer::LbMicro;
    c.plan_override = Some(plans2);
    let Some(hybrid) = try_train(&c) else { return };
    for (a, b) in solo.logs.iter().zip(&hybrid.logs) {
        assert_eq!(a.tokens, b.tokens, "step {}", a.step);
        // per-microbatch loss sums are f32 values accumulated exactly in
        // f64, so even the f64 trajectory is order-independent here
        assert_eq!(a.loss, b.loss, "step {}: losses must be bit-identical", a.step);
    }
    for (l, (pa, pb)) in solo.final_params.iter().zip(&hybrid.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: hybrid shard state must be bit-identical to the oracle");
    }
}

/// Hybrid is deterministic even with multiple groups (the daemons fold
/// buffered pieces in fixed order): two identical runs, identical bits.
#[test]
fn hybrid_multi_group_deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Hybrid;
    c.devices_per_node = 1; // world 2 → two groups: cross path exercised
    c.balancer = Balancer::LbMicro;
    let Some(a) = try_train(&c) else { return };
    let Some(b) = try_train(&c) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}");
    }
}

/// LB-Mini × {ODC, Hybrid}: same seed, same plans, equivalent training.
#[test]
fn hybrid_lb_mini_matches_odc_lb_mini() {
    if !have_artifacts() {
        return;
    }
    let mut odc_cfg = base_cfg();
    odc_cfg.scheme = CommScheme::Odc;
    odc_cfg.balancer = Balancer::LbMini;
    let Some(odc) = try_train(&odc_cfg) else { return };
    let mut c = base_cfg();
    c.scheme = CommScheme::Hybrid;
    c.balancer = Balancer::LbMini;
    let Some(hyb) = try_train(&c) else { return };
    for (a, b) in odc.logs.iter().zip(&hyb.logs) {
        assert_eq!(a.tokens, b.tokens);
        assert!((a.loss - b.loss).abs() < 1e-4, "step {}: {} vs {}", a.step, a.loss, b.loss);
    }
    for (l, (pa, pb)) in odc.final_params.iter().zip(&hyb.final_params).enumerate() {
        let d = rel_l2(pb, pa);
        assert!(d < 1e-4, "layer {l}: rel L2 {d}");
    }
}

/// Gather caching under hybrid: determinism makes cached vs uncached
/// bit-comparable even at world 2 (unlike ODC, which needs world 1).
#[test]
fn hybrid_gather_cache_bit_identical() {
    if !have_artifacts() {
        return;
    }
    let mut cached = base_cfg();
    cached.scheme = CommScheme::Hybrid;
    cached.balancer = Balancer::LbMicro;
    cached.gather_cache = true;
    let mut uncached = cached.clone();
    uncached.gather_cache = false;
    let Some(a) = try_train(&cached) else { return };
    let Some(b) = try_train(&uncached) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: cached vs uncached must be bit-identical");
    }
}

/// The pinned world=2 Queue-packed plans (LB-Mini composition) plus the
/// single-device oracle replaying them flattened in canonical (device
/// asc, slot asc) id order — the order the id-keyed fold reproduces
/// under ANY dispatch interleaving.
fn queue_plans_and_oracle() -> Option<(Vec<Plan>, TrainRun)> {
    let mut pin = base_cfg();
    pin.scheme = CommScheme::Odc;
    pin.balancer = Balancer::Queue;
    let plans2 = plan_preview(&pin).unwrap();
    let flat: Vec<Plan> = plans2
        .iter()
        .map(|p| Plan { micro: vec![p.micro.iter().flatten().filter(|m| !m.is_empty()).cloned().collect()] })
        .collect();
    let mut solo_cfg = base_cfg();
    solo_cfg.world = 1;
    solo_cfg.minibs = 4; // 1×4 == 2×2 samples per optimizer step
    solo_cfg.scheme = CommScheme::Odc;
    solo_cfg.balancer = Balancer::LbMicro;
    solo_cfg.plan_override = Some(flat);
    let solo = try_train(&solo_cfg)?;
    Some((plans2, solo))
}

/// THE DynDispatch acceptance case: work-queue dispatch while one
/// device runs 4× slow. Placement is decided at runtime by whichever
/// device pulls first — yet the id-keyed fold makes the run
/// BIT-identical in loss and parameters to the single-device oracle,
/// for both one-sided backends. assert_eq, no tolerance.
#[test]
fn queue_dispatch_bit_identical_to_oracle_under_straggler() {
    if !have_artifacts() {
        return;
    }
    let Some((plans2, solo)) = queue_plans_and_oracle() else { return };
    for (scheme, label) in [(CommScheme::Odc, "queue×odc"), (CommScheme::Hybrid, "queue×hybrid")] {
        let mut c = base_cfg();
        c.scheme = scheme;
        c.balancer = Balancer::Queue;
        c.devices_per_node = 0;
        c.device_speed = vec![0.25, 1.0]; // device 0 is a 4× straggler
        c.plan_override = Some(plans2.clone());
        let Some(r) = try_train(&c) else { return };
        for (a, b) in solo.logs.iter().zip(&r.logs) {
            assert_eq!(a.tokens, b.tokens, "{label} step {}", a.step);
            assert_eq!(a.loss, b.loss, "{label} step {}: loss must be bit-identical to the oracle", a.step);
        }
        for (l, (pa, pb)) in solo.final_params.iter().zip(&r.final_params).enumerate() {
            assert_eq!(pa, pb, "{label} layer {l}: params must be bit-identical to the oracle");
        }
    }
}

/// Queue dispatch is repeatable: two runs under the same skew give the
/// same bits even though the realized placements may differ — the fold
/// key is the plan, not the schedule.
#[test]
fn queue_dispatch_deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::Queue;
    c.device_speed = vec![1.0, 0.25];
    let Some(a) = try_train(&c) else { return };
    let Some(b) = try_train(&c) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}");
    }
}

/// Queue×Collective is a config error (runtime placement cannot honour
/// a fixed barrier schedule) — rejected before artifacts are touched.
#[test]
fn queue_rejected_under_collective() {
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::Queue;
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");
}

/// Malformed device_speed vectors are config errors too.
#[test]
fn device_speed_validated() {
    let mut c = base_cfg();
    c.device_speed = vec![1.0]; // world is 2
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("one entry per device"), "unexpected error: {err}");
    c.device_speed = vec![1.0, 0.0];
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("finite and > 0"), "unexpected error: {err}");
}

/// THE ElasticWorld acceptance case: world 4, device 0 crashes during
/// minibatch 1 immediately before its 3rd pulled microbatch (the CLI's
/// `--fail-at 0:1:2`). All steps still complete, recovery overhead is
/// reported, and the final parameters match the surviving-world oracle
/// within 1e-5 — the id-keyed fold makes the re-dispatched microbatches
/// placement-free and the rendezvous successor recovers bit-exact Adam
/// state from the replicated store.
#[test]
fn elastic_fail_matches_surviving_world_oracle() {
    if !have_artifacts() {
        return;
    }
    let mut pin = base_cfg();
    pin.world = 4;
    pin.minibs = 2;
    pin.steps = 3;
    pin.scheme = CommScheme::Odc;
    pin.balancer = Balancer::Queue;
    let plans4 = plan_preview(&pin).unwrap();
    let flat: Vec<Plan> = plans4
        .iter()
        .map(|p| Plan { micro: vec![p.micro.iter().flatten().filter(|m| !m.is_empty()).cloned().collect()] })
        .collect();
    let mut solo = base_cfg();
    solo.world = 1;
    solo.minibs = 8; // 1×8 == 4×2 samples per optimizer step
    solo.steps = 3;
    solo.scheme = CommScheme::Odc;
    solo.balancer = Balancer::LbMicro;
    solo.plan_override = Some(flat);
    let Some(oracle) = try_train(&solo) else { return };

    let mut c = pin.clone();
    c.fail_at = vec![(0, 1, 2)];
    c.plan_override = Some(plans4);
    let Some(r) = try_train(&c) else { return };
    assert_eq!(r.logs.len(), 3, "all steps must complete despite the crash");
    assert!(r.recovery_s > 0.0, "recovery overhead must be measured and reported");
    for (a, b) in oracle.logs.iter().zip(&r.logs) {
        assert_eq!(a.tokens, b.tokens, "step {}: exactly-once delivery", a.step);
        assert!(
            (a.loss - b.loss).abs() < 1e-6,
            "step {}: oracle {} vs elastic {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    for (l, (pa, pb)) in oracle.final_params.iter().zip(&r.final_params).enumerate() {
        let d = rel_l2(pb, pa);
        assert!(d < 1e-5, "layer {l}: rel L2 {d} vs the surviving-world oracle");
    }
}

/// A join at a minibatch boundary is bit-identical to a fresh run at
/// the larger world size (the replica refresh path): device 1 sits out
/// step 0 — its share redistributed, its shard served by the ring
/// successor — then joins at step 1 recovering params + Adam moments
/// from the replicated store. The bytes cannot tell the difference.
#[test]
fn join_bit_identical_to_fresh_run_at_larger_world() {
    if !have_artifacts() {
        return;
    }
    let mut fresh = base_cfg();
    fresh.steps = 3;
    fresh.scheme = CommScheme::Odc;
    fresh.balancer = Balancer::Queue;
    let Some(a) = try_train(&fresh) else { return };
    let mut late = fresh.clone();
    late.join_at = vec![(1, 1)];
    let Some(b) = try_train(&late) else { return };
    assert!(b.recovery_s > 0.0, "the join refresh is recovery work");
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}: a join must not move a bit", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: join must be bit-identical to the fresh run");
    }
}

/// Elastic knobs are config errors under Collective — one dead rank
/// deadlocks its per-layer barriers, which is the PS-vs-collective
/// contrast the scenario exists to measure. Validation runs before
/// artifacts are touched.
#[test]
fn elastic_rejected_under_collective() {
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::LbMicro;
    c.fail_at = vec![(0, 1, 0)];
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");
    let mut j = base_cfg();
    j.scheme = CommScheme::Collective;
    j.balancer = Balancer::LbMicro;
    j.join_at = vec![(1, 1)];
    let err = train(&j).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");
}

/// Malformed elastic schedules are rejected before anything runs.
#[test]
fn elastic_schedule_validated() {
    // fail step beyond the run
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.fail_at = vec![(0, 99, 0)];
    assert!(train(&c).is_err());
    // nobody survives the step
    let mut c2 = base_cfg();
    c2.scheme = CommScheme::Odc;
    c2.fail_at = vec![(0, 1, 0), (1, 1, 0)];
    assert!(train(&c2).is_err());
    // hybrid: the dead device is alone in its node group — its replica
    // and super-shard duties would be unrecoverable
    let mut c3 = base_cfg();
    c3.scheme = CommScheme::Hybrid;
    c3.devices_per_node = 1;
    c3.fail_at = vec![(0, 1, 0)];
    let err = train(&c3).unwrap_err().to_string();
    assert!(err.contains("no completing member"), "unexpected error: {err}");
}

/// AsyncPS staleness goes through the same pre-artifact validation:
/// contradictory combos die in `RunSpec::validate`, not at artifact
/// load or mid-run (the full legality matrix lives in
/// `tests/async_prop.rs`).
#[test]
fn staleness_rejected_before_artifact_load() {
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::LbMicro;
    c.staleness = Some(1);
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");

    let mut f = base_cfg();
    f.scheme = CommScheme::Odc;
    f.balancer = Balancer::Queue;
    f.staleness = Some(1);
    f.fail_at = vec![(0, 1, 0)];
    let err = train(&f).unwrap_err().to_string();
    assert!(err.contains("static membership"), "unexpected error: {err}");
}

/// Config validation runs before artifacts are touched, so this holds
/// even without `make artifacts`.
#[test]
fn hybrid_rejects_groups_that_do_not_tile_world() {
    let mut c = base_cfg();
    c.world = 4;
    c.scheme = CommScheme::Hybrid;
    c.balancer = Balancer::LbMicro;
    c.devices_per_node = 3;
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("tile the device set"), "unexpected error: {err}");
}

/// SeqSplit's fraction knob on the tiny corpus: with minibs=2 and
/// world=2 the per-device budget is roughly half a minibatch, so a 0.5
/// threshold reliably splits at least one sequence — the helper asserts
/// it did, keeping the matrix honest about exercising chunks.
const SPLIT_FRAC: f64 = 0.5;

/// The pinned world=2 split plans (chunk virtual ids included) plus the
/// single-device oracle replaying the SAME chunk composition: both the
/// plans AND the `SplitMap` are pinned via `plan_override` +
/// `split_override`, so oracle and distributed runs compute identical
/// chunk slices and fold them under identical synthetic keys. `None`
/// when the PJRT stub is active (skip).
fn split_plans_and_oracle(balancer: Balancer) -> Option<(Vec<Plan>, SplitMap, TrainRun)> {
    let mut pin = base_cfg();
    pin.scheme = CommScheme::Odc;
    pin.balancer = balancer;
    pin.seq_split = SPLIT_FRAC;
    let (plans2, split) = plan_preview_split(&pin).unwrap();
    assert!(!split.is_empty(), "the pinned corpus must actually split under frac {SPLIT_FRAC}");
    let flat: Vec<Plan> = plans2
        .iter()
        .map(|p| Plan { micro: vec![p.micro.iter().flatten().filter(|m| !m.is_empty()).cloned().collect()] })
        .collect();
    let mut solo_cfg = base_cfg();
    solo_cfg.world = 1;
    solo_cfg.minibs = 4; // 1×4 == 2×2 samples per optimizer step
    solo_cfg.scheme = CommScheme::Odc;
    solo_cfg.balancer = Balancer::LbMicro;
    solo_cfg.plan_override = Some(flat);
    solo_cfg.split_override = Some(split.clone());
    let solo = try_train(&solo_cfg)?;
    Some((plans2, split, solo))
}

/// THE SeqSplit acceptance matrix: split × {ODC, Hybrid} × {LB-Mini,
/// Queue} against the single-device oracle running the same chunk
/// composition, within 1e-5. The per-sequence fold is chunk-index
/// ordered and the reconstituted gradient joins the id-keyed micro
/// fold, so placement (static rows or runtime pulls) cannot move a bit.
#[test]
fn split_matrix_matches_single_device_oracle() {
    if !have_artifacts() {
        return;
    }
    for balancer in [Balancer::LbMini, Balancer::Queue] {
        let Some((plans2, split, solo)) = split_plans_and_oracle(balancer) else { return };
        for (scheme, label) in [(CommScheme::Odc, "split×odc"), (CommScheme::Hybrid, "split×hybrid")] {
            let mut c = base_cfg();
            c.scheme = scheme;
            c.balancer = balancer;
            c.seq_split = SPLIT_FRAC;
            c.plan_override = Some(plans2.clone());
            c.split_override = Some(split.clone());
            let Some(r) = try_train(&c) else { return };
            for (a, b) in solo.logs.iter().zip(&r.logs) {
                assert_eq!(a.tokens, b.tokens, "{label}×{balancer} step {}: chunk token conservation", a.step);
                assert!(
                    (a.loss - b.loss).abs() < 1e-5,
                    "{label}×{balancer} step {}: oracle {} vs {}",
                    a.step,
                    a.loss,
                    b.loss
                );
            }
            for (l, (pa, pb)) in solo.final_params.iter().zip(&r.final_params).enumerate() {
                let d = rel_l2(pb, pa);
                assert!(d < 1e-5, "{label}×{balancer} layer {l}: rel L2 {d} vs the oracle");
            }
        }
    }
}

/// `--seq-split 0` IS the seed path: `plan_preview_split` must return
/// the seed plans plus an empty map, and a training run with the knob
/// explicitly zeroed must be BIT-identical to one that never mentions
/// it — the empty-`SplitMap` wrappers threaded through packer,
/// dispatcher and trainer may not perturb a single RNG draw or float.
#[test]
fn split_disabled_bit_identical_to_seed_path() {
    if !have_artifacts() {
        return;
    }
    let mut zeroed = base_cfg();
    zeroed.scheme = CommScheme::Odc;
    zeroed.balancer = Balancer::LbMini;
    zeroed.seq_split = 0.0;
    let (plans, split) = plan_preview_split(&zeroed).unwrap();
    assert!(split.is_empty(), "frac 0 must not split anything");
    let seed = base_cfg();
    let mut seed_cfg = seed.clone();
    seed_cfg.scheme = CommScheme::Odc;
    seed_cfg.balancer = Balancer::LbMini;
    assert_eq!(plans, plan_preview(&seed_cfg).unwrap(), "frac 0 must reproduce the seed plans");
    let Some(a) = try_train(&zeroed) else { return };
    let Some(b) = try_train(&seed_cfg) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}: split-disabled must be bit-identical", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}: split-disabled must be bit-identical to the seed path");
    }
}

/// Split runs are repeatable under runtime placement with a straggler:
/// two Queue×ODC runs with a 4× slow device give the same bits even
/// though realized chunk placement may differ — the rendezvous fold is
/// keyed by (seq, chunk), not by schedule.
#[test]
fn split_deterministic_across_runs_under_straggler() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::Queue;
    c.seq_split = SPLIT_FRAC;
    c.device_speed = vec![1.0, 0.25];
    let Some(a) = try_train(&c) else { return };
    let Some(b) = try_train(&c) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}");
    }
}

/// Split × Collective is a config error (padded per-layer barriers
/// assume whole sequences), as are synchronized-k balancers and
/// out-of-range fractions. Validation runs before artifacts are
/// touched, so these hold even without `make artifacts`.
#[test]
fn split_rejected_under_collective_and_synchronized_balancers() {
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::LbMicro;
    c.seq_split = SPLIT_FRAC;
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");

    let mut b = base_cfg();
    b.scheme = CommScheme::Odc;
    b.balancer = Balancer::LbMicro;
    b.seq_split = SPLIT_FRAC;
    let err = train(&b).unwrap_err().to_string();
    assert!(err.contains("LB-Mini or Queue"), "unexpected error: {err}");

    let mut f = base_cfg();
    f.scheme = CommScheme::Odc;
    f.balancer = Balancer::LbMini;
    f.seq_split = 1.5;
    let err = train(&f).unwrap_err().to_string();
    assert!(err.contains("(0, 1]"), "unexpected error: {err}");
}

/// Split × `fail_at` on a device that can host a chunk is rejected
/// after planning: under Queue ANY scheduled crash could land on a
/// chunk (runtime placement), and under static LB-Mini the plan row at
/// the fail step is inspected for chunk virtual ids.
#[test]
fn split_rejected_when_failure_can_host_a_chunk() {
    if !have_artifacts() {
        return;
    }
    // Queue: blanket rejection — placement is decided at runtime.
    let mut q = base_cfg();
    q.scheme = CommScheme::Odc;
    q.balancer = Balancer::Queue;
    q.seq_split = SPLIT_FRAC;
    q.fail_at = vec![(0, 1, 0)];
    let err = train(&q).unwrap_err().to_string();
    assert!(err.contains("split chunk"), "unexpected error: {err}");

    // Static LB-Mini: find a (device, step) whose planned row holds a
    // chunk virtual id and schedule the crash exactly there.
    let mut pin = base_cfg();
    pin.scheme = CommScheme::Odc;
    pin.balancer = Balancer::LbMini;
    pin.seq_split = SPLIT_FRAC;
    let (plans, split) = plan_preview_split(&pin).unwrap();
    let hit = plans
        .iter()
        .enumerate()
        .flat_map(|(step, p)| {
            let split = &split;
            p.micro
                .iter()
                .enumerate()
                .filter(move |(_, row)| row.iter().flatten().any(|&i| split.is_chunk(i)))
                .map(move |(d, _)| (d, step))
        })
        .next();
    let (d, step) = hit.expect("frac 0.5 on the tiny corpus must place a chunk somewhere");
    let mut s = pin.clone();
    s.fail_at = vec![(d, step, 0)];
    let err = train(&s).unwrap_err().to_string();
    assert!(err.contains("split chunk"), "unexpected error: {err}");
}

#[test]
fn pjrt_shard_ops_match_native_rust() {
    if !have_artifacts() {
        return;
    }
    // The Rust AdamW loop and the PJRT adam_chunk kernel implement the
    // same update: a run through each must land on the same parameters.
    let mut a = base_cfg();
    a.steps = 1;
    let mut b = a.clone();
    b.pjrt_shard_ops = true;
    let ra = train(&a).unwrap();
    let rb = train(&b).unwrap();
    for (l, (pa, pb)) in ra.final_params.iter().zip(&rb.final_params).enumerate() {
        let d = rel_l2(pb, pa);
        assert!(d < 5e-5, "layer {l}: rel L2 {d}");
    }
}
