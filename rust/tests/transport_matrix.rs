//! WireComm acceptance matrix: the byte-moving transports must be
//! invisible to training semantics.
//!
//! The in-process mailbox (`inproc`), the shared-memory ring (`shm`)
//! and the socket transport (`uds`) carry the SAME `Msg` streams; the
//! per-destination ticket sequence reproduces the mailbox's total
//! arrival order at every daemon, so a training run over a byte
//! transport is BIT-identical to the in-proc run — assert_eq on every
//! loss and every parameter, no tolerance. That holds for static
//! dispatch AND for Queue (runtime placement): the id-keyed fold makes
//! the folded bits placement-free, and the ticket order makes arrival
//! transport-free.
//!
//! Everything here is artifact-gated on the `tiny` preset and
//! self-skips when PJRT is stubbed or the environment cannot bind
//! sockets (documented contract, see `engine_equivalence.rs`).

use odc::comm::TransportKind;
use odc::config::{Balancer, CommScheme, WireDtype};
use odc::engine::trainer::{train, TrainRun, TrainerConfig};
use std::path::{Path, PathBuf};

fn tiny_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have_artifacts() -> bool {
    tiny_dir().join("manifest.json").exists()
}

fn base_cfg() -> TrainerConfig {
    let mut c = TrainerConfig::new(tiny_dir());
    c.world = 2;
    c.minibs = 2;
    c.steps = 2;
    c.seed = 42;
    c
}

/// Run the trainer; `None` skips on the two documented environmental
/// gaps (PJRT stub, unbindable sockets), anything else is a hard error.
fn try_train(cfg: &TrainerConfig) -> Option<TrainRun> {
    match train(cfg) {
        Ok(r) => Some(r),
        Err(e) if format!("{e:#}").contains("PJRT backend unavailable") => {
            eprintln!("skipping: {e:#}");
            None
        }
        Err(e) if format!("{e:#}").contains("failed to bind") => {
            eprintln!("skipping (sandbox without sockets?): {e:#}");
            None
        }
        Err(e) => panic!("training run: {e:#}"),
    }
}

fn assert_bit_identical(label: &str, a: &TrainRun, b: &TrainRun) {
    assert_eq!(a.logs.len(), b.logs.len(), "{label}: step counts");
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens, "{label} step {}", x.step);
        assert_eq!(x.loss, y.loss, "{label} step {}: losses must be bit-identical", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "{label} layer {l}: params must be bit-identical");
    }
}

/// THE WireComm acceptance case: ODC and Hybrid over every byte
/// transport, static dispatch, against their own in-proc run from the
/// identical config — assert_eq, no tolerance.
#[test]
fn byte_transports_bit_identical_to_inproc_static() {
    if !have_artifacts() {
        return;
    }
    for (scheme, balancer, label) in [
        (CommScheme::Odc, Balancer::LbMicro, "odc×lb-micro"),
        (CommScheme::Odc, Balancer::LbMini, "odc×lb-mini"),
        (CommScheme::Hybrid, Balancer::LbMini, "hybrid×lb-mini"),
    ] {
        let mut c = base_cfg();
        c.scheme = scheme;
        c.balancer = balancer;
        let Some(oracle) = try_train(&c) else { return };
        for kind in [TransportKind::Shm, TransportKind::Uds] {
            let mut w = c.clone();
            w.transport = kind;
            let Some(r) = try_train(&w) else { return };
            assert_bit_identical(&format!("{label} over {kind}"), &oracle, &r);
            assert_eq!(
                oracle.wire_bytes, r.wire_bytes,
                "{label} over {kind}: the transport must not change pushed-byte accounting"
            );
        }
    }
}

/// Queue dispatch with a 4× straggler over the byte transports: runtime
/// placement AND real byte movement together still cannot move a bit —
/// the fold key is the plan, the arrival order is the ticket sequence.
#[test]
fn queue_dispatch_over_byte_transports_bit_identical() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::Queue;
    c.device_speed = vec![0.25, 1.0];
    let Some(oracle) = try_train(&c) else { return };
    for kind in [TransportKind::Shm, TransportKind::Uds] {
        let mut w = c.clone();
        w.transport = kind;
        let Some(r) = try_train(&w) else { return };
        assert_bit_identical(&format!("queue×odc over {kind}"), &oracle, &r);
    }
}

/// The wire-precision knob composes with the transport: a bf16 run over
/// the ring carries half the f32 bytes (same counter the inproc run
/// reports) and lands on the same bits as bf16 over inproc — encode
/// happens before the transport, decode after, error feedback included.
#[test]
fn bf16_wire_composes_with_byte_transports() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::LbMini;
    c.wire_dtype = WireDtype::Bf16;
    let Some(oracle) = try_train(&c) else { return };
    let mut w = c.clone();
    w.transport = TransportKind::Shm;
    let Some(r) = try_train(&w) else { return };
    assert_bit_identical("odc×bf16 over shm", &oracle, &r);
    assert_eq!(oracle.wire_bytes, r.wire_bytes, "bf16 byte halving must survive the transport");
}

/// Elastic recovery over the ring: device 0 crashes mid-minibatch and
/// the run still completes with the same bits as the same crash over
/// inproc — retract/adopt/re-pull traffic is ordinary `Msg` traffic.
#[test]
fn elastic_crash_over_ring_matches_inproc() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.world = 4;
    c.steps = 3;
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::Queue;
    c.fail_at = vec![(0, 1, 1)];
    let Some(oracle) = try_train(&c) else { return };
    let mut w = c.clone();
    w.transport = TransportKind::Shm;
    let Some(r) = try_train(&w) else { return };
    assert!(r.recovery_s > 0.0, "recovery overhead must be measured over the ring too");
    assert_bit_identical("elastic×odc over shm", &oracle, &r);
}

/// Collective × byte transport is a config error: the collective
/// backend's per-layer barriers assume the shared-memory mailbox, so
/// the combination is rejected before artifacts are touched (holds
/// even without `make artifacts`).
#[test]
fn collective_rejected_over_byte_transports() {
    for kind in [TransportKind::Shm, TransportKind::Uds] {
        let mut c = base_cfg();
        c.scheme = CommScheme::Collective;
        c.balancer = Balancer::LbMicro;
        c.transport = kind;
        let err = train(&c).unwrap_err().to_string();
        assert!(err.contains("one-sided"), "unexpected error: {err}");
    }
}

/// `--transport inproc` through the `with_stack` path is the seed path:
/// explicitly selecting the default must be bit-identical to never
/// mentioning it (the stack constructor may not perturb anything).
#[test]
fn explicit_inproc_bit_identical_to_default() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::LbMicro;
    let Some(a) = try_train(&c) else { return };
    let mut e = c.clone();
    e.transport = TransportKind::Inproc;
    let Some(b) = try_train(&e) else { return };
    assert_bit_identical("explicit inproc", &a, &b);
}
