//! Chaos property test: ElasticWorld under randomized device failure.
//!
//! Kills a random device at a random microbatch pull of a random step,
//! under work-queue dispatch × {ODC, Hybrid}, and asserts the recovery
//! contract end to end at the backend + dispatcher level (no PJRT, no
//! artifacts — this suite always runs):
//!
//! * **exactly-once** — every microbatch of every minibatch executes
//!   exactly once, across the crash: completed micros are not re-run,
//!   orphaned micros run on exactly one survivor;
//! * **oracle equality** — each step's folded gradient equals the
//!   sequential oracle sum EXACTLY (grads are distinct powers of two,
//!   so any double/dropped delivery flips a bit);
//! * **arena hygiene** (ODC) — push-level acquire counts are exact
//!   (no re-push), the dead client's arenas are released at recovery,
//!   and total arena growth stays inside the step-count-independent
//!   in-flight bound even many minibatches after the crash — a
//!   recovery leak would scale with the post-crash steps and blow it.
//!
//! A join trial runs the mirror image: a device sits out the early
//! steps and enters at a minibatch boundary, with identical invariants.

use odc::balance::cost::CostModel;
use odc::balance::dispatch::{make_elastic_dispatcher, Dispatcher};
use odc::balance::packers::Plan;
use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::{ArenaStats, CommStack, Membership, OdcComm};
use odc::config::{Balancer, CommScheme, PaperModel};
use odc::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Two layers, lengths chosen so padding differs across world sizes.
const LAYERS: [usize; 2] = [12, 7];
const MICROS_PER_DEV: usize = 3;

/// Singleton microbatches with strictly decreasing cost, so the LPT
/// pull order is deterministic and ids are distinct.
fn make_plan(world: usize) -> (Plan, Vec<usize>) {
    let n = world * MICROS_PER_DEV;
    let lens: Vec<usize> = (0..n).map(|i| 4000 - 100 * i).collect();
    let micro: Vec<Vec<Vec<usize>>> = (0..world)
        .map(|d| (0..MICROS_PER_DEV).map(|m| vec![d * MICROS_PER_DEV + m]).collect())
        .collect();
    (Plan { micro }, lens)
}

struct TrialOutcome {
    /// ids executed per step (any order).
    executed: Vec<Vec<u64>>,
    arena: Option<ArenaStats>,
}

/// Drive `steps` minibatches of the synthetic workload over an elastic
/// membership, with trainer-faithful crash/join handling. Every shard
/// owner asserts the exact oracle fold in-line.
fn run_elastic(
    scheme: CommScheme,
    group_size: usize,
    world: usize,
    membership: Arc<Membership>,
    fail: Option<(usize, usize, usize)>,
    steps: usize,
) -> TrialOutcome {
    let params = Arc::new(ParamStore::new(&LAYERS, world));
    let stack =
        CommStack::builder(Arc::clone(&params), world).membership(Arc::clone(&membership));
    let (backend, odc_handle): (Arc<dyn CommBackend>, Option<Arc<OdcComm>>) = match scheme {
        CommScheme::Odc => {
            let c = stack.build_odc().expect("in-process odc stack");
            (Arc::clone(&c) as Arc<dyn CommBackend>, Some(c))
        }
        CommScheme::Hybrid => (
            stack.groups(group_size).build_hybrid().expect("in-process hybrid stack")
                as Arc<dyn CommBackend>,
            None,
        ),
        CommScheme::Collective => unreachable!("elastic × Collective is rejected at config time"),
    };
    let (plan, lens) = make_plan(world);
    let cost = CostModel::for_model(PaperModel::M1_5B);
    let n_micros = (world * MICROS_PER_DEV) as u64;
    // every micro pushes 2^id: the full fold is exactly 2^n - 1
    let want = ((1u64 << n_micros) - 1) as f32;
    let executed: Arc<Vec<Mutex<Vec<u64>>>> =
        Arc::new((0..steps).map(|_| Mutex::new(Vec::new())).collect());
    let dispatchers: Vec<Arc<dyn Dispatcher>> = (0..steps)
        .map(|step| {
            let crasher: Vec<bool> = (0..world).map(|d| membership.fails_during(d, step)).collect();
            let absent: Vec<bool> = (0..world).map(|d| membership.absent(d, step)).collect();
            make_elastic_dispatcher(Balancer::Queue, scheme, &plan, &lens, &cost, &crasher, &absent)
        })
        .collect();
    let dispatchers = Arc::new(dispatchers);

    std::thread::scope(|s| {
        for dev in 0..world {
            let backend = Arc::clone(&backend);
            let params = Arc::clone(&params);
            let membership = Arc::clone(&membership);
            let executed = Arc::clone(&executed);
            let dispatchers = Arc::clone(&dispatchers);
            s.spawn(move || {
                let join = membership.joins_at(dev);
                if join > 0 {
                    backend.await_join(dev);
                }
                for step in join..steps {
                    let disp = dispatchers[step].as_ref();
                    let mut pulls = 0usize;
                    let mut crashed = false;
                    while let Some(a) = disp.next_micro(dev) {
                        if fail == Some((dev, step, pulls)) {
                            disp.report_failed(dev);
                            crashed = true;
                            break;
                        }
                        pulls += 1;
                        executed[step].lock().unwrap().push(a.id);
                        for (l, p) in params.layers.iter().enumerate() {
                            let grad = vec![(1u64 << a.id) as f32; p.padded_len()];
                            backend.reduce_grad(dev, l, &grad, 1.0, a.id);
                        }
                    }
                    if !crashed && matches!(fail, Some((d, st, _)) if d == dev && st == step) {
                        disp.report_failed(dev);
                        crashed = true;
                    }
                    if crashed {
                        return; // simulated crash: the worker vanishes
                    }
                    backend.end_minibatch(dev);
                    for &shard in &membership.shards_owned_by(dev, step) {
                        if shard != dev {
                            backend.flush_shard(shard);
                        }
                        for (l, p) in params.layers.iter().enumerate() {
                            let mut g = vec![0.0f32; p.shard_len];
                            backend.take_grad_shard(shard, l, &mut g);
                            for &v in &g {
                                assert_eq!(
                                    v, want,
                                    "step {step} shard {shard} layer {l}: fold != oracle"
                                );
                            }
                        }
                    }
                    backend.end_step(dev);
                }
            });
        }
    });

    TrialOutcome {
        executed: executed.iter().map(|m| m.lock().unwrap().clone()).collect(),
        arena: odc_handle.map(|c| c.arena_stats()),
    }
}

fn assert_exactly_once(outcome: &TrialOutcome, world: usize, steps: usize) {
    let n = (world * MICROS_PER_DEV) as u64;
    for (step, ids) in outcome.executed.iter().enumerate() {
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        let want: Vec<u64> = (0..n).collect();
        assert_eq!(sorted, want, "step {step}: every microbatch must run exactly once");
    }
    assert_eq!(outcome.executed.len(), steps);
}

#[test]
fn chaos_kill_random_device_odc() {
    let world = 4;
    let mut rng = Rng::new(0xE1A5);
    for trial in 0..6 {
        let fail_dev = rng.below(world as u64) as usize;
        let fail_step = 1 + rng.below(2) as usize;
        // pull index may exceed the device's actual pulls: then it
        // crashes at the minibatch's end instead (both paths covered)
        let fail_pull = rng.below((world * MICROS_PER_DEV) as u64 + 2) as usize;
        let steps = fail_step + 7; // many post-recovery minibatches
        let membership =
            Arc::new(Membership::with_schedule(world, &[], &[(fail_dev, fail_step)]).unwrap());
        let outcome = run_elastic(
            CommScheme::Odc,
            0,
            world,
            membership,
            Some((fail_dev, fail_step, fail_pull)),
            steps,
        );
        assert_exactly_once(&outcome, world, steps);

        let stats = outcome.arena.expect("odc arena stats");
        // Push-level exactly-once: each executed micro acquires exactly
        // world × layers payload buffers, once.
        let pushes = (steps * world * MICROS_PER_DEV * LAYERS.len() * world) as u64;
        assert_eq!(stats.acquires, pushes, "trial {trial}: double or dropped pushes");
        // The dead client's arena columns were released at recovery:
        // at least their prealloc is gone from residency.
        let prealloc_total = (world * world * (LAYERS.len() + 1)) as u64;
        let dead_prealloc = (world * (LAYERS.len() + 1)) as u64;
        assert!(
            stats.resident <= prealloc_total + stats.fresh_allocs - dead_prealloc,
            "trial {trial}: dead client's arenas not released (resident {}, fresh {})",
            stats.resident,
            stats.fresh_allocs
        );
        // Growth bound independent of the step count: in-flight per
        // pair is capped by one minibatch's total pushes, so a per-step
        // recovery leak would overflow this across the 7 post-crash
        // steps.
        let bound = (world * world * (world * MICROS_PER_DEV) * LAYERS.len()) as u64;
        assert!(
            stats.fresh_allocs <= bound,
            "trial {trial}: arena growth {} exceeds in-flight bound {bound}",
            stats.fresh_allocs
        );
    }
}

#[test]
fn chaos_kill_random_device_hybrid() {
    let world = 4;
    let mut rng = Rng::new(0xB0B);
    for group_size in [2usize, 2, 4, 1] {
        let fail_dev = rng.below(world as u64) as usize;
        let fail_step = 1 + rng.below(2) as usize;
        let fail_pull = rng.below((world * MICROS_PER_DEV) as u64 + 2) as usize;
        let steps = fail_step + 5;
        let membership =
            Arc::new(Membership::with_schedule(world, &[], &[(fail_dev, fail_step)]).unwrap());
        // every group keeps a live member (single fail, group_size > 1
        // or the dead device alone in its group is excluded)
        if membership.validate_groups(group_size, steps).is_err() {
            continue; // per-device groups with the dead device: unrecoverable by design
        }
        let outcome = run_elastic(
            CommScheme::Hybrid,
            group_size,
            world,
            membership,
            Some((fail_dev, fail_step, fail_pull)),
            steps,
        );
        assert_exactly_once(&outcome, world, steps);
    }
}

#[test]
fn join_at_minibatch_boundary_odc() {
    let world = 4;
    for join_step in [1usize, 2] {
        let steps = join_step + 4;
        let membership =
            Arc::new(Membership::with_schedule(world, &[(3, join_step)], &[]).unwrap());
        let outcome = run_elastic(CommScheme::Odc, 0, world, membership, None, steps);
        assert_exactly_once(&outcome, world, steps);
    }
}

#[test]
fn join_then_fail_same_run() {
    // A device joins late AND another crashes afterwards: both
    // transitions in one run, still exactly-once everywhere.
    let world = 4;
    let membership =
        Arc::new(Membership::with_schedule(world, &[(2, 1)], &[(0, 2)]).unwrap());
    let steps = 6;
    let outcome =
        run_elastic(CommScheme::Odc, 0, world, membership, Some((0, 2, 1)), steps);
    assert_exactly_once(&outcome, world, steps);
}
