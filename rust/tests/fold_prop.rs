//! FastFold property suite: the chunk-parallel fold kernel, bf16 wire
//! payloads with error feedback, and the byte-arena accounting.
//!
//! Three claims, matching `docs/wire_precision.md`:
//!
//! 1. `fold_pieces` is BIT-identical to the scalar fold at every thread
//!    count and across every chunk-boundary shape — parallelism splits
//!    the element range, never the fold order, so each element's
//!    accumulation sequence is unchanged (no tolerance).
//! 2. Bf16 + error feedback tracks the f32 oracle: over 20 minibatches
//!    of a real `OdcComm` schedule, every folded gradient shard stays
//!    within 1e-2 relative L2 of the f32-wire run, while pushing at
//!    most 0.55x the wire bytes (exactly 0.5x, in fact).
//! 3. Byte-sized payload arenas change nothing about the allocation
//!    discipline: the same schedule performs the same acquire count and
//!    the same fresh-alloc count under either wire dtype.

use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::fold::{self, CHUNK_ELEMS};
use odc::comm::{ArenaStats, CommStack, FoldPiece, HotpathStats, PieceData, WireDtype};
use std::sync::Arc;

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

/// Deterministic pseudo-gradient value, no rng state to thread through.
fn gval(seed: usize, i: usize) -> f32 {
    ((seed.wrapping_mul(31).wrapping_add(i.wrapping_mul(131))) % 197) as f32 / 197.0 - 0.5
}

// ---------------------------------------------------------------------
// 1. kernel: parallel == scalar, bit for bit
// ---------------------------------------------------------------------

#[test]
fn parallel_fold_bit_identical_across_thread_counts_and_boundaries() {
    // Lengths straddling every interesting chunk boundary: below the
    // parallel threshold (scalar fallback), exactly at it, one past it,
    // and a many-chunk length with a ragged tail.
    let lens = [
        1,
        CHUNK_ELEMS - 1,
        CHUNK_ELEMS,
        2 * CHUNK_ELEMS - 1,
        2 * CHUNK_ELEMS,
        2 * CHUNK_ELEMS + 5,
        3 * CHUNK_ELEMS + 1234,
    ];
    for &len in &lens {
        let sources: Vec<Vec<f32>> =
            (0..4).map(|p| (0..len).map(|i| gval(p, i)).collect()).collect();
        let pieces: Vec<FoldPiece> = sources
            .iter()
            .enumerate()
            .map(|(p, s)| FoldPiece { weight: 0.25 + p as f32 * 0.5, data: PieceData::F32(s) })
            .collect();
        let base: Vec<f32> = (0..len).map(|i| gval(99, i)).collect();

        let mut oracle = base.clone();
        fold::fold_pieces(&mut oracle, &pieces, 1);
        for threads in [2, 3, 4, 5, 8] {
            let mut acc = base.clone();
            fold::fold_pieces(&mut acc, &pieces, threads);
            for (i, (a, o)) in acc.iter().zip(&oracle).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    o.to_bits(),
                    "len {len} threads {threads} elem {i}: {a} != {o}"
                );
            }
        }
    }
}

#[test]
fn parallel_fold_bit_identical_with_mixed_wire_pieces() {
    // The daemons fold raw wire payloads (decode fused into the
    // accumulate) next to already-decoded f32 pieces — the parallel
    // kernel must stay bit-identical across representations too.
    let len = 2 * CHUNK_ELEMS + 77;
    let plain: Vec<f32> = (0..len).map(|i| gval(1, i)).collect();
    let as_f32_wire = {
        let src: Vec<f32> = (0..len).map(|i| gval(2, i)).collect();
        let mut b = Vec::new();
        fold::encode(&mut b, &src, WireDtype::F32);
        b
    };
    let as_bf16_wire = {
        let src: Vec<f32> = (0..len).map(|i| gval(3, i)).collect();
        let mut b = Vec::new();
        fold::encode(&mut b, &src, WireDtype::Bf16);
        b
    };
    let pieces = [
        FoldPiece { weight: 1.0, data: PieceData::F32(&plain) },
        FoldPiece { weight: 0.5, data: PieceData::Wire(&as_f32_wire, WireDtype::F32) },
        FoldPiece { weight: 0.125, data: PieceData::Wire(&as_bf16_wire, WireDtype::Bf16) },
    ];
    let mut oracle = vec![0.0f32; len];
    fold::fold_pieces(&mut oracle, &pieces, 1);
    for threads in [2, 4, 7] {
        let mut acc = vec![0.0f32; len];
        fold::fold_pieces(&mut acc, &pieces, threads);
        for (i, (a, o)) in acc.iter().zip(&oracle).enumerate() {
            assert_eq!(a.to_bits(), o.to_bits(), "threads {threads} elem {i}");
        }
    }
}

// ---------------------------------------------------------------------
// 2 + 3. backend: bf16+EF drift, wire volume, arena accounting
// ---------------------------------------------------------------------

const WORLD: usize = 2;
const LAYERS: [usize; 2] = [600, 300];
const STEPS: usize = 20;
const MICROS: u64 = 2;

/// Drive `STEPS` full minibatches through a real `OdcComm` under `wire`
/// and return (per-step concatenated folded shards per device, hotpath
/// counters, arena counters). The push sequence is identical for every
/// dtype — only the encoding differs.
fn run_backend(wire: WireDtype) -> (Vec<Vec<Vec<f32>>>, HotpathStats, ArenaStats) {
    let params = Arc::new(ParamStore::new(&LAYERS, WORLD));
    let comm = CommStack::builder(Arc::clone(&params), WORLD)
        .wire(wire)
        .build_odc()
        .expect("in-process odc stack");
    let mut per_dev = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..WORLD)
            .map(|dev| {
                let comm = Arc::clone(&comm);
                let params = Arc::clone(&params);
                s.spawn(move || {
                    let mut out = Vec::new();
                    for step in 0..STEPS {
                        for micro in 0..MICROS {
                            for l in 0..params.n_layers() {
                                let plen = params.layers[l].padded_len();
                                let seed = dev * 10_000 + step * 100 + micro as usize * 10 + l;
                                let grad: Vec<f32> = (0..plen).map(|i| gval(seed, i)).collect();
                                comm.reduce_grad(dev, l, &grad, 0.5, step as u64 * MICROS + micro);
                            }
                        }
                        comm.end_minibatch(dev);
                        let mut shards = Vec::new();
                        for l in 0..params.n_layers() {
                            let mut sh = vec![0.0f32; params.layers[l].shard_len];
                            comm.take_grad_shard(dev, l, &mut sh);
                            shards.extend(sh);
                        }
                        comm.end_step(dev);
                        out.push(shards);
                    }
                    out
                })
            })
            .collect();
        per_dev = handles.into_iter().map(|h| h.join().unwrap()).collect();
    });
    (per_dev, comm.hotpath_stats(), comm.arena_stats())
}

#[test]
fn bf16_error_feedback_tracks_f32_oracle_over_20_steps() {
    let (f32_shards, f32_hot, _) = run_backend(WireDtype::F32);
    let (bf_shards, bf_hot, _) = run_backend(WireDtype::Bf16);
    for step in 0..STEPS {
        let oracle: Vec<f32> =
            f32_shards.iter().flat_map(|dev| dev[step].iter().copied()).collect();
        let got: Vec<f32> = bf_shards.iter().flat_map(|dev| dev[step].iter().copied()).collect();
        let d = rel_l2(&got, &oracle);
        assert!(
            d < 1e-2,
            "step {step}: bf16+EF folded shards drifted {d} rel L2 from the f32 oracle"
        );
    }
    // The error-feedback residuals bound the drift instead of letting
    // quantization bias accumulate: the LAST step must be as close as
    // the first (same order of magnitude, not a random walk).
    let first = rel_l2(
        &bf_shards.iter().flat_map(|d| d[0].iter().copied()).collect::<Vec<_>>(),
        &f32_shards.iter().flat_map(|d| d[0].iter().copied()).collect::<Vec<_>>(),
    );
    let last = rel_l2(
        &bf_shards.iter().flat_map(|d| d[STEPS - 1].iter().copied()).collect::<Vec<_>>(),
        &f32_shards.iter().flat_map(|d| d[STEPS - 1].iter().copied()).collect::<Vec<_>>(),
    );
    assert!(last < first * 10.0 + 1e-3, "EF drift grew: step0 {first} -> step19 {last}");

    // Wire volume: the acceptance bound is <=0.55x; the exact halving is
    // what the byte counters actually deliver (2 vs 4 bytes/elem over
    // identical shard ranges).
    assert!(f32_hot.wire_bytes > 0);
    assert!(
        bf_hot.wire_bytes * 100 <= f32_hot.wire_bytes * 55,
        "bf16 pushed {} of {} f32 bytes (> 0.55x)",
        bf_hot.wire_bytes,
        f32_hot.wire_bytes
    );
    assert_eq!(bf_hot.wire_bytes * 2, f32_hot.wire_bytes, "bf16 wire must be exactly half");
}

#[test]
fn arena_accounting_invariant_under_wire_dtype() {
    // Byte-sized arenas must not change the allocation discipline: the
    // identical schedule performs the identical acquire/fresh counts
    // whether payloads are 4- or 2-byte elements.
    let (_, _, f32_arena) = run_backend(WireDtype::F32);
    let (_, _, bf_arena) = run_backend(WireDtype::Bf16);
    assert_eq!(f32_arena.acquires, bf_arena.acquires, "acquire counts must match");
    assert_eq!(
        f32_arena.fresh_allocs, bf_arena.fresh_allocs,
        "fresh-alloc counts must match"
    );
    assert!(f32_arena.acquires > 0);
}

#[test]
fn f32_wire_fold_is_deterministic_across_runs() {
    // F32 wire is an exact byte image and the fold order is pinned, so
    // two identical runs produce bit-identical shards — the property
    // every equivalence/chaos/elastic suite leans on.
    let (a, _, _) = run_backend(WireDtype::F32);
    let (b, _, _) = run_backend(WireDtype::F32);
    for (dev, (da, db)) in a.iter().zip(&b).enumerate() {
        for (step, (sa, sb)) in da.iter().zip(db).enumerate() {
            for (i, (x, y)) in sa.iter().zip(sb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "dev {dev} step {step} elem {i}");
            }
        }
    }
}
