//! Property suite for SeqSplit (context-parallel straggler splitting):
//! randomized corpora and worlds through `plan_run_split` and the
//! dispatch layer, pinning the invariants the equivalence matrix in
//! `engine_equivalence.rs` relies on:
//!
//! * every chunk is planned AND dispatched exactly once (split parents
//!   leave the plan, chunks ride as singleton micros);
//! * on a dominant-sequence corpus — one sequence holding the bulk of a
//!   minibatch's tokens — splitting strictly lowers the makespan (the
//!   acceptance criterion). Fully random corpora are deliberately NOT
//!   asserted here: list scheduling is subject to Graham anomalies, so
//!   "split never hurts" is only a theorem when the unsplit makespan is
//!   pinned by the straggler itself;
//! * split plans are a pure function of (corpus, knobs, seed);
//! * a corpus with no over-budget sequence splits nothing and plans
//!   bit-identically to the seed path.
//!
//! Plus the shared-kernel regression (docs/seqsplit.md): the CLI bubble
//! line and the timeline's dispatch-wait line price splitting through
//! ONE makespan kernel (`queue_busy_split`) and may not drift.

use odc::balance::cost::CostModel;
use odc::balance::dispatch::{queue_busy_split, Dispatcher, WorkQueue};
use odc::balance::packers::{plan_run_split, PackOpts, Plan};
use odc::balance::{estimate_bubble_dispatch_split, SplitMap, SplitMode};
use odc::comm::topology::Topology;
use odc::config::{Balancer, CommScheme, PaperModel, Sharding};
use odc::sim::timeline::{seqsplit_reduce_epilogue_s, time_minibatch_dispatch_split};
use odc::util::prop::{check, vec_of};
use odc::util::rng::Rng;

const MAX_TOKENS: usize = 65_536;

fn cost() -> CostModel {
    CostModel::for_model(PaperModel::M1_5B)
}

fn split_plans(
    lens: &[usize],
    world: usize,
    minibs: usize,
    frac: f64,
    mode: SplitMode,
    seed: u64,
) -> (Vec<Plan>, SplitMap) {
    let mut rng = Rng::new(seed);
    plan_run_split(
        Balancer::Queue,
        lens,
        world,
        minibs,
        MAX_TOKENS,
        &cost(),
        &mut rng,
        PackOpts::default(),
        frac,
        mode,
    )
}

/// The canonical (id, samples) set of a plan's non-empty microbatches —
/// ids assigned in (device asc, slot asc) order over every slot, the
/// fold-key contract of `balance::dispatch`.
fn nonempty_micros(plan: &Plan) -> Vec<(u64, Vec<usize>)> {
    let mut out = Vec::new();
    let mut id = 0u64;
    for row in &plan.micro {
        for m in row {
            if !m.is_empty() {
                out.push((id, m.clone()));
            }
            id += 1;
        }
    }
    out
}

/// Every chunk planned exactly once, as a singleton micro, with its
/// parent gone — and the work queue serves exactly the plan's micros
/// (ids canonical) under any pull interleaving. Random corpora, random
/// worlds, both modes.
#[test]
fn chunks_planned_and_dispatched_exactly_once() {
    check(
        "seqsplit-exactly-once",
        60,
        |r| (vec_of(r, 1, 32, |r| r.below(60_000) as usize), r.below(1_000) as usize),
        |(raw_lens, raw)| {
            if raw_lens.is_empty() {
                return Ok(());
            }
            let lens: Vec<usize> = raw_lens.iter().map(|&v| 16 + v % 50_000).collect();
            let world = 2 + raw % 7;
            let mode = if raw % 2 == 0 { SplitMode::Ring } else { SplitMode::Zigzag };
            let (plans, split) = split_plans(&lens, world, 2, 0.4, mode, 0xA11CE);

            let mut seen = vec![0usize; lens.len() + split.n_chunks()];
            for plan in &plans {
                for row in &plan.micro {
                    for micro in row {
                        if micro.len() > 1 && micro.iter().any(|&i| split.is_chunk(i)) {
                            return Err(format!("chunk co-packed with another sample: {micro:?}"));
                        }
                        for &i in micro {
                            seen[i] += 1;
                        }
                    }
                }
            }
            let split_parents: Vec<usize> = split.iter().map(|c| c.parent).collect();
            for (i, &n) in seen.iter().enumerate() {
                let want = if split_parents.contains(&i) { 0 } else { 1 };
                if n != want {
                    return Err(format!("id {i} planned {n} times, want {want} (base {})", split.base()));
                }
            }
            // token conservation: each split parent's chunks cover it
            for &p in &split_parents {
                let toks: usize =
                    split.iter().filter(|c| c.parent == p).map(|c| c.len).sum();
                if toks != lens[p] {
                    return Err(format!("parent {p}: chunks cover {toks} of {} tokens", lens[p]));
                }
            }

            // dispatch level: the queue serves exactly the plan's
            // non-empty micros, ids canonical, each exactly once
            for plan in &plans {
                let mut want = nonempty_micros(plan);
                let q = WorkQueue::new_split(plan, &lens, &cost(), &split);
                let mut got = Vec::new();
                let mut dev = 0usize;
                while let Some(a) = q.next_micro(dev) {
                    got.push((a.id, a.samples.to_vec()));
                    dev = (dev + 1) % world;
                }
                got.sort();
                want.sort();
                if got != want {
                    return Err(format!("queue served {got:?}, plan holds {want:?}"));
                }
            }
            Ok(())
        },
    );
}

/// THE acceptance property: on a corpus where one sequence dominates
/// the minibatch (>= 40% of its tokens — here far more in cost, since
/// cost grows quadratically), splitting strictly beats not splitting,
/// for both the queue makespan (the shared kernel) and the static
/// LB-Mini bubble total, at every world >= 4 and in both modes.
#[test]
fn split_strictly_beats_unsplit_on_dominant_corpus() {
    check(
        "seqsplit-dominant-strict-improvement",
        40,
        |r| (vec_of(r, 3, 7, |r| r.below(4_096) as usize), r.below(1_000) as usize),
        |(raw_rest, raw)| {
            if raw_rest.is_empty() {
                return Ok(());
            }
            let world = 4 + raw % 5;
            let mode = if raw % 2 == 0 { SplitMode::Ring } else { SplitMode::Zigzag };
            let mut lens: Vec<usize> = raw_rest.iter().map(|&v| 256 + v % 3_584).collect();
            lens.push(MAX_TOKENS); // the dominant straggler
            let c = cost();

            let (unsplit, empty) = split_plans(&lens, world, 2, 0.0, mode, 9);
            let (splitp, map) = split_plans(&lens, world, 2, 0.5, mode, 9);
            if !empty.is_empty() {
                return Err("frac 0 must not split".into());
            }
            if map.is_empty() {
                return Err("the dominant sequence must split".into());
            }
            if unsplit.len() != 1 || splitp.len() != 1 {
                return Err("corpus must fit one minibatch".into());
            }

            let makespan = |plan: &Plan, split: &SplitMap| -> f64 {
                queue_busy_split(plan, &lens, &c, split, |f, _| f)
                    .into_iter()
                    .fold(0.0, f64::max)
            };
            let mu = makespan(&unsplit[0], &empty);
            let ms = makespan(&splitp[0], &map);
            if !(ms < mu) {
                return Err(format!("queue makespan: split {ms} !< unsplit {mu} (world {world}, {mode})"));
            }
            // unsplit can never beat the straggler's own cost; split must
            if mu < c.sample_cost(MAX_TOKENS) {
                return Err("unsplit makespan fell below the straggler cost".into());
            }
            if !(ms < c.sample_cost(MAX_TOKENS)) {
                return Err(format!("split makespan {ms} still floored by the straggler"));
            }

            // static LB-Mini story, through the bubble estimator
            let bu = estimate_bubble_dispatch_split(&unsplit[0], &lens, &c, CommScheme::Odc, &[], false, &empty);
            let bs = estimate_bubble_dispatch_split(&splitp[0], &lens, &c, CommScheme::Odc, &[], false, &map);
            if !(bs.total < bu.total) {
                return Err(format!("static total: split {} !< unsplit {}", bs.total, bu.total));
            }
            Ok(())
        },
    );
}

/// Split plans are a pure function of (corpus, world, frac, mode, seed):
/// two invocations agree bit for bit, plans and map both.
#[test]
fn split_plans_deterministic_for_fixed_seed() {
    check(
        "seqsplit-deterministic",
        40,
        |r| (vec_of(r, 1, 24, |r| r.below(60_000) as usize), r.below(1_000) as usize),
        |(raw_lens, raw)| {
            if raw_lens.is_empty() {
                return Ok(());
            }
            let lens: Vec<usize> = raw_lens.iter().map(|&v| 16 + v % 50_000).collect();
            let world = 2 + raw % 7;
            let mode = if raw % 2 == 0 { SplitMode::Ring } else { SplitMode::Zigzag };
            let a = split_plans(&lens, world, 2, 0.5, mode, 0xFEED);
            let b = split_plans(&lens, world, 2, 0.5, mode, 0xFEED);
            if a != b {
                return Err("same seed, different (plans, map)".into());
            }
            Ok(())
        },
    );
}

/// A corpus with no over-budget sequence splits nothing: empty map, and
/// the plans are BIT-identical to the seed (frac 0) path — uniform
/// minibatches whose members all sit at exactly the balanced share.
#[test]
fn no_split_when_everything_fits_budget() {
    check(
        "seqsplit-under-budget-is-seed",
        40,
        |r| (r.below(4_096) as usize, r.below(4) as usize),
        |&(len_raw, n_raw)| {
            let world = 4;
            let minibs = 2;
            let len = 64 + len_raw % 4_096;
            // full minibatches only: a partial trailing minibatch could
            // legitimately split (one sample CAN dominate a short one)
            let n = world * minibs * (1 + n_raw % 4);
            let lens = vec![len; n];
            let (with_knob, map) = split_plans(&lens, world, minibs, 0.75, SplitMode::Zigzag, 3);
            let (seed, _) = split_plans(&lens, world, minibs, 0.0, SplitMode::Zigzag, 3);
            if !map.is_empty() {
                return Err(format!("{} chunks from an under-budget corpus", map.n_chunks()));
            }
            if with_knob != seed {
                return Err("under-budget plans must be bit-identical to the seed path".into());
            }
            Ok(())
        },
    );
}

/// The shared-kernel regression (satellite of docs/seqsplit.md §sim):
/// bubble and timeline price split queue dispatch through the one
/// `queue_busy_split` kernel, so on a comm-free topology the timeline's
/// per-device busy seconds ARE the bubble kernel's FLOPs through
/// `CostModel::seconds` — and on a real topology the rendezvous
/// epilogue lands on the wall, never on per-device busy.
#[test]
fn bubble_and_timeline_agree_under_splitting() {
    let mut lens = vec![2_048usize; 7];
    lens.push(MAX_TOKENS); // dominant straggler: the split actually fires
    let c = cost();
    let world = 4;
    let (plans, split) = split_plans(&lens, world, 2, 0.5, SplitMode::Zigzag, 7);
    assert!(!split.is_empty(), "the dominant corpus must split");

    // comm-free topology: every slot is compute-bound, epilogue free
    let free = Topology {
        devices: world,
        devices_per_node: world,
        intra_bw: f64::INFINITY,
        inter_bw: f64::INFINITY,
        latency: 0.0,
    };
    for plan in &plans {
        let b = estimate_bubble_dispatch_split(plan, &lens, &c, CommScheme::Odc, &[], true, &split);
        let t = time_minibatch_dispatch_split(
            plan,
            &lens,
            PaperModel::M1_5B,
            &c,
            CommScheme::Odc,
            Sharding::Full,
            &free,
            false,
            &[],
            true,
            &split,
        );
        for (d, (&flops, &secs)) in b.busy.iter().zip(&t.busy).enumerate() {
            let want = c.seconds(flops);
            assert!(
                (secs - want).abs() <= 1e-9 * want.max(f64::MIN_POSITIVE),
                "device {d}: timeline busy {secs} vs bubble busy {want} — the kernels drifted"
            );
        }
        let want_wall = c.seconds(b.total);
        assert!(
            (t.wall - want_wall).abs() <= 1e-9 * want_wall,
            "wall {} vs bubble total {want_wall}",
            t.wall
        );
    }

    // paper topology: wall == max(busy) + epilogue EXACTLY (same floats)
    let paper = Topology::paper(world, world);
    let ep = seqsplit_reduce_epilogue_s(PaperModel::M1_5B, world, &paper, &split);
    assert!(ep > 0.0, "a split map must price a rendezvous epilogue");
    for plan in &plans {
        let t = time_minibatch_dispatch_split(
            plan,
            &lens,
            PaperModel::M1_5B,
            &c,
            CommScheme::Odc,
            Sharding::Full,
            &paper,
            false,
            &[],
            true,
            &split,
        );
        let max_busy = t.busy.iter().cloned().fold(0.0, f64::max);
        assert_eq!(t.wall, max_busy + ep, "the epilogue must land on the wall, not on busy");
    }
}
