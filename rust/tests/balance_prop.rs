//! Property tests over the balancing subsystem (`util::prop`, the
//! in-repo proptest substrate): partition exactness, planner
//! determinism, and the LB-Mini-beats-LocalSort spread guarantee the
//! paper's §5.1 relies on.

use odc::balance::cost::CostModel;
use odc::balance::kk::karmarkar_karp;
use odc::balance::packers::{plan_run, Plan};
use odc::config::{Balancer, PaperModel};
use odc::util::prop::{check, vec_of};
use odc::util::rng::Rng;

fn cost() -> CostModel {
    CostModel::for_model(PaperModel::M1_5B)
}

/// Flattened, sorted sample indices of a plan set.
fn all_placed(plans: &[Plan]) -> Vec<usize> {
    let mut v: Vec<usize> = plans.iter().flat_map(|p| p.all_samples()).collect();
    v.sort_unstable();
    v
}

/// Karmarkar–Karp emits an exact cover: every item index appears in
/// exactly one partition, for both the free and the equal-size variant.
#[test]
fn prop_kk_partitions_are_exact_covers() {
    check(
        "kk-exact-cover",
        80,
        |r| {
            let costs = vec_of(r, 0, 40, |r| r.below(100_000) + 1);
            let k = r.range(1, 9) as u64;
            (costs, k)
        },
        |(costs, k)| {
            let f: Vec<f64> = costs.iter().map(|&c| c as f64).collect();
            for eq in [false, true] {
                let parts = karmarkar_karp(&f, *k as usize, eq);
                if parts.len() != *k as usize {
                    return Err(format!("eq={eq}: {} partitions, wanted {k}", parts.len()));
                }
                let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
                all.sort_unstable();
                if all != (0..costs.len()).collect::<Vec<_>>() {
                    return Err(format!("eq={eq}: not an exact cover of 0..{}", costs.len()));
                }
            }
            Ok(())
        },
    );
}

/// Every balancer's plan set is an exact cover of the global batch:
/// each sample placed exactly once, across all minibatches, whenever the
/// batch tiles into whole minibatches (the planners drop ragged tails,
/// so shrunk inputs that no longer tile are vacuously accepted).
#[test]
fn prop_plan_run_exact_cover_all_balancers() {
    check(
        "plan-exact-cover",
        30,
        |r| {
            let world = r.range(1, 5) as u64;
            let minibs = r.range(1, 5) as u64;
            let steps = r.range(1, 4) as u64;
            let n = (world * minibs * steps) as usize;
            let lens: Vec<u64> =
                (0..n).map(|_| (r.lognormal(8.0, 1.0) as u64).clamp(16, 60_000)).collect();
            (lens, (world, minibs))
        },
        |(lens, (world, minibs))| {
            let (world, minibs) = (*world as usize, *minibs as usize);
            let per_step = world * minibs;
            if per_step == 0 || lens.is_empty() || lens.len() % per_step != 0 {
                return Ok(()); // shrunk input no longer tiles: vacuous
            }
            let lens_u: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
            let c = cost();
            for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative] {
                let mut rng = Rng::new(7);
                let plans = plan_run(b, &lens_u, world, minibs, 65_536, &c, &mut rng);
                if plans.len() != lens.len() / per_step {
                    return Err(format!("{b:?}: {} plans for {} minibatches", plans.len(), lens.len() / per_step));
                }
                if all_placed(&plans) != (0..lens.len()).collect::<Vec<_>>() {
                    return Err(format!("{b:?}: plans are not an exact cover"));
                }
            }
            Ok(())
        },
    );
}

/// `plan_run` is a pure function of (inputs, seed): two runs from the
/// same seed are identical, composition and ordering included.
#[test]
fn prop_plan_run_deterministic_under_fixed_seed() {
    check(
        "plan-deterministic",
        25,
        |r| {
            let world = r.range(2, 6) as u64;
            let minibs = r.range(1, 5) as u64;
            let n = (world * minibs * 2) as usize;
            let lens: Vec<u64> =
                (0..n).map(|_| (r.lognormal(8.0, 1.1) as u64).clamp(16, 60_000)).collect();
            (lens, (world, minibs))
        },
        |(lens, (world, minibs))| {
            let (world, minibs) = (*world as usize, *minibs as usize);
            if world == 0 || minibs == 0 || lens.is_empty() {
                return Ok(());
            }
            let lens_u: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
            let c = cost();
            for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative] {
                let a = plan_run(b, &lens_u, world, minibs, 65_536, &c, &mut Rng::new(123));
                let bp = plan_run(b, &lens_u, world, minibs, 65_536, &c, &mut Rng::new(123));
                if a.len() != bp.len()
                    || a.iter().zip(&bp).any(|(x, y)| x.micro != y.micro)
                {
                    return Err(format!("{b:?}: same seed produced different plans"));
                }
            }
            Ok(())
        },
    );
}

/// The §5.1 claim behind LB-Mini: minibatch-level KK balancing never
/// leaves a worse per-device compute-cost spread than LocalSort's
/// deal-and-sort (which does not balance at all). Compared as relative
/// spread (max-min)/max averaged over the run's minibatches, with a 2%
/// slack for heuristic ties on near-uniform inputs.
#[test]
fn prop_lb_mini_spread_never_worse_than_local_sort() {
    check(
        "lb-mini-spread",
        25,
        |r| {
            let world = 2 + 2 * r.below(2); // 2 or 4 devices
            let minibs = r.range(4, 9) as u64;
            let steps = r.range(1, 4) as u64;
            let n = (world * minibs * steps) as usize;
            let lens: Vec<u64> =
                (0..n).map(|_| (r.lognormal(8.3, 1.1) as u64).clamp(16, 60_000)).collect();
            (lens, (world, minibs))
        },
        |(lens, (world, minibs))| {
            let (world, minibs) = (*world as usize, *minibs as usize);
            let per_step = world * minibs;
            if world < 2 || minibs == 0 || lens.len() < per_step {
                return Ok(());
            }
            let lens_u: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
            let c = cost();
            let mini = plan_run(Balancer::LbMini, &lens_u, world, minibs, 65_536, &c, &mut Rng::new(9));
            let sorted = plan_run(Balancer::LocalSort, &lens_u, world, minibs, 65_536, &c, &mut Rng::new(9));
            let rel_spread = |plans: &[Plan]| -> f64 {
                plans
                    .iter()
                    .map(|p| {
                        let busy: Vec<f64> = (0..p.devices())
                            .map(|d| p.device_samples(d).map(|i| c.sample_cost(lens_u[i])).sum())
                            .collect();
                        let mx = busy.iter().cloned().fold(f64::MIN, f64::max);
                        let mn = busy.iter().cloned().fold(f64::MAX, f64::min);
                        if mx > 0.0 {
                            (mx - mn) / mx
                        } else {
                            0.0
                        }
                    })
                    .sum::<f64>()
                    / plans.len().max(1) as f64
            };
            let (sm, ss) = (rel_spread(&mini), rel_spread(&sorted));
            if sm <= ss + 0.02 {
                Ok(())
            } else {
                Err(format!("LB-Mini spread {sm:.4} worse than LocalSort {ss:.4}"))
            }
        },
    );
}
