//! Property tests over the dispatch layer (`balance::dispatch`):
//! exactly-once service under concurrent pulls, full drains under any
//! thread interleaving, the world-1 degradation to a static replay of
//! the LPT order, and the LPT-pull makespan guarantees on the sim cost
//! model.

use odc::balance::cost::CostModel;
use odc::balance::dispatch::{lpt_order, pull_makespan, Dispatcher, StaticDispatch, WorkQueue};
use odc::balance::packers::{plan_run, Plan};
use odc::config::{Balancer, PaperModel};
use odc::util::prop::check;
use odc::util::rng::Rng;

fn cost() -> CostModel {
    CostModel::for_model(PaperModel::M1_5B)
}

/// A (plan, lens) pair from the real LB-Mini packer.
fn packed_plan(lens: &[usize], world: usize, minibs: usize, seed: u64) -> Plan {
    let c = cost();
    let mut rng = Rng::new(seed);
    let mut plans = plan_run(Balancer::LbMini, lens, world, minibs, 65_536, &c, &mut rng);
    plans.remove(0)
}

/// Ids of the plan's non-empty microbatches in canonical (device asc,
/// slot asc) flattening — the fold keys a dispatcher must serve.
fn expected_ids(plan: &Plan) -> Vec<u64> {
    let mut ids = Vec::new();
    let mut id = 0u64;
    for row in &plan.micro {
        for m in row {
            if !m.is_empty() {
                ids.push(id);
            }
            id += 1;
        }
    }
    ids
}

/// Pull the queue dry from `world` concurrent threads; returns every
/// (id, samples) served, in arbitrary order.
fn drain_concurrently(q: &WorkQueue, world: usize) -> Vec<(u64, Vec<usize>)> {
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for dev in 0..world {
            handles.push(s.spawn(move || {
                let mut got = Vec::new();
                while let Some(a) = q.next_micro(dev) {
                    got.push((a.id, a.samples.to_vec()));
                    // widen the interleaving window between pulls
                    std::thread::yield_now();
                }
                got
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    })
}

/// The WorkQueue serves every non-empty microbatch of the plan exactly
/// once and drains completely, under concurrent pulls from `world`
/// threads — for any packed plan.
#[test]
fn prop_queue_serves_each_micro_exactly_once() {
    check(
        "queue-exactly-once",
        25,
        |r| {
            let world = r.range(1, 6) as u64;
            let minibs = r.range(1, 6) as u64;
            let n = (world * minibs) as usize;
            let lens: Vec<u64> =
                (0..n).map(|_| (r.lognormal(8.3, 1.1) as u64).clamp(16, 60_000)).collect();
            (lens, (world, minibs))
        },
        |(lens, (world, minibs))| {
            let (world, minibs) = (*world as usize, *minibs as usize);
            if world == 0 || minibs == 0 || lens.len() != world * minibs {
                return Ok(()); // shrunk input no longer tiles: vacuous
            }
            let lens_u: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
            let plan = packed_plan(&lens_u, world, minibs, 11);
            let q = WorkQueue::new(&plan, &lens_u, &cost());
            let served = drain_concurrently(&q, world);
            let mut ids: Vec<u64> = served.iter().map(|(id, _)| *id).collect();
            ids.sort_unstable();
            let want = {
                let mut w = expected_ids(&plan);
                w.sort_unstable();
                w
            };
            if ids != want {
                return Err(format!("served ids {ids:?} != plan ids {want:?}"));
            }
            // drained: further pulls from any device return None
            for dev in 0..world {
                if q.next_micro(dev).is_some() {
                    return Err("queue served a microbatch after draining".into());
                }
            }
            // every served sample set matches the plan's microbatch of that id
            let mut by_id: Vec<(u64, Vec<usize>)> = served;
            by_id.sort_by_key(|(id, _)| *id);
            let mut id = 0u64;
            for row in &plan.micro {
                for m in row {
                    if !m.is_empty() {
                        let got = &by_id[by_id.binary_search_by_key(&id, |(i, _)| *i).unwrap()].1;
                        if got != m {
                            return Err(format!("id {id}: served {got:?}, plan has {m:?}"));
                        }
                    }
                    id += 1;
                }
            }
            Ok(())
        },
    );
}

/// Repeated threaded drains agree with a single-threaded drain on the
/// SET of (id, samples) served — the queue's service is interleaving-
/// independent (the stress analogue of the engine's bit-identity).
#[test]
fn queue_drains_identically_under_any_interleaving() {
    let mut rng = Rng::new(77);
    let lens: Vec<usize> = (0..24).map(|_| (rng.lognormal(8.5, 1.2) as usize).clamp(16, 60_000)).collect();
    let plan = packed_plan(&lens, 4, 6, 5);
    let c = cost();
    let solo = {
        let q = WorkQueue::new(&plan, &lens, &c);
        let mut got = Vec::new();
        while let Some(a) = q.next_micro(0) {
            got.push((a.id, a.samples.to_vec()));
        }
        got
    };
    for trial in 0..8 {
        let q = WorkQueue::new(&plan, &lens, &c);
        let mut served = drain_concurrently(&q, 4);
        served.sort_by_key(|(id, _)| *id);
        let mut want = solo.clone();
        want.sort_by_key(|(id, _)| *id);
        assert_eq!(served, want, "trial {trial}");
    }
}

/// At world 1 the queue degrades to a static replay: a single device
/// pulls exactly the LPT order, which equals `StaticDispatch` over the
/// one-device plan built from that order.
#[test]
fn queue_world1_degrades_to_static_order() {
    let mut rng = Rng::new(31);
    let lens: Vec<usize> = (0..12).map(|_| (rng.lognormal(8.2, 1.0) as usize).clamp(16, 60_000)).collect();
    let plan = packed_plan(&lens, 3, 4, 9);
    let c = cost();
    let q = WorkQueue::new(&plan, &lens, &c);
    let canonical = Plan { micro: vec![q.pull_order()] };
    let stat = StaticDispatch::new(&canonical, false);
    loop {
        let (a, b) = (q.next_micro(0), stat.next_micro(0));
        match (a, b) {
            (None, None) => break,
            (Some(x), Some(y)) => {
                assert_eq!(&x.samples[..], &y.samples[..], "pull order must equal the static replay");
            }
            (x, y) => panic!("queue and static drained at different lengths: {x:?} vs {y:?}"),
        }
    }
    // and the LPT order really is cost-descending
    let order = lpt_order(&plan, &lens, &c);
    let costs: Vec<f64> = order
        .iter()
        .map(|&(d, m)| plan.micro[d][m].iter().map(|&i| c.sample_cost(lens[i])).sum())
        .collect();
    assert!(costs.windows(2).all(|w| w[0] >= w[1]), "not LPT-sorted: {costs:?}");
}

/// Static dispatch serves each device exactly its plan row, in slot
/// order, and pads every device to the common count when asked to
/// (the Collective barrier contract).
#[test]
fn static_dispatch_row_semantics() {
    let mut rng = Rng::new(13);
    let lens: Vec<usize> = (0..16).map(|_| (rng.lognormal(8.0, 1.1) as usize).clamp(16, 60_000)).collect();
    let plan = packed_plan(&lens, 4, 4, 21);
    for pad in [false, true] {
        let d = StaticDispatch::new(&plan, pad);
        for (dev, row) in plan.micro.iter().enumerate() {
            let mut served = Vec::new();
            while let Some(a) = d.next_micro(dev) {
                served.push(a.samples.to_vec());
            }
            if pad {
                assert_eq!(served.len(), plan.max_micro_count(), "dev {dev} padded to common count");
                assert!(served[row.len()..].iter().all(|m| m.is_empty()));
            } else {
                assert_eq!(served.len(), row.len(), "dev {dev}");
            }
            assert_eq!(&served[..row.len()], &row[..], "dev {dev} row replayed in order");
        }
    }
}

/// Makespan of a pull order under greedy list scheduling. LPT obeys the
/// provable any-order bound AND (comparatively) never loses to random
/// pull order by more than noise — on skewed instances it wins outright.
#[test]
fn prop_lpt_pull_makespan_bounds() {
    check(
        "lpt-makespan",
        40,
        |r| {
            let world = r.range(2, 5) as u64;
            let n = (world * r.range(3, 7) as u64) as usize;
            // heavy-tailed micro costs: the regime dynamic dispatch targets
            let costs: Vec<u64> = (0..n).map(|_| (r.lognormal(3.0, 1.2) as u64).clamp(1, 100_000)).collect();
            (costs, world)
        },
        |(costs, world)| {
            let m = *world as usize;
            if m < 2 || costs.is_empty() {
                return Ok(());
            }
            let f: Vec<f64> = costs.iter().map(|&c| c as f64).collect();
            let total: f64 = f.iter().sum();
            let max: f64 = f.iter().cloned().fold(0.0, f64::max);
            let mut lpt = f.clone();
            lpt.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let lpt_span = pull_makespan(&lpt, m, &[]);
            // provable greedy bound (any order): T <= total/m + (1-1/m)·max
            let bound = total / m as f64 + (1.0 - 1.0 / m as f64) * max;
            if lpt_span > bound * (1.0 + 1e-12) {
                return Err(format!("LPT {lpt_span} above the greedy bound {bound}"));
            }
            // comparative: LPT does not lose to random pulls (mean of 6)
            let mut rng = Rng::new(costs.iter().sum::<u64>() ^ 0xD15);
            let mut rand_sum = 0.0;
            let trials = 6;
            for _ in 0..trials {
                let mut shuffled = f.clone();
                rng.shuffle(&mut shuffled);
                rand_sum += pull_makespan(&shuffled, m, &[]);
            }
            let rand_mean = rand_sum / trials as f64;
            if lpt_span > rand_mean * 1.02 {
                return Err(format!("LPT {lpt_span} worse than mean random pull {rand_mean}"));
            }
            Ok(())
        },
    );
}

/// Hand-verified skewed instances where LPT strictly beats bad pull
/// orders (a dominant job must start first or the tail pays for it).
#[test]
fn lpt_strictly_beats_adverse_orders_on_skew() {
    // jobs {8,1,1,1,1,1,1} on 2 devices: LPT = 8 (optimal); serving the
    // 8 last lands it on a device already 3 deep => 11.
    let lpt = [8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
    let worst = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 8.0];
    assert_eq!(pull_makespan(&lpt, 2, &[]), 8.0);
    assert_eq!(pull_makespan(&worst, 2, &[]), 11.0);
    // {10, 3×6} on 3 devices: LPT = 10 (optimal); 10 last => 16.
    let lpt3 = [10.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0];
    let worst3 = [3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 10.0];
    assert_eq!(pull_makespan(&lpt3, 3, &[]), 10.0);
    assert_eq!(pull_makespan(&worst3, 3, &[]), 16.0);
}

/// With a straggler in the fleet, the pull simulation routes load away
/// from it: makespan under LPT pulls with speeds [0.25, 1, 1, 1] stays
/// close to the fast devices' fair share instead of 4× the straggler's.
#[test]
fn pull_simulation_absorbs_straggler() {
    // 16 unit jobs, 4 devices, one at quarter speed. A static even deal
    // (4 each) costs max(4·4, 4) = 16; greedy pulls halve it: the
    // straggler takes the tie-broken first job (busy till 4) and one
    // more at the 4-way tie (till 8) while the fast three absorb the
    // other 14 — hand-traced makespan exactly 8.
    let jobs = vec![1.0f64; 16];
    let speeds = [0.25, 1.0, 1.0, 1.0];
    let span = pull_makespan(&jobs, 4, &speeds);
    assert_eq!(span, 8.0, "greedy pulls under the straggler");
    // and never below the theoretical optimum total/(Σspeed)
    let opt = 16.0 / (0.25 + 3.0);
    assert!(span >= opt - 1e-9, "span {span} below optimum {opt}?");
}
