//! AsyncPS property suite: the bounded-staleness parameter-server tier
//! against the synchronous ODC engine it generalizes.
//!
//! Three claims, matching `docs/asyncps.md`:
//!
//! 1. **k = 0 is the synchronous engine, bit for bit.** With the
//!    admission gate at zero the shard servers still run the optimizer
//!    (the async machinery is fully engaged), but every worker waits
//!    for every apply before re-pulling — same fold order (sorted by
//!    (micro, client) per layer), same update order, same bytes. Pinned
//!    across Queue × {inproc, shm, uds}: assert_eq, no tolerance.
//! 2. **The staleness bound is an invariant, not a hint.** Under a 4×
//!    straggler with `k = 2`, every admission observes parameters at
//!    most 2 applies behind — `staleness_max ≤ k` by construction, and
//!    the run still completes every step.
//! 3. **Bounded staleness still trains.** `k = 2` descends on the tiny
//!    preset and lands near the synchronous trajectory — async is a
//!    throughput knob, not a different optimization problem.

use odc::comm::TransportKind;
use odc::config::{Balancer, CommScheme};
use odc::engine::trainer::{train, TrainRun, TrainerConfig};
use std::path::{Path, PathBuf};

fn tiny_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have_artifacts() -> bool {
    tiny_dir().join("manifest.json").exists()
}

fn base_cfg() -> TrainerConfig {
    let mut c = TrainerConfig::new(tiny_dir());
    c.world = 2;
    c.minibs = 2;
    c.steps = 2;
    c.seed = 42;
    c.scheme = CommScheme::Odc;
    c.balancer = Balancer::Queue;
    c
}

/// Run the trainer, treating the in-tree PJRT stub as a skip — the
/// documented contract: artifact-gated tests stay green until the real
/// `xla` crate is wired in. Any other failure is a hard error.
fn try_train(cfg: &TrainerConfig) -> Option<TrainRun> {
    match train(cfg) {
        Ok(r) => Some(r),
        Err(e) if format!("{e:#}").contains("PJRT backend unavailable") => {
            eprintln!("skipping: {e:#}");
            None
        }
        Err(e) => panic!("training run: {e:#}"),
    }
}

/// THE AsyncPS acceptance case: `--staleness 0` swaps in the whole
/// parameter-server tier (shard-server daemons running the optimizer,
/// admission gates, version clock) and must not move a single bit
/// relative to the synchronous backend — on the typed in-process
/// transport AND over real bytes (shm ring, unix sockets).
#[test]
fn staleness_zero_bit_identical_to_sync_odc_across_transports() {
    if !have_artifacts() {
        return;
    }
    for kind in [TransportKind::Inproc, TransportKind::Shm, TransportKind::Uds] {
        let mut sync_cfg = base_cfg();
        sync_cfg.transport = kind;
        let mut async_cfg = sync_cfg.clone();
        async_cfg.staleness = Some(0);
        let (Some(s), Some(a)) = (try_train(&sync_cfg), try_train(&async_cfg)) else { return };
        for (x, y) in s.logs.iter().zip(&a.logs) {
            assert_eq!(x.tokens, y.tokens, "{kind:?} step {}", x.step);
            assert_eq!(
                x.loss, y.loss,
                "{kind:?} step {}: k=0 loss must be bit-identical to sync",
                x.step
            );
        }
        for (l, (ps, pa)) in s.final_params.iter().zip(&a.final_params).enumerate() {
            assert_eq!(ps, pa, "{kind:?} layer {l}: k=0 params must be bit-identical to sync");
        }
        assert_eq!(a.staleness_max, 0, "{kind:?}: k=0 admissions can never observe staleness");
        assert_eq!(a.staleness_p99, 0, "{kind:?}: k=0 admissions can never observe staleness");
        assert_eq!(s.staleness_max, 0, "{kind:?}: a sync run reports no staleness");
    }
}

/// The bound is enforced at admission, so no schedule — not even a 4×
/// straggler racing ahead of the slow device's quorum — can observe
/// parameters more than `k` applies old.
#[test]
fn staleness_bound_holds_under_straggler() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.steps = 4;
    c.staleness = Some(2);
    c.device_speed = vec![0.25, 1.0]; // device 0 is a 4× straggler
    let Some(r) = try_train(&c) else { return };
    assert_eq!(r.logs.len(), 4, "all steps must complete under async admission");
    assert!(
        r.staleness_max <= 2,
        "observed staleness {} exceeds the configured bound k=2",
        r.staleness_max
    );
    assert!(r.staleness_p99 <= r.staleness_max, "p99 cannot exceed the max");
}

/// Convergence ablation: a `k = 2` run descends and lands near the
/// synchronous trajectory. The trajectories are NOT bit-comparable
/// (that is the point of admitting stale parameters), so the assertion
/// is about optimization health, not bits.
#[test]
fn staleness_two_still_converges_near_sync() {
    if !have_artifacts() {
        return;
    }
    let mut sync_cfg = base_cfg();
    sync_cfg.steps = 4;
    sync_cfg.adam.lr = 3e-3;
    let mut async_cfg = sync_cfg.clone();
    async_cfg.staleness = Some(2);
    let (Some(s), Some(a)) = (try_train(&sync_cfg), try_train(&async_cfg)) else { return };
    let a_first = a.logs.first().unwrap().loss;
    let a_last = a.logs.last().unwrap().loss;
    let s_last = s.logs.last().unwrap().loss;
    assert!(a_last < a_first, "async loss should descend: {a_first} -> {a_last}");
    assert!(
        (a_last - s_last).abs() < 0.1 * s_last.abs().max(1.0),
        "k=2 final loss {a_last} strayed from the sync trajectory {s_last}"
    );
}

/// `k = 0` is also deterministic across runs (the property every other
/// equivalence suite leans on): the admission gate serializes applies,
/// and the per-layer fold is keyed, not arrival-ordered.
#[test]
fn staleness_zero_deterministic_across_runs() {
    if !have_artifacts() {
        return;
    }
    let mut c = base_cfg();
    c.staleness = Some(0);
    c.device_speed = vec![1.0, 0.25];
    let (Some(a), Some(b)) = (try_train(&c), try_train(&c)) else { return };
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.loss, y.loss, "step {}", x.step);
    }
    for (l, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa, pb, "layer {l}");
    }
}

/// The legality matrix runs before artifacts are touched, so these hold
/// even without `make artifacts` (the bugfix this PR pins: contradictory
/// combos must die in validation, not at artifact load or mid-run).
#[test]
fn staleness_rejected_in_illegal_combinations() {
    // Collective has no admission gate to bound — its barriers ARE the
    // synchronization.
    let mut c = base_cfg();
    c.scheme = CommScheme::Collective;
    c.balancer = Balancer::LbMicro;
    c.staleness = Some(1);
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("barrier-free"), "unexpected error: {err}");

    // Hybrid's two-tier fold has no single apply clock per shard.
    let mut h = base_cfg();
    h.scheme = CommScheme::Hybrid;
    h.staleness = Some(1);
    let err = train(&h).unwrap_err().to_string();
    assert!(err.contains("requires the odc scheme"), "unexpected error: {err}");

    // Synchronized-k balancers assume the barrier the tier removes.
    let mut b = base_cfg();
    b.balancer = Balancer::LbMicro;
    b.staleness = Some(1);
    let err = train(&b).unwrap_err().to_string();
    assert!(err.contains("LB-Mini or Queue"), "unexpected error: {err}");

    // Elastic membership would race the version clock.
    let mut f = base_cfg();
    f.staleness = Some(1);
    f.fail_at = vec![(0, 1, 0)];
    let err = train(&f).unwrap_err().to_string();
    assert!(err.contains("static membership"), "unexpected error: {err}");

    // The PJRT shard-op path batches applies in the synchronous phase.
    let mut p = base_cfg();
    p.staleness = Some(0);
    p.pjrt_shard_ops = true;
    let err = train(&p).unwrap_err().to_string();
    assert!(err.contains("synchronous optimizer phase"), "unexpected error: {err}");
}
