//! ChaosComm property test: lossy transport under Queue × {ODC, Hybrid}.
//!
//! Runs the synthetic elastic workload over a [`FaultyTransport`] that
//! drops, duplicates, reorders and delays messages on every link, and
//! asserts the hardening contract end to end (no PJRT, no artifacts —
//! this suite always runs):
//!
//! * **bit-identity under transient loss** — with drop ≥ 5% plus
//!   duplication and reordering on every link, each step's folded
//!   gradient equals the sequential oracle EXACTLY (grads are distinct
//!   powers of two, so any double/dropped delivery flips a bit);
//! * **exactly-once** — every microbatch of every minibatch executes
//!   exactly once despite retransmissions and duplicate deliveries;
//! * **arena hygiene** (ODC) — push-level acquire counts stay exact
//!   (a retransmit re-sends the same buffer, it never re-acquires) and
//!   arena growth stays inside the step-count-independent in-flight
//!   bound;
//! * **deterministic replay** — a fixed fault-plan seed under static
//!   dispatch reproduces the exact fault counters run over run (the
//!   determinism scope documented in `docs/faults.md`);
//! * **escalation** — a fully partitioned link past the retry budget
//!   escalates its src into the EXISTING ElasticWorld machinery
//!   (retract → report_failed → successor takeover → orphan re-pull)
//!   and the run still completes bit-identical with
//!   `fault_stats().escalations ≥ 1`;
//! * **InProc equivalence** — the trait-wrapped in-process transport
//!   with an empty plan behaves exactly like the plain constructors
//!   (same oracle folds, zero fault counters);
//! * **chaos over real bytes** — the same soak layered over the
//!   shared-memory ring (`FaultyTransport::over(RingTransport)`), so
//!   encode → fault-inject → ring → decode hardening is proven on a
//!   transport that actually moves bytes, not pointers.

use odc::balance::cost::CostModel;
use odc::balance::dispatch::{make_elastic_dispatcher, Dispatcher};
use odc::balance::packers::Plan;
use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::{
    ArenaStats, CommStack, FaultPlan, FaultStats, Membership, OdcComm, RetryPolicy,
    TransportKind,
};
use odc::config::{Balancer, CommScheme, PaperModel, WireDtype};
use std::sync::{Arc, Mutex};

/// Two layers, lengths chosen so padding differs across world sizes.
const LAYERS: [usize; 2] = [12, 7];
const MICROS_PER_DEV: usize = 3;

/// Singleton microbatches with strictly decreasing cost, so the LPT
/// pull order is deterministic and ids are distinct.
fn make_plan(world: usize) -> (Plan, Vec<usize>) {
    let n = world * MICROS_PER_DEV;
    let lens: Vec<usize> = (0..n).map(|i| 4000 - 100 * i).collect();
    let micro: Vec<Vec<Vec<usize>>> = (0..world)
        .map(|d| (0..MICROS_PER_DEV).map(|m| vec![d * MICROS_PER_DEV + m]).collect())
        .collect();
    (Plan { micro }, lens)
}

/// A chaos plan with every transient fault class active on every link.
fn chaos_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        drop: 0.08,
        dup: 0.05,
        reorder: 0.10,
        delay: 0.05,
        seed,
        partition: Vec::new(),
    }
}

struct TrialOutcome {
    /// ids executed (and not retracted) per step, any order.
    executed: Vec<Vec<u64>>,
    arena: Option<ArenaStats>,
    stats: FaultStats,
}

/// Drive `steps` minibatches of the synthetic workload with
/// trainer-faithful escalation handling: after each microbatch and after
/// `end_minibatch`, a device whose link escalated reports itself failed
/// and vanishes — the backend has already retracted the in-flight micro,
/// so a survivor re-runs it (the id is recorded only when it stuck).
/// Every shard owner asserts the exact oracle fold in-line.
fn run_chaos(
    scheme: CommScheme,
    group_size: usize,
    kind: TransportKind,
    world: usize,
    membership: Arc<Membership>,
    balancer: Balancer,
    plan: Option<FaultPlan>,
    steps: usize,
) -> TrialOutcome {
    let params = Arc::new(ParamStore::new(&LAYERS, world));
    // `CommStack` builds the base transport for `kind` and layers
    // `FaultyTransport::over` on top when a plan is given — the exact
    // construction path the trainer uses, so the soak covers it too.
    let mut stack = CommStack::builder(Arc::clone(&params), world)
        .membership(Arc::clone(&membership))
        .wire(WireDtype::F32)
        .transport(kind);
    if let Some(p) = plan {
        stack = stack.faults(p, RetryPolicy::default());
    }
    let (backend, odc_handle): (Arc<dyn CommBackend>, Option<Arc<OdcComm>>) = match scheme {
        CommScheme::Odc => {
            let c = stack.build_odc().expect("transport binds");
            (Arc::clone(&c) as Arc<dyn CommBackend>, Some(c))
        }
        CommScheme::Hybrid => (
            stack.groups(group_size).build_hybrid().expect("transport binds")
                as Arc<dyn CommBackend>,
            None,
        ),
        CommScheme::Collective => unreachable!("chaos × Collective is rejected at config time"),
    };
    let (plan, lens) = make_plan(world);
    let cost = CostModel::for_model(PaperModel::M1_5B);
    let n_micros = (world * MICROS_PER_DEV) as u64;
    // every micro pushes 2^id: the full fold is exactly 2^n - 1
    let want = ((1u64 << n_micros) - 1) as f32;
    let executed: Arc<Vec<Mutex<Vec<u64>>>> =
        Arc::new((0..steps).map(|_| Mutex::new(Vec::new())).collect());
    let dispatchers: Vec<Arc<dyn Dispatcher>> = (0..steps)
        .map(|step| {
            let crasher: Vec<bool> = (0..world).map(|d| membership.fails_during(d, step)).collect();
            let absent: Vec<bool> = (0..world).map(|d| membership.absent(d, step)).collect();
            make_elastic_dispatcher(balancer, scheme, &plan, &lens, &cost, &crasher, &absent)
        })
        .collect();
    let dispatchers = Arc::new(dispatchers);

    std::thread::scope(|s| {
        for dev in 0..world {
            let backend = Arc::clone(&backend);
            let params = Arc::clone(&params);
            let membership = Arc::clone(&membership);
            let executed = Arc::clone(&executed);
            let dispatchers = Arc::clone(&dispatchers);
            s.spawn(move || {
                let join = membership.joins_at(dev);
                if join > 0 {
                    backend.await_join(dev);
                }
                for step in join..steps {
                    let disp = dispatchers[step].as_ref();
                    let mut crashed = false;
                    while let Some(a) = disp.next_micro(dev) {
                        for (l, p) in params.layers.iter().enumerate() {
                            let grad = vec![(1u64 << a.id) as f32; p.padded_len()];
                            backend.reduce_grad(dev, l, &grad, 1.0, a.id);
                        }
                        // Trainer-faithful escalation: the backend has
                        // already retracted this micro's delivered
                        // pieces, so it re-runs on a survivor — record
                        // the id only when it stuck.
                        if backend.link_escalated(dev) {
                            disp.report_failed(dev);
                            crashed = true;
                            break;
                        }
                        executed[step].lock().unwrap().push(a.id);
                    }
                    if crashed {
                        return; // escalation: the worker vanishes
                    }
                    backend.end_minibatch(dev);
                    if backend.link_escalated(dev) {
                        // Link died during the Done broadcast: no grads
                        // were taken, bail before the optimizer phase.
                        disp.report_failed(dev);
                        return;
                    }
                    for &shard in &membership.shards_owned_by(dev, step) {
                        if shard != dev {
                            backend.flush_shard(shard);
                        }
                        for (l, p) in params.layers.iter().enumerate() {
                            let mut g = vec![0.0f32; p.shard_len];
                            backend.take_grad_shard(shard, l, &mut g);
                            for &v in &g {
                                assert_eq!(
                                    v, want,
                                    "step {step} shard {shard} layer {l}: fold != oracle"
                                );
                            }
                        }
                    }
                    backend.end_step(dev);
                }
            });
        }
    });

    TrialOutcome {
        executed: executed.iter().map(|m| m.lock().unwrap().clone()).collect(),
        arena: odc_handle.as_ref().map(|c| c.arena_stats()),
        stats: backend.fault_stats(),
    }
}

fn assert_exactly_once(outcome: &TrialOutcome, world: usize, steps: usize) {
    let n = (world * MICROS_PER_DEV) as u64;
    for (step, ids) in outcome.executed.iter().enumerate() {
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        let want: Vec<u64> = (0..n).collect();
        assert_eq!(sorted, want, "step {step}: every microbatch must run exactly once");
    }
    assert_eq!(outcome.executed.len(), steps);
}

#[test]
fn transient_chaos_bit_identical_odc() {
    let world = 4;
    let steps = 4;
    for seed in [0xC0FFEEu64, 7, 0xA5A5] {
        let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
        let outcome = run_chaos(
            CommScheme::Odc,
            0,
            TransportKind::Inproc,
            world,
            membership,
            Balancer::Queue,
            Some(chaos_plan(seed)),
            steps,
        );
        // The in-line fold asserts already proved bit-identity to the
        // oracle; here: exactly-once, retransmissions happened, nothing
        // escalated, and the arena stayed inside its in-flight bound.
        assert_exactly_once(&outcome, world, steps);
        assert!(outcome.stats.retries > 0, "seed {seed:#x}: an 8% drop rate must retransmit");
        assert!(outcome.stats.retransmitted_bytes > 0);
        assert_eq!(outcome.stats.escalations, 0, "transient loss must never escalate");

        let stats = outcome.arena.expect("odc arena stats");
        // Push-level exactly-once: retransmits re-send, they never
        // re-acquire — each executed micro acquires exactly
        // world × layers buffers, once.
        let pushes = (steps * world * MICROS_PER_DEV * LAYERS.len() * world) as u64;
        assert_eq!(stats.acquires, pushes, "seed {seed:#x}: double or dropped pushes");
        // Growth bound independent of the step count: duplicates return
        // clones to the free list, but fresh misses stay capped by one
        // minibatch's in-flight maximum.
        let bound = (world * world * (world * MICROS_PER_DEV) * LAYERS.len()) as u64;
        assert!(
            stats.fresh_allocs <= bound,
            "seed {seed:#x}: arena growth {} exceeds in-flight bound {bound}",
            stats.fresh_allocs
        );
    }
}

#[test]
fn transient_chaos_bit_identical_hybrid() {
    let world = 4;
    let steps = 4;
    let mut seed = 0xB0B0u64;
    for group_size in [2usize, 4, 1] {
        seed += 1;
        let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
        let outcome = run_chaos(
            CommScheme::Hybrid,
            group_size,
            TransportKind::Inproc,
            world,
            membership,
            Balancer::Queue,
            Some(chaos_plan(seed)),
            steps,
        );
        assert_exactly_once(&outcome, world, steps);
        assert!(outcome.stats.retries > 0, "group {group_size}: drop must retransmit");
        assert_eq!(outcome.stats.escalations, 0);
    }
}

#[test]
fn fixed_seed_replays_exact_fault_counters() {
    // Determinism scope (docs/faults.md): per-link fault decisions are a
    // pure function of (plan seed, link, message sequence). Static
    // dispatch fixes every device's pull order, so two runs replay the
    // exact same counters bit for bit.
    let world = 4;
    let steps = 3;
    let run = || {
        let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
        run_chaos(
            CommScheme::Odc,
            0,
            TransportKind::Inproc,
            world,
            membership,
            Balancer::LbMini,
            Some(chaos_plan(0xD00D)),
            steps,
        )
    };
    let a = run();
    let b = run();
    assert_exactly_once(&a, world, steps);
    assert_eq!(a.stats, b.stats, "fixed seed must replay identical fault counters");
    assert!(a.stats.retries > 0);
}

#[test]
fn partitioned_link_escalates_into_elastic_takeover() {
    // A fully partitioned link (src 0 → dst 2 from step 1) exhausts the
    // retry budget at its first touch: device 0 retracts its in-flight
    // micro, reports itself failed, the ring successor adopts its shard
    // and survivors re-pull the orphans. The fold stays exact and the
    // transport records the escalation.
    let world = 4;
    let fail_step = 1;
    let steps = fail_step + 4; // several post-recovery minibatches
    for seed in [3u64, 0xE5C4] {
        let plan = FaultPlan {
            drop: 0.05,
            dup: 0.02,
            reorder: 0.05,
            delay: 0.0,
            seed,
            partition: vec![(0, 2, fail_step)],
        };
        let membership =
            Arc::new(Membership::with_schedule(world, &[], &[(0, fail_step)]).unwrap());
        let outcome = run_chaos(
            CommScheme::Odc,
            0,
            TransportKind::Inproc,
            world,
            membership,
            Balancer::Queue,
            Some(plan),
            steps,
        );
        assert_exactly_once(&outcome, world, steps);
        assert!(
            outcome.stats.escalations >= 1,
            "seed {seed:#x}: the partitioned link must escalate"
        );
    }
}

#[test]
fn inproc_transport_with_empty_plan_matches_plain_backends() {
    // The trait seam is free: an empty plan routes through
    // InProcTransport and behaves exactly like the pre-transport
    // constructors — same oracle folds (asserted in-line by both runs),
    // same executed sets, zero fault counters on both sides.
    let world = 4;
    let steps = 3;
    let run = |plan: Option<FaultPlan>| {
        let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
        run_chaos(CommScheme::Odc, 0, TransportKind::Inproc, world, membership, Balancer::LbMini, plan, steps)
    };
    let plain = run(None);
    let wrapped = run(Some(FaultPlan::default()));
    assert_exactly_once(&plain, world, steps);
    assert_exactly_once(&wrapped, world, steps);
    for step in 0..steps {
        let mut a = plain.executed[step].clone();
        let mut b = wrapped.executed[step].clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "step {step}: empty plan must not change the schedule");
    }
    assert_eq!(plain.stats, FaultStats::default());
    assert_eq!(wrapped.stats, FaultStats::default());
    assert_eq!(
        plain.arena.unwrap().acquires,
        wrapped.arena.unwrap().acquires,
        "the transport seam must not change push accounting"
    );
}

#[test]
fn transient_chaos_bit_identical_over_ring() {
    // The WireComm soak: the SAME chaos plan layered over the
    // shared-memory ring, so the fault machinery exercises real encoded
    // bytes — retransmits replay the encoded envelope, the ring
    // fragments/reassembles it, and the decoded fold still equals the
    // oracle bit for bit (asserted in-line by run_chaos).
    let world = 4;
    let steps = 4;
    let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
    let outcome = run_chaos(
        CommScheme::Odc,
        0,
        TransportKind::Shm,
        world,
        membership,
        Balancer::Queue,
        Some(chaos_plan(0x51C5)),
        steps,
    );
    assert_exactly_once(&outcome, world, steps);
    assert!(outcome.stats.retries > 0, "an 8% drop rate must retransmit over the ring");
    assert_eq!(outcome.stats.escalations, 0, "transient loss must never escalate");
    // The arena contracts are transport-independent: acquires count
    // reduce_grad calls (exactly once per executed push) and growth
    // stays inside the in-flight bound even though the ring copies
    // bytes instead of moving pointers.
    let stats = outcome.arena.expect("odc arena stats");
    let pushes = (steps * world * MICROS_PER_DEV * LAYERS.len() * world) as u64;
    assert_eq!(stats.acquires, pushes, "double or dropped pushes over the ring");
    let bound = (world * world * (world * MICROS_PER_DEV) * LAYERS.len()) as u64;
    assert!(
        stats.fresh_allocs <= bound,
        "arena growth {} exceeds in-flight bound {bound} over the ring",
        stats.fresh_allocs
    );
}

#[test]
fn hybrid_chaos_over_ring_stays_exact() {
    // Two-level traffic (intra fold + cross exchange) over the ring
    // under the full transient fault mix.
    let world = 4;
    let steps = 3;
    let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
    let outcome = run_chaos(
        CommScheme::Hybrid,
        2,
        TransportKind::Shm,
        world,
        membership,
        Balancer::Queue,
        Some(chaos_plan(0x716E)),
        steps,
    );
    assert_exactly_once(&outcome, world, steps);
    assert!(outcome.stats.retries > 0);
    assert_eq!(outcome.stats.escalations, 0);
}

#[test]
fn ring_with_empty_plan_matches_inproc_schedule() {
    // The byte transport itself must be invisible: an empty fault plan
    // over the ring executes the same schedule as inproc with zero
    // fault counters (the folds are oracle-asserted in-line).
    let world = 4;
    let steps = 3;
    let run = |kind: TransportKind| {
        let membership = Arc::new(Membership::with_schedule(world, &[], &[]).unwrap());
        run_chaos(CommScheme::Odc, 0, kind, world, membership, Balancer::LbMini, None, steps)
    };
    let inproc = run(TransportKind::Inproc);
    let ring = run(TransportKind::Shm);
    assert_exactly_once(&inproc, world, steps);
    assert_exactly_once(&ring, world, steps);
    assert_eq!(ring.stats, FaultStats::default(), "a clean ring must count no faults");
    assert_eq!(
        inproc.arena.unwrap().acquires,
        ring.arena.unwrap().acquires,
        "the ring must not change push accounting"
    );
}
