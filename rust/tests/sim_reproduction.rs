//! Integration: simulator reproduces the paper's qualitative results
//! across the full evaluation grid (the shapes of Tables 3–6, Figs 8–10).

use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel};
use odc::sim::parametric::{acceleration_ratio, sweep, Factor};
use odc::sim::run::simulate_cell;

const STEPS: usize = 8;
const SEED: u64 = 5;

fn cell(model: PaperModel, ds: Dataset, scheme: CommScheme, bal: Balancer, minibs: usize) -> f64 {
    let devices = ExperimentConfig::paper_devices(model);
    simulate_cell(model, ds, scheme, bal, minibs, devices, STEPS, SEED).samples_per_sec_per_device
}

#[test]
fn sft_odc_wins_across_models_and_datasets() {
    // Fig 8 / Table 5 headline: ODC >= Collective with packing at minibs 4.
    for model in [PaperModel::M1_5B, PaperModel::M7B] {
        for ds in [Dataset::LongAlign, Dataset::SweSmith] {
            let col = cell(model, ds, CommScheme::Collective, Balancer::LbMicro, 4);
            let odc = cell(model, ds, CommScheme::Odc, Balancer::LbMicro, 4);
            assert!(odc > col * 0.99, "{model} {ds}: odc {odc} vs col {col}");
        }
    }
}

#[test]
fn speedup_magnitude_in_paper_range() {
    // Paper reports up to ~36% SFT speedups; our simulator should land
    // gains in a comparable band (3%..90%) rather than 0% or 10x.
    let col = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LbMicro, 4);
    let odc = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMini, 4);
    let speedup = odc / col - 1.0;
    assert!((0.03..0.9).contains(&speedup), "speedup {speedup} out of plausible band");
}

#[test]
fn rl_gains_smaller_than_sft() {
    // §5.2: RL gains (~10%) are less pronounced than SFT (~36%).
    let sft_col = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LbMicro, 4);
    let sft_odc = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMini, 4);
    let rl_col = cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Collective, Balancer::LbMicro, 4);
    let rl_odc = cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Odc, Balancer::LbMini, 4);
    let sft_gain = sft_odc / sft_col;
    let rl_gain = rl_odc / rl_col;
    assert!(sft_gain > rl_gain, "SFT gain {sft_gain} should exceed RL gain {rl_gain}");
}

#[test]
fn throughput_decreases_with_model_size() {
    // absolute samples/s/device ordering across scales (Table 5 rows)
    let t15 = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMicro, 4);
    let t7 = cell(PaperModel::M7B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMicro, 4);
    let t14 = cell(PaperModel::M14B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMicro, 4);
    assert!(t15 > t7 && t7 > t14, "{t15} {t7} {t14}");
}

#[test]
fn localsort_slower_than_packing() {
    let ls = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LocalSort, 8);
    let lb = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LbMicro, 8);
    assert!(lb > ls, "packing {lb} should beat unpacked {ls}");
}

#[test]
fn parametric_factors_move_in_paper_direction() {
    // Fig 10, all four panels in one pass (coarse grids for test speed).
    let mb = sweep(Factor::MinibatchSize, &[1.0, 4.0], 6, SEED);
    assert!(mb[1].ratio >= mb[0].ratio - 0.02, "ratio should rise from minibs 1 to 4");

    let ml = sweep(Factor::MaxLength, &[8_192.0, 65_536.0], 6, SEED);
    assert!(ml[1].ratio >= ml[0].ratio - 0.02, "longer sequences should help ODC");

    let pr = sweep(Factor::PackingRatio, &[1.0, 8.0], 6, SEED);
    assert!(pr[1].ratio <= pr[0].ratio + 0.02, "bigger budget should help the baseline");

    let dv = sweep(Factor::Devices, &[2.0, 16.0], 6, SEED);
    assert!(dv[1].ratio >= dv[0].ratio - 0.02, "more devices, more heterogeneity");
}

#[test]
fn golden_setting_acceleration_positive() {
    let mut exp = ExperimentConfig::golden();
    exp.steps = STEPS;
    exp.seed = SEED;
    let r = acceleration_ratio(&exp);
    assert!(r > 1.0, "golden acceleration {r}");
}

#[test]
fn bubble_tracks_speedup() {
    // Appendix G: the ODC acceleration closely correlates with the
    // collective bubble rate — higher bubble, higher speedup.
    let low_b =
        simulate_cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Collective, Balancer::LbMicro, 16, 8, STEPS, SEED);
    let high_b =
        simulate_cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LbMicro, 8, 8, STEPS, SEED);
    assert!(high_b.bubble_rate > low_b.bubble_rate);
    let s_low = cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Odc, Balancer::LbMini, 16)
        / cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Collective, Balancer::LbMicro, 16);
    let s_high = cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Odc, Balancer::LbMini, 8)
        / cell(PaperModel::M1_5B, Dataset::LongAlign, CommScheme::Collective, Balancer::LbMicro, 8);
    assert!(s_high > s_low, "speedup should track bubble: {s_high} vs {s_low}");
}
