//! Integration: stress tests on the communication backends — every
//! scheme must compute the identical reduction regardless of timing,
//! arrival order, or per-device push counts (ODC / Hybrid) — plus
//! steady-state buffer-reuse guarantees on the zero-copy push paths
//! (per-pair payload arenas, at both hybrid levels) and the
//! minibatch-scoped gather cache.

use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::{CommStack, GatherCache};
use odc::config::CommScheme;
use std::sync::Arc;

/// Backend under test: 0 = Collective, 1 = ODC, 2 = Hybrid with a
/// single group (all-intra), 3 = Hybrid with per-device groups
/// (all-cross), 4 = Hybrid with two-device groups (needs world % 2 == 0).
fn make_backend(which: usize, params: &Arc<ParamStore>, world: usize) -> Arc<dyn CommBackend> {
    let stack = || CommStack::builder(Arc::clone(params), world);
    match which {
        0 => stack().build(CommScheme::Collective).unwrap(),
        1 => stack().build(CommScheme::Odc).unwrap(),
        2 => stack().groups(world).build(CommScheme::Hybrid).unwrap(),
        3 => stack().groups(1).build(CommScheme::Hybrid).unwrap(),
        4 => stack().groups(2).build(CommScheme::Hybrid).unwrap(),
        _ => unreachable!(),
    }
}

/// Run one synthetic minibatch (3 micros/device, deterministic grads +
/// weights) and return the reassembled full gradient per layer.
fn run_minibatch(which: usize, world: usize, layer_lens: &[usize]) -> Vec<Vec<f32>> {
    let params = Arc::new(ParamStore::new(layer_lens, world));
    let backend = make_backend(which, &params, world);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for dev in 0..world {
            let backend = Arc::clone(&backend);
            let store = Arc::clone(&params);
            handles.push(s.spawn(move || {
                for micro in 0..3 {
                    for (l, p) in store.layers.iter().enumerate() {
                        let grad: Vec<f32> =
                            (0..p.padded_len()).map(|i| ((dev + 1) * (i + 1) % 17) as f32).collect();
                        let w = ((dev + l) % 3) as f32 * 0.5 + 0.5;
                        backend.reduce_grad(dev, l, &grad, w, (3 * dev + micro) as u64);
                    }
                }
                backend.end_minibatch(dev);
                let mut shards = Vec::new();
                for (l, p) in store.layers.iter().enumerate() {
                    let mut g = vec![0.0f32; p.shard_len];
                    backend.take_grad_shard(dev, l, &mut g);
                    shards.push(g);
                }
                backend.end_step(dev);
                (dev, shards)
            }));
        }
        let mut per_dev: Vec<(usize, Vec<Vec<f32>>)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        per_dev.sort_by_key(|(d, _)| *d);
        params
            .layers
            .iter()
            .enumerate()
            .map(|(l, p)| {
                let mut full = vec![0.0f32; p.padded_len()];
                for (dev, shards) in &per_dev {
                    let r = p.shard_range(*dev);
                    full[r].copy_from_slice(&shards[l]);
                }
                full
            })
            .collect()
    })
}

#[test]
fn backends_agree_under_stress() {
    let layer_lens = vec![37, 64, 101];
    let world = 4;
    let a = run_minibatch(0, world, &layer_lens);
    // every other scheme — ODC and all three hybrid group shapes — must
    // produce the same reduction as the collective baseline
    for which in 1..=4 {
        let b = run_minibatch(which, world, &layer_lens);
        for (l, (x, y)) in a.iter().zip(&b).enumerate() {
            for (i, (p, q)) in x.iter().zip(y).enumerate() {
                assert!((p - q).abs() < 1e-4, "backend {which} layer {l} idx {i}: {p} vs {q}");
            }
        }
    }
}

#[test]
fn repeated_runs_deterministic() {
    let layer_lens = vec![29];
    // world 3: collective, odc, hybrid/1-group, hybrid/per-device groups
    for which in 0..=3 {
        let a = run_minibatch(which, 3, &layer_lens);
        let b = run_minibatch(which, 3, &layer_lens);
        assert_eq!(a, b, "backend {which} must be deterministic");
    }
}

/// ODC with wildly unequal push counts per device (the LB-Mini regime)
/// across several minibatches.
#[test]
fn odc_unequal_counts_many_minibatches() {
    let world = 3;
    let params = Arc::new(ParamStore::new(&[50], world));
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    std::thread::scope(|s| {
        for dev in 0..world {
            let comm = Arc::clone(&comm);
            s.spawn(move || {
                for step in 0..5 {
                    let pushes = 1 + (dev + step) % 4;
                    for m in 0..pushes {
                        comm.reduce_grad(dev, 0, &vec![1.0f32; 51], 1.0, (4 * dev + m) as u64);
                    }
                    comm.end_minibatch(dev);
                    let mut g = vec![0.0f32; 17];
                    comm.take_grad_shard(dev, 0, &mut g);
                    let want: usize = (0..world).map(|d| 1 + (d + step) % 4).sum();
                    for &v in &g {
                        assert!((v - want as f32).abs() < 1e-5, "step {step}: {v} vs {want}");
                    }
                    comm.end_step(dev);
                }
            });
        }
    });
}

/// Steady-state buffer reuse: with per-(server, client) arenas sized at
/// `layers + 1` buffers per pair, a workload whose per-minibatch pushes
/// per pair stay within the prealloc must NEVER heap-allocate a
/// payload — not during warm-up, not ever.
#[test]
fn odc_arena_never_allocates_within_prealloc() {
    let world = 3;
    // 2 layers => prealloc is 3 buffers per pair; push each layer once
    // per minibatch (2 in-flight max per pair).
    let params = Arc::new(ParamStore::new(&[30, 12], world));
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    std::thread::scope(|s| {
        for dev in 0..world {
            let comm = Arc::clone(&comm);
            let store = Arc::clone(&params);
            s.spawn(move || {
                for _step in 0..25 {
                    for (l, p) in store.layers.iter().enumerate() {
                        comm.reduce_grad(dev, l, &vec![1.0f32; p.padded_len()], 1.0, dev as u64);
                    }
                    comm.end_minibatch(dev);
                    let mut g = vec![0.0f32; store.layers[0].shard_len];
                    comm.take_grad_shard(dev, 0, &mut g);
                    comm.end_step(dev);
                }
            });
        }
    });
    let stats = comm.arena_stats();
    assert_eq!(stats.acquires, (25 * world * world * 2) as u64);
    assert_eq!(stats.fresh_allocs, 0, "push path must be allocation-free inside the prealloc");
}

/// Heavy bursts CAN exceed the prealloc — but growth is bounded by one
/// minibatch's in-flight pushes per pair (end_minibatch fully drains
/// every daemon), so the arena stops growing after warm-up no matter
/// how many minibatches follow.
#[test]
fn odc_arena_growth_bounded_and_stops_after_warmup() {
    let world = 2;
    let micros = 8; // 8 pushes per pair per minibatch vs prealloc of 2
    let params = Arc::new(ParamStore::new(&[40], world));
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    let run_minibatches = |n: usize| {
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for _ in 0..n {
                        for m in 0..micros {
                            comm.reduce_grad(dev, 0, &[1.0f32; 40], 1.0, (micros * dev + m) as u64);
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 20];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                    }
                });
            }
        });
    };
    run_minibatches(3); // warm-up
    let warm = comm.arena_stats();
    let prealloc_per_pair = 2; // 1 layer + 1
    let bound = (world * world * (micros - prealloc_per_pair)) as u64;
    assert!(warm.fresh_allocs <= bound, "fresh {} exceeds in-flight bound {bound}", warm.fresh_allocs);

    run_minibatches(20);
    let after = comm.arena_stats();
    assert!(
        after.fresh_allocs <= bound,
        "arena kept growing after warm-up: {} -> {} (bound {bound})",
        warm.fresh_allocs,
        after.fresh_allocs
    );
    // every payload is back home after the final drain
    assert_eq!(after.resident, (world * world * prealloc_per_pair) as u64 + after.fresh_allocs);
}

/// The id-keyed fold ignores push order: pushing the same set of
/// (micro, client, grad) pieces in ANY sequence yields bit-identical
/// shards on every one-sided backend. The values are chosen so an
/// arrival-order fold WOULD differ bitwise ((1e8 + 1) - 1e8 = 0 in f32,
/// but (-1e8 + 1e8) + 1 = 1), so this pins exactly the property that
/// makes work-stealing dispatch semantically free.
#[test]
fn id_keyed_fold_ignores_push_order() {
    let world = 2;
    // (client, micro, value): three microbatches, client 0 ran two of them
    let pieces: [(usize, u64, f32); 3] = [(0, 0, 1e8), (1, 1, 1.0), (0, 2, -1e8)];
    // ODC and single-group Hybrid (the all-intra path) — the two
    // backends whose daemons fold id-keyed
    for which in [1usize, 2] {
        let run = |order: &[usize]| -> Vec<Vec<f32>> {
            let params = Arc::new(ParamStore::new(&[4], world));
            let backend = make_backend(which, &params, world);
            // every push from this thread: arrival order == `order`
            for &k in order {
                let (client, micro, val) = pieces[k];
                backend.reduce_grad(client, 0, &[val; 4], 1.0, micro);
            }
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for dev in 0..world {
                    let backend = Arc::clone(&backend);
                    handles.push(s.spawn(move || {
                        backend.end_minibatch(dev);
                        let mut g = vec![0.0f32; 2];
                        backend.take_grad_shard(dev, 0, &mut g);
                        backend.end_step(dev);
                        g
                    }));
                }
                let mut out: Vec<(usize, Vec<f32>)> =
                    handles.into_iter().enumerate().map(|(d, h)| (d, h.join().unwrap())).collect();
                out.sort_by_key(|(d, _)| *d);
                out.into_iter().map(|(_, g)| g).collect()
            })
        };
        let in_order = run(&[0, 1, 2]);
        for order in [[2, 0, 1], [1, 2, 0], [2, 1, 0]] {
            let scrambled = run(&order);
            assert_eq!(in_order, scrambled, "backend {which}, order {order:?}");
        }
        // id-order fold: (1e8 + 1.0) + (-1e8) == 0.0 in f32
        for (d, shard) in in_order.iter().enumerate() {
            assert_eq!(shard, &vec![0.0f32; 2], "backend {which} dev {d}");
        }
    }
}

/// The minibatch-scoped gather cache returns bytes identical to direct
/// (seed-path) gathers, for every device, layer, and repetition.
#[test]
fn gather_cache_bit_identical_to_direct_gathers() {
    let world = 4;
    let layer_lens = vec![37, 64, 101];
    let params = Arc::new(ParamStore::new(&layer_lens, world));
    for (l, p) in params.layers.iter().enumerate() {
        let vals: Vec<f32> = (0..p.logical_len).map(|i| ((l + 1) * (i + 3) % 97) as f32).collect();
        p.init_from(&vals);
    }
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    assert!(comm.gathers_cacheable());
    for dev in 0..world {
        let mut cache = GatherCache::new(&params, dev, true);
        for (l, p) in params.layers.iter().enumerate() {
            let mut direct = vec![0.0f32; p.padded_len()];
            comm.gather_params(dev, l, &mut direct);
            for _ in 0..3 {
                let cached = cache.gather(comm.as_ref(), l);
                assert_eq!(&cached[..], &direct[..], "dev {dev} layer {l}");
            }
        }
        let s = cache.stats();
        assert_eq!(s.misses as usize, layer_lens.len(), "one backend gather per layer");
        assert_eq!(s.hits as usize, 2 * layer_lens.len());
    }
}

/// Parameter updates published at end_step are visible to the next
/// minibatch's gathers under every backend — for hybrid this pins the
/// replica refresh: the write lands in the GLOBAL store, and gathers
/// read the group replicas, so staleness here means a broken refresh.
#[test]
fn param_updates_visible_next_step() {
    let world = 2;
    for which in 0..=4 {
        let params = Arc::new(ParamStore::new(&[8], world));
        params.layers[0].init_from(&[1.0; 8]);
        let backend = make_backend(which, &params, world);
        let store = Arc::clone(&params);
        std::thread::scope(|s| {
            for dev in 0..world {
                let backend = Arc::clone(&backend);
                let store = Arc::clone(&store);
                s.spawn(move || {
                    let p = &store.layers[0];
                    let mut buf = vec![0.0f32; p.padded_len()];
                    for step in 0..3 {
                        backend.gather_params(dev, 0, &mut buf);
                        assert!(
                            buf.iter().all(|&x| (x - (1.0 + step as f32)).abs() < 1e-6),
                            "backend {which} step {step}: saw {buf:?}"
                        );
                        backend.reduce_grad(dev, 0, &vec![0.0f32; p.padded_len()], 1.0, dev as u64);
                        backend.end_minibatch(dev);
                        let r = p.shard_range(dev);
                        let newv = vec![2.0 + step as f32; r.len()];
                        p.buf.write(r.start, &newv);
                        backend.end_step(dev);
                    }
                });
            }
        });
    }
}

/// Hybrid under maximally skewed per-device microbatch counts (one
/// device pushes 8× the others — the adversarial LB-Mini regime): the
/// reduction stays exact across groups, and BOTH arena levels stop
/// growing after warm-up. In-flight intra payloads per (server, client)
/// pair are bounded by one minibatch's pushes (the daemons buffer until
/// the flush); cross payloads per (owner, group) pair are bounded by the
/// layer count, which the prealloc covers outright.
#[test]
fn hybrid_skewed_counts_arena_growth_stops_after_warmup() {
    let world = 4;
    let group_size = 2;
    let layers = [30usize, 12];
    let params = Arc::new(ParamStore::new(&layers, world));
    let comm =
        CommStack::builder(Arc::clone(&params), world).groups(group_size).build_hybrid().unwrap();
    let micros = |dev: usize| if dev == 0 { 8 } else { 1 };
    let run_minibatches = |n: usize| {
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                let store = Arc::clone(&params);
                s.spawn(move || {
                    for _ in 0..n {
                        for _m in 0..micros(dev) {
                            for (l, p) in store.layers.iter().enumerate() {
                                comm.reduce_grad(dev, l, &vec![1.0f32; p.padded_len()], 1.0, (8 * dev + _m) as u64);
                            }
                        }
                        comm.end_minibatch(dev);
                        let total: usize = (0..world).map(micros).sum();
                        for (l, p) in store.layers.iter().enumerate() {
                            let mut g = vec![0.0f32; p.shard_len];
                            comm.take_grad_shard(dev, l, &mut g);
                            for &v in &g {
                                assert_eq!(v, total as f32, "layer {l}");
                            }
                        }
                        comm.end_step(dev);
                    }
                });
            }
        });
    };
    run_minibatches(2); // warm-up: arenas grow to the per-minibatch max
    let warm = comm.arena_stats();
    // intra in-flight bound per (server, client) pair: client's pushes
    // per minibatch (micros × layers) minus the prealloc (layers + 1)
    let intra_bound: usize = (0..world)
        .map(|c| group_size * (micros(c) * layers.len()).saturating_sub(layers.len() + 1))
        .sum();
    assert!(
        warm.fresh_allocs <= intra_bound as u64,
        "fresh {} exceeds in-flight bound {intra_bound}",
        warm.fresh_allocs
    );
    assert_eq!(
        comm.cross_arena_stats().fresh_allocs,
        0,
        "cross epilogue must stay inside the prealloc"
    );

    run_minibatches(20);
    let after = comm.arena_stats();
    assert_eq!(
        after.fresh_allocs, warm.fresh_allocs,
        "arenas kept growing after warm-up: {} -> {}",
        warm.fresh_allocs, after.fresh_allocs
    );
    // every payload is back home after the final drain
    let prealloc = (world * group_size + world * (world / group_size)) * (layers.len() + 1);
    assert_eq!(after.resident, prealloc as u64 + after.fresh_allocs);
}

/// SeqSplit's per-sequence rendezvous under the friendly regime: one
/// split sequence whose chunks land on every device (the maximal
/// rendezvous), plus a whole micro per device, every minibatch. The
/// chunk payloads ride the SAME per-pair arenas as micro payloads —
/// within the prealloc the push path must stay allocation-free, the
/// acquire count must be EXACT (one payload per shard server per push,
/// chunk or not), and the fold must release every payload (resident
/// accounting after the final drain).
#[test]
fn odc_seq_fold_arena_exact_accounting_within_prealloc() {
    let world = 4;
    let steps = 25usize;
    // 1 layer => prealloc 2 buffers/pair; 2 pushes/pair per minibatch
    // (one chunk + one micro) — exactly at the prealloc, never past it.
    let params = Arc::new(ParamStore::new(&[40], world));
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    std::thread::scope(|s| {
        for dev in 0..world {
            let comm = Arc::clone(&comm);
            s.spawn(move || {
                for _step in 0..steps {
                    // chunk `dev` of split sequence 0 (count = world)
                    comm.reduce_grad_seq(dev, 0, &[1.0f32; 40], 1.0, 0, dev as u32, world as u32);
                    // plus an ordinary whole-sample micro
                    comm.reduce_grad(dev, 0, &[1.0f32; 40], 1.0, dev as u64);
                    comm.end_minibatch(dev);
                    let mut g = vec![0.0f32; 10];
                    comm.take_grad_shard(dev, 0, &mut g);
                    // seq fold: Σ over `world` chunks + `world` micros
                    for &v in &g {
                        assert_eq!(v, 2.0 * world as f32, "reconstituted sequence + micros");
                    }
                    comm.end_step(dev);
                }
            });
        }
    });
    let stats = comm.arena_stats();
    // 2 pushes per device per minibatch, each acquiring one payload per
    // shard server — chunk pushes are accounted exactly like micros.
    assert_eq!(stats.acquires, (steps * world * 2 * world) as u64);
    assert_eq!(stats.fresh_allocs, 0, "chunk push path must be allocation-free inside the prealloc");
    assert_eq!(stats.resident, (world * world * 2) as u64, "every chunk payload must come home");
}

/// The adversarial single-long-sequence skew: ONE device pushes all 8
/// chunks of one overlong sequence every minibatch (8× the prealloc),
/// the other only a whole micro. Growth is bounded by one minibatch's
/// in-flight chunk pushes per pair and STOPS after warm-up — the
/// per-sequence fold releases every non-accumulator payload, and the
/// accumulator is released by the micro fold it feeds.
#[test]
fn odc_seq_fold_arena_growth_bounded_under_split_skew() {
    let world = 2;
    let chunks = 8usize;
    let params = Arc::new(ParamStore::new(&[40], world));
    let comm = CommStack::builder(Arc::clone(&params), world).build_odc().unwrap();
    let run_minibatches = |n: usize| {
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for _ in 0..n {
                        if dev == 0 {
                            for k in 0..chunks {
                                comm.reduce_grad_seq(dev, 0, &[1.0f32; 40], 1.0, 0, k as u32, chunks as u32);
                            }
                        } else {
                            comm.reduce_grad(dev, 0, &[1.0f32; 40], 1.0, 7);
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 20];
                        comm.take_grad_shard(dev, 0, &mut g);
                        for &v in &g {
                            assert_eq!(v, chunks as f32 + 1.0, "8-chunk sequence + 1 micro");
                        }
                        comm.end_step(dev);
                    }
                });
            }
        });
    };
    run_minibatches(3); // warm-up
    let warm = comm.arena_stats();
    let prealloc_per_pair = 2; // 1 layer + 1
    // device 0's 8 chunk pushes per minibatch, to each of `world`
    // servers, less the prealloc; device 1 stays inside its prealloc
    let bound = (world * (chunks - prealloc_per_pair)) as u64;
    assert!(warm.fresh_allocs <= bound, "fresh {} exceeds in-flight bound {bound}", warm.fresh_allocs);

    run_minibatches(20);
    let after = comm.arena_stats();
    assert_eq!(
        after.fresh_allocs, warm.fresh_allocs,
        "arena kept growing after warm-up under split skew: {} -> {}",
        warm.fresh_allocs, after.fresh_allocs
    );
    assert_eq!(after.resident, (world * world * prealloc_per_pair) as u64 + after.fresh_allocs);
}

/// SeqSplit across hybrid's two levels: a sequence split across node
/// groups rendezvouses per group at the intra level, and the group
/// partials meet in the cross-level sum. The chunk payloads ride the
/// per-(server, client) INTRA arenas — exact acquire accounting, no
/// allocation inside the prealloc — and the cross epilogue's
/// per-sequence partials stay inside the cross prealloc (they fold into
/// ordinary per-layer cross pieces, adding no cross traffic).
#[test]
fn hybrid_seq_fold_arena_exact_accounting_across_groups() {
    let world = 4;
    let group_size = 2;
    let steps = 25usize;
    let params = Arc::new(ParamStore::new(&[40], world));
    let comm =
        CommStack::builder(Arc::clone(&params), world).groups(group_size).build_hybrid().unwrap();
    std::thread::scope(|s| {
        for dev in 0..world {
            let comm = Arc::clone(&comm);
            s.spawn(move || {
                for _step in 0..steps {
                    // chunk `dev` of sequence 0: groups {0,1} and {2,3}
                    // each fold a 2-chunk partial, summed cross-group
                    comm.reduce_grad_seq(dev, 0, &[1.0f32; 40], 1.0, 0, dev as u32, world as u32);
                    comm.reduce_grad(dev, 0, &[1.0f32; 40], 1.0, dev as u64);
                    comm.end_minibatch(dev);
                    let mut g = vec![0.0f32; 10];
                    comm.take_grad_shard(dev, 0, &mut g);
                    for &v in &g {
                        assert_eq!(v, 2.0 * world as f32, "group partials must sum exactly");
                    }
                    comm.end_step(dev);
                }
            });
        }
    });
    let stats = comm.arena_stats();
    // 2 pushes per device per minibatch, each acquiring one super-shard
    // payload per group member
    assert_eq!(stats.acquires, (steps * world * 2 * group_size) as u64);
    assert_eq!(stats.fresh_allocs, 0, "intra chunk pushes must stay inside the prealloc");
    assert_eq!(
        comm.cross_arena_stats().fresh_allocs,
        0,
        "per-sequence partials must not grow the cross epilogue"
    );
}

/// The minibatch-scoped gather cache over hybrid group membership:
/// cached bytes are bit-identical to direct replica reads for every
/// device of every group, and stay correct across an end_step replica
/// refresh (invalidate → re-gather sees the republished params).
#[test]
fn hybrid_gather_cache_bit_identical_across_groups() {
    let world = 4;
    let layer_lens = vec![37, 64, 101];
    let params = Arc::new(ParamStore::new(&layer_lens, world));
    for (l, p) in params.layers.iter().enumerate() {
        let vals: Vec<f32> = (0..p.logical_len).map(|i| ((l + 1) * (i + 3) % 97) as f32).collect();
        p.init_from(&vals);
    }
    let comm = CommStack::builder(Arc::clone(&params), world).groups(2).build_hybrid().unwrap();
    assert!(comm.gathers_cacheable());
    for dev in 0..world {
        let mut cache = GatherCache::for_policy(&params, dev, comm.gather_policy());
        for (l, p) in params.layers.iter().enumerate() {
            let mut direct = vec![0.0f32; p.padded_len()];
            comm.gather_params(dev, l, &mut direct);
            for _ in 0..3 {
                let cached = cache.gather(comm.as_ref(), l);
                assert_eq!(&cached[..], &direct[..], "dev {dev} layer {l}");
            }
        }
        let s = cache.stats();
        assert_eq!(s.misses as usize, layer_lens.len(), "one replica read per layer");
        assert_eq!(s.hits as usize, 2 * layer_lens.len());
    }

    // One optimizer cycle republishes params; invalidated caches must
    // see the refreshed replicas on every device.
    std::thread::scope(|s| {
        for dev in 0..world {
            let comm = Arc::clone(&comm);
            let store = Arc::clone(&params);
            s.spawn(move || {
                comm.end_minibatch(dev); // zero pushes: empty fold
                let p = &store.layers[0];
                let r = p.shard_range(dev);
                p.buf.write(r.start, &vec![7.0f32; r.len()]);
                comm.end_step(dev);
            });
        }
    });
    for dev in 0..world {
        let mut cache = GatherCache::for_policy(&params, dev, comm.gather_policy());
        cache.invalidate();
        let g = cache.gather(comm.as_ref(), 0);
        assert!(
            g.iter().all(|&x| x == 7.0),
            "dev {dev}: replica refresh not visible through the cache"
        );
    }
}
