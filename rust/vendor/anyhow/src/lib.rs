//! Minimal, API-compatible subset of the `anyhow` error crate, vendored
//! so the workspace builds with zero network access.
//!
//! Matches real-anyhow semantics for everything the repo uses:
//!
//! * [`Error`]: an opaque boxed error with a display message and an
//!   optional source chain. Like upstream, it deliberately does NOT
//!   implement `std::error::Error` itself, which is what makes the
//!   blanket `From<E: std::error::Error>` impl (powering `?`) legal.
//! * [`Result<T>`] with the `E = Error` default.
//! * [`anyhow!`], [`bail!`], [`ensure!`] format-style macros.
//! * [`Context`] for `Result<T, E>` and `Option<T>`.
//!
//! `{}` shows the outermost message; `{:?}` shows the cause chain.

use std::error::Error as StdError;
use std::fmt;

pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: ctx.to_string(), source: Some(Box::new(ChainLink(self.msg, self.source))) }
    }
}

/// Internal node letting a context-wrapped Error participate in the
/// std source chain (Error itself cannot, by design).
struct ChainLink(String, Option<Box<dyn StdError + Send + Sync + 'static>>);

impl fmt::Display for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for ChainLink {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.1.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source.as_deref().map(|e| e as &(dyn StdError + 'static));
        if src.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = src {
            write!(f, "\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub trait Context<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn context_wraps_and_debug_shows_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("reading manifest"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("missing"));
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(7u32).context("empty").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} of {}", 1, "2");
        assert_eq!(e.to_string(), "bad 1 of 2");
        fn f(x: bool) -> Result<u32> {
            ensure!(x, "must be true");
            if !x {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(false).is_err());
        assert_eq!(f(true).unwrap(), 1);
    }
}
