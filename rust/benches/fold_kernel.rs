//! FastFold kernel benchmark: chunk-parallel fold throughput and bf16
//! wire payload reduction, with machine-readable output.
//!
//! Two measurements:
//!
//! * `fold.gbps` — throughput of `comm::fold::fold_pieces` over the
//!   world-4 bench shape (8 pieces × 8 MiB accumulator): source bytes
//!   folded per second, scalar (threads=1) vs chunk-parallel. The
//!   chunked kernel is bit-identical to the scalar one at any thread
//!   count (see `tests/fold_prop.rs`), so this is a pure-speed knob.
//! * `wire.bytes_reduction_fraction` — measured pushed-byte reduction
//!   of `WireDtype::Bf16` vs `WireDtype::F32` on a real `OdcComm`
//!   schedule, read back from `hotpath_stats().wire_bytes` (not
//!   computed from the dtype widths — the counter sits after the
//!   encoder, so a payload regression shows up here).
//!
//! MERGES its `fold` / `wire` sections into `BENCH_hotpath.json` rather
//! than rewriting it: run AFTER `--bench comm_path`, which writes the
//! file wholesale. ODC_BENCH_ITERS scales sampling.

use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::{fold, CommStack, FoldPiece, PieceData, WireDtype};
use odc::util::bench::Bencher;
use odc::util::json::Json;
use std::sync::Arc;

/// 8 MiB f32 accumulator — large enough that the parallel path engages
/// (`len >= 2 * CHUNK_ELEMS`) and spans many chunk boundaries.
const ACC_ELEMS: usize = 1 << 21;
/// World-4 bench shape: 2 microbatches from each of 4 clients.
const PIECES: usize = 8;
const PAR_THREADS: usize = 4;

/// Run a tiny but complete ODC minibatch (4 devices, 2 micros each,
/// 3 layers) under `wire` and return the measured pushed wire bytes.
fn pushed_bytes(wire: WireDtype) -> u64 {
    const WORLD: usize = 4;
    const LAYERS: [usize; 3] = [1 << 16, 1 << 15, 1 << 15];
    let params = Arc::new(ParamStore::new(&LAYERS, WORLD));
    let comm = CommStack::builder(Arc::clone(&params), WORLD)
        .wire(wire)
        .build_odc()
        .expect("in-process odc stack");
    std::thread::scope(|s| {
        for dev in 0..WORLD {
            let comm = Arc::clone(&comm);
            let params = Arc::clone(&params);
            s.spawn(move || {
                let grad = vec![0.5f32; params.max_padded_len()];
                let mut gshard =
                    vec![0.0f32; params.layers.iter().map(|p| p.shard_len).max().unwrap()];
                for micro in 0..2u64 {
                    for l in 0..params.n_layers() {
                        comm.reduce_grad(dev, l, &grad[..params.layers[l].padded_len()], 1.0, micro);
                    }
                }
                comm.end_minibatch(dev);
                for l in 0..params.n_layers() {
                    comm.take_grad_shard(dev, l, &mut gshard[..params.layers[l].shard_len]);
                }
                comm.end_step(dev);
            });
        }
    });
    comm.hotpath_stats().wire_bytes
}

fn main() {
    let b = Bencher::default();
    println!("== fold-kernel benchmark: chunk-parallel fold + bf16 wire reduction ==");
    println!("   acc_elems={ACC_ELEMS} pieces={PIECES} threads={PAR_THREADS}\n");

    // ---- fold throughput: scalar vs chunk-parallel -----------------------
    let sources: Vec<Vec<f32>> = (0..PIECES)
        .map(|p| (0..ACC_ELEMS).map(|i| ((i + p) % 17) as f32 * 0.25 - 2.0).collect())
        .collect();
    let pieces: Vec<FoldPiece> =
        sources.iter().map(|s| FoldPiece { weight: 0.5, data: PieceData::F32(s) }).collect();
    let mut acc = vec![0.0f32; ACC_ELEMS];
    let r_scalar =
        b.run("fold_scalar_8x8MiB", || fold::fold_pieces(&mut acc, &pieces, 1));
    let r_par = b.run("fold_parallel_8x8MiB", || {
        fold::fold_pieces(&mut acc, &pieces, PAR_THREADS)
    });

    let src_bytes = (PIECES * ACC_ELEMS * 4) as f64;
    let scalar_gbps = src_bytes / r_scalar.mean_ns; // bytes/ns == GB/s
    let par_gbps = src_bytes / r_par.mean_ns;
    let speedup = r_scalar.mean_ns / r_par.mean_ns;
    println!(
        "\n  fold throughput: scalar {scalar_gbps:.2} GB/s  ->  parallel {par_gbps:.2} GB/s  ({speedup:.2}x, {PAR_THREADS} threads)"
    );

    // ---- wire payload reduction: bf16 vs f32 -----------------------------
    let f32_bytes = pushed_bytes(WireDtype::F32);
    let bf16_bytes = pushed_bytes(WireDtype::Bf16);
    assert!(f32_bytes > 0, "the schedule must push something");
    let reduction = 1.0 - bf16_bytes as f64 / f32_bytes as f64;
    println!(
        "  wire payloads: f32 {f32_bytes} B  ->  bf16 {bf16_bytes} B  ({:.1}% reduction)",
        reduction * 100.0
    );

    // ---- merge into the shared hot-path record ---------------------------
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .unwrap_or_else(|| Json::Obj(Default::default()));
    let Json::Obj(m) = &mut root else { panic!("{path} is not a JSON object") };
    m.entry("measured".to_string()).or_insert(Json::Bool(true));
    m.insert(
        "fold".to_string(),
        Json::obj(vec![
            ("gbps", Json::num(par_gbps)),
            ("scalar_gbps", Json::num(scalar_gbps)),
            ("parallel_speedup", Json::num(speedup)),
            ("threads", Json::num(PAR_THREADS as f64)),
            ("acc_elems", Json::num(ACC_ELEMS as f64)),
            ("pieces", Json::num(PIECES as f64)),
            ("generated_by", Json::str("cargo bench --bench fold_kernel")),
        ]),
    );
    m.insert(
        "wire".to_string(),
        Json::obj(vec![
            ("bytes_reduction_fraction", Json::num(reduction)),
            ("f32_bytes", Json::num(f32_bytes as f64)),
            ("bf16_bytes", Json::num(bf16_bytes as f64)),
            ("generated_by", Json::str("cargo bench --bench fold_kernel")),
        ]),
    );
    std::fs::write(path, root.dump() + "\n").expect("writing BENCH_hotpath.json");
    println!("\n  merged fold/wire sections into {path}");
}
