//! Comm-path benchmark: the per-microbatch ODC data path, seed-style
//! vs zero-copy, with machine-readable output.
//!
//! Two modes over the SAME backend, world threads, and layer shapes:
//!
//! * `seed`     — the seed trainer's call pattern: every microbatch
//!                gathers the embed layer once and every block twice
//!                (forward + backward recompute), each a full-layer
//!                copy, then pushes one gradient per layer.
//! * `zerocopy` — the BufferPlan pattern: gathers go through the
//!                minibatch-scoped `GatherCache` (one real gather per
//!                layer per MINIBATCH, refcount clones after), same
//!                gradient pushes.
//!
//! Both modes push through the per-(server, client) payload arenas; the
//! seed global-pool push path no longer exists, so its removal shows up
//! in the counters (every acquire used to be a scan under ONE global
//! lock) rather than as a timed before/after.
//!
//! Writes `BENCH_hotpath.json` at the repo root so future PRs can track
//! the perf trajectory: ns/microbatch per mode, ns/gather (direct vs
//! cached), ns/reduce_grad, and payload-allocation counters proving the
//! steady state is allocation-free. ODC_BENCH_ITERS scales sampling.

use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::{CommStack, GatherCache, OdcComm};
use odc::util::bench::Bencher;
use odc::util::json::Json;
use std::sync::Arc;

const WORLD: usize = 4;
const MICROS: usize = 4;
const MINIBATCHES: usize = 3;
/// embed + 4 blocks (f32 elements)
const LAYERS: [usize; 5] = [1 << 19, 1 << 18, 1 << 18, 1 << 18, 1 << 18];

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Seed,
    ZeroCopy,
}

/// Run `MINIBATCHES` minibatches of the comm schedule on `world`
/// threads; returns nothing — timing wraps the whole call.
fn run_minibatches(comm: &Arc<OdcComm>, params: &Arc<ParamStore>, mode: Mode) {
    std::thread::scope(|s| {
        for dev in 0..WORLD {
            let comm = Arc::clone(comm);
            let params = Arc::clone(params);
            s.spawn(move || {
                let n_blocks = params.n_layers() - 1;
                let max_padded = params.max_padded_len();
                let mut scratch = vec![0.0f32; max_padded];
                let grad = vec![0.5f32; max_padded];
                let mut gshard = vec![0.0f32; params.layers.iter().map(|p| p.shard_len).max().unwrap()];
                let mut cache = GatherCache::new(&params, dev, mode == Mode::ZeroCopy);
                for _mb in 0..MINIBATCHES {
                    for _m in 0..MICROS {
                        // forward: embed + blocks
                        for l in 0..=n_blocks {
                            gather(&comm, &mut cache, dev, l, &mut scratch, mode);
                        }
                        // backward: blocks again + all grads
                        for l in (1..=n_blocks).rev() {
                            gather(&comm, &mut cache, dev, l, &mut scratch, mode);
                            comm.reduce_grad(dev, l, &grad[..params.layers[l].padded_len()], 1.0, (_mb * MICROS + _m) as u64);
                        }
                        comm.reduce_grad(dev, 0, &grad[..params.layers[0].padded_len()], 1.0, (_mb * MICROS + _m) as u64);
                    }
                    comm.end_minibatch(dev);
                    for l in 0..params.n_layers() {
                        comm.take_grad_shard(dev, l, &mut gshard[..params.layers[l].shard_len]);
                    }
                    comm.end_step(dev);
                    cache.invalidate();
                }
            });
        }
    });
}

fn gather(
    comm: &OdcComm,
    cache: &mut GatherCache,
    dev: usize,
    layer: usize,
    scratch: &mut [f32],
    mode: Mode,
) {
    match mode {
        // seed path: a full-layer copy on every call
        Mode::Seed => comm.gather_params(dev, layer, scratch),
        // zero-copy path: one real gather per layer per minibatch
        Mode::ZeroCopy => {
            let shared = cache.gather(comm, layer);
            std::hint::black_box(&shared);
        }
    }
}

fn main() {
    let b = Bencher::default();
    println!("== comm-path benchmark: seed vs zero-copy ODC data path ==");
    println!(
        "   world={WORLD} micros={MICROS} minibatches={MINIBATCHES} layers={:?}\n",
        LAYERS
    );

    let params = Arc::new(ParamStore::new(&LAYERS, WORLD));
    let micro_total = (MINIBATCHES * MICROS) as f64;

    // ---- end-to-end minibatch schedule, per mode -------------------------
    let comm_seed =
        CommStack::builder(Arc::clone(&params), WORLD).build_odc().expect("in-process odc stack");
    let r_seed = b.run("commpath_seed_3minibatches", || {
        run_minibatches(&comm_seed, &params, Mode::Seed)
    });
    let seed_ns_per_micro = r_seed.mean_ns / micro_total;

    let comm_zc =
        CommStack::builder(Arc::clone(&params), WORLD).build_odc().expect("in-process odc stack");
    // warm-up (arena growth + first cache fill happen here, untimed)
    run_minibatches(&comm_zc, &params, Mode::ZeroCopy);
    let warm = comm_zc.arena_stats();
    let r_zc = b.run("commpath_zerocopy_3minibatches", || {
        run_minibatches(&comm_zc, &params, Mode::ZeroCopy)
    });
    let zc_ns_per_micro = r_zc.mean_ns / micro_total;
    let after = comm_zc.arena_stats();

    let steady_micros = ((b.warmup + b.iters) * MINIBATCHES * MICROS) as f64;
    let fresh_after_warmup = after.fresh_allocs - warm.fresh_allocs;
    let acquires_per_micro = (after.acquires - warm.acquires) as f64 / steady_micros;
    let reduction = 1.0 - zc_ns_per_micro / seed_ns_per_micro;

    // ---- isolated primitives (single device, no thread noise) -----------
    let pstore = Arc::new(ParamStore::new(&LAYERS, 1));
    let prim1 =
        CommStack::builder(Arc::clone(&pstore), 1).build_odc().expect("in-process odc stack");
    let mut scratch = vec![0.0f32; pstore.max_padded_len()];
    let r_direct = b.run("gather_direct_2MiB", || prim1.gather_params(0, 0, &mut scratch));
    let mut cache1 = GatherCache::new(&pstore, 0, true);
    let _ = cache1.gather(prim1.as_ref(), 0); // fill once
    let r_cached = b.run("gather_cached_2MiB", || {
        std::hint::black_box(cache1.gather(prim1.as_ref(), 0))
    });
    // reduce measured as a full push+drain cycle: a tight reduce-only
    // loop would race the daemon and measure mailbox backlog, not the
    // warm path (the arena is back to steady state after each drain)
    let grad = vec![0.5f32; pstore.layers[0].padded_len()];
    let mut gs = vec![0.0f32; pstore.layers[0].shard_len];
    let r_reduce = b.run("reduce_drain_cycle_2MiB", || {
        prim1.reduce_grad(0, 0, &grad, 1.0, 0);
        prim1.end_minibatch(0);
        prim1.take_grad_shard(0, 0, &mut gs);
        prim1.end_step(0);
    });

    println!("\n  per-microbatch comm wall: seed {:.3} ms  ->  zerocopy {:.3} ms  ({:.1}% reduction)", seed_ns_per_micro / 1e6, zc_ns_per_micro / 1e6, reduction * 100.0);
    println!("  payload arenas: {:.1} acquires/microbatch, {} fresh allocs after warm-up", acquires_per_micro, fresh_after_warmup);

    // ---- machine-readable record ----------------------------------------
    let json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("generated_by", Json::str("cargo bench --bench comm_path")),
        (
            "config",
            Json::obj(vec![
                ("world", Json::num(WORLD as f64)),
                ("micros_per_minibatch", Json::num(MICROS as f64)),
                ("minibatches_per_iter", Json::num(MINIBATCHES as f64)),
                ("layer_elems", Json::arr(LAYERS.iter().map(|&l| Json::num(l as f64)).collect())),
                ("bench_iters", Json::num(b.iters as f64)),
            ]),
        ),
        (
            "per_microbatch",
            Json::obj(vec![
                ("seed_ns", Json::num(seed_ns_per_micro)),
                ("zerocopy_ns", Json::num(zc_ns_per_micro)),
                ("reduction_pct", Json::num(reduction * 100.0)),
                ("payload_acquires", Json::num(acquires_per_micro)),
                ("payload_fresh_allocs_after_warmup", Json::num(fresh_after_warmup as f64)),
            ]),
        ),
        (
            "primitives",
            Json::obj(vec![
                ("gather_direct_ns", Json::num(r_direct.mean_ns)),
                ("gather_cached_ns", Json::num(r_cached.mean_ns)),
                ("reduce_drain_cycle_ns", Json::num(r_reduce.mean_ns)),
            ]),
        ),
        (
            "notes",
            Json::str(
                "Both modes push gradients through the per-(server,client) payload \
                 arenas; the seed's single global-Mutex payload pool was removed, so \
                 every `payload_acquires` per microbatch used to be a capacity scan \
                 under one contended lock and is now an uncontended per-pair pop. \
                 `seed_ns` reproduces the seed gather schedule (embed once + every \
                 block twice per microbatch); `zerocopy_ns` is the GatherCache \
                 schedule (each layer once per minibatch).",
            ),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    std::fs::write(path, json.dump() + "\n").expect("writing BENCH_hotpath.json");
    println!("\n  wrote {path}");
}
