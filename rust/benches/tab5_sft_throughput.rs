//! Table 5 / Figure 8: SFT samples/s/device across model scales,
//! datasets, minibatch sizes, and methods. Set ODC_BENCH_FULL=1 for the
//! complete 1.5B–32B grid (slower); default runs 1.5B + 7B.

use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel};
use odc::report::{pct_delta, Table};
use odc::sim::run::simulate_cell;

fn main() {
    let full = std::env::var("ODC_BENCH_FULL").is_ok();
    let models: Vec<PaperModel> = if full {
        vec![PaperModel::M1_5B, PaperModel::M7B, PaperModel::M14B, PaperModel::M32B]
    } else {
        vec![PaperModel::M1_5B, PaperModel::M7B]
    };
    let steps = if full { 16 } else { 8 };
    let seed = 5;
    let minibs_grid = [1usize, 2, 4, 8];

    println!("== Table 5 / Fig 8: SFT samples/s/device (simulated A100 testbed) ==\n");
    for ds in [Dataset::LongAlign, Dataset::SweSmith] {
        for &model in &models {
            let devices = ExperimentConfig::paper_devices(model);
            let mut t = Table::new(&["method", "minibs=1", "2", "4", "8"]);
            let run = |scheme, bal, mb| {
                simulate_cell(model, ds, scheme, bal, mb, devices, steps, seed).samples_per_sec_per_device
            };
            let methods: Vec<(&str, CommScheme, Balancer)> = vec![
                ("Collective LocalSort", CommScheme::Collective, Balancer::LocalSort),
                ("ODC LocalSort", CommScheme::Odc, Balancer::LocalSort),
                ("Collective LB-Micro", CommScheme::Collective, Balancer::LbMicro),
                ("ODC LB-Micro", CommScheme::Odc, Balancer::LbMicro),
                ("ODC LB-Mini", CommScheme::Odc, Balancer::LbMini),
            ];
            // baselines for the (+x%) annotations, as in the paper
            let base: Vec<Vec<f64>> = methods
                .iter()
                .map(|&(_, s, b)| minibs_grid.iter().map(|&mb| run(s, b, mb)).collect())
                .collect();
            for (i, (name, scheme, _)) in methods.iter().enumerate() {
                let baseline_row = match i {
                    1 => Some(0), // ODC LocalSort vs Collective LocalSort
                    3 | 4 => Some(2), // ODC LB-* vs Collective LB-Micro
                    _ => None,
                };
                let mut cells = vec![name.to_string()];
                for (j, _) in minibs_grid.iter().enumerate() {
                    let v = base[i][j];
                    match baseline_row {
                        Some(b) => cells.push(format!("{v:.3} {}", pct_delta(v, base[b][j]))),
                        None => cells.push(format!("{v:.3}")),
                    }
                }
                let _ = scheme;
                t.row(cells);
            }
            println!("{model} on {ds} ({devices} devices):\n{}", t.markdown());
        }
    }
}
