//! Figure 13: per-device memory consumption, hybrid vs full sharding.

use odc::config::PaperModel;
use odc::engine::memory::{full_sharding, hybrid_sharding, MemoryInputs};
use odc::report::Table;

fn main() {
    println!("== Fig 13: per-device memory (GiB), full vs hybrid sharding ==\n");
    let mut t = Table::new(&["model", "devices", "full (GiB)", "hybrid (GiB)", "hybrid/full"]);
    for (model, devices) in [
        (PaperModel::M1_5B, 8),
        (PaperModel::M7B, 8),
        (PaperModel::M7B, 32),
        (PaperModel::M14B, 16),
        (PaperModel::M32B, 32),
    ] {
        let (layers, hidden, params) = model.shape();
        let m = MemoryInputs {
            params,
            devices,
            devices_per_node: 8,
            hidden,
            layers,
            micro_tokens: 8_192, // the Fig 12/13 truncated-LongAlign setting
        };
        let f = full_sharding(&m).gib();
        let h = hybrid_sharding(&m).gib();
        t.row(vec![
            model.to_string(),
            devices.to_string(),
            format!("{f:.1}"),
            format!("{h:.1}"),
            format!("{:.2}x", h / f),
        ]);
    }
    println!("{}", t.markdown());
}
