//! Table 6: SFT bubble rates (packing-algorithm estimate).

use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel};
use odc::report::Table;
use odc::sim::run::simulate_cell;

fn main() {
    let full = std::env::var("ODC_BENCH_FULL").is_ok();
    let models: Vec<PaperModel> = if full {
        vec![PaperModel::M1_5B, PaperModel::M7B, PaperModel::M14B, PaperModel::M32B]
    } else {
        vec![PaperModel::M1_5B]
    };
    let steps = 16;
    let minibs_grid = [1usize, 2, 4, 8];

    println!("== Table 6: SFT bubble rate %, estimated by the packer ==\n");
    for ds in [Dataset::LongAlign, Dataset::SweSmith] {
        for &model in &models {
            let devices = ExperimentConfig::paper_devices(model);
            let mut t = Table::new(&["method", "minibs=1", "2", "4", "8"]);
            for (name, scheme, bal) in [
                ("Collective LocalSort", CommScheme::Collective, Balancer::LocalSort),
                ("Collective LB-Micro", CommScheme::Collective, Balancer::LbMicro),
                ("ODC LocalSort", CommScheme::Odc, Balancer::LocalSort),
                ("ODC LB-Micro", CommScheme::Odc, Balancer::LbMicro),
                ("ODC LB-Mini", CommScheme::Odc, Balancer::LbMini),
            ] {
                let mut cells = vec![name.to_string()];
                for &mb in &minibs_grid {
                    let r = simulate_cell(model, ds, scheme, bal, mb, devices, steps, 5);
                    cells.push(format!("{:.2}", 100.0 * r.bubble_rate));
                }
                t.row(cells);
            }
            println!("{model} on {ds} ({devices} devices):\n{}", t.markdown());
        }
    }
}
