//! Figure 10: parametric study — acceleration ratio of ODC vs Collective
//! (both LB-Micro), varying one factor at a time from the golden setting
//! (Table 1: 1.5B, LongAlign 64K, minibs 4, 8 devices, packing ratio 1).

use odc::report::Table;
use odc::sim::parametric::{sweep, Factor};

fn main() {
    let steps = if std::env::var("ODC_BENCH_FULL").is_ok() { 24 } else { 10 };
    println!("== Figure 10: ODC/Collective acceleration ratio (golden setting sweeps) ==\n");
    for factor in [Factor::MinibatchSize, Factor::MaxLength, Factor::PackingRatio, Factor::Devices] {
        let grid = factor.default_grid();
        let pts = sweep(factor, &grid, steps, 11);
        let mut t = Table::new(&[factor.label(), "acceleration"]);
        for p in &pts {
            t.row(vec![format!("{}", p.x), format!("{:.3}x", p.ratio)]);
        }
        println!("{}", t.markdown());
    }
}
