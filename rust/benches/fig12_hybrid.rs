//! Figure 12 (Appendix E): ZeRO++-style hybrid sharding on the truncated
//! LongAlign (1/8 length => max 8K), where short microbatches cannot hide
//! ODC's extra inter-node traffic — hybrid sharding removes it.

use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, Sharding};
use odc::report::{pct_delta, Table};
use odc::sim::run::{simulate, SimConfig};

fn run(scheme: CommScheme, bal: Balancer, sharding: Sharding, minibs: usize, devices: usize) -> f64 {
    let exp = ExperimentConfig {
        model: PaperModel::M1_5B,
        dataset: Dataset::LongAlign,
        scheme,
        balancer: bal,
        sharding,
        minibs,
        devices,
        devices_per_node: 8,
        packing_ratio: 1.0,
        max_len: 8_192, // truncated LongAlign (Appendix E)
        steps: 12,
        seed: 5,
    };
    simulate(&SimConfig::new(exp)).samples_per_sec_per_device
}

fn main() {
    println!("== Fig 12: hybrid sharding, truncated LongAlign (max 8K), 1.5B, 16 devices ==\n");
    let devices = 16; // multi-node so inter-node traffic matters
    let mut t = Table::new(&["method", "minibs=2", "4", "8"]);
    for (name, scheme, bal, sh) in [
        ("Collective LB-Micro (full)", CommScheme::Collective, Balancer::LbMicro, Sharding::Full),
        ("ODC LB-Micro (full)", CommScheme::Odc, Balancer::LbMicro, Sharding::Full),
        ("ODC LB-Mini (full)", CommScheme::Odc, Balancer::LbMini, Sharding::Full),
        ("ODC LB-Micro (hybrid)", CommScheme::Odc, Balancer::LbMicro, Sharding::Hybrid),
        ("ODC LB-Mini (hybrid)", CommScheme::Odc, Balancer::LbMini, Sharding::Hybrid),
    ] {
        let mut cells = vec![name.to_string()];
        for minibs in [2usize, 4, 8] {
            let v = run(scheme, bal, sh, minibs, devices);
            let base = run(CommScheme::Collective, Balancer::LbMicro, Sharding::Full, minibs, devices);
            if name.starts_with("ODC") {
                cells.push(format!("{v:.3} {}", pct_delta(v, base)));
            } else {
                cells.push(format!("{v:.3}"));
            }
        }
        t.row(cells);
    }
    println!("{}", t.markdown());
}
