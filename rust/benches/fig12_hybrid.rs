//! Figure 12 (Appendix E): ZeRO++-style hybrid sharding on the truncated
//! LongAlign (1/8 length => max 8K), where short microbatches cannot hide
//! ODC's extra inter-node traffic — hybrid sharding removes it.
//!
//! Two modes:
//!
//! * default — the analytic simulator over the paper-scale testbed,
//!   including the REAL two-level scheme (`CommScheme::Hybrid`) next to
//!   the legacy `Sharding::Hybrid` toggle, plus the sim-predicted
//!   per-minibatch hybrid step overhead (cross-node optimizer exchange +
//!   replica refresh);
//! * `--engine` — drives the real trainer on the `tiny` preset through
//!   every backend and prints the sim-predicted step overhead next to
//!   the measured one (mean hybrid step wall minus mean ODC step wall),
//!   closing the loop between `sim/timeline.rs` and `comm/hybrid.rs`.
//!   Self-skips cleanly when artifacts or the PJRT runtime are absent,
//!   so CI's bench smoke gate can always run it.

use odc::comm::topology::Topology;
use odc::comm::TransportKind;
use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, Sharding, WireDtype};
use odc::engine::trainer::{train, TrainerConfig};
use odc::report::{pct_delta, Table};
use odc::sim::run::{simulate, SimConfig, WireCalib};
use odc::sim::timeline::{hybrid_step_overhead_bytes, recovery_epilogue_bytes};
use std::path::Path;

fn cell(scheme: CommScheme, bal: Balancer, sharding: Sharding, minibs: usize, devices: usize) -> ExperimentConfig {
    ExperimentConfig {
        model: PaperModel::M1_5B,
        dataset: Dataset::LongAlign,
        scheme,
        balancer: bal,
        sharding,
        minibs,
        devices,
        devices_per_node: 8,
        packing_ratio: 1.0,
        max_len: 8_192, // truncated LongAlign (Appendix E)
        steps: 12,
        seed: 5,
    }
}

fn run(scheme: CommScheme, bal: Balancer, sharding: Sharding, minibs: usize, devices: usize) -> f64 {
    simulate(&SimConfig::new(cell(scheme, bal, sharding, minibs, devices))).samples_per_sec_per_device
}

fn sim_mode() {
    println!("== Fig 12: hybrid sharding, truncated LongAlign (max 8K), 1.5B, 16 devices ==\n");
    let devices = 16; // multi-node so inter-node traffic matters
    const MINIBS: [usize; 3] = [2, 4, 8];
    let baselines: Vec<f64> = MINIBS
        .iter()
        .map(|&mb| run(CommScheme::Collective, Balancer::LbMicro, Sharding::Full, mb, devices))
        .collect();
    let mut t = Table::new(&["method", "minibs=2", "4", "8"]);
    for (name, scheme, bal, sh) in [
        ("Collective LB-Micro (full)", CommScheme::Collective, Balancer::LbMicro, Sharding::Full),
        ("ODC LB-Micro (full)", CommScheme::Odc, Balancer::LbMicro, Sharding::Full),
        ("ODC LB-Mini (full)", CommScheme::Odc, Balancer::LbMini, Sharding::Full),
        ("ODC LB-Micro (hybrid)", CommScheme::Odc, Balancer::LbMicro, Sharding::Hybrid),
        ("ODC LB-Mini (hybrid)", CommScheme::Odc, Balancer::LbMini, Sharding::Hybrid),
        ("Hybrid LB-Micro (two-level)", CommScheme::Hybrid, Balancer::LbMicro, Sharding::Hybrid),
        ("Hybrid LB-Mini (two-level)", CommScheme::Hybrid, Balancer::LbMini, Sharding::Hybrid),
    ] {
        let mut cells = vec![name.to_string()];
        for (&minibs, &base) in MINIBS.iter().zip(&baselines) {
            let v = if scheme == CommScheme::Collective && sh == Sharding::Full {
                base // the baseline row itself
            } else {
                run(scheme, bal, sh, minibs, devices)
            };
            if scheme == CommScheme::Collective {
                cells.push(format!("{v:.3}"));
            } else {
                cells.push(format!("{v:.3} {}", pct_delta(v, base)));
            }
        }
        t.row(cells);
    }
    println!("{}", t.markdown());
    let r = simulate(&SimConfig::new(cell(CommScheme::Hybrid, Balancer::LbMini, Sharding::Hybrid, 4, devices)));
    println!(
        "\nsim-predicted hybrid step overhead: {:.3} ms/minibatch (cross-node optimizer exchange + replica refresh)",
        r.hybrid_step_overhead_s * 1e3
    );
}

/// Real-engine parity check: run the actual trainer on the tiny preset
/// and put the analytic prediction next to the measurement.
fn engine_mode() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        println!("fig12 --engine: no artifacts/tiny (run `make artifacts`); skipping real-engine mode.");
        return;
    }
    let world = 2;
    let devices_per_node = 1; // per-device groups: the cross-group epilogue is real
    let mk = |scheme: CommScheme, balancer: Balancer, dpn: usize| {
        let mut c = TrainerConfig::new(dir.clone());
        c.world = world;
        c.minibs = 2;
        c.steps = 4;
        c.seed = 11;
        c.scheme = scheme;
        c.balancer = balancer;
        c.devices_per_node = dpn;
        c
    };
    // (mean step wall, measured wire bytes, measured fold seconds)
    let mean_wall = |cfg: &TrainerConfig| -> Option<(f64, u64, f64)> {
        match train(cfg) {
            Ok(r) => {
                let n = r.logs.len().max(1);
                Some((r.logs.iter().map(|l| l.wall_s).sum::<f64>() / n as f64, r.wire_bytes, r.fold_s))
            }
            Err(e) => {
                println!("fig12 --engine: real engine unavailable ({e}); skipping.");
                None
            }
        }
    };
    println!("== Fig 12 --engine: real trainer on tiny preset (world={world}) ==\n");
    let mut t = Table::new(&["backend", "mean step wall (ms)", "wire KiB", "fold ms"]);
    let mut odc_wall = None;
    let mut hybrid_wall = None;
    for (name, scheme, bal, dpn, wire) in [
        ("collective LB-Micro", CommScheme::Collective, Balancer::LbMicro, 0, WireDtype::F32),
        ("odc LB-Mini", CommScheme::Odc, Balancer::LbMini, 0, WireDtype::F32),
        ("odc LB-Mini (bf16 wire)", CommScheme::Odc, Balancer::LbMini, 0, WireDtype::Bf16),
        ("hybrid LB-Mini (2 groups)", CommScheme::Hybrid, Balancer::LbMini, devices_per_node, WireDtype::F32),
    ] {
        let mut cfg = mk(scheme, bal, dpn);
        cfg.wire_dtype = wire;
        let Some((w, wire_bytes, fold_s)) = mean_wall(&cfg) else { return };
        if scheme == CommScheme::Odc && wire == WireDtype::F32 {
            odc_wall = Some(w);
        }
        if scheme == CommScheme::Hybrid {
            hybrid_wall = Some(w);
        }
        t.row(vec![
            name.to_string(),
            format!("{:.3}", w * 1e3),
            format!("{:.1}", wire_bytes as f64 / 1024.0),
            format!("{:.3}", fold_s * 1e3),
        ]);
    }
    println!("{}", t.markdown());
    println!("(bf16 wire halves the pushed KiB of the odc row above — the FastFold payload knob)");

    // Predicted: the analytic model over a paper-shaped topology with
    // this run's device/group counts and the tiny model's actual
    // parameter bytes (f32). Measured: the extra wall the hybrid step
    // pays over ODC (its epilogue does strictly more work: group fold +
    // cross exchange + replica refresh).
    let man = odc::runtime::Manifest::load(&dir).expect("manifest");
    let topo = Topology::paper(world, devices_per_node);
    let groups = topo.group_map().expect("engine groups tile the world");
    let predicted = hybrid_step_overhead_bytes(4.0 * man.total_params as f64, &topo);
    let measured = hybrid_wall.unwrap_or(0.0) - odc_wall.unwrap_or(0.0);
    println!(
        "\nhybrid step overhead per minibatch ({} groups of {}):  sim-predicted {:.3} ms  |  engine-measured {:.3} ms",
        groups.n_groups(),
        groups.group_size,
        predicted * 1e3,
        measured * 1e3
    );
    println!("(prediction prices the paper topology's NICs; the engine moves shared memory — compare shapes, not absolutes)");

    // ---- WireComm: calibrated link pricing vs measured transports ----
    // With a measured BENCH_wire.json (`cargo bench --bench wire_calib`)
    // the hand-set NIC guess above is replaced by fitted alpha/beta, and
    // the SAME trainer runs over the real byte transport: predicted =
    // inproc step wall + pushed bytes/step over beta (the bandwidth term
    // of the wire model — alpha rides inside the measured inproc wall).
    for kind in [TransportKind::Shm, TransportKind::Uds] {
        let calib = match WireCalib::load(kind) {
            Ok(c) => c,
            Err(_) => {
                println!(
                    "wire step time (odc over {kind}): BENCH_wire.json not measured yet — \
                     run `cargo bench --bench wire_calib`; skipping."
                );
                continue;
            }
        };
        let mut cfg = mk(CommScheme::Odc, Balancer::LbMini, 0);
        cfg.transport = kind;
        match train(&cfg) {
            Ok(r) => {
                let n = r.logs.len().max(1);
                let measured = r.logs.iter().map(|l| l.wall_s).sum::<f64>() / n as f64;
                let wire_s = (r.wire_bytes as f64 / n as f64) / (calib.beta_gbps * 1e9);
                let predicted = odc_wall.unwrap_or(0.0) + wire_s;
                println!(
                    "wire step time (odc over {kind}):  sim-predicted {:.3} ms  |  engine-measured {:.3} ms   (calibrated alpha {:.2} µs, beta {:.2} GB/s)",
                    predicted * 1e3,
                    measured * 1e3,
                    calib.alpha_us,
                    calib.beta_gbps
                );
            }
            Err(e) => println!("fig12 --engine: {kind} transport run unavailable ({e}); skipping."),
        }
    }

    // ---- ElasticWorld: predicted vs measured recovery overhead ----
    // One crash (device 1, minibatch 1, before its 2nd pull) under
    // Queue×ODC: the sim prices the successor's state re-read + orphan
    // re-dispatch (recovery_epilogue_bytes over the tiny model's f32
    // bytes); the trainer measures the same recovery work end to end
    // (orphan daemon flush, shard adoption, optimizer catch-up).
    let mut fcfg = mk(CommScheme::Odc, Balancer::Queue, 0);
    fcfg.fail_at = vec![(1, 1, 1)];
    match train(&fcfg) {
        Ok(r) => {
            let predicted_rec = recovery_epilogue_bytes(4.0 * man.total_params as f64, world, &topo, 1);
            println!(
                "elastic recovery overhead (1 crash):  sim-predicted {:.3} ms  |  engine-measured {:.3} ms",
                predicted_rec * 1e3,
                r.recovery_s * 1e3
            );
        }
        Err(e) => println!("fig12 --engine: elastic run unavailable ({e}); skipping recovery row."),
    }
}

fn main() {
    if std::env::args().any(|a| a == "--engine") {
        engine_mode();
    } else {
        sim_mode();
    }
}
