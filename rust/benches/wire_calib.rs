//! WireComm calibration: measure per-message and per-byte cost on the
//! real byte-moving transports and fit the sim's link pricing.
//!
//! For each transport (`inproc` mailbox, `shm` ring, `uds` sockets) a
//! sender thread streams `MSGS` blobs of each size in `SIZES` to a
//! receiving rank — the mailbox push pattern the backends actually use
//! (pipelined, fusion-eligible) — and the mean per-message wall time is
//! fitted by least squares to the two-parameter LogP-style model
//!
//! ```text
//! t(bytes) = alpha_us µs + bytes / (beta_gbps GB/s)
//! ```
//!
//! The fitted cells go to `BENCH_wire.json` at the repo root with
//! `measured: true`; `SimConfig` loads a cell via `WireCalib::load`
//! (`odc sim --transport shm|uds`) to replace the hand-set intra-node
//! topology pricing, and `fig12_hybrid --engine` prints the calibrated
//! prediction next to the measured engine step. The headline
//! `alpha_us`/`beta_gbps` mirror the `uds` cell — the transport whose
//! costs are closest to a real NIC path.

use odc::comm::transport::{frame, InProcTransport, Transport, WireCodec, WireMsg};
use odc::comm::{RingTransport, SocketTransport, TransportKind};
use odc::util::bench::Bencher;
use odc::util::json::Json;
use std::sync::Arc;

/// Message sizes swept per transport (bytes).
const SIZES: [usize; 5] = [256, 4 * 1024, 32 * 1024, 256 * 1024, 1024 * 1024];
/// Messages streamed per timed exchange.
const MSGS: usize = 32;

#[derive(Clone)]
enum CalMsg {
    Blob(Vec<u8>),
    Done,
}

impl WireMsg for CalMsg {
    fn is_barrier(&self) -> bool {
        matches!(self, CalMsg::Done)
    }
    fn payload_bytes(&self) -> usize {
        match self {
            CalMsg::Blob(b) => b.len(),
            CalMsg::Done => 0,
        }
    }
}

impl WireCodec for CalMsg {
    fn encode(&self, out: &mut Vec<u8>) -> bool {
        match self {
            CalMsg::Blob(b) => {
                out.push(0);
                frame::put_bytes(out, b);
            }
            CalMsg::Done => out.push(1),
        }
        true
    }
    fn decode(bytes: &[u8]) -> Option<CalMsg> {
        let mut r = frame::Reader::new(bytes.get(1..)?);
        match bytes.first()? {
            0 => Some(CalMsg::Blob(r.bytes()?)),
            1 => Some(CalMsg::Done),
            _ => None,
        }
    }
}

/// Stream `MSGS` blobs of `size` from rank 0 to rank 1 and drain them;
/// returns nothing — timing wraps the call.
fn exchange(t: &Arc<dyn Transport<CalMsg>>, size: usize) {
    let tx = Arc::clone(t);
    let sender = std::thread::spawn(move || {
        let blob = vec![0xA5u8; size];
        for i in 0..MSGS {
            tx.send(0, 1, i as u64, CalMsg::Blob(blob.clone())).expect("calibration send");
        }
        tx.send(0, 1, MSGS as u64, CalMsg::Done).expect("calibration done");
    });
    let mut got = 0usize;
    loop {
        match t.recv(1).expect("transport open").msg {
            CalMsg::Blob(b) => {
                std::hint::black_box(b.len());
                got += 1;
            }
            CalMsg::Done => break,
        }
    }
    assert_eq!(got, MSGS);
    sender.join().expect("sender thread");
}

/// Least-squares fit of per-message ns vs bytes → (alpha_us, beta_gbps).
/// 1 byte/ns = 1 GB/s, so beta is the reciprocal slope directly; the
/// slope is clamped to keep `inproc` (which moves pointers, not bytes)
/// from reporting infinite bandwidth.
fn fit(points: &[(f64, f64)]) -> (f64, f64) {
    let n = points.len() as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let slope = ((n * sxy - sx * sy) / (n * sxx - sx * sx)).max(1e-6); // ns/byte
    let intercept = ((sy - slope * sx) / n).max(0.0); // ns
    (intercept / 1e3, 1.0 / slope)
}

fn calibrate(b: &Bencher, kind: TransportKind) -> (f64, f64, Vec<(f64, f64)>) {
    let make = || -> Arc<dyn Transport<CalMsg>> {
        match kind {
            TransportKind::Inproc => Arc::new(InProcTransport::new(2)),
            TransportKind::Shm => Arc::new(RingTransport::new(2)),
            TransportKind::Uds => {
                Arc::new(SocketTransport::bind_world(2).expect("socket transport binds"))
            }
        }
    };
    let mut points = Vec::new();
    for &size in &SIZES {
        let t = make();
        let r = b.run(&format!("wire_{kind}_{size}B"), || exchange(&t, size));
        points.push((size as f64, r.mean_ns / MSGS as f64));
    }
    let (alpha_us, beta_gbps) = fit(&points);
    println!(
        "  {kind:<6}  alpha {alpha_us:8.2} µs/msg   beta {beta_gbps:8.2} GB/s   ({} sizes × {MSGS} msgs)",
        SIZES.len()
    );
    (alpha_us, beta_gbps, points)
}

fn cell(alpha_us: f64, beta_gbps: f64, points: &[(f64, f64)]) -> Json {
    Json::obj(vec![
        ("alpha_us", Json::num(alpha_us)),
        ("beta_gbps", Json::num(beta_gbps)),
        (
            "sweep_ns_per_msg",
            Json::arr(
                points
                    .iter()
                    .map(|&(bytes, ns)| {
                        Json::obj(vec![("bytes", Json::num(bytes)), ("ns", Json::num(ns))])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn main() {
    let b = Bencher::default();
    println!("== wire calibration: t(bytes) = alpha + bytes/beta per transport ==\n");
    let (ai, bi, pi) = calibrate(&b, TransportKind::Inproc);
    let (as_, bs, ps) = calibrate(&b, TransportKind::Shm);
    let (au, bu, pu) = calibrate(&b, TransportKind::Uds);

    let json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("generated_by", Json::str("cargo bench --bench wire_calib")),
        // headline = the uds cell (closest analogue of a real NIC path)
        ("alpha_us", Json::num(au)),
        ("beta_gbps", Json::num(bu)),
        (
            "config",
            Json::obj(vec![
                ("msgs_per_exchange", Json::num(MSGS as f64)),
                ("sizes", Json::arr(SIZES.iter().map(|&s| Json::num(s as f64)).collect())),
                ("bench_iters", Json::num(b.iters as f64)),
            ]),
        ),
        (
            "transports",
            Json::obj(vec![
                ("inproc", cell(ai, bi, &pi)),
                ("shm", cell(as_, bs, &ps)),
                ("uds", cell(au, bu, &pu)),
            ]),
        ),
        (
            "notes",
            Json::str(
                "Least-squares fit of mean per-message wall time vs payload bytes over \
                 a streamed (pipelined, fusion-eligible) 0->1 push pattern, the mailbox \
                 traffic shape the one-sided backends generate. alpha_us maps to \
                 Topology::latency and beta_gbps (GB/s) to Topology::intra_bw when \
                 SimConfig loads a cell (`odc sim --transport shm|uds`). The headline \
                 alpha_us/beta_gbps mirror the uds cell.",
            ),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_wire.json");
    std::fs::write(path, json.dump() + "\n").expect("writing BENCH_wire.json");
    println!("\n  wrote {path}");
}
