//! Ablations for the paper's §6.2 future-work directions + the design
//! choices DESIGN.md calls out.
//!
//! 1. Hierarchical gather (node-leader caching): inter-node ODC traffic
//!    /G — how much of the flat-p2p penalty does it recover?
//! 2. Heavy-micro alignment in LB-Micro (sorting microbatches desc so
//!    heavy ones share a barrier index) — on vs off.

use odc::balance::cost::CostModel;
use odc::balance::packers::{plan_run, Plan};
use odc::comm::topology::Topology;
use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, Sharding};
use odc::data::distributions::sample_lengths;
use odc::report::Table;
use odc::sim::run::{simulate, SimConfig};
use odc::sim::timeline::time_minibatch;
use odc::util::rng::Rng;

fn main() {
    hierarchical_gather();
    alignment_ablation();
}

/// §6.2 hierarchical gather at multi-node scale, short-context workload
/// (where comm is exposed — same setting as Fig 12).
fn hierarchical_gather() {
    println!("== Ablation: §6.2 hierarchical gather (truncated LongAlign 8K, 1.5B) ==\n");
    let mut t = Table::new(&["devices", "collective", "ODC flat p2p", "ODC hierarchical", "hier/flat"]);
    for devices in [16usize, 32] {
        let mk = |scheme, hier| {
            let exp = ExperimentConfig {
                model: PaperModel::M1_5B,
                dataset: Dataset::LongAlign,
                scheme,
                balancer: Balancer::LbMicro,
                sharding: Sharding::Full,
                minibs: 4,
                devices,
                devices_per_node: 8,
                packing_ratio: 1.0,
                max_len: 8_192,
                steps: 12,
                seed: 5,
            };
            let mut cfg = SimConfig::new(exp);
            cfg.hierarchical_gather = hier;
            simulate(&cfg).samples_per_sec_per_device
        };
        let col = mk(CommScheme::Collective, false);
        let flat = mk(CommScheme::Odc, false);
        let hier = mk(CommScheme::Odc, true);
        t.row(vec![
            devices.to_string(),
            format!("{col:.3}"),
            format!("{flat:.3}"),
            format!("{hier:.3}"),
            format!("{:.2}x", hier / flat),
        ]);
    }
    println!("{}", t.markdown());
}

/// DESIGN.md design choice: LB-Micro sorts each device's microbatches by
/// cost desc so heavy microbatches align on the same barrier index.
/// Compare the collective wall time with aligned vs shuffled micro order.
fn alignment_ablation() {
    println!("== Ablation: heavy-microbatch alignment under collective barriers ==\n");
    let cost = CostModel::for_model(PaperModel::M1_5B);
    let topo = Topology::paper(8, 8);
    let mut rng = Rng::new(9);
    let lens = sample_lengths(Dataset::LongAlign, None, 8 * 8 * 16, &mut rng);
    let mut plan_rng = Rng::new(10);
    let plans = plan_run(Balancer::LbMicro, &lens, 8, 8, 65_536, &cost, &mut plan_rng);

    let wall = |ps: &[Plan]| -> f64 {
        ps.iter()
            .map(|p| {
                time_minibatch(p, &lens, PaperModel::M1_5B, &cost, CommScheme::Collective, Sharding::Full, &topo).wall
            })
            .sum()
    };
    let aligned = wall(&plans);

    // shuffle each device's microbatch order (de-align)
    let mut shuf_rng = Rng::new(11);
    let shuffled: Vec<Plan> = plans
        .iter()
        .map(|p| {
            let mut q = p.clone();
            for dev in q.micro.iter_mut() {
                shuf_rng.shuffle(dev);
            }
            q
        })
        .collect();
    let dealigned = wall(&shuffled);

    let mut t = Table::new(&["micro order", "total wall (s)", "vs aligned"]);
    t.row(vec!["aligned (sorted desc)".into(), format!("{aligned:.2}"), "1.00x".into()]);
    t.row(vec!["shuffled".into(), format!("{dealigned:.2}"), format!("{:.2}x", dealigned / aligned)]);
    println!("{}", t.markdown());
    println!("(ODC is invariant to microbatch order — only the barrier scheme cares.)");
}
