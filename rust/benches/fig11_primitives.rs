//! Figure 11 + Table 2: communication-primitive bandwidth.
//!
//! Intra-node: measured on the REAL shared-memory backends (this testbed
//! is one "node"); devices are threads, so absolute numbers reflect host
//! memcpy bandwidth, but the comparison ODC-vs-collective is live.
//! Inter-node: reported from the Appendix D analytic model (Table 2
//! volumes over the paper's NVSwitch/RoCE bandwidths).

use odc::comm::primbench::{bench_primitive, Primitive};
use odc::comm::topology::Topology;
use odc::comm::volume;
use odc::report::Table;

fn main() {
    let full = std::env::var("ODC_BENCH_FULL").is_ok();
    let elems: usize = if full { 1 << 22 } else { 1 << 18 }; // f32 buffer
    let iters = if full { 20 } else { 5 };

    println!("== Fig 11 (intra-node, measured): primitive completion bandwidth ==");
    println!("   buffer = {} MiB, {} iters\n", elems * 4 >> 20, iters);
    let mut t = Table::new(&["primitive", "devices=2", "4", "8"]);
    for prim in [Primitive::AllGather, Primitive::Gather, Primitive::ReduceScatter, Primitive::ScatterAccumulate] {
        let mut cells = vec![prim.label().to_string()];
        for world in [2usize, 4, 8] {
            let r = bench_primitive(prim, world, elems, iters);
            cells.push(format!("{:.2} GB/s", r.gbps));
        }
        t.row(cells);
    }
    println!("{}", t.markdown());

    println!("== Fig 11 (inter-node, analytic — Table 2 volumes / paper bandwidths) ==\n");
    let layer_bytes = 64.0 * 1e6; // 64 MB layer
    let mut t2 = Table::new(&["devices", "collective ring (ms)", "ODC p2p (ms)", "ODC/collective"]);
    for d in [8usize, 16, 32, 64] {
        let topo = Topology::paper(d, 8);
        let c = volume::layer_op_time(false, layer_bytes, &topo) * 1e3;
        let o = volume::layer_op_time(true, layer_bytes, &topo) * 1e3;
        t2.row(vec![format!("{d}"), format!("{c:.3}"), format!("{o:.3}"), format!("{:.2}x", o / c)]);
    }
    println!("{}", t2.markdown());

    println!("== Table 2: per-client volume split (K = per-device shard bytes) ==\n");
    let k = 1.0;
    let mut t3 = Table::new(&["method", "intra-node (xK)", "inter-node (xK)", "total (xK)"]);
    for (name, v) in [
        ("Collective all-gather (ring)", volume::collective_ring(32, 8, k)),
        ("ODC gather", volume::odc_p2p(32, 8, k)),
        ("Collective reduce-scatter (ring)", volume::collective_ring(32, 8, k)),
        ("ODC scatter-accumulate", volume::odc_p2p(32, 8, k)),
    ] {
        t3.row(vec![name.to_string(), format!("{:.2}", v.intra), format!("{:.2}", v.inter), format!("{:.2}", v.total())]);
    }
    println!("(D=32, G=8)\n{}", t3.markdown());
}
