//! Table 3 / Figure 9: RL (GRPO on AIME) samples/s/device, including
//! verl's Native balancer. RL mode constrains LB-Mini to equal sample
//! counts per device (§5.2-a). ODC_BENCH_FULL=1 adds the 14B model.

use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel};
use odc::report::{pct_delta, Table};
use odc::sim::run::simulate_cell;

fn main() {
    let full = std::env::var("ODC_BENCH_FULL").is_ok();
    let models: Vec<PaperModel> =
        if full { vec![PaperModel::M1_5B, PaperModel::M7B, PaperModel::M14B] } else { vec![PaperModel::M1_5B, PaperModel::M7B] };
    let steps = if full { 16 } else { 8 };
    let minibs_grid = [2usize, 4, 8, 16];

    println!("== Table 3 / Fig 9: RL (AIME) samples/s/device ==\n");
    for &model in &models {
        let devices = if model == PaperModel::M14B { 16 } else { 8 };
        let _ = ExperimentConfig::paper_devices(model);
        let run = |scheme, bal, mb| {
            simulate_cell(model, Dataset::Aime, scheme, bal, mb, devices, steps, 5).samples_per_sec_per_device
        };
        let methods: Vec<(&str, CommScheme, Balancer)> = vec![
            ("Collective Native", CommScheme::Collective, Balancer::VerlNative),
            ("Collective LB-Micro", CommScheme::Collective, Balancer::LbMicro),
            ("ODC LB-Micro", CommScheme::Odc, Balancer::LbMicro),
            ("ODC LB-Mini", CommScheme::Odc, Balancer::LbMini),
        ];
        let vals: Vec<Vec<f64>> =
            methods.iter().map(|&(_, s, b)| minibs_grid.iter().map(|&mb| run(s, b, mb)).collect()).collect();
        let mut t = Table::new(&["method", "minibs=2", "4", "8", "16"]);
        for (i, (name, ..)) in methods.iter().enumerate() {
            let mut cells = vec![name.to_string()];
            for j in 0..minibs_grid.len() {
                let v = vals[i][j];
                if i >= 2 {
                    cells.push(format!("{v:.3} {}", pct_delta(v, vals[1][j])));
                } else {
                    cells.push(format!("{v:.3}"));
                }
            }
            t.row(cells);
        }
        println!("{model} on AIME ({devices} devices):\n{}", t.markdown());
    }
}
