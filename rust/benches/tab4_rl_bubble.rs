//! Table 4: RL bubble rates (packing-algorithm estimate), per method and
//! minibatch size.

use odc::config::{Balancer, CommScheme, Dataset, PaperModel};
use odc::report::Table;
use odc::sim::run::simulate_cell;

fn main() {
    let full = std::env::var("ODC_BENCH_FULL").is_ok();
    let models: Vec<(PaperModel, usize)> = if full {
        vec![(PaperModel::M1_5B, 8), (PaperModel::M7B, 8), (PaperModel::M14B, 16)]
    } else {
        vec![(PaperModel::M1_5B, 8)]
    };
    let steps = 16;
    let minibs_grid = [2usize, 4, 8, 16];

    println!("== Table 4: RL (AIME) bubble rate %, estimated by the packer ==\n");
    for (model, devices) in models {
        let mut t = Table::new(&["method", "minibs=2", "4", "8", "16"]);
        for (name, scheme, bal) in [
            ("Collective Native", CommScheme::Collective, Balancer::VerlNative),
            ("Collective LB-Micro", CommScheme::Collective, Balancer::LbMicro),
            ("ODC LB-Micro", CommScheme::Odc, Balancer::LbMicro),
            ("ODC LB-Mini", CommScheme::Odc, Balancer::LbMini),
        ] {
            let mut cells = vec![name.to_string()];
            for &mb in &minibs_grid {
                let r = simulate_cell(model, Dataset::Aime, scheme, bal, mb, devices, steps, 5);
                cells.push(format!("{:.2}", 100.0 * r.bubble_rate));
            }
            t.row(cells);
        }
        println!("{model} ({devices} devices):\n{}", t.markdown());
    }
}
