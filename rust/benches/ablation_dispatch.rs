//! DynDispatch ablation: static vs work-queue dispatch as one device
//! slows down.
//!
//! Sweeps a single-device slowdown (1×, 2×, 4×, 8×) over the same
//! LB-Mini-packed cells and prices both dispatch policies with the
//! timeline simulator: `Balancer::LbMini` replays the static plan
//! (placement fixed from predicted cost) while `Balancer::Queue` pulls
//! the identical microbatches LPT-first at runtime, so fast devices
//! absorb the straggler's share. Reported per cell: samples/s/device,
//! device utilization, and the absolute bubble time
//! (`RunResult::dispatch_wait_s` — device-seconds idle against the
//! dispatch source).
//!
//! Writes `BENCH_dispatch.json` at the repo root with the full sweep
//! and the acceptance gate `queue_lower_bubble_at_4x` (queue must show
//! STRICTLY lower bubble time than static LB-Mini at the 4× slowdown);
//! CI's bench smoke step fails on malformed output.

use odc::balance::cost::CostModel;
use odc::balance::dispatch::queue_busy_split;
use odc::balance::packers::{plan_run_split, PackOpts};
use odc::balance::SplitMode;
use odc::comm::FaultPlan;
use odc::config::{Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, Sharding};
use odc::report::{pct, pct_delta, Table};
use odc::sim::run::{simulate, RunResult, SimConfig};
use odc::util::json::Json;
use odc::util::rng::Rng;

const DEVICES: usize = 4;
const SLOWDOWNS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
/// ChaosComm pricing cell: a fixed transient fault plan over the same
/// 1x cell, tracked by the trend gate as a retained-throughput fraction.
const CHAOS_PLAN: &str = "drop=0.05,dup=0.02,reorder=0.05,seed=7";

fn run(balancer: Balancer, slowdown: f64) -> RunResult {
    run_plan(balancer, slowdown, "")
}

fn run_plan(balancer: Balancer, slowdown: f64, fault_plan: &str) -> RunResult {
    let exp = ExperimentConfig {
        model: PaperModel::M1_5B,
        dataset: Dataset::LongAlign,
        scheme: CommScheme::Odc,
        balancer,
        sharding: Sharding::Full,
        minibs: 8,
        devices: DEVICES,
        devices_per_node: DEVICES,
        packing_ratio: 1.0,
        max_len: 65_536,
        steps: 8,
        seed: 7,
    };
    let mut cfg = SimConfig::new(exp);
    if slowdown > 1.0 {
        let mut speeds = vec![1.0; DEVICES];
        speeds[0] = 1.0 / slowdown; // device 0 is the straggler
        cfg.device_speed = speeds;
    }
    cfg.fault_plan = FaultPlan::parse(fault_plan).expect("bench fault plan parses");
    simulate(&cfg)
}

/// SeqSplit pricing cell: a dominant-sequence minibatch — one 64k
/// document plus short context filling exactly one minibatch — priced
/// with and without context-parallel splitting through the SAME shared
/// makespan kernel (`dispatch::queue_busy_split`) the timeline and the
/// bubble estimator use. Returns (unsplit makespan s, split makespan s,
/// reduction fraction). Fully deterministic: no wall-clock sampling.
fn seqsplit_cell() -> (f64, f64, f64) {
    let cost = CostModel::for_model(PaperModel::M1_5B);
    let mut lens = vec![2_048usize; 2 * DEVICES - 1];
    lens.push(65_536); // the dominant straggler: no whole-sequence packing can beat it
    let makespan = |frac: f64| -> f64 {
        let mut rng = Rng::new(7);
        let (plans, split) = plan_run_split(
            Balancer::Queue,
            &lens,
            DEVICES,
            2,
            65_536,
            &cost,
            &mut rng,
            PackOpts::default(),
            frac,
            SplitMode::Zigzag,
        );
        plans
            .iter()
            .map(|p| {
                queue_busy_split(p, &lens, &cost, &split, |flops, _| cost.seconds(flops))
                    .into_iter()
                    .fold(0.0, f64::max)
            })
            .sum()
    };
    let unsplit = makespan(0.0);
    let with_split = makespan(0.5);
    (unsplit, with_split, 1.0 - with_split / unsplit)
}

/// AsyncPS pricing cell: the Queue cell at the 4× slowdown, priced with
/// (`Some(2)`) and without (`None`) the bounded-staleness admission
/// schedule. Identical per-step timelines — only the end-of-minibatch
/// barrier differs — so the throughput ratio isolates exactly the
/// overlap AsyncPS buys. Fully deterministic (timeline simulator).
fn async_cell(staleness: Option<usize>) -> RunResult {
    let exp = ExperimentConfig {
        model: PaperModel::M1_5B,
        dataset: Dataset::LongAlign,
        scheme: CommScheme::Odc,
        balancer: Balancer::Queue,
        sharding: Sharding::Full,
        minibs: 8,
        devices: DEVICES,
        devices_per_node: DEVICES,
        packing_ratio: 1.0,
        max_len: 65_536,
        steps: 8,
        seed: 7,
    };
    let mut cfg = SimConfig::new(exp);
    let mut speeds = vec![1.0; DEVICES];
    speeds[0] = 0.25; // device 0 is a 4x straggler
    cfg.device_speed = speeds;
    cfg.staleness = staleness;
    simulate(&cfg)
}

fn main() {
    println!("== dispatch ablation: static (LB-Mini) vs work queue, device 0 slowing down ==");
    println!("   1.5B LongAlign, ODC, {DEVICES} devices, minibs=8, 8 minibatches\n");

    let mut t = Table::new(&["slowdown", "static s/s/dev", "queue s/s/dev", "static bubble s", "queue bubble s", "static util", "queue util"]);
    let mut rows = Vec::new();
    let mut queue_lower_bubble_at_4x = false;
    for &slow in &SLOWDOWNS {
        let stat = run(Balancer::LbMini, slow);
        let dyn_ = run(Balancer::Queue, slow);
        if slow == 4.0 {
            queue_lower_bubble_at_4x = dyn_.dispatch_wait_s < stat.dispatch_wait_s;
        }
        t.row(vec![
            format!("{slow:.0}x"),
            format!("{:.3}", stat.samples_per_sec_per_device),
            format!("{:.3} {}", dyn_.samples_per_sec_per_device, pct_delta(dyn_.samples_per_sec_per_device, stat.samples_per_sec_per_device)),
            format!("{:.3}", stat.dispatch_wait_s),
            format!("{:.3}", dyn_.dispatch_wait_s),
            pct(stat.device_utilization),
            pct(dyn_.device_utilization),
        ]);
        rows.push(Json::obj(vec![
            ("slowdown", Json::num(slow)),
            ("static_samples_per_sec_per_device", Json::num(stat.samples_per_sec_per_device)),
            ("queue_samples_per_sec_per_device", Json::num(dyn_.samples_per_sec_per_device)),
            ("static_bubble_time_s", Json::num(stat.dispatch_wait_s)),
            ("queue_bubble_time_s", Json::num(dyn_.dispatch_wait_s)),
            ("static_device_utilization", Json::num(stat.device_utilization)),
            ("queue_device_utilization", Json::num(dyn_.device_utilization)),
        ]));
    }
    println!("{}", t.markdown());
    println!(
        "queue bubble strictly below static at 4x slowdown: {}",
        if queue_lower_bubble_at_4x { "yes" } else { "NO (acceptance regression)" }
    );

    // ChaosComm: the same uniform-speed cell under a lossy transport —
    // the trend gate tracks the retained-throughput fraction so retry
    // pricing cannot silently get more expensive.
    let clean = run(Balancer::LbMini, 1.0);
    let chaos = run_plan(Balancer::LbMini, 1.0, CHAOS_PLAN);
    let retained = chaos.samples_per_sec_per_device / clean.samples_per_sec_per_device;
    println!(
        "\nchaos overhead ({CHAOS_PLAN}): {} retries, {} retransmitted bytes, \
         retained throughput {}",
        chaos.retries,
        chaos.retransmitted_bytes,
        pct(retained)
    );

    // SeqSplit: the dominant-corpus cell — the fraction of the
    // straggler-pinned makespan that context-parallel splitting shears
    // off. Trend-tracked and held to an absolute 0.15 floor.
    let (unsplit_ms, split_ms, reduction) = seqsplit_cell();
    println!(
        "\nseqsplit dominant-corpus cell (frac=0.5, zigzag, {DEVICES} devices): \
         unsplit makespan {unsplit_ms:.3}s, split {split_ms:.3}s, reduction {}",
        pct(reduction)
    );

    // AsyncPS: the same Queue cell with the end-of-minibatch barrier
    // replaced by bounded-staleness (k=2) admission — the trend gate
    // tracks the whole-run throughput gain so the overlap win cannot
    // silently erode.
    let sync_r = async_cell(None);
    let async_r = async_cell(Some(2));
    let async_gain =
        async_r.samples_per_sec_per_device / sync_r.samples_per_sec_per_device - 1.0;
    println!(
        "\nasyncps 4x-straggler cell (k=2, queue, {DEVICES} devices): \
         sync {:.3} s/s/dev, async {:.3} s/s/dev ({} gain), staleness p99 {:.1}",
        sync_r.samples_per_sec_per_device,
        async_r.samples_per_sec_per_device,
        pct_delta(async_r.samples_per_sec_per_device, sync_r.samples_per_sec_per_device),
        async_r.staleness_p99
    );

    let json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("measured", Json::Bool(true)),
        ("generated_by", Json::str("cargo bench --bench ablation_dispatch")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str("1.5B")),
                ("dataset", Json::str("LongAlign")),
                ("scheme", Json::str("ODC")),
                ("devices", Json::num(DEVICES as f64)),
                ("minibs", Json::num(8.0)),
                ("steps", Json::num(8.0)),
                ("straggler_device", Json::num(0.0)),
            ]),
        ),
        ("rows", Json::arr(rows)),
        ("queue_lower_bubble_at_4x", Json::Bool(queue_lower_bubble_at_4x)),
        (
            "chaos",
            Json::obj(vec![
                ("fault_plan", Json::str(CHAOS_PLAN)),
                ("retries", Json::num(chaos.retries as f64)),
                ("retransmitted_bytes", Json::num(chaos.retransmitted_bytes as f64)),
                ("escalations", Json::num(chaos.escalations as f64)),
                ("clean_samples_per_sec_per_device", Json::num(clean.samples_per_sec_per_device)),
                ("chaos_samples_per_sec_per_device", Json::num(chaos.samples_per_sec_per_device)),
                ("retained_throughput_fraction", Json::num(retained)),
            ]),
        ),
        (
            "seqsplit",
            Json::obj(vec![
                ("frac", Json::num(0.5)),
                ("mode", Json::str("zigzag")),
                ("devices", Json::num(DEVICES as f64)),
                ("unsplit_makespan_s", Json::num(unsplit_ms)),
                ("split_makespan_s", Json::num(split_ms)),
                ("makespan_reduction_fraction", Json::num(reduction)),
            ]),
        ),
        (
            "async",
            Json::obj(vec![
                ("staleness", Json::num(2.0)),
                ("slowdown", Json::num(4.0)),
                ("sync_samples_per_sec_per_device", Json::num(sync_r.samples_per_sec_per_device)),
                ("async_samples_per_sec_per_device", Json::num(async_r.samples_per_sec_per_device)),
                ("async_whole_run_samples_per_sec", Json::num(async_r.async_throughput)),
                ("staleness_p99", Json::num(async_r.staleness_p99)),
                ("throughput_gain_fraction", Json::num(async_gain)),
            ]),
        ),
        (
            "notes",
            Json::str(
                "Deterministic timeline-simulator sweep (no wall-clock sampling): both \
                 policies run the SAME LB-Mini-packed microbatches; only placement differs. \
                 bubble_time_s is RunResult::dispatch_wait_s — device-seconds idle against \
                 the dispatch source during the microbatch phases.",
            ),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dispatch.json");
    std::fs::write(path, json.dump() + "\n").expect("writing BENCH_dispatch.json");
    println!("\n  wrote {path}");
}
