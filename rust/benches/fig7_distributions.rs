//! Figure 7: sequence-length distributions of the evaluation datasets.
//! Prints summary percentiles + an ASCII log-bucket histogram per
//! dataset (the synthetic fits behind every simulated experiment).

use odc::config::Dataset;
use odc::data::distributions::{sample_lengths, summarize};
use odc::report::{ascii_hist, Table};
use odc::util::rng::Rng;

fn main() {
    let n = 50_000;
    println!("== Figure 7: sequence length distributions (n={n} draws each) ==\n");
    let mut t = Table::new(&["dataset", "p50", "p90", "p99", "max", "mean"]);
    for ds in [Dataset::LongAlign, Dataset::SweSmith, Dataset::Aime] {
        let mut rng = Rng::new(7);
        let lens = sample_lengths(ds, None, n, &mut rng);
        let (p50, p90, p99, max, mean) = summarize(&lens);
        t.row(vec![
            ds.to_string(),
            format!("{p50:.0}"),
            format!("{p90:.0}"),
            format!("{p99:.0}"),
            format!("{max}"),
            format!("{mean:.0}"),
        ]);
    }
    println!("{}", t.markdown());

    for ds in [Dataset::LongAlign, Dataset::SweSmith, Dataset::Aime] {
        let mut rng = Rng::new(7);
        let lens = sample_lengths(ds, None, n, &mut rng);
        // log2 buckets from 256 to 64K
        let mut buckets = vec![0usize; 9];
        for &l in &lens {
            let b = ((l as f64 / 256.0).log2().floor() as i64).clamp(0, 8) as usize;
            buckets[b] += 1;
        }
        println!("{ds} (tokens, log2 buckets from 256):");
        println!("{}\n", ascii_hist(&buckets, 48));
    }
}
