//! L3 hot-path microbenchmarks (the §Perf targets): planner cost, the
//! simulator inner loop, KK partitioning, and the comm backends' data
//! path — including the zero-copy pieces (minibatch-scoped gather
//! cache, per-pair payload arenas). Uses the in-repo bench harness
//! (criterion is unavailable offline). ODC_BENCH_ITERS to increase
//! sampling. The machine-readable perf record is emitted by the
//! companion `comm_path` bench (BENCH_hotpath.json).

use odc::balance::cost::CostModel;
use odc::balance::kk::karmarkar_karp;
use odc::balance::packers::plan_run;
use odc::comm::backend::{CommBackend, ParamStore};
use odc::comm::primbench::{bench_primitive, Primitive};
use odc::comm::shared::SharedBuf;
use odc::comm::{CommStack, GatherCache};
use odc::config::{Balancer, Dataset, ExperimentConfig, PaperModel};
use odc::sim::run::{simulate, SimConfig};
use odc::util::bench::Bencher;
use odc::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let b = Bencher::default();
    println!("== L3 hot-path microbenchmarks ==\n");

    // Karmarkar–Karp at planner scale
    let mut rng = Rng::new(3);
    let costs: Vec<f64> = (0..256).map(|_| rng.f64() * 1e15).collect();
    b.run("kk_256x8_equal", || karmarkar_karp(&costs, 8, true));
    b.run("kk_256x8_free", || karmarkar_karp(&costs, 8, false));

    // whole-run planning (the per-step scheduler cost)
    let cost = CostModel::for_model(PaperModel::M1_5B);
    let mut rng2 = Rng::new(4);
    let lens: Vec<usize> = (0..512).map(|_| (rng2.lognormal(9.0, 0.8) as usize).clamp(32, 65_536)).collect();
    for bal in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative] {
        b.run(&format!("plan_512samples_{bal}"), || {
            let mut r = Rng::new(5);
            plan_run(bal, &lens, 8, 4, 65_536, &cost, &mut r)
        });
    }

    // one simulated experiment cell end-to-end
    let mut exp = ExperimentConfig::golden();
    exp.dataset = Dataset::LongAlign;
    exp.steps = 8;
    b.run("simulate_golden_8steps", || simulate(&SimConfig::new(exp.clone())));

    // shared-memory window ops (the gather/scatter data path)
    let buf = SharedBuf::new(1 << 20);
    let src = vec![1.0f32; 1 << 20];
    let mut dst = vec![0.0f32; 1 << 20];
    b.run("sharedbuf_write_4MiB", || buf.write(0, &src));
    b.run("sharedbuf_read_4MiB", || buf.read(0, &mut dst));
    b.run("sharedbuf_accumulate_4MiB", || buf.accumulate(0, &src, 0.5));

    // full backend primitives at engine scale (2 and 4 device threads)
    for world in [2usize, 4] {
        for prim in [Primitive::Gather, Primitive::ScatterAccumulate] {
            let r = bench_primitive(prim, world, 1 << 18, 3);
            println!("{:<44} {:>10.3} ms/op   ({:.2} GB/s, {} dev)", format!("prim_{}_{world}dev", r.name), r.secs * 1e3, r.gbps, world);
        }
    }

    // zero-copy hot path: cached gather vs seed per-call gather, and the
    // arena-backed reduce push (proves the §6.2 caching + Appendix B
    // buffer wins at engine scale)
    // (single device+daemon so the drain below can't block on peers)
    let params = Arc::new(ParamStore::new(&[1 << 20], 1));
    let comm =
        CommStack::builder(Arc::clone(&params), 1).build_odc().expect("in-process odc stack");
    let mut direct = vec![0.0f32; params.layers[0].padded_len()];
    b.run("gather_direct_4MiB", || comm.gather_params(0, 0, &mut direct));
    let mut cache = GatherCache::new(&params, 0, true);
    let _ = cache.gather(comm.as_ref(), 0); // one real gather per minibatch…
    b.run("gather_cached_4MiB", || std::hint::black_box(cache.gather(comm.as_ref(), 0)));
    // one full reduce+drain cycle per iteration: the arena is back to
    // steady state after every end_minibatch, so the counters below
    // measure the warm path (bounded in-flight), not producer backlog
    let grad = vec![0.5f32; params.layers[0].padded_len()];
    let mut gshard = vec![0.0f32; params.layers[0].shard_len];
    b.run("reduce_drain_cycle_4MiB", || {
        comm.reduce_grad(0, 0, &grad, 1.0, 0);
        comm.end_minibatch(0);
        comm.take_grad_shard(0, 0, &mut gshard);
        comm.end_step(0);
    });
    let stats = comm.arena_stats();
    println!(
        "{:<44} {:>10} acquires, {} fresh allocs (warm push path)",
        "odc_payload_arena_counters", stats.acquires, stats.fresh_allocs
    );

    // param store construction (allocation cost at trainer startup)
    b.run("paramstore_new_13M", || ParamStore::new(&[4_200_000, 790_000, 790_000, 790_000, 790_000], 4));
    let _ = Arc::new(());
}
