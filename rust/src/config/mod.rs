//! Experiment configuration: typed configs, paper presets, JSON I/O.
//!
//! Every paper experiment cell (model × dataset × devices × minibatch ×
//! method) is expressible as an [`ExperimentConfig`]; `presets` holds the
//! golden setting (Table 1) and the grids behind Tables 3–6 / Figs 8–12.

use crate::util::json::Json;
use std::fmt;

/// Wire payload precision for gradient pushes (FastFold). Defined in
/// [`crate::comm::fold`] next to its codecs; re-exported here because it
/// is a first-class experiment knob alongside [`CommScheme`].
pub use crate::comm::fold::WireDtype;

pub mod runspec;
pub use runspec::RunSpec;

/// Paper evaluation models (DeepSeek-R1-Distill-Qwen family shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PaperModel {
    M1_5B,
    M7B,
    M14B,
    M32B,
}

impl PaperModel {
    pub fn all() -> [PaperModel; 4] {
        [PaperModel::M1_5B, PaperModel::M7B, PaperModel::M14B, PaperModel::M32B]
    }

    /// (layers, hidden, params) of the underlying Qwen2.5 shapes.
    pub fn shape(self) -> (usize, usize, f64) {
        match self {
            PaperModel::M1_5B => (28, 1536, 1.54e9),
            PaperModel::M7B => (28, 3584, 7.62e9),
            PaperModel::M14B => (48, 5120, 14.77e9),
            PaperModel::M32B => (64, 5120, 32.76e9),
        }
    }

    pub fn layers(self) -> usize {
        self.shape().0
    }

    pub fn hidden(self) -> usize {
        self.shape().1
    }

    pub fn params(self) -> f64 {
        self.shape().2
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "1.5b" | "1_5b" | "m1_5b" => Some(PaperModel::M1_5B),
            "7b" | "m7b" => Some(PaperModel::M7B),
            "14b" | "m14b" => Some(PaperModel::M14B),
            "32b" | "m32b" => Some(PaperModel::M32B),
            _ => None,
        }
    }
}

impl fmt::Display for PaperModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PaperModel::M1_5B => "1.5B",
            PaperModel::M7B => "7B",
            PaperModel::M14B => "14B",
            PaperModel::M32B => "32B",
        };
        write!(f, "{s}")
    }
}

/// Evaluation datasets (Fig 7 distributions; synthetic fits in `data`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    LongAlign,
    SweSmith,
    Aime,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "longalign" => Some(Dataset::LongAlign),
            "swesmith" | "swe-smith" => Some(Dataset::SweSmith),
            "aime" => Some(Dataset::Aime),
            _ => None,
        }
    }
}

impl fmt::Display for Dataset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dataset::LongAlign => "LongAlign",
            Dataset::SweSmith => "SWE-Smith",
            Dataset::Aime => "AIME",
        };
        write!(f, "{s}")
    }
}

/// Communication scheme under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CommScheme {
    /// Baseline: ring all-gather / reduce-scatter, per-layer barriers.
    Collective,
    /// The paper's contribution: p2p gather / scatter-accumulate,
    /// one barrier per minibatch.
    Odc,
    /// §6.1 two-level hybrid sharding: params/grads sharded within a
    /// node group (intra-group gathers/reduces), optimizer shards across
    /// all devices with an ODC-style cross-group epilogue. Devices
    /// free-run within the minibatch exactly like ODC (LB-Mini legal).
    Hybrid,
}

impl CommScheme {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "collective" => Some(CommScheme::Collective),
            "odc" => Some(CommScheme::Odc),
            "hybrid" => Some(CommScheme::Hybrid),
            _ => None,
        }
    }
}

impl fmt::Display for CommScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", match self {
            CommScheme::Collective => "Collective",
            CommScheme::Odc => "ODC",
            CommScheme::Hybrid => "Hybrid",
        })
    }
}

/// Load-balancing algorithm (§5.1 and Appendix C) — or, for
/// [`Balancer::Queue`], the runtime dispatch policy layered on top of
/// LB-Mini's packing (see `balance::dispatch`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Balancer {
    /// Sort by length on each device, no packing (LongAlign-style).
    LocalSort,
    /// Microbatch-level packing, equal microbatch count per device.
    LbMicro,
    /// Minibatch-level balancing (ODC only): per-device microbatch count.
    LbMini,
    /// verl's native two-level partitioning (Listing 2) — RL baseline.
    VerlNative,
    /// Dynamic dispatch (barrier-free schemes only): LB-Mini packing,
    /// then a shared work queue that free-running devices pull from at
    /// runtime in LPT order — placement follows ACTUAL device progress
    /// instead of predicted cost, absorbing cost-model error and
    /// stragglers.
    Queue,
}

impl Balancer {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "local-sort" | "localsort" => Some(Balancer::LocalSort),
            "lb-micro" | "lbmicro" => Some(Balancer::LbMicro),
            "lb-mini" | "lbmini" => Some(Balancer::LbMini),
            "native" | "verl-native" | "verl" => Some(Balancer::VerlNative),
            "queue" | "work-queue" => Some(Balancer::Queue),
            _ => None,
        }
    }

    /// Whether this balancer may run under `scheme`. The per-layer
    /// rendezvous of `Collective` forces equal microbatch counts per
    /// device, which LB-Mini's unequal counts and the work queue's
    /// runtime placement both violate; the barrier-free schemes accept
    /// everything (see the legality table in `balance`'s module docs).
    pub fn legal_under(self, scheme: CommScheme) -> bool {
        match self {
            Balancer::LbMini | Balancer::Queue => scheme != CommScheme::Collective,
            Balancer::LocalSort | Balancer::LbMicro | Balancer::VerlNative => true,
        }
    }
}

impl fmt::Display for Balancer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", match self {
            Balancer::LocalSort => "LocalSort",
            Balancer::LbMicro => "LB-Micro",
            Balancer::LbMini => "LB-Mini",
            Balancer::VerlNative => "Native",
            Balancer::Queue => "Queue",
        })
    }
}

/// Parameter/gradient sharding extent (§6.1 Hybrid Sharding, ZeRO++-style).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Sharding {
    /// Parameters + grads + optimizer state sharded across ALL devices.
    Full,
    /// Params/grads sharded within a node; optimizer states across nodes.
    Hybrid,
}

/// One experiment cell.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: PaperModel,
    pub dataset: Dataset,
    pub scheme: CommScheme,
    pub balancer: Balancer,
    pub sharding: Sharding,
    /// Samples per minibatch PER DEVICE (paper's "minibatch size").
    pub minibs: usize,
    pub devices: usize,
    pub devices_per_node: usize,
    /// max tokens per microbatch = packing_ratio * max_seq_len.
    pub packing_ratio: f64,
    /// Maximum sequence length in the (possibly rescaled) dataset.
    pub max_len: usize,
    /// Minibatches to run per measurement.
    pub steps: usize,
    pub seed: u64,
}

impl ExperimentConfig {
    /// Golden setting of the parametric study (Table 1).
    pub fn golden() -> Self {
        ExperimentConfig {
            model: PaperModel::M1_5B,
            dataset: Dataset::LongAlign,
            scheme: CommScheme::Odc,
            balancer: Balancer::LbMicro,
            sharding: Sharding::Full,
            minibs: 4,
            devices: 8,
            devices_per_node: 8,
            packing_ratio: 1.0,
            max_len: 65536,
            steps: 16,
            seed: 0,
        }
    }

    /// Devices used in the paper for a model scale (SFT experiments).
    pub fn paper_devices(model: PaperModel) -> usize {
        match model {
            PaperModel::M1_5B | PaperModel::M7B => 8,
            PaperModel::M14B => 16,
            PaperModel::M32B => 32,
        }
    }

    /// Cross-field validity: balancer × scheme legality (the simulator
    /// asserts this; the real trainer rejects the same combinations in
    /// `engine::trainer::train`), plus numeric sanity — a non-finite
    /// packing ratio would flow into NaN microbatch costs, which the
    /// LPT dispatch order must never be fed (see `balance::dispatch`).
    pub fn validate(&self) -> Result<(), String> {
        if !self.balancer.legal_under(self.scheme) {
            return Err(format!(
                "{} requires a barrier-free comm scheme: {}'s per-layer rendezvous needs equal \
                 microbatch counts on every device",
                self.balancer, self.scheme
            ));
        }
        if !self.packing_ratio.is_finite() || self.packing_ratio <= 0.0 {
            return Err(format!(
                "packing_ratio must be finite and positive, got {} — a NaN/∞ ratio poisons \
                 every downstream microbatch cost",
                self.packing_ratio
            ));
        }
        Ok(())
    }

    /// Token budget for one microbatch.
    pub fn max_tokens_per_micro(&self) -> usize {
        ((self.packing_ratio * self.max_len as f64).round() as usize).max(self.max_len)
    }

    pub fn label(&self) -> String {
        format!("{} {} {} {} minibs={}", self.model, self.dataset, self.scheme, self.balancer, self.minibs)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.to_string())),
            ("dataset", Json::str(self.dataset.to_string())),
            ("scheme", Json::str(self.scheme.to_string())),
            ("balancer", Json::str(self.balancer.to_string())),
            ("minibs", Json::num(self.minibs as f64)),
            ("devices", Json::num(self.devices as f64)),
            ("devices_per_node", Json::num(self.devices_per_node as f64)),
            ("packing_ratio", Json::num(self.packing_ratio)),
            ("max_len", Json::num(self.max_len as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_matches_table1() {
        let g = ExperimentConfig::golden();
        assert_eq!(g.model, PaperModel::M1_5B);
        assert_eq!(g.dataset, Dataset::LongAlign);
        assert_eq!(g.minibs, 4);
        assert_eq!(g.devices, 8);
        assert!((g.packing_ratio - 1.0).abs() < 1e-12);
        assert_eq!(g.max_len, 65536);
    }

    #[test]
    fn model_parse_roundtrip() {
        for m in PaperModel::all() {
            assert_eq!(PaperModel::parse(&m.to_string()), Some(m));
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in [CommScheme::Collective, CommScheme::Odc, CommScheme::Hybrid] {
            assert_eq!(CommScheme::parse(&s.to_string()), Some(s));
        }
        assert_eq!(CommScheme::parse("hybrid"), Some(CommScheme::Hybrid));
        assert_eq!(CommScheme::parse("ring"), None);
    }

    #[test]
    fn wire_dtype_parse_roundtrip() {
        for d in [WireDtype::F32, WireDtype::Bf16] {
            assert_eq!(WireDtype::parse(&d.to_string()), Some(d));
        }
        assert_eq!(WireDtype::parse("bfloat16"), Some(WireDtype::Bf16));
        assert_eq!(WireDtype::parse("fp8"), None);
        assert_eq!(WireDtype::default(), WireDtype::F32);
    }

    #[test]
    fn paper_device_counts() {
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M1_5B), 8);
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M14B), 16);
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M32B), 32);
    }

    #[test]
    fn balancer_parse_roundtrip() {
        for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative, Balancer::Queue] {
            let cli_name = match b {
                Balancer::LocalSort => "local-sort",
                Balancer::LbMicro => "lb-micro",
                Balancer::LbMini => "lb-mini",
                Balancer::VerlNative => "native",
                Balancer::Queue => "queue",
            };
            assert_eq!(Balancer::parse(cli_name), Some(b));
        }
        assert_eq!(Balancer::parse("round-robin"), None);
    }

    #[test]
    fn queue_and_lb_mini_illegal_under_collective_only() {
        for b in [Balancer::LbMini, Balancer::Queue] {
            assert!(!b.legal_under(CommScheme::Collective));
            assert!(b.legal_under(CommScheme::Odc));
            assert!(b.legal_under(CommScheme::Hybrid));
        }
        assert!(Balancer::LbMicro.legal_under(CommScheme::Collective));
        let mut g = ExperimentConfig::golden();
        g.balancer = Balancer::Queue;
        g.scheme = CommScheme::Collective;
        let err = g.validate().unwrap_err();
        assert!(err.contains("barrier-free"), "unexpected message: {err}");
        g.scheme = CommScheme::Odc;
        assert!(g.validate().is_ok());
    }

    #[test]
    fn validate_rejects_non_finite_packing_ratio() {
        let mut g = ExperimentConfig::golden();
        assert!(g.validate().is_ok());
        g.packing_ratio = f64::NAN;
        assert!(g.validate().is_err(), "NaN packing ratio must be rejected");
        g.packing_ratio = f64::INFINITY;
        assert!(g.validate().is_err());
        g.packing_ratio = 0.0;
        assert!(g.validate().is_err());
    }

    #[test]
    fn max_tokens_scales_with_ratio() {
        let mut g = ExperimentConfig::golden();
        assert_eq!(g.max_tokens_per_micro(), 65536);
        g.packing_ratio = 2.0;
        assert_eq!(g.max_tokens_per_micro(), 131072);
    }

    #[test]
    fn config_json_has_fields() {
        let j = ExperimentConfig::golden().to_json();
        assert_eq!(j.get("devices").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("dataset").unwrap().as_str(), Some("LongAlign"));
    }
}
