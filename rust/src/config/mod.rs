//! Experiment configuration: typed configs, paper presets, JSON I/O.
//!
//! Every paper experiment cell (model × dataset × devices × minibatch ×
//! method) is expressible as an [`ExperimentConfig`]; `presets` holds the
//! golden setting (Table 1) and the grids behind Tables 3–6 / Figs 8–12.

use crate::util::json::Json;
use std::fmt;

/// Paper evaluation models (DeepSeek-R1-Distill-Qwen family shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PaperModel {
    M1_5B,
    M7B,
    M14B,
    M32B,
}

impl PaperModel {
    pub fn all() -> [PaperModel; 4] {
        [PaperModel::M1_5B, PaperModel::M7B, PaperModel::M14B, PaperModel::M32B]
    }

    /// (layers, hidden, params) of the underlying Qwen2.5 shapes.
    pub fn shape(self) -> (usize, usize, f64) {
        match self {
            PaperModel::M1_5B => (28, 1536, 1.54e9),
            PaperModel::M7B => (28, 3584, 7.62e9),
            PaperModel::M14B => (48, 5120, 14.77e9),
            PaperModel::M32B => (64, 5120, 32.76e9),
        }
    }

    pub fn layers(self) -> usize {
        self.shape().0
    }

    pub fn hidden(self) -> usize {
        self.shape().1
    }

    pub fn params(self) -> f64 {
        self.shape().2
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "1.5b" | "1_5b" | "m1_5b" => Some(PaperModel::M1_5B),
            "7b" | "m7b" => Some(PaperModel::M7B),
            "14b" | "m14b" => Some(PaperModel::M14B),
            "32b" | "m32b" => Some(PaperModel::M32B),
            _ => None,
        }
    }
}

impl fmt::Display for PaperModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PaperModel::M1_5B => "1.5B",
            PaperModel::M7B => "7B",
            PaperModel::M14B => "14B",
            PaperModel::M32B => "32B",
        };
        write!(f, "{s}")
    }
}

/// Evaluation datasets (Fig 7 distributions; synthetic fits in `data`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    LongAlign,
    SweSmith,
    Aime,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "longalign" => Some(Dataset::LongAlign),
            "swesmith" | "swe-smith" => Some(Dataset::SweSmith),
            "aime" => Some(Dataset::Aime),
            _ => None,
        }
    }
}

impl fmt::Display for Dataset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dataset::LongAlign => "LongAlign",
            Dataset::SweSmith => "SWE-Smith",
            Dataset::Aime => "AIME",
        };
        write!(f, "{s}")
    }
}

/// Communication scheme under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CommScheme {
    /// Baseline: ring all-gather / reduce-scatter, per-layer barriers.
    Collective,
    /// The paper's contribution: p2p gather / scatter-accumulate,
    /// one barrier per minibatch.
    Odc,
    /// §6.1 two-level hybrid sharding: params/grads sharded within a
    /// node group (intra-group gathers/reduces), optimizer shards across
    /// all devices with an ODC-style cross-group epilogue. Devices
    /// free-run within the minibatch exactly like ODC (LB-Mini legal).
    Hybrid,
}

impl CommScheme {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "collective" => Some(CommScheme::Collective),
            "odc" => Some(CommScheme::Odc),
            "hybrid" => Some(CommScheme::Hybrid),
            _ => None,
        }
    }
}

impl fmt::Display for CommScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", match self {
            CommScheme::Collective => "Collective",
            CommScheme::Odc => "ODC",
            CommScheme::Hybrid => "Hybrid",
        })
    }
}

/// Load-balancing algorithm (§5.1 and Appendix C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Balancer {
    /// Sort by length on each device, no packing (LongAlign-style).
    LocalSort,
    /// Microbatch-level packing, equal microbatch count per device.
    LbMicro,
    /// Minibatch-level balancing (ODC only): per-device microbatch count.
    LbMini,
    /// verl's native two-level partitioning (Listing 2) — RL baseline.
    VerlNative,
}

impl fmt::Display for Balancer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", match self {
            Balancer::LocalSort => "LocalSort",
            Balancer::LbMicro => "LB-Micro",
            Balancer::LbMini => "LB-Mini",
            Balancer::VerlNative => "Native",
        })
    }
}

/// Parameter/gradient sharding extent (§6.1 Hybrid Sharding, ZeRO++-style).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Sharding {
    /// Parameters + grads + optimizer state sharded across ALL devices.
    Full,
    /// Params/grads sharded within a node; optimizer states across nodes.
    Hybrid,
}

/// One experiment cell.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: PaperModel,
    pub dataset: Dataset,
    pub scheme: CommScheme,
    pub balancer: Balancer,
    pub sharding: Sharding,
    /// Samples per minibatch PER DEVICE (paper's "minibatch size").
    pub minibs: usize,
    pub devices: usize,
    pub devices_per_node: usize,
    /// max tokens per microbatch = packing_ratio * max_seq_len.
    pub packing_ratio: f64,
    /// Maximum sequence length in the (possibly rescaled) dataset.
    pub max_len: usize,
    /// Minibatches to run per measurement.
    pub steps: usize,
    pub seed: u64,
}

impl ExperimentConfig {
    /// Golden setting of the parametric study (Table 1).
    pub fn golden() -> Self {
        ExperimentConfig {
            model: PaperModel::M1_5B,
            dataset: Dataset::LongAlign,
            scheme: CommScheme::Odc,
            balancer: Balancer::LbMicro,
            sharding: Sharding::Full,
            minibs: 4,
            devices: 8,
            devices_per_node: 8,
            packing_ratio: 1.0,
            max_len: 65536,
            steps: 16,
            seed: 0,
        }
    }

    /// Devices used in the paper for a model scale (SFT experiments).
    pub fn paper_devices(model: PaperModel) -> usize {
        match model {
            PaperModel::M1_5B | PaperModel::M7B => 8,
            PaperModel::M14B => 16,
            PaperModel::M32B => 32,
        }
    }

    /// Token budget for one microbatch.
    pub fn max_tokens_per_micro(&self) -> usize {
        ((self.packing_ratio * self.max_len as f64).round() as usize).max(self.max_len)
    }

    pub fn label(&self) -> String {
        format!("{} {} {} {} minibs={}", self.model, self.dataset, self.scheme, self.balancer, self.minibs)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.to_string())),
            ("dataset", Json::str(self.dataset.to_string())),
            ("scheme", Json::str(self.scheme.to_string())),
            ("balancer", Json::str(self.balancer.to_string())),
            ("minibs", Json::num(self.minibs as f64)),
            ("devices", Json::num(self.devices as f64)),
            ("devices_per_node", Json::num(self.devices_per_node as f64)),
            ("packing_ratio", Json::num(self.packing_ratio)),
            ("max_len", Json::num(self.max_len as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_matches_table1() {
        let g = ExperimentConfig::golden();
        assert_eq!(g.model, PaperModel::M1_5B);
        assert_eq!(g.dataset, Dataset::LongAlign);
        assert_eq!(g.minibs, 4);
        assert_eq!(g.devices, 8);
        assert!((g.packing_ratio - 1.0).abs() < 1e-12);
        assert_eq!(g.max_len, 65536);
    }

    #[test]
    fn model_parse_roundtrip() {
        for m in PaperModel::all() {
            assert_eq!(PaperModel::parse(&m.to_string()), Some(m));
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in [CommScheme::Collective, CommScheme::Odc, CommScheme::Hybrid] {
            assert_eq!(CommScheme::parse(&s.to_string()), Some(s));
        }
        assert_eq!(CommScheme::parse("hybrid"), Some(CommScheme::Hybrid));
        assert_eq!(CommScheme::parse("ring"), None);
    }

    #[test]
    fn paper_device_counts() {
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M1_5B), 8);
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M14B), 16);
        assert_eq!(ExperimentConfig::paper_devices(PaperModel::M32B), 32);
    }

    #[test]
    fn max_tokens_scales_with_ratio() {
        let mut g = ExperimentConfig::golden();
        assert_eq!(g.max_tokens_per_micro(), 65536);
        g.packing_ratio = 2.0;
        assert_eq!(g.max_tokens_per_micro(), 131072);
    }

    #[test]
    fn config_json_has_fields() {
        let j = ExperimentConfig::golden().to_json();
        assert_eq!(j.get("devices").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("dataset").unwrap().as_str(), Some("LongAlign"));
    }
}
