//! `RunSpec`: the one legality matrix for a run's comm/fault/split knobs.
//!
//! The trainer and the simulator grew the same validation twice — every
//! flag PR (`--device-speed`, `--fail-at`, `--fault-plan`, `--seq-split`,
//! `--wire-dtype`, `--transport`, now `--staleness`) added a near-copy
//! of its legality checks to `engine::trainer::train` and `sim::run::
//! simulate`, and the two drifted in wording and occasionally in
//! substance. `RunSpec` is the shared shape both CLIs parse into and
//! both entry points validate through: [`RunSpec::validate`] holds the
//! full cross-knob matrix in ONE place, so a combination cannot be
//! legal in the simulator and rejected by the trainer (or vice versa)
//! by accident.
//!
//! Deliberate asymmetries that stay OUT of the shared matrix:
//!
//! * `wire_dtype = bf16` under `Collective` — the simulator PRICES bf16
//!   wire bytes as an assumption (its historical default), while the
//!   engine has a real codec and rejects the combination because the
//!   rendezvous fold has no encode/decode stage. Engine-only, in
//!   [`RunSpec::validate_engine`].
//! * `seq_split` × `fail_at` — the engine permits a crash on a device
//!   that hosts no chunks (checked after planning, when placement is
//!   known); the simulator's failover pricing path is split-unaware and
//!   rejects the combination outright. Each keeps its own check.
//! * `pjrt_shard_ops` × `staleness` — engine-only knob, checked in the
//!   trainer.
//!
//! `validate()` returns the run's [`Membership`] (derived fail-stops
//! from fault-plan partitions already merged) so callers don't rebuild
//! the elastic schedule a second time.

use crate::comm::membership::Membership;
use crate::comm::transport::{FaultPlan, TransportKind};
use crate::config::{Balancer, CommScheme, WireDtype};
use std::sync::Arc;

/// Shared run shape: everything the trainer and the simulator both
/// understand about a run, independent of artifacts or pricing.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub scheme: CommScheme,
    pub balancer: Balancer,
    /// Device count (the trainer's `world`, the simulator's `devices`).
    pub world: usize,
    /// Minibatches (bounds the elastic event schedule).
    pub steps: usize,
    /// Hybrid node-group size; 0 = all devices in one group.
    pub devices_per_node: usize,
    /// Per-device relative speed; empty = homogeneous fleet.
    pub device_speed: Vec<f64>,
    /// Crash events `(device, step, micro)`.
    pub fail_at: Vec<(usize, usize, usize)>,
    /// Join events `(device, step)`.
    pub join_at: Vec<(usize, usize)>,
    /// ChaosComm lossy-transport plan; noop = clean links.
    pub fault_plan: FaultPlan,
    /// SeqSplit threshold as a fraction of the per-device budget; 0 = off.
    pub seq_split: f64,
    /// Gradient payload precision on the wire.
    pub wire_dtype: WireDtype,
    /// Byte transport under the one-sided backends.
    pub transport: TransportKind,
    /// `Some(k)` = AsyncPS bounded-staleness tier; `None` = synchronous.
    /// `Some(0)` still runs the async machinery (the bit-identity
    /// degenerate case) — see `comm::async_ps`.
    pub staleness: Option<usize>,
}

impl RunSpec {
    /// A spec with every optional knob at its neutral default: uniform
    /// fleet, static membership, clean links, no splitting, f32 wire,
    /// in-process transport, synchronous.
    pub fn new(scheme: CommScheme, balancer: Balancer, world: usize, steps: usize) -> RunSpec {
        RunSpec {
            scheme,
            balancer,
            world,
            steps,
            devices_per_node: 0,
            device_speed: Vec::new(),
            fail_at: Vec::new(),
            join_at: Vec::new(),
            fault_plan: FaultPlan::default(),
            seq_split: 0.0,
            wire_dtype: WireDtype::F32,
            transport: TransportKind::Inproc,
            staleness: None,
        }
    }

    /// Effective hybrid group size (0 means "one group spanning world").
    pub fn group_size(&self) -> usize {
        if self.devices_per_node == 0 {
            self.world
        } else {
            self.devices_per_node
        }
    }

    /// Fail-stop schedule `(device, step)` with fault-plan partitions
    /// merged in: a permanently partitioned link is a derived fail-stop
    /// for its src device at the partition step (earliest, if several).
    pub fn derived_fails(&self) -> Vec<(usize, usize)> {
        let mut fails: Vec<(usize, usize)> = self.fail_at.iter().map(|&(d, s, _)| (d, s)).collect();
        for &(src, _dst, step) in &self.fault_plan.partition {
            match fails.iter_mut().find(|f| f.0 == src) {
                Some(f) => f.1 = f.1.min(step),
                None => fails.push((src, step)),
            }
        }
        fails
    }

    /// The full shared legality matrix. On success returns the run's
    /// membership (with derived fail-stops merged and the elastic
    /// schedule validated against `steps`).
    pub fn validate(&self) -> Result<Arc<Membership>, String> {
        // --- balancer × scheme --------------------------------------------
        if !self.balancer.legal_under(self.scheme) {
            return Err(format!(
                "{} requires a barrier-free scheme: Collective's per-layer rendezvous needs equal \
                 microbatch counts on every device (LB-Mini runs unequal counts; Queue decides \
                 placement at runtime)",
                self.balancer
            ));
        }
        // --- heterogeneous fleet ------------------------------------------
        if !self.device_speed.is_empty() {
            if self.device_speed.len() != self.world {
                return Err(format!(
                    "device_speed needs one entry per device: got {} for world {}",
                    self.device_speed.len(),
                    self.world
                ));
            }
            if self.device_speed.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                return Err("device_speed entries must be finite and > 0".to_string());
            }
        }
        // --- hybrid grouping ----------------------------------------------
        if self.scheme == CommScheme::Hybrid {
            let g = self.group_size();
            if g == 0 || self.world % g != 0 {
                return Err(format!(
                    "hybrid sharding needs node groups that tile the device set: world {} % devices_per_node {} != 0",
                    self.world, g
                ));
            }
        }
        // --- WireComm transport (see docs/transport.md) -------------------
        if self.transport != TransportKind::Inproc && self.scheme == CommScheme::Collective {
            return Err(format!(
                "--transport {} requires a one-sided scheme: Collective's rendezvous fold runs \
                 in shared memory and never touches the mailbox transport",
                self.transport
            ));
        }
        // --- AsyncPS staleness (see docs/asyncps.md) ----------------------
        if let Some(k) = self.staleness {
            if self.scheme == CommScheme::Collective {
                return Err(format!(
                    "staleness {k} requires a barrier-free scheme: Collective's per-layer \
                     rendezvous IS a staleness-0 barrier — there is no admission gate to widen"
                ));
            }
            if self.scheme == CommScheme::Hybrid {
                return Err(format!(
                    "staleness {k} requires the odc scheme: hybrid's cross-group optimizer \
                     epilogue is a per-step rendezvous, synchronous by construction"
                ));
            }
            if !matches!(self.balancer, Balancer::LbMini | Balancer::Queue) {
                return Err(format!(
                    "staleness {k} requires an LB-Mini or Queue balancer: synchronized-k packers \
                     pad every device to equal microbatch counts, re-coupling the workers the \
                     async tier exists to decouple"
                ));
            }
            if !self.fail_at.is_empty() || !self.join_at.is_empty() {
                return Err(format!(
                    "staleness {k} requires a static membership: join/fail choreography \
                     rendezvouses at minibatch boundaries the free-running async tier no longer \
                     observes"
                ));
            }
            if !self.fault_plan.is_noop() {
                return Err(format!(
                    "staleness {k} cannot compose with a fault plan: retransmit escalation hands \
                     a dead link to the elastic recovery path, which is synchronous machinery"
                ));
            }
            if self.seq_split != 0.0 {
                return Err(format!(
                    "staleness {k} cannot combine with seq_split: chunk micros of one sequence \
                     rendezvous at their minibatch's fold, which free-running workers would \
                     interleave across minibatches"
                ));
            }
        }
        // --- SeqSplit (see balance::split and docs/seqsplit.md) -----------
        if self.seq_split != 0.0 {
            if !self.seq_split.is_finite() || self.seq_split < 0.0 || self.seq_split > 1.0 {
                return Err(format!(
                    "seq_split must be a fraction of the per-device budget in (0, 1]: got {}",
                    self.seq_split
                ));
            }
            if self.scheme == CommScheme::Collective {
                return Err(
                    "seq_split requires a barrier-free scheme: Collective's padded per-layer \
                     rendezvous assumes whole sequences, while a split sequence's chunks push \
                     independently and meet only at the minibatch flush"
                        .to_string(),
                );
            }
            if !matches!(self.balancer, Balancer::LbMini | Balancer::Queue) {
                return Err(
                    "seq_split requires an LB-Mini or Queue balancer: synchronized-k packers pad \
                     to equal microbatch counts, which singleton chunk micros break"
                        .to_string(),
                );
            }
        }
        // --- ChaosComm fault plan (see comm::transport) -------------------
        self.fault_plan.validate().map_err(|e| format!("fault_plan: {e}"))?;
        if !self.fault_plan.is_noop() {
            if self.scheme == CommScheme::Collective {
                return Err(
                    "fault_plan requires a barrier-free scheme: Collective's per-layer \
                     rendezvous has no retransmit ladder to absorb a lossy link (a dropped \
                     message stalls every rank at the next rendezvous)"
                        .to_string(),
                );
            }
            if let Some(&(s, d, _)) = self
                .fault_plan
                .partition
                .iter()
                .find(|&&(s, d, _)| s >= self.world || d >= self.world)
            {
                return Err(format!(
                    "fault_plan partition {s}:{d} references a device >= world {}",
                    self.world
                ));
            }
            if let Some(&(s, d, step)) =
                self.fault_plan.partition.iter().find(|&&(_, _, step)| step >= self.steps)
            {
                return Err(format!(
                    "fault_plan partition {s}:{d}:{step} references a step >= steps {}",
                    self.steps
                ));
            }
            if !self.fault_plan.partition.is_empty() {
                if !self.fail_at.is_empty() {
                    // A partition IS a declared fail-stop for its src
                    // device (derived in `derived_fails`); mixing it with
                    // explicit crash points would let a fail_at victim's
                    // in-flight pieces strand in a partitioned link's
                    // limbo — use part= entries alone.
                    return Err(
                        "fail_at cannot be combined with fault_plan partitions: a partition \
                         already implies a derived fail-stop for its src device"
                            .to_string(),
                    );
                }
                if self.scheme == CommScheme::Hybrid {
                    // ODC carries the partition-escalation guarantee; the
                    // hybrid cross-level quorum (one partial per group)
                    // has no per-message retraction for a half-shipped
                    // group partial. Transient rates are fully supported.
                    return Err(
                        "fault_plan partitions require --scheme odc (hybrid supports transient \
                         drop/dup/reorder/delay only)"
                            .to_string(),
                    );
                }
            }
        }
        // --- elastic membership (ElasticWorld, see comm::membership) ------
        let membership =
            Arc::new(Membership::with_schedule(self.world, &self.join_at, &self.derived_fails())?);
        if !membership.is_static() {
            if self.scheme == CommScheme::Collective {
                return Err(
                    "fail_at/join_at require a barrier-free scheme: one dead rank deadlocks \
                     Collective's per-layer all-gather rendezvous, while a dead PS client just \
                     stops pushing — the structural contrast the elastic scenario measures"
                        .to_string(),
                );
            }
            membership.validate(self.steps)?;
            if self.scheme == CommScheme::Hybrid {
                membership.validate_groups(self.group_size(), self.steps)?;
            }
        }
        Ok(membership)
    }

    /// The shared matrix plus the engine-only codec constraint: the real
    /// bf16 wire codec needs an encode/decode stage, which Collective's
    /// in-place rendezvous fold does not have. (The simulator prices
    /// bf16 under every scheme — pricing is an assumption, not a codec.)
    pub fn validate_engine(&self) -> Result<Arc<Membership>, String> {
        if self.wire_dtype == WireDtype::Bf16 && self.scheme == CommScheme::Collective {
            return Err(
                "wire_dtype bf16 requires a one-sided scheme: Collective's in-place rendezvous \
                 fold has no encode/decode stage to quantize (and no per-shard residual state \
                 for error feedback)"
                    .to_string(),
            );
        }
        self.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunSpec {
        RunSpec::new(CommScheme::Odc, Balancer::LbMini, 4, 4)
    }

    #[test]
    fn neutral_spec_is_legal_and_static() {
        let m = base().validate().unwrap();
        assert!(m.is_static());
        assert_eq!(m.world(), 4);
    }

    #[test]
    fn partitions_merge_into_derived_fails() {
        let mut s = base();
        s.fault_plan = FaultPlan::parse("drop=0.01,seed=1,part=1:2:2,part=1:3:1").unwrap();
        // Same src twice: earliest step wins.
        assert_eq!(s.derived_fails(), vec![(1, 1)]);
        let m = s.validate().unwrap();
        assert!(!m.is_static());
    }

    #[test]
    fn staleness_matrix_rejects_every_synchronous_companion() {
        let mut s = base();
        s.staleness = Some(1);
        s.scheme = CommScheme::Collective;
        s.balancer = Balancer::LbMicro; // legal under Collective — isolates the staleness check
        assert!(s.validate().unwrap_err().contains("barrier-free"));

        let mut s = base();
        s.staleness = Some(1);
        s.scheme = CommScheme::Hybrid;
        s.devices_per_node = 2;
        assert!(s.validate().unwrap_err().contains("requires the odc scheme"));

        let mut s = base();
        s.staleness = Some(1);
        s.balancer = Balancer::LbMicro;
        assert!(s.validate().unwrap_err().contains("LB-Mini or Queue"));

        let mut s = base();
        s.staleness = Some(1);
        s.fail_at = vec![(0, 1, 0)];
        assert!(s.validate().unwrap_err().contains("static membership"));

        let mut s = base();
        s.staleness = Some(1);
        s.fault_plan = FaultPlan::parse("drop=0.05,seed=7").unwrap();
        assert!(s.validate().unwrap_err().contains("fault plan"));

        let mut s = base();
        s.staleness = Some(1);
        s.seq_split = 0.5;
        assert!(s.validate().unwrap_err().contains("seq_split"));

        // And the legal stack passes, k = 0 included.
        for k in [0, 1, 4] {
            let mut s = base();
            s.staleness = Some(k);
            s.validate().unwrap();
        }
    }

    #[test]
    fn engine_matrix_adds_the_bf16_codec_constraint() {
        let mut s = base();
        s.scheme = CommScheme::Collective;
        s.balancer = Balancer::LbMicro;
        s.wire_dtype = WireDtype::Bf16;
        // Shared matrix prices it; the engine's real codec rejects it.
        s.validate().unwrap();
        assert!(s.validate_engine().unwrap_err().contains("one-sided"));
    }

    #[test]
    fn fault_plan_collective_names_the_barrier() {
        let mut s = base();
        s.scheme = CommScheme::Collective;
        s.balancer = Balancer::LbMicro;
        s.fault_plan = FaultPlan::parse("drop=0.05,seed=3").unwrap();
        assert!(s.validate().unwrap_err().contains("barrier-free"));
    }
}
