//! Per-sample compute-cost model shared by packers and simulator.
//!
//! For a sample of sequence length `s`, a transformer's fwd+bwd cost is
//!   cost(s) = 6·N·s  +  12·L·h·s²
//! (parameter FLOPs linear in tokens; attention FLOPs quadratic — the
//! O(s)-memory / O(s²)-compute mismatch at the heart of §4). Packed
//! microbatches use block-diagonal attention, so a microbatch's cost is
//! the SUM of its samples' costs plus a fixed launch overhead.

use crate::config::PaperModel;

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// FLOPs per token from parameters (≈ 6·N_params).
    pub linear: f64,
    /// FLOPs per token² from attention (≈ 12·L·h).
    pub quad: f64,
    /// Per-microbatch fixed overhead, in FLOP-equivalents (kernel launch,
    /// optimizer bookkeeping). Calibrated so overhead ≈ 2ms on an A100.
    pub micro_overhead: f64,
    /// Effective device throughput in FLOP/s (A100 bf16 at ~40% MFU).
    pub device_flops: f64,
}

impl CostModel {
    pub fn for_model(m: PaperModel) -> CostModel {
        let (layers, hidden, params) = m.shape();
        Self::from_dims(layers, hidden, params)
    }

    /// Cost model for arbitrary transformer dimensions (used by the real
    /// engine, whose models come from the artifact manifest).
    pub fn from_dims(layers: usize, hidden: usize, params: f64) -> CostModel {
        let device_flops = 1.25e14; // 312 TFLOPs bf16 * ~0.4 MFU
        CostModel {
            linear: 6.0 * params,
            quad: 12.0 * (layers * hidden) as f64,
            micro_overhead: 0.002 * device_flops,
            device_flops,
        }
    }

    /// Compute cost of one sample (FLOPs).
    #[inline]
    pub fn sample_cost(&self, len: usize) -> f64 {
        let s = len as f64;
        self.linear * s + self.quad * s * s
    }

    /// Compute cost of tokens `[start, end)` of a sequence run as a
    /// context-parallel chunk: linear work for the chunk's own tokens
    /// plus causal attention against the full prefix (each query at
    /// absolute position `p` attends to `p` keys, so the quadratic term
    /// integrates to `end² − start²`). Chunk costs telescope exactly —
    /// for any partition of `[0, s)`,
    /// `Σ chunk_cost(aᵢ, aᵢ₊₁) == sample_cost(s)` — which is what lets
    /// the split planner conserve total work while spreading it.
    #[inline]
    pub fn chunk_cost(&self, start: usize, end: usize) -> f64 {
        let (a, b) = (start as f64, end as f64);
        self.linear * (b - a) + self.quad * (b * b - a * a)
    }

    /// Cost of a packed microbatch given member lengths.
    pub fn micro_cost(&self, lens: &[usize]) -> f64 {
        self.micro_overhead + lens.iter().map(|&l| self.sample_cost(l)).sum::<f64>()
    }

    /// Convert FLOPs to seconds on one device.
    #[inline]
    pub fn seconds(&self, flops: f64) -> f64 {
        flops / self.device_flops
    }

    /// Per-layer slice of a cost (for the per-layer barrier simulator).
    pub fn per_layer(&self, flops: f64, layers: usize) -> f64 {
        flops / layers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_dominates_at_long_context() {
        let c = CostModel::for_model(PaperModel::M1_5B);
        // at 64K, attention should be a large share for a small model
        let s = 65_536;
        let quad = c.quad * (s as f64) * (s as f64);
        let lin = c.linear * s as f64;
        assert!(quad > lin, "quad {quad} vs lin {lin}");
        // at 256 tokens, parameters dominate
        let s = 256;
        assert!(c.linear * s as f64 > c.quad * (s as f64) * (s as f64));
    }

    #[test]
    fn cost_monotone_in_length() {
        let c = CostModel::for_model(PaperModel::M7B);
        let mut prev = 0.0;
        for s in [1usize, 128, 1024, 8192, 65536] {
            let x = c.sample_cost(s);
            assert!(x > prev);
            prev = x;
        }
    }

    #[test]
    fn micro_cost_is_sum_plus_overhead() {
        let c = CostModel::for_model(PaperModel::M1_5B);
        let lens = [100usize, 200, 300];
        let want: f64 = lens.iter().map(|&l| c.sample_cost(l)).sum::<f64>() + c.micro_overhead;
        assert!((c.micro_cost(&lens) - want).abs() < 1.0);
    }

    #[test]
    fn chunk_costs_telescope_to_sample_cost() {
        let c = CostModel::for_model(PaperModel::M7B);
        let s = 10_000usize;
        for cuts in [vec![0, s], vec![0, 1, s], vec![0, 2500, 5000, 7500, s]] {
            let total: f64 = cuts.windows(2).map(|w| c.chunk_cost(w[0], w[1])).sum();
            let rel = (total - c.sample_cost(s)).abs() / c.sample_cost(s);
            assert!(rel < 1e-12, "partition {cuts:?}: rel err {rel}");
        }
    }

    #[test]
    fn later_chunks_cost_more_at_equal_tokens() {
        // causal attention: the same token span costs more deeper into
        // the sequence (longer prefix), which is why zigzag boundaries
        // front-load tokens
        let c = CostModel::for_model(PaperModel::M1_5B);
        assert!(c.chunk_cost(32_768, 65_536) > c.chunk_cost(0, 32_768));
    }

    #[test]
    fn bigger_models_cost_more() {
        let s = 4096;
        let costs: Vec<f64> = PaperModel::all().iter().map(|&m| CostModel::for_model(m).sample_cost(s)).collect();
        for w in costs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
