//! Load balancing: the paper's packing algorithms (§4, Appendix C).
//!
//! * [`cost`] — the O(s) + O(s²) per-sample compute-cost model that both
//!   the packers and the simulator share.
//! * [`kk`] — Karmarkar–Karp k-way number partitioning (Listing 1's
//!   `karmarkar_karp`, with the `equal_size` variant).
//! * [`packers`] — LocalSort, LB-Micro, LB-Mini and verl's native
//!   two-level strategy (Listings 1–3).
//! * [`bubble`] — the idle-time estimator behind Tables 4 and 6.

pub mod bubble;
pub mod cost;
pub mod kk;
pub mod packers;

pub use bubble::{estimate_bubble, BubbleReport};
pub use cost::CostModel;
pub use kk::karmarkar_karp;
pub use packers::{plan_run, Plan};
