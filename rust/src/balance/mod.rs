//! Load balancing: the paper's packing algorithms (§4, Appendix C) plus
//! the dispatch layer that decides placement at runtime.
//!
//! * [`cost`] — the O(s) + O(s²) per-sample compute-cost model that both
//!   the packers and the simulator share.
//! * [`kk`] — Karmarkar–Karp k-way number partitioning (Listing 1's
//!   `karmarkar_karp`, with the `equal_size` variant).
//! * [`packers`] — LocalSort, LB-Micro, LB-Mini and verl's native
//!   two-level strategy (Listings 1–3).
//! * [`dispatch`] — the [`Dispatcher`] seam between a packed [`Plan`]
//!   and the devices that execute it: static replay or the shared
//!   work-stealing [`WorkQueue`].
//! * [`bubble`] — the idle-time estimator behind Tables 4 and 6.
//!
//! ## Static vs dynamic dispatch
//!
//! A *static* plan fixes placement before the step from **predicted**
//! cost: it cannot react to cost-model error, OS jitter, or a slow
//! device. The free-running property of the one-sided comm schemes (no
//! barrier until `end_minibatch`) makes placement a runtime degree of
//! freedom: `Balancer::Queue` packs once (LB-Mini composition), then
//! lets devices pull microbatches LPT-first from one shared queue, so a
//! 4×-slower device simply pulls ~4× fewer microbatches and nobody
//! stalls. Gradient folds are keyed by **global microbatch id** (see
//! [`dispatch`]), so every dispatch interleaving — static or queue,
//! uniform or skewed — produces bit-identical training under ODC and
//! single-group Hybrid. The one scoped exception: multi-group Hybrid
//! under Queue routes each microbatch's gradient through the *pulling*
//! device's group, so the cross-group float bracketing is
//! placement-dependent — exact as a sum and within the equivalence
//! tolerance, but not bit-reproducible across runs (documented in
//! [`crate::comm::HybridComm`]).
//!
//! ### Legality: Balancer × CommScheme
//!
//! | Balancer   | Collective | ODC | Hybrid | why |
//! |------------|------------|-----|--------|-----|
//! | LocalSort  | ✓          | ✓   | ✓      | equal microbatch counts by construction |
//! | LB-Micro   | ✓          | ✓   | ✓      | packs with a synchronized k (equal counts) |
//! | Native     | ✓          | ✓   | ✓      | verl's scheme, synchronized k per step |
//! | LB-Mini    | ✗          | ✓   | ✓      | unequal per-device counts: a per-layer rendezvous would deadlock/stall |
//! | Queue      | ✗          | ✓   | ✓      | placement decided at runtime: the barrier schedule cannot be known in advance |
//!
//! The two ✗ cells are rejected at config validation
//! ([`crate::config::Balancer::legal_under`] — the trainer and the sim
//! CLI both enforce it) rather than discovered as a deadlock at runtime.
//!
//! ### Legality: SeqSplit (`--seq-split`) × the rest of the matrix
//!
//! | knob combination            | legal? | why |
//! |-----------------------------|--------|-----|
//! | split × Collective          | ✗      | padded barrier slots assume whole sequences; splitting needs a barrier-free scheme |
//! | split × ODC / Hybrid        | ✓      | chunk micros push independently; the per-sequence fold rendezvouses at the flush |
//! | split × LB-Mini / Queue     | ✓      | chunks enter the same KK / LPT balancing as whole samples |
//! | split × LocalSort / LB-Micro / Native | ✗ | synchronized-k packers pad to equal micro counts; singleton chunk micros break the symmetry |
//! | split × `fail_at` on a chunk-hosting device | ✗ | the crash would strand its sequence's rendezvous partners |
//!
//! Enforced in the trainer, the simulator and both CLIs; see
//! [`split`] and `docs/seqsplit.md`.
//!
//! ### Elastic membership
//!
//! The same freedom extends to the fleet itself: under an ElasticWorld
//! schedule ([`crate::comm::membership`]) each minibatch's dispatcher
//! is wrapped in [`ElasticDispatch`], which re-enqueues a crashed
//! device's in-flight and reserved microbatches for surviving pullers
//! (`Dispatcher::report_failed`) and redistributes an absent device's
//! share — exactly-once either way, and bit-identical thanks to the
//! id-keyed fold. Elastic knobs are likewise ✗ under Collective: one
//! dead rank deadlocks a per-layer barrier schedule, which is the
//! paradigm contrast the failure scenario exists to measure.

pub mod bubble;
pub mod cost;
pub mod dispatch;
pub mod kk;
pub mod packers;
pub mod split;

pub use bubble::{
    estimate_bubble, estimate_bubble_dispatch, estimate_bubble_dispatch_split, BubbleReport,
};
pub use cost::CostModel;
pub use dispatch::{
    make_dispatcher, make_dispatcher_split, make_elastic_dispatcher,
    make_elastic_dispatcher_split, Dispatcher, ElasticDispatch, MicroAssignment, StaticDispatch,
    WorkQueue,
};
pub use kk::karmarkar_karp;
pub use packers::{plan_run, plan_run_split, Plan};
pub use split::{ChunkInfo, SplitMap, SplitMode};
