//! The paper's load-balancing strategies (Listings 1–3).
//!
//! All planners consume the GLOBAL batch (sample lengths for an entire
//! run segment) and emit one [`Plan`] per minibatch = per optimizer step:
//!
//! * **LocalSort** (Bai et al. 2024 adaptation) — samples dealt to
//!   devices, locally sorted by length, NOT packed (one sample per
//!   microbatch).
//! * **LB-Micro** — per-minibatch Karmarkar–Karp across devices with
//!   equal sample counts, then synchronized microbatch packing (all
//!   devices use the same microbatch count — collective's constraint).
//! * **LB-Mini** (ODC only) — per-minibatch KK *without* the equal-count
//!   constraint, then fully local microbatch packing: devices may run
//!   different microbatch counts, which is only sound when the comm
//!   scheme has no per-layer barrier.
//! * **VerlNative** (Listing 2) — verl's two-level scheme: balance the
//!   whole global batch across ranks FIRST, then split into minibatches;
//!   suboptimal because nothing balances within a minibatch.

use super::cost::CostModel;
use super::kk::karmarkar_karp;
use super::split::{split_minibatch, SplitMap, SplitMode};
use crate::config::Balancer;
use crate::util::rng::Rng;

/// Placement of one minibatch: `micro[d][m]` = global sample indices of
/// device d's m-th microbatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Plan {
    pub micro: Vec<Vec<Vec<usize>>>,
}

impl Plan {
    pub fn devices(&self) -> usize {
        self.micro.len()
    }

    pub fn max_micro_count(&self) -> usize {
        self.micro.iter().map(|d| d.len()).max().unwrap_or(0)
    }

    /// All sample indices placed on device d — borrows, no allocation
    /// (the seed returned a fresh `Vec` on every call, which simulator
    /// and spread metrics hit in per-minibatch loops).
    pub fn device_samples(&self, d: usize) -> impl Iterator<Item = usize> + '_ {
        self.micro[d].iter().flatten().copied()
    }

    /// Every sample index in the plan, in (device, slot, position)
    /// order — allocation-free.
    pub fn iter_samples(&self) -> impl Iterator<Item = usize> + '_ {
        self.micro.iter().flatten().flatten().copied()
    }

    /// Number of samples placed in the plan — allocation-free.
    pub fn sample_count(&self) -> usize {
        self.micro.iter().flatten().map(|m| m.len()).sum()
    }

    /// Every sample index in the plan (sorted) — partition check helper
    /// for tests; sorting forces the allocation, so hot paths should use
    /// [`Plan::iter_samples`] / [`Plan::sample_count`] instead.
    pub fn all_samples(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.iter_samples().collect();
        v.sort_unstable();
        v
    }
}

/// `microbatch_partition` of Listing 1: split one device's minibatch into
/// the fewest microbatches that satisfy the token budget. OOM check uses
/// TOKENS (activation memory is O(s)); partition quality uses COMPUTE
/// cost (O(s²)) — the paper's memory/compute mismatch.
///
/// Singleton microbatches are always feasible: a lone max-length sample
/// must be runnable by assumption (budget >= max sample length).
pub fn microbatch_partition(
    sample_ids: &[usize],
    lens: &[usize],
    max_tokens: usize,
    cost: &CostModel,
    k_start: usize,
) -> (Vec<Vec<usize>>, usize) {
    if sample_ids.is_empty() {
        return (Vec::new(), k_start.max(1));
    }
    let costs: Vec<f64> = sample_ids.iter().map(|&i| cost.sample_cost(lens[i])).collect();
    let mut k = k_start.max(1).min(sample_ids.len());
    loop {
        let parts = karmarkar_karp(&costs, k, false);
        if !oom(&parts, sample_ids, lens, max_tokens) || k >= sample_ids.len() {
            let micro: Vec<Vec<usize>> = parts
                .into_iter()
                .filter(|p| !p.is_empty())
                .map(|p| p.iter().map(|&j| sample_ids[j]).collect())
                .collect();
            return (micro, k);
        }
        k += 1;
    }
}

/// `check_oom` of Listing 1: token budget violated by any multi-sample
/// microbatch.
fn oom(parts: &[Vec<usize>], sample_ids: &[usize], lens: &[usize], max_tokens: usize) -> bool {
    parts.iter().any(|p| {
        p.len() > 1 && p.iter().map(|&j| lens[sample_ids[j]]).sum::<usize>() > max_tokens
    })
}

/// Split shuffled `order` into consecutive minibatches of `per_step`.
fn chunk_minibatches(order: &[usize], per_step: usize) -> Vec<Vec<usize>> {
    order.chunks(per_step).filter(|c| c.len() == per_step).map(|c| c.to_vec()).collect()
}

/// Sort microbatches by descending cost so heavy microbatches align on
/// the same index across devices (reduces the per-index max that the
/// collective barrier pays).
fn sort_micro_desc(micro: &mut [Vec<usize>], lens: &[usize], cost: &CostModel) {
    micro.sort_by(|a, b| {
        let ca: f64 = a.iter().map(|&i| cost.sample_cost(lens[i])).sum();
        let cb: f64 = b.iter().map(|&i| cost.sample_cost(lens[i])).sum();
        cb.partial_cmp(&ca).unwrap()
    });
}

/// Planner options beyond the balancer choice.
#[derive(Clone, Copy, Debug)]
pub struct PackOpts {
    /// RL mode (§5.2-a): verl requires identical sample counts per
    /// device, so LB-Mini runs its minibatch KK with `equal_size=true`
    /// (microbatch counts may still differ). SFT mode leaves sample
    /// counts free (`equal_size=false` in Listing 1).
    pub lb_mini_equal_size: bool,
}

impl Default for PackOpts {
    fn default() -> Self {
        PackOpts { lb_mini_equal_size: false }
    }
}

/// Produce per-minibatch plans for the whole global batch.
///
/// * `lens` — global sample lengths.
/// * `world` — device count.
/// * `minibs` — samples per minibatch PER DEVICE.
/// * `max_tokens` — microbatch token budget.
pub fn plan_run(
    balancer: Balancer,
    lens: &[usize],
    world: usize,
    minibs: usize,
    max_tokens: usize,
    cost: &CostModel,
    rng: &mut Rng,
) -> Vec<Plan> {
    plan_run_opts(balancer, lens, world, minibs, max_tokens, cost, rng, PackOpts::default())
}

/// `plan_run` with explicit [`PackOpts`].
#[allow(clippy::too_many_arguments)]
pub fn plan_run_opts(
    balancer: Balancer,
    lens: &[usize],
    world: usize,
    minibs: usize,
    max_tokens: usize,
    cost: &CostModel,
    rng: &mut Rng,
    opts: PackOpts,
) -> Vec<Plan> {
    let per_step = world * minibs;
    assert!(per_step > 0);
    let mut order: Vec<usize> = (0..lens.len()).collect();
    rng.shuffle(&mut order);

    match balancer {
        Balancer::LocalSort => chunk_minibatches(&order, per_step)
            .into_iter()
            .map(|mb| plan_local_sort(&mb, lens, world, cost))
            .collect(),
        Balancer::LbMicro => chunk_minibatches(&order, per_step)
            .into_iter()
            .map(|mb| plan_lb_micro(&mb, lens, world, max_tokens, cost))
            .collect(),
        // Queue packs exactly like LB-Mini (the "pack once" step);
        // whether devices then replay the plan statically or pull from
        // the shared runtime queue is the dispatch layer's decision
        // (`balance::dispatch::make_dispatcher`), not the packer's.
        Balancer::LbMini | Balancer::Queue => chunk_minibatches(&order, per_step)
            .into_iter()
            .map(|mb| plan_lb_mini(&mb, lens, world, max_tokens, cost, opts.lb_mini_equal_size))
            .collect(),
        Balancer::VerlNative => plan_verl_native(&order, lens, world, minibs, max_tokens, cost, rng),
    }
}

/// [`plan_run_opts`] with SeqSplit ([`crate::balance::split`]): after
/// the shuffle-and-chunk step, each minibatch runs the split rule —
/// any member whose cost exceeds `seq_split` of the balanced per-device
/// budget is replaced by chunk virtual ids — and the LB-Mini KK then
/// balances whole samples and chunks together, chunks priced by their
/// causal-prefix-aware [`CostModel::chunk_cost`]. Each chunk lands as a
/// **singleton microbatch** so its gradient push carries exactly that
/// chunk's contribution for the per-sequence rendezvous fold.
///
/// With `seq_split == 0` this is exactly `plan_run_opts` (identical rng
/// usage, bit-identical plans) plus an empty [`SplitMap`]. With a
/// positive fraction the balancer must be LbMini or Queue — the
/// synchronized-k packers have no slot for singleton chunk micros
/// (callers validate; this asserts).
#[allow(clippy::too_many_arguments)]
pub fn plan_run_split(
    balancer: Balancer,
    lens: &[usize],
    world: usize,
    minibs: usize,
    max_tokens: usize,
    cost: &CostModel,
    rng: &mut Rng,
    opts: PackOpts,
    seq_split: f64,
    split_mode: SplitMode,
) -> (Vec<Plan>, SplitMap) {
    if seq_split <= 0.0 {
        let plans = plan_run_opts(balancer, lens, world, minibs, max_tokens, cost, rng, opts);
        return (plans, SplitMap::empty(lens.len()));
    }
    assert!(
        matches!(balancer, Balancer::LbMini | Balancer::Queue),
        "seq-split requires an LB-Mini or Queue balancer (got {balancer:?})"
    );
    let per_step = world * minibs;
    assert!(per_step > 0);
    let mut order: Vec<usize> = (0..lens.len()).collect();
    rng.shuffle(&mut order);

    let mut map = SplitMap::empty(lens.len());
    let plans = chunk_minibatches(&order, per_step)
        .into_iter()
        .map(|mb| {
            let mb = split_minibatch(&mb, lens, world, seq_split, split_mode, cost, &mut map);
            plan_lb_mini_split(&mb, lens, world, max_tokens, cost, opts.lb_mini_equal_size, &map)
        })
        .collect();
    (plans, map)
}

/// LB-Mini over a minibatch that may contain chunk virtual ids: the KK
/// device partition prices every id through the [`SplitMap`] (chunks by
/// true prefix-aware cost), whole samples then pack locally as usual
/// while each chunk becomes its own singleton microbatch.
fn plan_lb_mini_split(
    mb: &[usize],
    lens: &[usize],
    world: usize,
    max_tokens: usize,
    cost: &CostModel,
    equal_size: bool,
    map: &SplitMap,
) -> Plan {
    let costs: Vec<f64> = mb.iter().map(|&i| map.cost_of(i, lens, cost)).collect();
    let parts = karmarkar_karp(&costs, world, equal_size);
    let micro = parts
        .into_iter()
        .map(|p| {
            let (chunks, whole): (Vec<usize>, Vec<usize>) =
                p.iter().map(|&j| mb[j]).partition(|&id| map.is_chunk(id));
            let (mut m, _) = microbatch_partition(&whole, lens, max_tokens, cost, 1);
            m.extend(chunks.into_iter().map(|c| vec![c]));
            m
        })
        .collect();
    Plan { micro }
}

/// LocalSort: deal samples round-robin, sort each device's set by length
/// descending, one sample per microbatch (no packing).
fn plan_local_sort(mb: &[usize], lens: &[usize], world: usize, cost: &CostModel) -> Plan {
    let mut per_dev: Vec<Vec<usize>> = vec![Vec::new(); world];
    for (i, &s) in mb.iter().enumerate() {
        per_dev[i % world].push(s);
    }
    let micro = per_dev
        .into_iter()
        .map(|mut samples| {
            samples.sort_by(|&a, &b| {
                cost.sample_cost(lens[b]).partial_cmp(&cost.sample_cost(lens[a])).unwrap()
            });
            samples.into_iter().map(|s| vec![s]).collect()
        })
        .collect();
    Plan { micro }
}

/// LB-Micro: KK across devices (equal counts), then microbatch packing
/// with a SYNCHRONIZED k (the all_reduce(is_oom) loop of Listing 1).
fn plan_lb_micro(mb: &[usize], lens: &[usize], world: usize, max_tokens: usize, cost: &CostModel) -> Plan {
    let costs: Vec<f64> = mb.iter().map(|&i| cost.sample_cost(lens[i])).collect();
    let parts = karmarkar_karp(&costs, world, true);
    let dev_samples: Vec<Vec<usize>> =
        parts.into_iter().map(|p| p.iter().map(|&j| mb[j]).collect()).collect();

    // Synchronized k: every rank must use the same microbatch count, so
    // k grows until NO rank OOMs (all_reduce over is_oom).
    let mut k = 1;
    loop {
        let mut ok = true;
        let mut plans: Vec<Vec<Vec<usize>>> = Vec::with_capacity(world);
        for samples in &dev_samples {
            let (micro, k_used) = microbatch_partition(samples, lens, max_tokens, cost, k);
            if k_used > k && samples.len() > k {
                ok = false;
                k = k_used;
                break;
            }
            plans.push(micro);
        }
        if ok {
            // pad rank plans to equal microbatch count with empty micros
            let kmax = plans.iter().map(|p| p.len()).max().unwrap_or(1);
            for p in &mut plans {
                sort_micro_desc(p, lens, cost);
                while p.len() < kmax {
                    p.push(Vec::new());
                }
            }
            return Plan { micro: plans };
        }
    }
}

/// LB-Mini (ODC only): KK across devices WITHOUT the equal-count
/// constraint, then fully independent local packing.
fn plan_lb_mini(
    mb: &[usize],
    lens: &[usize],
    world: usize,
    max_tokens: usize,
    cost: &CostModel,
    equal_size: bool,
) -> Plan {
    let costs: Vec<f64> = mb.iter().map(|&i| cost.sample_cost(lens[i])).collect();
    let parts = karmarkar_karp(&costs, world, equal_size);
    let micro = parts
        .into_iter()
        .map(|p| {
            let samples: Vec<usize> = p.iter().map(|&j| mb[j]).collect();
            let (m, _) = microbatch_partition(&samples, lens, max_tokens, cost, 1);
            m
        })
        .collect();
    Plan { micro }
}

/// Listing 2 — verl's native two-level strategy: balance the GLOBAL batch
/// across ranks first (equal counts), then each rank slices its local
/// stream into minibatches sequentially. Nothing balances within a
/// minibatch, which is why LB-Micro beats it (Fig 9).
fn plan_verl_native(
    order: &[usize],
    lens: &[usize],
    world: usize,
    minibs: usize,
    max_tokens: usize,
    cost: &CostModel,
    rng: &mut Rng,
) -> Vec<Plan> {
    let costs: Vec<f64> = order.iter().map(|&i| cost.sample_cost(lens[i])).collect();
    let parts = karmarkar_karp(&costs, world, true);
    let mut rank_stream: Vec<Vec<usize>> =
        parts.into_iter().map(|p| p.iter().map(|&j| order[j]).collect()).collect();
    // verl gives no ordering guarantee within a rank's stream; our KK
    // happens to emit cost-sorted sets, which would *accidentally*
    // balance the sequential minibatch slices. Shuffle to restore the
    // arbitrary order the real system slices on.
    for s in rank_stream.iter_mut() {
        rng.shuffle(s);
    }

    let n_steps = rank_stream.iter().map(|s| s.len() / minibs).min().unwrap_or(0);
    let mut plans = Vec::with_capacity(n_steps);
    for step in 0..n_steps {
        // Per-step, per-rank local packing with synchronized k.
        let dev_samples: Vec<Vec<usize>> = rank_stream
            .iter()
            .map(|s| s[step * minibs..(step + 1) * minibs].to_vec())
            .collect();
        let mut k = 1;
        let plan = loop {
            let mut ok = true;
            let mut micro: Vec<Vec<Vec<usize>>> = Vec::with_capacity(world);
            for samples in &dev_samples {
                let (m, k_used) = microbatch_partition(samples, lens, max_tokens, cost, k);
                if k_used > k && samples.len() > k {
                    ok = false;
                    k = k_used;
                    break;
                }
                micro.push(m);
            }
            if ok {
                let kmax = micro.iter().map(|p| p.len()).max().unwrap_or(1);
                for p in &mut micro {
                    sort_micro_desc(p, lens, cost);
                    while p.len() < kmax {
                        p.push(Vec::new());
                    }
                }
                break Plan { micro };
            }
        };
        plans.push(plan);
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Balancer, PaperModel};
    use crate::util::prop::check;

    fn setup(n: usize, seed: u64) -> (Vec<usize>, CostModel, Rng) {
        let mut rng = Rng::new(seed);
        let lens: Vec<usize> = (0..n).map(|_| (rng.lognormal(8.0, 1.0) as usize).clamp(16, 65_536)).collect();
        (lens, CostModel::for_model(PaperModel::M1_5B), Rng::new(seed + 1))
    }

    fn check_plan_partition(plans: &[Plan], world: usize, minibs: usize) {
        for p in plans {
            assert_eq!(p.devices(), world);
            let all = p.all_samples();
            assert_eq!(all.len(), world * minibs, "each plan holds one minibatch");
            let mut dedup = all.clone();
            dedup.dedup();
            assert_eq!(dedup.len(), all.len(), "no duplicated samples");
        }
        // no sample appears in two plans
        let mut seen: Vec<usize> = plans.iter().flat_map(|p| p.all_samples()).collect();
        let n = seen.len();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), n);
    }

    #[test]
    fn all_balancers_produce_valid_partitions() {
        let (lens, cost, mut rng) = setup(64, 3);
        for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative, Balancer::Queue] {
            let plans = plan_run(b, &lens, 4, 4, 65_536, &cost, &mut rng);
            assert!(!plans.is_empty(), "{b:?}");
            check_plan_partition(&plans, 4, 4);
        }
    }

    #[test]
    fn queue_packs_identically_to_lb_mini() {
        // Queue is a dispatch policy, not a packing policy: same seed,
        // same microbatch composition as LB-Mini, bit for bit.
        let (lens, cost, _) = setup(64, 19);
        let mini = plan_run(Balancer::LbMini, &lens, 4, 4, 65_536, &cost, &mut Rng::new(3));
        let queue = plan_run(Balancer::Queue, &lens, 4, 4, 65_536, &cost, &mut Rng::new(3));
        assert_eq!(mini.len(), queue.len());
        for (a, b) in mini.iter().zip(&queue) {
            assert_eq!(a.micro, b.micro);
        }
    }

    #[test]
    fn plan_iterators_match_owned_views() {
        let (lens, cost, mut rng) = setup(32, 23);
        let plans = plan_run(Balancer::LbMini, &lens, 4, 4, 65_536, &cost, &mut rng);
        for p in &plans {
            assert_eq!(p.sample_count(), p.iter_samples().count());
            let mut via_iter: Vec<usize> = p.iter_samples().collect();
            via_iter.sort_unstable();
            assert_eq!(via_iter, p.all_samples());
            let per_dev: usize = (0..p.devices()).map(|d| p.device_samples(d).count()).sum();
            assert_eq!(per_dev, p.sample_count());
        }
    }

    #[test]
    fn local_sort_is_unpacked_and_sorted() {
        let (lens, cost, mut rng) = setup(32, 5);
        let plans = plan_run(Balancer::LocalSort, &lens, 4, 8, usize::MAX, &cost, &mut rng);
        for p in &plans {
            for dev in &p.micro {
                assert_eq!(dev.len(), 8, "one microbatch per sample");
                for m in dev {
                    assert_eq!(m.len(), 1);
                }
                // sorted descending by length
                let l: Vec<usize> = dev.iter().map(|m| lens[m[0]]).collect();
                assert!(l.windows(2).all(|w| w[0] >= w[1]), "{l:?}");
            }
        }
    }

    #[test]
    fn lb_micro_equal_micro_count_across_devices() {
        let (lens, cost, mut rng) = setup(64, 7);
        let plans = plan_run(Balancer::LbMicro, &lens, 4, 4, 65_536, &cost, &mut rng);
        for p in &plans {
            let counts: Vec<usize> = p.micro.iter().map(|d| d.len()).collect();
            assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
        }
    }

    #[test]
    fn lb_mini_may_vary_micro_count() {
        // Adversarial minibatch: 4 max-length samples whose compute cost
        // exceeds the per-device average, and 28 mid-length samples. KK
        // gives the long samples their own devices (1 microbatch each)
        // while the other devices take 7 mid samples that overflow the
        // token budget (2 microbatches) — the per-device microbatch-count
        // freedom only ODC can exploit.
        let mut lens = vec![65_536usize; 4];
        lens.extend(std::iter::repeat(12_000).take(28));
        let cost = CostModel::for_model(PaperModel::M1_5B);
        let mut rng = Rng::new(0);
        let plans = plan_run(Balancer::LbMini, &lens, 8, 4, 65_536, &cost, &mut rng);
        let varied = plans.iter().any(|p| {
            let c: Vec<usize> = p.micro.iter().map(|d| d.len()).collect();
            c.iter().any(|&x| x != c[0])
        });
        assert!(varied, "expected some variation in microbatch counts");
    }

    #[test]
    fn token_budget_respected() {
        let (lens, cost, mut rng) = setup(128, 13);
        let budget = 65_536;
        for b in [Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative] {
            let plans = plan_run(b, &lens, 4, 8, budget, &cost, &mut rng);
            for p in &plans {
                for dev in &p.micro {
                    for m in dev {
                        if m.len() > 1 {
                            let toks: usize = m.iter().map(|&i| lens[i]).sum();
                            assert!(toks <= budget, "{b:?}: {toks} > {budget}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lb_mini_balances_better_than_local_sort() {
        let (lens, cost, _) = setup(512, 17);
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        let mini = plan_run(Balancer::LbMini, &lens, 8, 8, 65_536, &cost, &mut r1);
        let sorted = plan_run(Balancer::LocalSort, &lens, 8, 8, 65_536, &cost, &mut r2);
        let spread = |plans: &[Plan]| -> f64 {
            plans
                .iter()
                .map(|p| {
                    let busy: Vec<f64> = (0..p.devices())
                        .map(|d| p.device_samples(d).map(|i| cost.sample_cost(lens[i])).sum())
                        .collect();
                    let mx = busy.iter().cloned().fold(f64::MIN, f64::max);
                    let mn = busy.iter().cloned().fold(f64::MAX, f64::min);
                    (mx - mn) / mx
                })
                .sum::<f64>()
                / plans.len() as f64
        };
        assert!(spread(&mini) < spread(&sorted), "LB-Mini should balance device totals better");
    }

    #[test]
    fn microbatch_partition_min_k() {
        let lens = vec![100, 100, 100, 100];
        let cost = CostModel::for_model(PaperModel::M1_5B);
        // budget 250 tokens: 4 samples of 100 need >= 2 microbatches
        let (micro, k) = microbatch_partition(&[0, 1, 2, 3], &lens, 250, &cost, 1);
        assert!(k >= 2);
        for m in &micro {
            assert!(m.iter().map(|&i| lens[i]).sum::<usize>() <= 250 || m.len() == 1);
        }
    }

    #[test]
    fn singleton_over_budget_is_feasible() {
        let lens = vec![1_000];
        let cost = CostModel::for_model(PaperModel::M1_5B);
        let (micro, _) = microbatch_partition(&[0], &lens, 10, &cost, 1);
        assert_eq!(micro.len(), 1);
        assert_eq!(micro[0], vec![0]);
    }

    #[test]
    fn split_disabled_is_bit_identical_to_plan_run() {
        let (lens, cost, _) = setup(64, 29);
        let plain = plan_run(Balancer::LbMini, &lens, 4, 4, 65_536, &cost, &mut Rng::new(11));
        let (split, map) = plan_run_split(
            Balancer::LbMini,
            &lens,
            4,
            4,
            65_536,
            &cost,
            &mut Rng::new(11),
            PackOpts::default(),
            0.0,
            SplitMode::Ring,
        );
        assert!(map.is_empty());
        assert_eq!(plain.len(), split.len());
        for (a, b) in plain.iter().zip(&split) {
            assert_eq!(a.micro, b.micro);
        }
    }

    #[test]
    fn split_plans_cover_each_parent_exactly_once() {
        // one dominant sequence per minibatch worth of samples
        let mut lens = Vec::new();
        for _ in 0..4 {
            lens.push(60_000usize);
            lens.extend(std::iter::repeat(2_000).take(15));
        }
        let cost = CostModel::for_model(PaperModel::M1_5B);
        let (plans, map) = plan_run_split(
            Balancer::Queue,
            &lens,
            4,
            4,
            65_536,
            &cost,
            &mut Rng::new(5),
            PackOpts::default(),
            0.5,
            SplitMode::Zigzag,
        );
        assert!(!map.is_empty(), "the dominant sequences must split");
        // every base id is either placed whole exactly once, or fully
        // covered by its chunk set exactly once — and chunk micros are
        // singletons
        let mut whole = vec![0usize; lens.len()];
        let mut chunk_tokens = vec![0usize; lens.len()];
        let mut chunk_seen = vec![0usize; lens.len()];
        for p in &plans {
            for dev in &p.micro {
                for m in dev {
                    for &id in m {
                        match map.get(id) {
                            Some(c) => {
                                assert_eq!(m.len(), 1, "chunk {id} must be a singleton micro");
                                chunk_tokens[c.parent] += c.len;
                                chunk_seen[c.parent] += 1;
                            }
                            None => whole[id] += 1,
                        }
                    }
                }
            }
        }
        for id in 0..lens.len() {
            if chunk_seen[id] > 0 {
                assert_eq!(whole[id], 0, "sample {id} placed both whole and chunked");
                assert_eq!(chunk_tokens[id], lens[id], "chunks of {id} must cover it exactly");
            } else {
                assert!(whole[id] <= 1, "sample {id} duplicated");
            }
        }
    }

    #[test]
    fn split_plans_deterministic_for_fixed_seed() {
        let (lens, cost, _) = setup(64, 31);
        let mk = || {
            plan_run_split(
                Balancer::LbMini,
                &lens,
                4,
                4,
                65_536,
                &cost,
                &mut Rng::new(77),
                PackOpts::default(),
                0.4,
                SplitMode::Ring,
            )
        };
        let (pa, ma) = mk();
        let (pb, mb) = mk();
        assert_eq!(ma, mb);
        assert_eq!(pa.len(), pb.len());
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.micro, b.micro);
        }
    }

    #[test]
    fn prop_plans_are_partitions() {
        check(
            "plan-partition",
            25,
            |r| {
                let world = r.range(1, 6) as u64;
                let minibs = r.range(1, 6) as u64;
                let n = (world * minibs * r.range(1, 4) as u64) as usize;
                let lens: Vec<u64> = (0..n).map(|_| r.below(60_000) + 16).collect();
                (lens, (world, minibs))
            },
            |(lens, (world, minibs))| {
                let lens_u: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
                let cost = CostModel::for_model(PaperModel::M1_5B);
                let mut rng = Rng::new(1);
                for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative, Balancer::Queue] {
                    let plans = plan_run(b, &lens_u, *world as usize, *minibs as usize, 65_536, &cost, &mut rng);
                    let mut seen: Vec<usize> = plans.iter().flat_map(|p| p.all_samples()).collect();
                    let n = seen.len();
                    seen.sort_unstable();
                    seen.dedup();
                    if seen.len() != n {
                        return Err(format!("{b:?}: duplicated samples"));
                    }
                    if n > lens_u.len() {
                        return Err(format!("{b:?}: invented samples"));
                    }
                }
                Ok(())
            },
        );
    }
}
