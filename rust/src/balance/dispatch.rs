//! The dispatch layer: WHO runs each packed microbatch, decided either
//! before the step (static plans) or at runtime (work-stealing pulls).
//!
//! The packers ([`super::packers`]) decide *composition* — which samples
//! share a microbatch — which is semantically meaningful (packing
//! offsets select positional embeddings). Dispatch decides *placement*,
//! which is semantically FREE under a barrier-free comm scheme: ODC and
//! Hybrid devices only rendezvous at `end_minibatch`, so any device may
//! run any microbatch at any time. A static plan can only balance
//! *predicted* cost; a runtime queue also absorbs cost-model error and
//! straggling/heterogeneous devices (the paper's "simpler and more
//! effective load balancing at the minibatch level", pushed to runtime).
//!
//! Two implementations of [`Dispatcher`]:
//!
//! * [`StaticDispatch`] — replays a [`Plan`] exactly: device `d` pulls
//!   its own row in slot order. Under `Collective` the rows are padded
//!   to the common microbatch count so every device joins the identical
//!   barrier sequence (the seed engine's behaviour, verbatim).
//! * [`WorkQueue`] — packs once, dispatches at runtime: every non-empty
//!   microbatch of the plan goes into one shared pool, pre-sorted by
//!   descending predicted cost (LPT — longest processing time first),
//!   and free-running devices pull from an atomic cursor whenever they
//!   finish their previous microbatch. Lock-free on the pull path; a
//!   straggling device simply pulls less often and the fast devices
//!   absorb the remainder.
//!
//! ## Determinism: the global microbatch id
//!
//! Every assignment carries the microbatch's **global id**: its position
//! in the canonical flattening of the plan (device ascending, slot
//! ascending) — a pure function of the plan, independent of which device
//! ends up running it or when. The one-sided backends
//! ([`crate::comm::OdcComm`] / [`crate::comm::HybridComm`]) buffer
//! gradient pieces and fold them **in id order** at the minibatch flush,
//! so the reduction is bit-identical to the single-device oracle
//! replaying the flattened plan — under ANY dispatch interleaving,
//! static or queue, uniform or skewed devices (asserted end-to-end by
//! `tests/engine_equivalence.rs`). One scoped exception: multi-group
//! Hybrid under queue dispatch folds cross-group partials whose
//! membership depends on runtime placement — exact and
//! tolerance-equivalent, but not bit-reproducible (see
//! [`crate::comm::HybridComm`]'s determinism notes).

use super::cost::CostModel;
use super::packers::Plan;
use super::split::SplitMap;
use crate::config::{Balancer, CommScheme};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// One dispatched unit of work: a packed microbatch plus the fold key.
#[derive(Clone, Debug)]
pub struct MicroAssignment {
    /// Global microbatch id within the minibatch — position in the
    /// canonical (device asc, slot asc) flattening of the plan. The
    /// comm backends key the gradient fold on this, NOT on arrival
    /// order, so placement and timing cannot change a single bit.
    pub id: u64,
    /// Global sample indices packed into this microbatch. Empty for a
    /// padded collective slot (the device must still join the barrier
    /// sequence via the engine's idle participation).
    pub samples: Arc<[usize]>,
}

/// A minibatch's work source: each device thread loops on `next_micro`
/// until it returns `None`, then proceeds to `end_minibatch`.
pub trait Dispatcher: Send + Sync {
    /// The next microbatch for `device`, or `None` when the device is
    /// done with this minibatch. Never blocks — EXCEPT under an elastic
    /// wrapper ([`ElasticDispatch`]), where a drained survivor briefly
    /// waits for a scheduled crash to resolve so orphaned work cannot
    /// be abandoned.
    fn next_micro(&self, device: usize) -> Option<MicroAssignment>;

    /// `device` crashed: re-enqueue its in-flight assignment (pulled
    /// but never run) and anything still reserved for it, for surviving
    /// pullers. Exactly-once stays intact — completed microbatches were
    /// already delivered and are NOT re-enqueued. Default: no-op (the
    /// plain dispatchers have no failure concept; the engine only
    /// reports failures through the elastic wrapper).
    fn report_failed(&self, _device: usize) {}

    /// Total assignments this dispatcher serves across all devices
    /// (padded empty slots included).
    fn total_micros(&self) -> usize;

    /// Human-readable dispatch-policy name (reports/logs).
    fn name(&self) -> &'static str;
}

/// Canonical per-device assignment rows for a plan: ids assigned in
/// (device asc, slot asc) order over every slot, empty slots included.
fn canonical_rows(plan: &Plan) -> Vec<Vec<MicroAssignment>> {
    let mut rows = Vec::with_capacity(plan.micro.len());
    let mut id = 0u64;
    for row in &plan.micro {
        let mut out = Vec::with_capacity(row.len());
        for m in row {
            out.push(MicroAssignment { id, samples: m.clone().into() });
            id += 1;
        }
        rows.push(out);
    }
    rows
}

/// Static dispatch: the seed engine's fixed per-device plan, behind the
/// [`Dispatcher`] seam.
pub struct StaticDispatch {
    rows: Vec<Vec<MicroAssignment>>,
    cursors: Vec<AtomicUsize>,
    total: usize,
}

impl StaticDispatch {
    /// `pad_to_common` replays the Collective contract: every device is
    /// served the common (maximum) slot count, with empty assignments
    /// past its own row so the barrier schedule stays in lockstep.
    pub fn new(plan: &Plan, pad_to_common: bool) -> Self {
        let mut rows = canonical_rows(plan);
        if pad_to_common {
            let m_max = plan.max_micro_count();
            let mut pad_id = rows.iter().map(|r| r.len()).sum::<usize>() as u64;
            for row in rows.iter_mut() {
                while row.len() < m_max {
                    row.push(MicroAssignment { id: pad_id, samples: Vec::<usize>::new().into() });
                    pad_id += 1;
                }
            }
        }
        let total = rows.iter().map(|r| r.len()).sum();
        let cursors = (0..rows.len()).map(|_| AtomicUsize::new(0)).collect();
        StaticDispatch { rows, cursors, total }
    }
}

impl Dispatcher for StaticDispatch {
    fn next_micro(&self, device: usize) -> Option<MicroAssignment> {
        let pos = self.cursors[device].fetch_add(1, Ordering::Relaxed);
        self.rows[device].get(pos).cloned()
    }

    fn total_micros(&self) -> usize {
        self.total
    }

    fn name(&self) -> &'static str {
        "static"
    }
}

/// The canonical pull order of a plan's non-empty microbatches under
/// LPT dispatch: indices into the (device asc, slot asc) flattening,
/// sorted by descending predicted cost, ties broken by flattened
/// position — a pure function of (plan, lens, cost).
pub fn lpt_order(plan: &Plan, lens: &[usize], cost: &CostModel) -> Vec<(usize, usize)> {
    lpt_order_split(plan, lens, cost, &SplitMap::empty(lens.len()))
}

/// [`lpt_order`] under SeqSplit: chunk virtual ids are priced by their
/// causal-prefix-aware [`CostModel::chunk_cost`] through the
/// [`SplitMap`] (an empty map reproduces `lpt_order` bit for bit).
pub fn lpt_order_split(
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    split: &SplitMap,
) -> Vec<(usize, usize)> {
    let mut order: Vec<(f64, usize, usize)> = Vec::new();
    for (d, row) in plan.micro.iter().enumerate() {
        for (m, micro) in row.iter().enumerate() {
            if micro.is_empty() {
                continue;
            }
            let c: f64 = micro.iter().map(|&i| split.cost_of(i, lens, cost)).sum();
            order.push((c, d, m));
        }
    }
    // descending cost; (d, m) tie-break keeps the order deterministic.
    // total_cmp, not partial_cmp().unwrap(): a NaN cost (rejected at
    // config validation, but reachable through a hand-built CostModel)
    // must yield a deterministic order, never a panic mid-dispatch.
    order.sort_by(|a, b| b.0.total_cmp(&a.0).then_with(|| (a.1, a.2).cmp(&(b.1, b.2))));
    order.into_iter().map(|(_, d, m)| (d, m)).collect()
}

/// Split-aware microbatch FLOP cost: `micro_overhead` plus each
/// member's chunk-true cost — identical to [`CostModel::micro_cost`]
/// when no member is a chunk.
pub fn micro_flops_split(micro: &[usize], lens: &[usize], cost: &CostModel, split: &SplitMap) -> f64 {
    cost.micro_overhead + micro.iter().map(|&i| split.cost_of(i, lens, cost)).sum::<f64>()
}

/// THE split-aware work-queue makespan kernel, shared by
/// [`super::bubble::estimate_bubble_dispatch_split`] and the timeline
/// simulator's queue path so the two can never drift (the same seam
/// [`pull_schedule`] provides for the pull dynamics): the plan's
/// non-empty micros in split-aware LPT order, replayed through
/// [`pull_schedule`], each priced by `slot(flops, device)` — the bubble
/// estimator passes FLOP-equivalents straight through, the timeline
/// converts to seconds and applies the comm floor. Returns per-device
/// busy totals in `slot`'s units.
pub fn queue_busy_split(
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    split: &SplitMap,
    mut slot: impl FnMut(f64, usize) -> f64,
) -> Vec<f64> {
    let order = lpt_order_split(plan, lens, cost, split);
    let flops: Vec<f64> = order
        .iter()
        .map(|&(d, m)| micro_flops_split(&plan.micro[d][m], lens, cost, split))
        .collect();
    pull_schedule(order.len(), plan.devices(), |i, dev| slot(flops[i], dev))
}

/// Work-stealing dispatch: one shared LPT-ordered pool of the plan's
/// microbatches, pulled through an atomic cursor by whichever device
/// frees up first. The plan's device dimension only contributes the
/// canonical fold ids; placement is decided entirely at runtime.
pub struct WorkQueue {
    pool: Vec<MicroAssignment>,
    cursor: AtomicUsize,
}

impl WorkQueue {
    pub fn new(plan: &Plan, lens: &[usize], cost: &CostModel) -> Self {
        WorkQueue::new_split(plan, lens, cost, &SplitMap::empty(lens.len()))
    }

    /// [`WorkQueue::new`] under SeqSplit: the LPT pool prices chunk
    /// virtual ids through the [`SplitMap`], so a heavy late chunk is
    /// pulled as early as its true prefix-aware cost warrants. Ids stay
    /// canonical either way — the fold never sees the difference.
    pub fn new_split(plan: &Plan, lens: &[usize], cost: &CostModel, split: &SplitMap) -> Self {
        let rows = canonical_rows(plan);
        let pool = lpt_order_split(plan, lens, cost, split)
            .into_iter()
            .map(|(d, m)| rows[d][m].clone())
            .collect();
        WorkQueue { pool, cursor: AtomicUsize::new(0) }
    }

    /// The pull order as sample lists — the single-device replay an
    /// oracle run would execute (tests build a world-1 [`Plan`] from
    /// this to pin composition).
    pub fn pull_order(&self) -> Vec<Vec<usize>> {
        self.pool.iter().map(|a| a.samples.to_vec()).collect()
    }
}

impl Dispatcher for WorkQueue {
    fn next_micro(&self, _device: usize) -> Option<MicroAssignment> {
        let pos = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.pool.get(pos).cloned()
    }

    fn total_micros(&self) -> usize {
        self.pool.len()
    }

    fn name(&self) -> &'static str {
        "queue"
    }
}

/// Elastic wrapper around any inner dispatcher: the ElasticWorld seam
/// where a crashed device's work is recovered and a dormant (not yet
/// joined) device's share is redistributed.
///
/// * Every assignment served is recorded **in-flight** for its puller;
///   the puller's next call implicitly completes it (the trainer's pull
///   loop is synchronous). `report_failed(device)` moves the device's
///   in-flight assignment — pulled at the crash point, never run — to
///   the front of a shared **orphan queue**, and (for row-based inners,
///   i.e. static plans) drains the device's unpulled row behind it.
///   Survivors serve orphans before pulling their own source, so the
///   LPT-ish order is preserved and every microbatch runs exactly once.
/// * A survivor that drains its source while a scheduled crash is still
///   unresolved WAITS (condvar) instead of returning `None`: the
///   crasher's orphans may still appear, and abandoning them would
///   deadlock the minibatch fold. A scheduled crasher itself never
///   waits — its `None` lets the trainer resolve the crash at drain
///   time ("crash at the k-th pull, or at the minibatch's end if fewer
///   pulls happen", so the membership schedule always holds).
/// * Exactly-once is asserted end-to-end by `tests/elastic_prop.rs`.
pub struct ElasticDispatch {
    inner: Arc<dyn Dispatcher>,
    /// Whether the inner dispatcher reserves work per device row
    /// (static plans) — then a failed/absent device's row must be
    /// drained into the orphan queue; a shared-pool inner (WorkQueue)
    /// needs no draining, survivors pull the pool directly.
    row_based: bool,
    /// Devices scheduled to crash during this minibatch.
    crasher: Vec<bool>,
    state: Mutex<ElasticState>,
    cond: Condvar,
}

struct ElasticState {
    in_flight: Vec<Option<MicroAssignment>>,
    orphans: VecDeque<MicroAssignment>,
    resolved: Vec<bool>,
    unresolved: usize,
}

impl ElasticDispatch {
    /// Wrap `inner` for one minibatch. `crasher[d]` = device d crashes
    /// during this minibatch; `absent[d]` = device d contributes
    /// nothing (not yet joined, or dead since an earlier step) — its
    /// row (if any) is orphaned immediately.
    pub fn new(inner: Arc<dyn Dispatcher>, crasher: Vec<bool>, absent: &[bool], row_based: bool) -> Self {
        let world = crasher.len();
        assert_eq!(absent.len(), world);
        let mut orphans = VecDeque::new();
        if row_based {
            for (dev, &gone) in absent.iter().enumerate() {
                if gone {
                    while let Some(a) = inner.next_micro(dev) {
                        if !a.samples.is_empty() {
                            orphans.push_back(a);
                        }
                    }
                }
            }
        }
        let unresolved = crasher.iter().filter(|&&c| c).count();
        ElasticDispatch {
            inner,
            row_based,
            crasher,
            state: Mutex::new(ElasticState {
                in_flight: vec![None; world],
                orphans,
                resolved: vec![false; world],
                unresolved,
            }),
            cond: Condvar::new(),
        }
    }
}

impl Dispatcher for ElasticDispatch {
    fn next_micro(&self, device: usize) -> Option<MicroAssignment> {
        {
            // The previous assignment (if any) completed; orphans first,
            // so recovered work is never starved behind fresh pulls.
            let mut st = self.state.lock().unwrap();
            st.in_flight[device] = None;
            if let Some(a) = st.orphans.pop_front() {
                st.in_flight[device] = Some(a.clone());
                return Some(a);
            }
        }
        if let Some(a) = self.inner.next_micro(device) {
            let mut st = self.state.lock().unwrap();
            st.in_flight[device] = Some(a.clone());
            return Some(a);
        }
        // Source drained: leave only once no scheduled crash can still
        // orphan work. The crasher itself leaves immediately (the
        // trainer resolves it via report_failed).
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(a) = st.orphans.pop_front() {
                st.in_flight[device] = Some(a.clone());
                return Some(a);
            }
            if st.unresolved == 0 || self.crasher[device] {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    fn report_failed(&self, device: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(a) = st.in_flight[device].take() {
            // pulled at the crash point, never run: next in line
            st.orphans.push_front(a);
        }
        if self.row_based {
            // the rest of the dead device's statically reserved row
            while let Some(a) = self.inner.next_micro(device) {
                if !a.samples.is_empty() {
                    st.orphans.push_back(a);
                }
            }
        }
        if self.crasher[device] && !st.resolved[device] {
            st.resolved[device] = true;
            st.unresolved -= 1;
        }
        self.cond.notify_all();
    }

    fn total_micros(&self) -> usize {
        self.inner.total_micros()
    }

    fn name(&self) -> &'static str {
        "elastic"
    }
}

/// The dispatcher a (balancer, scheme) pair gets for one minibatch plan.
/// `Balancer::Queue` runs the shared work queue (legal because its
/// validity was checked at config time: never under `Collective`); every
/// other balancer replays its plan statically, padded to the common
/// count under `Collective`.
pub fn make_dispatcher(
    balancer: Balancer,
    scheme: CommScheme,
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
) -> Arc<dyn Dispatcher> {
    make_dispatcher_split(balancer, scheme, plan, lens, cost, &SplitMap::empty(lens.len()))
}

/// [`make_dispatcher`] under SeqSplit: the work queue prices chunk ids
/// through the [`SplitMap`]; static replay is placement-fixed and needs
/// no costs, so only the queue path differs.
pub fn make_dispatcher_split(
    balancer: Balancer,
    scheme: CommScheme,
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    split: &SplitMap,
) -> Arc<dyn Dispatcher> {
    match balancer {
        Balancer::Queue => {
            debug_assert!(scheme != CommScheme::Collective, "Queue×Collective is rejected at config validation");
            Arc::new(WorkQueue::new_split(plan, lens, cost, split))
        }
        _ => Arc::new(StaticDispatch::new(plan, scheme == CommScheme::Collective)),
    }
}

/// [`make_dispatcher`] plus the ElasticWorld wrapper: when this
/// minibatch has scheduled crashers or absent devices, the inner
/// dispatcher is wrapped in [`ElasticDispatch`] so their work is
/// orphaned and re-pulled by survivors; otherwise the plain dispatcher
/// is returned untouched (zero overhead for static membership).
#[allow(clippy::too_many_arguments)]
pub fn make_elastic_dispatcher(
    balancer: Balancer,
    scheme: CommScheme,
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    crasher: &[bool],
    absent: &[bool],
) -> Arc<dyn Dispatcher> {
    let empty = SplitMap::empty(lens.len());
    make_elastic_dispatcher_split(balancer, scheme, plan, lens, cost, crasher, absent, &empty)
}

/// [`make_elastic_dispatcher`] under SeqSplit: the inner queue prices
/// chunk ids through the [`SplitMap`] (config validation already
/// rejected the one illegal corner — a scheduled crash on a device that
/// could host a chunk).
#[allow(clippy::too_many_arguments)]
pub fn make_elastic_dispatcher_split(
    balancer: Balancer,
    scheme: CommScheme,
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    crasher: &[bool],
    absent: &[bool],
    split: &SplitMap,
) -> Arc<dyn Dispatcher> {
    let inner = make_dispatcher_split(balancer, scheme, plan, lens, cost, split);
    if crasher.iter().any(|&c| c) || absent.iter().any(|&a| a) {
        let row_based = balancer != Balancer::Queue;
        Arc::new(ElasticDispatch::new(inner, crasher.to_vec(), absent, row_based))
    } else {
        inner
    }
}

/// THE greedy pull-scheduling kernel: item `i` (in pull order) runs on
/// the device with the lowest accumulated busy time (lowest index on
/// ties), occupying it for `duration(i, device)`. This is the engine's
/// queue-pull dynamics on an analytic clock — the single definition the
/// timeline simulator, the bubble estimator and the makespan tests all
/// share, so the priced model and the property-tested model cannot
/// diverge. Returns the final per-device busy times.
pub fn pull_schedule(n: usize, world: usize, duration: impl FnMut(usize, usize) -> f64) -> Vec<f64> {
    let mut budget = vec![usize::MAX; world];
    pull_schedule_budgeted(n, world, &mut budget, duration)
}

/// [`pull_schedule`] with a per-device pull budget — the ElasticWorld
/// failover variant: a dead device has budget 0, a device crashing
/// mid-minibatch has exactly its completed pull count, everyone else is
/// unbounded. Item `i` runs on the earliest-free device with budget
/// remaining (lowest index on ties — the same rule as the unbudgeted
/// kernel, which delegates here), consuming one unit. Keeping one
/// definition means failure-step pricing cannot diverge from
/// healthy-step pricing or from the property-tested makespan model.
pub fn pull_schedule_budgeted(
    n: usize,
    world: usize,
    budget: &mut [usize],
    mut duration: impl FnMut(usize, usize) -> f64,
) -> Vec<f64> {
    assert!(world > 0);
    assert_eq!(budget.len(), world);
    let mut busy = vec![0.0f64; world];
    for item in 0..n {
        let mut pick: Option<usize> = None;
        for d in 0..world {
            if budget[d] == 0 {
                continue;
            }
            match pick {
                Some(p) if busy[d] >= busy[p] => {}
                _ => pick = Some(d),
            }
        }
        let d = pick.expect("at least one device with pull budget left");
        budget[d] -= 1;
        busy[d] += duration(item, d);
    }
    busy
}

/// Makespan of serving `costs` (in pull order) to `world` devices via
/// [`pull_schedule`]. `speeds` are relative device speeds (empty =
/// uniform); a micro of cost `c` occupies device `d` for
/// `c / speeds[d]`.
pub fn pull_makespan(costs: &[f64], world: usize, speeds: &[f64]) -> f64 {
    let inv = |d: usize| 1.0 / speeds.get(d).copied().unwrap_or(1.0);
    pull_schedule(costs.len(), world, |i, d| costs[i] * inv(d)).into_iter().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PaperModel;

    fn cost() -> CostModel {
        CostModel::for_model(PaperModel::M1_5B)
    }

    /// dev0: two micros, dev1: one micro + (unpadded) nothing.
    fn plan() -> (Plan, Vec<usize>) {
        let plan = Plan { micro: vec![vec![vec![0], vec![1, 2]], vec![vec![3]]] };
        let lens = vec![50_000, 8_000, 7_000, 30_000];
        (plan, lens)
    }

    #[test]
    fn static_serves_rows_in_order_with_canonical_ids() {
        let (plan, _) = plan();
        let d = StaticDispatch::new(&plan, false);
        assert_eq!(d.total_micros(), 3);
        let a0 = d.next_micro(0).unwrap();
        let a1 = d.next_micro(0).unwrap();
        assert!(d.next_micro(0).is_none());
        let b0 = d.next_micro(1).unwrap();
        assert!(d.next_micro(1).is_none());
        assert_eq!((a0.id, a1.id, b0.id), (0, 1, 2));
        assert_eq!(&a1.samples[..], &[1, 2]);
        assert_eq!(&b0.samples[..], &[3]);
    }

    #[test]
    fn static_pads_to_common_count_for_collective() {
        let (plan, _) = plan();
        let d = StaticDispatch::new(&plan, true);
        assert_eq!(d.total_micros(), 4);
        let _ = d.next_micro(1).unwrap();
        let pad = d.next_micro(1).unwrap();
        assert!(pad.samples.is_empty(), "second slot of device 1 is a padded barrier slot");
        assert!(d.next_micro(1).is_none());
    }

    /// Regression: a NaN predicted cost (e.g. a hand-built CostModel
    /// with non-finite coefficients — the config path rejects these at
    /// validation) used to panic `partial_cmp().unwrap()` mid-sort.
    /// total_cmp totally orders NaN, so the pull order stays
    /// deterministic and every microbatch is still served exactly once.
    #[test]
    fn lpt_order_survives_nan_costs() {
        let (plan, lens) = plan();
        let nan_cost = CostModel { linear: f64::NAN, quad: 0.0, micro_overhead: 0.0, device_flops: 1.0 };
        let order_a = lpt_order(&plan, &lens, &nan_cost);
        let order_b = lpt_order(&plan, &lens, &nan_cost);
        assert_eq!(order_a, order_b, "NaN costs must still give a deterministic order");
        let mut served = order_a.clone();
        served.sort_unstable();
        assert_eq!(served, vec![(0, 0), (0, 1), (1, 0)], "every non-empty micro served once");
        let q = WorkQueue::new(&plan, &lens, &nan_cost);
        let ids: Vec<u64> = std::iter::from_fn(|| q.next_micro(0)).map(|a| a.id).collect();
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn queue_pulls_lpt_order_exactly_once() {
        let (plan, lens) = plan();
        let c = cost();
        let q = WorkQueue::new(&plan, &lens, &c);
        assert_eq!(q.total_micros(), 3);
        // costs: micro(id 0)=50k sample (largest), id 2=[3] (30k), id 1=[1,2] (15k)
        let ids: Vec<u64> = std::iter::from_fn(|| q.next_micro(0)).map(|a| a.id).collect();
        assert_eq!(ids, vec![0, 2, 1], "pull order is LPT, ids stay canonical");
        assert!(q.next_micro(0).is_none(), "drained queue stays drained");
    }

    #[test]
    fn queue_ids_are_plan_canonical_not_pull_positions() {
        let (plan, lens) = plan();
        let q = WorkQueue::new(&plan, &lens, &cost());
        let mut served: Vec<(u64, Vec<usize>)> =
            std::iter::from_fn(|| q.next_micro(0)).map(|a| (a.id, a.samples.to_vec())).collect();
        served.sort_by_key(|(id, _)| *id);
        let want: Vec<Vec<usize>> = vec![vec![0], vec![1, 2], vec![3]];
        assert_eq!(served.into_iter().map(|(_, s)| s).collect::<Vec<_>>(), want);
    }

    #[test]
    fn make_dispatcher_picks_policy() {
        let (plan, lens) = plan();
        let c = cost();
        let q = make_dispatcher(Balancer::Queue, CommScheme::Odc, &plan, &lens, &c);
        assert_eq!(q.name(), "queue");
        let s = make_dispatcher(Balancer::LbMini, CommScheme::Odc, &plan, &lens, &c);
        assert_eq!(s.name(), "static");
    }

    #[test]
    fn elastic_wrapper_reenqueues_failed_work() {
        let (plan, lens) = plan();
        let c = cost();
        let inner = make_dispatcher(Balancer::Queue, CommScheme::Odc, &plan, &lens, &c);
        let d = ElasticDispatch::new(inner, vec![true, false], &[false, false], false);
        // device 0 pulls the costliest micro, then crashes holding it
        let a = d.next_micro(0).unwrap();
        d.report_failed(0);
        // device 1 gets the orphan FIRST, then the rest — exactly once
        let ids: Vec<u64> = std::iter::from_fn(|| d.next_micro(1)).map(|x| x.id).collect();
        assert_eq!(ids[0], a.id, "the orphaned in-flight assignment is served next");
        let mut all = ids;
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "every microbatch exactly once across the crash");
    }

    #[test]
    fn elastic_wrapper_drains_absent_static_rows() {
        let (plan, _lens) = plan();
        let inner: Arc<dyn Dispatcher> = Arc::new(StaticDispatch::new(&plan, false));
        let d = ElasticDispatch::new(inner, vec![false, false], &[false, true], true);
        // device 1 is absent (not yet joined): its whole row is orphaned
        // at construction, and device 0 serves orphans before its own row
        let ids: Vec<u64> = std::iter::from_fn(|| d.next_micro(0)).map(|x| x.id).collect();
        let mut all = ids;
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2], "the absent device's share is redistributed");
    }

    #[test]
    fn elastic_wrapper_crash_at_drain_resolves() {
        let (plan, lens) = plan();
        let c = cost();
        let inner = make_dispatcher(Balancer::Queue, CommScheme::Odc, &plan, &lens, &c);
        let d = ElasticDispatch::new(inner, vec![true, false], &[false, false], false);
        // the crasher itself drains the queue without hitting its fail
        // pull: it gets None immediately (never waits on itself)...
        while d.next_micro(0).is_some() {}
        // ...and its drain-time report resolves the pending crash so
        // survivors stop waiting and leave.
        d.report_failed(0);
        assert!(d.next_micro(1).is_none());
    }

    #[test]
    fn pull_makespan_matches_hand_schedule() {
        // jobs 8,1,1,1,1,1,1 on 2 devices: LPT parks the 8 alone => 8;
        // worst order stacks it on a warm device => 11.
        let lpt = [8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let spt = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 8.0];
        assert_eq!(pull_makespan(&lpt, 2, &[]), 8.0);
        assert_eq!(pull_makespan(&spt, 2, &[]), 11.0);
    }

    #[test]
    fn pull_makespan_respects_device_speeds() {
        // one job of cost 4 on a half-speed device takes 8
        assert_eq!(pull_makespan(&[4.0], 1, &[0.5]), 8.0);
        // two jobs, speeds [1, 0.5]: both start free; job1 -> dev0 (4),
        // job2 -> dev1 at half speed (8)
        assert_eq!(pull_makespan(&[4.0, 4.0], 2, &[1.0, 0.5]), 8.0);
    }
}
