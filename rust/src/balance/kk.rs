//! Karmarkar–Karp (largest differencing method) k-way number partitioning.
//!
//! This is Listing 1's `karmarkar_karp(compute_costs, k_partitions,
//! equal_size)`. The `equal_size=true` variant (used whenever devices
//! must receive identical sample counts — all collective schemes, and
//! ODC in RL mode) follows the verl implementation: items are grouped
//! k-at-a-time so every intermediate state assigns exactly the same
//! number of items to each partition; merging pairs the largest sums of
//! one state with the smallest of the other, preserving the invariant.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One in-progress partition tuple.
#[derive(Clone, Debug)]
struct State {
    /// Per-partition sums, kept sorted DESCENDING.
    sums: Vec<f64>,
    /// Item indices per partition, aligned with `sums`.
    sets: Vec<Vec<usize>>,
}

impl State {
    fn spread(&self) -> f64 {
        self.sums[0] - self.sums[self.sums.len() - 1]
    }

    /// Sort partitions by sum descending (canonical form).
    fn canon(mut self) -> Self {
        let mut idx: Vec<usize> = (0..self.sums.len()).collect();
        idx.sort_by(|&a, &b| self.sums[b].partial_cmp(&self.sums[a]).unwrap());
        self.sums = idx.iter().map(|&i| self.sums[i]).collect();
        self.sets = idx.iter().map(|&i| std::mem::take(&mut self.sets[i])).collect();
        self
    }

    /// KK merge: largest of `self` paired with smallest of `other`.
    fn merge(self, other: State) -> State {
        let k = self.sums.len();
        let mut sums = Vec::with_capacity(k);
        let mut sets = Vec::with_capacity(k);
        for i in 0..k {
            let j = k - 1 - i;
            sums.push(self.sums[i] + other.sums[j]);
            let mut s = self.sets[i].clone();
            s.extend_from_slice(&other.sets[j]);
            sets.push(s);
        }
        State { sums, sets }.canon()
    }
}

struct HeapEntry(State);

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.0.spread() == other.0.spread()
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.spread().partial_cmp(&other.0.spread()).unwrap_or(Ordering::Equal)
    }
}

/// Partition `costs` into `k` sets minimizing the max-set sum (heuristic).
///
/// Returns item-index sets, ordered by descending set sum. With
/// `equal_size`, every set receives exactly `ceil(n/k)` or `floor(n/k)`
/// items (zero-cost padding is used internally and stripped).
pub fn karmarkar_karp(costs: &[f64], k: usize, equal_size: bool) -> Vec<Vec<usize>> {
    assert!(k >= 1);
    let n = costs.len();
    if k == 1 {
        return vec![(0..n).collect()];
    }

    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    if equal_size {
        // Group items k at a time (largest first), each group becoming one
        // state whose partitions hold exactly one (possibly dummy) item.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
        let n_pad = n.div_ceil(k) * k;
        for chunk_start in (0..n_pad).step_by(k) {
            let mut sums = Vec::with_capacity(k);
            let mut sets = Vec::with_capacity(k);
            for j in 0..k {
                let pos = chunk_start + j;
                if pos < n {
                    sums.push(costs[order[pos]]);
                    sets.push(vec![order[pos]]);
                } else {
                    sums.push(0.0);
                    sets.push(vec![]); // dummy
                }
            }
            heap.push(HeapEntry(State { sums, sets }.canon()));
        }
    } else {
        for (i, &c) in costs.iter().enumerate() {
            let mut sums = vec![0.0; k];
            let mut sets = vec![Vec::new(); k];
            sums[0] = c;
            sets[0] = vec![i];
            heap.push(HeapEntry(State { sums, sets }));
        }
        if heap.is_empty() {
            return vec![Vec::new(); k];
        }
    }

    while heap.len() > 1 {
        let a = heap.pop().unwrap().0;
        let b = heap.pop().unwrap().0;
        heap.push(HeapEntry(a.merge(b)));
    }
    heap.pop().map(|e| e.0.sets).unwrap_or_else(|| vec![Vec::new(); k])
}

/// Max-sum minus min-sum of a partition under `costs` (test helper +
/// used by bubble estimates).
pub fn partition_spread(costs: &[f64], parts: &[Vec<usize>]) -> f64 {
    let sums: Vec<f64> = parts.iter().map(|p| p.iter().map(|&i| costs[i]).sum()).collect();
    let max = sums.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = sums.iter().cloned().fold(f64::INFINITY, f64::min);
    max - min
}

/// Greedy LPT baseline (largest item to the smallest bin) — used in tests
/// to sanity-check KK quality, and by the simulator as a cheap fallback.
pub fn greedy_partition(costs: &[f64], k: usize) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    let mut sums = vec![0.0; k];
    let mut sets = vec![Vec::new(); k];
    for i in order {
        let j = (0..k).min_by(|&a, &b| sums[a].partial_cmp(&sums[b]).unwrap()).unwrap();
        sums[j] += costs[i];
        sets[j].push(i);
    }
    sets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, vec_of};
    use crate::util::rng::Rng;

    fn is_partition(n: usize, parts: &[Vec<usize>]) -> bool {
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        all == (0..n).collect::<Vec<_>>()
    }

    #[test]
    fn partitions_exactly() {
        let costs = vec![5.0, 3.0, 8.0, 1.0, 9.0, 2.0, 7.0];
        for k in 1..=4 {
            for eq in [false, true] {
                let p = karmarkar_karp(&costs, k, eq);
                assert_eq!(p.len(), k);
                assert!(is_partition(costs.len(), &p), "k={k} eq={eq}");
            }
        }
    }

    #[test]
    fn classic_kk_example() {
        // {4,5,6,7,8} into 2: optimum is {4,5,6}/{7,8} (spread 0); the LDM
        // heuristic famously lands at spread 2 on this instance — accept
        // anything at least that good.
        let costs = vec![4.0, 5.0, 6.0, 7.0, 8.0];
        let p = karmarkar_karp(&costs, 2, false);
        assert!(partition_spread(&costs, &p) <= 2.0, "{p:?}");
    }

    #[test]
    fn equal_size_counts() {
        let costs: Vec<f64> = (1..=12).map(|i| i as f64).collect();
        let p = karmarkar_karp(&costs, 4, true);
        for set in &p {
            assert_eq!(set.len(), 3);
        }
        assert!(is_partition(12, &p));
    }

    #[test]
    fn equal_size_with_remainder() {
        let costs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let p = karmarkar_karp(&costs, 4, true);
        let mut counts: Vec<usize> = p.iter().map(|s| s.len()).collect();
        counts.sort_unstable();
        assert_eq!(counts, vec![2, 2, 3, 3]);
        assert!(is_partition(10, &p));
    }

    #[test]
    fn kk_not_worse_than_greedy_on_seeds() {
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let n = rng.range(8, 40) as usize;
            let k = rng.range(2, 8) as usize;
            let costs: Vec<f64> = (0..n).map(|_| rng.f64() * 1000.0 + 1.0).collect();
            let kk = karmarkar_karp(&costs, k, false);
            let gr = greedy_partition(&costs, k);
            // KK (LDM) should rarely lose to LPT; allow small slack.
            assert!(
                partition_spread(&costs, &kk) <= partition_spread(&costs, &gr) * 1.5 + 1e-9,
                "KK much worse than greedy"
            );
        }
    }

    #[test]
    fn prop_partition_preserves_multiset() {
        check(
            "kk-partition",
            60,
            |r| {
                let costs = vec_of(r, 1, 30, |r| r.below(1_000) + 1);
                let k = r.range(1, 6) as u64;
                (costs, k)
            },
            |(costs, k)| {
                let f: Vec<f64> = costs.iter().map(|&c| c as f64).collect();
                for eq in [false, true] {
                    let p = karmarkar_karp(&f, *k as usize, eq);
                    if !is_partition(costs.len(), &p) {
                        return Err(format!("not a partition (eq={eq})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_equal_size_balanced_counts() {
        check(
            "kk-equal-counts",
            60,
            |r| {
                let costs = vec_of(r, 1, 40, |r| r.below(1_000) + 1);
                let k = r.range(1, 8) as u64;
                (costs, k)
            },
            |(costs, k)| {
                let f: Vec<f64> = costs.iter().map(|&c| c as f64).collect();
                let p = karmarkar_karp(&f, *k as usize, true);
                let max = p.iter().map(|s| s.len()).max().unwrap();
                let min = p.iter().map(|s| s.len()).min().unwrap();
                if max - min > 1 {
                    return Err(format!("counts differ by {} (>1)", max - min));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(karmarkar_karp(&[], 3, false), vec![Vec::<usize>::new(); 3]);
        let p = karmarkar_karp(&[5.0], 3, false);
        assert_eq!(p.iter().map(|s| s.len()).sum::<usize>(), 1);
    }
}
