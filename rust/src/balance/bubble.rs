//! Bubble-rate estimation (Tables 4 and 6).
//!
//! The paper defines bubble rate as "the ratio of device idle time —
//! caused by workload imbalance — to the total run time, as estimated by
//! the packing algorithm" (Appendix G). Communication is ignored here
//! (that's the simulator's job); this is the pure compute-imbalance
//! estimate, which the paper shows closely tracks the measured speedups.
//!
//! * **Collective** (eq. 1 collapsed over uniform layers): every
//!   microbatch index is a barrier, so T = Σ_m max_d c(m, d).
//! * **ODC**: devices only sync at the minibatch end: T = max_d Σ_m c(m, d).

use super::cost::CostModel;
use super::dispatch::{micro_flops_split, queue_busy_split};
use super::packers::Plan;
use super::split::SplitMap;
use crate::config::CommScheme;

#[derive(Clone, Debug)]
pub struct BubbleReport {
    /// Estimated minibatch wall time (FLOP-equivalents).
    pub total: f64,
    /// Per-device busy time.
    pub busy: Vec<f64>,
    /// 1 - mean(busy)/total.
    pub bubble_rate: f64,
}

/// Estimate the bubble rate of one minibatch plan under a comm scheme.
pub fn estimate_bubble(plan: &Plan, lens: &[usize], cost: &CostModel, scheme: CommScheme) -> BubbleReport {
    let d = plan.devices();
    let m_max = plan.max_micro_count();
    let micro_cost = |dev: usize, m: usize| -> f64 {
        match plan.micro[dev].get(m) {
            Some(mb) if !mb.is_empty() => {
                let ls: Vec<usize> = mb.iter().map(|&i| lens[i]).collect();
                cost.micro_cost(&ls)
            }
            _ => 0.0,
        }
    };

    let busy: Vec<f64> = (0..d).map(|dev| (0..m_max).map(|m| micro_cost(dev, m)).sum()).collect();

    let total = match scheme {
        CommScheme::Collective => {
            // per-microbatch barrier: wait for the slowest device each index
            (0..m_max)
                .map(|m| (0..d).map(|dev| micro_cost(dev, m)).fold(0.0, f64::max))
                .sum()
        }
        // hybrid devices free-run within the minibatch exactly like ODC
        // (intra-group reduces are mailbox pushes, not barriers)
        CommScheme::Odc | CommScheme::Hybrid => busy.iter().cloned().fold(0.0, f64::max),
    };

    let total = total.max(f64::MIN_POSITIVE);
    let bubble_rate = 1.0 - busy.iter().sum::<f64>() / (d as f64 * total);
    BubbleReport { total, busy, bubble_rate }
}

/// `estimate_bubble` generalized over the straggler scenario and the
/// dispatch policy, so the simulator's bubble rate and its
/// `dispatch_wait_s` tell one consistent story. `speeds` scales each
/// device's compute by `1/speed` (empty = homogeneous, the seed
/// behaviour); `queue` replays the plan's microbatches through the
/// greedy LPT pull schedule ([`pull_schedule`] — the engine's
/// `WorkQueue` dynamics) instead of the static placement. Still
/// compute-only: communication stays the timeline simulator's job.
pub fn estimate_bubble_dispatch(
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    scheme: CommScheme,
    speeds: &[f64],
    queue: bool,
) -> BubbleReport {
    let empty = SplitMap::empty(lens.len());
    estimate_bubble_dispatch_split(plan, lens, cost, scheme, speeds, queue, &empty)
}

/// `estimate_bubble_dispatch` made split-aware: chunk virtual samples
/// (ids ≥ `split.base()`) are priced by [`CostModel::chunk_cost`]
/// through the one shared makespan kernel
/// ([`queue_busy_split`] — also the simulator's queue path), so the CLI
/// bubble line and the timeline's dispatch-wait line agree under
/// splitting by construction. With an empty map this is bit-identical
/// to `estimate_bubble_dispatch`.
#[allow(clippy::too_many_arguments)]
pub fn estimate_bubble_dispatch_split(
    plan: &Plan,
    lens: &[usize],
    cost: &CostModel,
    scheme: CommScheme,
    speeds: &[f64],
    queue: bool,
    split: &SplitMap,
) -> BubbleReport {
    if speeds.is_empty() && !queue && split.is_empty() {
        return estimate_bubble(plan, lens, cost, scheme);
    }
    let d = plan.devices();
    let inv = |dev: usize| 1.0 / speeds.get(dev).copied().unwrap_or(1.0);
    let micro_cost = |dev: usize, m: usize| -> f64 {
        match plan.micro[dev].get(m) {
            Some(mb) if !mb.is_empty() => micro_flops_split(mb, lens, cost, split),
            _ => 0.0,
        }
    };

    let busy: Vec<f64> = if queue {
        debug_assert!(scheme != CommScheme::Collective, "Queue×Collective is rejected at config validation");
        queue_busy_split(plan, lens, cost, split, |flops, dev| flops * inv(dev))
    } else {
        (0..d)
            .map(|dev| (0..plan.micro[dev].len()).map(|m| micro_cost(dev, m)).sum::<f64>() * inv(dev))
            .collect()
    };

    let total = match scheme {
        CommScheme::Collective => {
            let m_max = plan.max_micro_count();
            (0..m_max)
                .map(|m| (0..d).map(|dev| micro_cost(dev, m) * inv(dev)).fold(0.0, f64::max))
                .sum()
        }
        CommScheme::Odc | CommScheme::Hybrid => busy.iter().cloned().fold(0.0, f64::max),
    };

    let total = total.max(f64::MIN_POSITIVE);
    let bubble_rate = 1.0 - busy.iter().sum::<f64>() / (d as f64 * total);
    BubbleReport { total, busy, bubble_rate }
}

/// Aggregate bubble rate over a whole run (time-weighted).
pub fn run_bubble(plans: &[Plan], lens: &[usize], cost: &CostModel, scheme: CommScheme) -> f64 {
    let mut total = 0.0;
    let mut busy = 0.0;
    let mut d = 1.0;
    for p in plans {
        let r = estimate_bubble(p, lens, cost, scheme);
        total += r.total;
        busy += r.busy.iter().sum::<f64>();
        d = r.busy.len() as f64;
    }
    if total <= 0.0 {
        return 0.0;
    }
    1.0 - busy / (d * total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::packers::plan_run;
    use crate::config::{Balancer, PaperModel};
    use crate::util::rng::Rng;

    fn cost() -> CostModel {
        CostModel::for_model(PaperModel::M1_5B)
    }

    /// Two devices, two microbatches each, costs chosen by hand.
    fn hand_plan() -> (Plan, Vec<usize>) {
        // device 0: micro [s0], [s1]; device 1: micro [s2], [s3]
        let plan = Plan { micro: vec![vec![vec![0], vec![1]], vec![vec![2], vec![3]]] };
        let lens = vec![10_000, 1_000, 1_000, 10_000];
        (plan, lens)
    }

    #[test]
    fn collective_pays_per_micro_max() {
        let (plan, lens) = hand_plan();
        let c = cost();
        let big = c.micro_cost(&[10_000]);
        let small = c.micro_cost(&[1_000]);
        let r = estimate_bubble(&plan, &lens, &c, CommScheme::Collective);
        // step 1 max = big (dev0), step 2 max = big (dev1)
        assert!((r.total - 2.0 * big).abs() < 1e-3 * big);
        let expect_bubble = 1.0 - (2.0 * big + 2.0 * small) / (2.0 * 2.0 * big);
        assert!((r.bubble_rate - expect_bubble).abs() < 1e-9);
    }

    #[test]
    fn odc_pays_per_device_total() {
        let (plan, lens) = hand_plan();
        let c = cost();
        let big = c.micro_cost(&[10_000]);
        let small = c.micro_cost(&[1_000]);
        let r = estimate_bubble(&plan, &lens, &c, CommScheme::Odc);
        // both devices have busy = big + small; perfectly balanced
        assert!((r.total - (big + small)).abs() < 1e-3 * big);
        assert!(r.bubble_rate.abs() < 1e-9);
    }

    #[test]
    fn odc_never_worse_than_collective() {
        let c = cost();
        let mut rng = Rng::new(21);
        for trial in 0..20 {
            let lens: Vec<usize> =
                (0..64).map(|_| (rng.lognormal(8.5, 1.1) as usize).clamp(16, 65_536)).collect();
            let mut r2 = Rng::new(trial);
            for b in [Balancer::LocalSort, Balancer::LbMicro] {
                for plan in plan_run(b, &lens, 4, 4, 65_536, &c, &mut r2) {
                    let col = estimate_bubble(&plan, &lens, &c, CommScheme::Collective);
                    let odc = estimate_bubble(&plan, &lens, &c, CommScheme::Odc);
                    assert!(
                        odc.total <= col.total + 1e-6,
                        "ODC total must not exceed collective on the same plan"
                    );
                }
            }
        }
    }

    #[test]
    fn minibs_one_equalizes_schemes() {
        // With one sample per device per minibatch, ODC == Collective
        // (the §5.2 observation that all methods match at minibatch 1).
        let c = cost();
        let mut rng = Rng::new(5);
        let lens: Vec<usize> = (0..32).map(|_| (rng.lognormal(8.0, 1.0) as usize).clamp(16, 65_536)).collect();
        let mut r = Rng::new(6);
        for plan in plan_run(Balancer::LbMicro, &lens, 8, 1, 65_536, &c, &mut r) {
            let col = estimate_bubble(&plan, &lens, &c, CommScheme::Collective);
            let odc = estimate_bubble(&plan, &lens, &c, CommScheme::Odc);
            assert!((col.total - odc.total).abs() < 1e-6 * col.total);
        }
    }

    #[test]
    fn bubble_rate_in_unit_interval() {
        let c = cost();
        let mut rng = Rng::new(33);
        let lens: Vec<usize> = (0..128).map(|_| (rng.lognormal(8.0, 1.2) as usize).clamp(16, 65_536)).collect();
        let mut r = Rng::new(34);
        for b in [Balancer::LocalSort, Balancer::LbMicro, Balancer::LbMini, Balancer::VerlNative] {
            for plan in plan_run(b, &lens, 4, 4, 65_536, &c, &mut r) {
                for s in [CommScheme::Collective, CommScheme::Odc] {
                    let rep = estimate_bubble(&plan, &lens, &c, s);
                    assert!((0.0..1.0).contains(&rep.bubble_rate), "{b:?} {s:?}: {}", rep.bubble_rate);
                }
            }
        }
    }

    #[test]
    fn dispatch_variant_matches_seed_estimator_when_unperturbed() {
        let (plan, lens) = hand_plan();
        let c = cost();
        for scheme in [CommScheme::Collective, CommScheme::Odc] {
            let a = estimate_bubble(&plan, &lens, &c, scheme);
            let b = estimate_bubble_dispatch(&plan, &lens, &c, scheme, &[], false);
            assert_eq!(a.total, b.total);
            assert_eq!(a.busy, b.busy);
            assert_eq!(a.bubble_rate, b.bubble_rate);
        }
    }

    #[test]
    fn straggler_inflates_static_bubble_and_queue_recovers_it() {
        // 8 equal singleton micros dealt 4+4; device 0 at quarter speed.
        // Static: dev0's column takes 4× while dev1 idles => large
        // bubble. Queue: dev1 absorbs most micros => smaller bubble.
        let plan = Plan {
            micro: vec![(0..4).map(|i| vec![i]).collect(), (4..8).map(|i| vec![i]).collect()],
        };
        let lens = vec![10_000usize; 8];
        let c = cost();
        let speeds = [0.25, 1.0];
        let uniform = estimate_bubble_dispatch(&plan, &lens, &c, CommScheme::Odc, &[], false);
        let stat = estimate_bubble_dispatch(&plan, &lens, &c, CommScheme::Odc, &speeds, false);
        let queue = estimate_bubble_dispatch(&plan, &lens, &c, CommScheme::Odc, &speeds, true);
        assert!(stat.bubble_rate > uniform.bubble_rate, "straggler must show up in the bubble rate");
        assert!(queue.bubble_rate < stat.bubble_rate, "queue {} should shrink static bubble {}", queue.bubble_rate, stat.bubble_rate);
    }

    #[test]
    fn lb_mini_lowers_odc_bubble_vs_lb_micro() {
        // The paper's Table 6 pattern at small minibatch sizes.
        let c = cost();
        let mut rng = Rng::new(44);
        let lens: Vec<usize> = (0..1024).map(|_| (rng.lognormal(8.5, 1.15) as usize).clamp(32, 65_536)).collect();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let micro = plan_run(Balancer::LbMicro, &lens, 8, 2, 65_536, &c, &mut r1);
        let mini = plan_run(Balancer::LbMini, &lens, 8, 2, 65_536, &c, &mut r2);
        let b_micro = run_bubble(&micro, &lens, &c, CommScheme::Odc);
        let b_mini = run_bubble(&mini, &lens, &c, CommScheme::Odc);
        assert!(b_mini <= b_micro + 0.02, "LB-Mini {b_mini} should be <= LB-Micro {b_micro} under ODC");
    }
}
