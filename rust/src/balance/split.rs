//! SeqSplit: context-parallel splitting of overlong sequences.
//!
//! LB-Mini and the work queue balance at whole-sequence granularity, so
//! one overlong sequence caps minibatch makespan no matter how the
//! dispatcher shuffles placement — the straggler IS the sequence. The
//! split rule chunks any sequence whose predicted cost exceeds a
//! configurable fraction of the balanced per-device budget into
//! contiguous token spans, each of which becomes a **virtual sample**:
//! an id `>= base` (the corpus size) that every downstream layer —
//! packers, dispatch, bubble, timeline, trainer — resolves through the
//! [`SplitMap`] produced alongside the plans.
//!
//! ## The split rule
//!
//! Per minibatch, with `B = Σ sample costs / world` the balanced
//! per-device budget and `T = frac·B` the threshold: a sample with
//! `sample_cost(len) > T` is cut into
//! `c = min(world, ceil(cost / T))` chunks (never more chunks than
//! devices — a chunk per device already removes the straggler, and more
//! would only add rendezvous traffic). Chunk boundaries depend on the
//! mode:
//!
//! * [`SplitMode::Ring`] — equal **token** spans, the classic ring
//!   attention slicing. Simple, but under causal attention later chunks
//!   carry more work (longer prefix).
//! * [`SplitMode::Zigzag`] — equal **cost** spans: boundaries solve the
//!   quadratic `CostModel::chunk_cost` so every chunk prices the same,
//!   the load-equalization goal of zigzag sharding achieved with
//!   contiguous spans (front chunks get more tokens, back chunks
//!   fewer).
//!
//! Chunk costs telescope exactly to the parent's cost
//! ([`CostModel::chunk_cost`]), so splitting conserves total work; it
//! only redistributes it. Each chunk is packed as a **singleton
//! microbatch** — never co-packed with other samples — so its gradient
//! push carries exactly that chunk's contribution and the per-sequence
//! rendezvous fold in the comm daemons ([`crate::comm`]) can
//! reconstitute the parent's gradient deterministically.
//!
//! ## Legality
//!
//! Splitting needs the barrier-free schemes: Collective's padded
//! barrier slots assume whole sequences (every rank walks the same
//! per-layer barrier count), so `--seq-split` is rejected under it at
//! config validation, exactly like LB-Mini and Queue are. It also
//! requires an LB-Mini-family balancer (LbMini or Queue): the
//! synchronized-k packers pad to equal microbatch counts and have no
//! slot for singleton chunk micros.

use super::cost::CostModel;
use std::fmt;

/// Chunk boundary rule. See the module docs for the trade-off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitMode {
    /// Equal token spans (ring attention slicing).
    Ring,
    /// Equal cost spans (zigzag-style load equalization, contiguous).
    Zigzag,
}

impl SplitMode {
    pub fn parse(s: &str) -> Option<SplitMode> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Some(SplitMode::Ring),
            "zigzag" => Some(SplitMode::Zigzag),
            _ => None,
        }
    }
}

impl fmt::Display for SplitMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SplitMode::Ring => write!(f, "ring"),
            SplitMode::Zigzag => write!(f, "zigzag"),
        }
    }
}

/// One chunk of a split sequence: tokens `[start, start+len)` of sample
/// `parent`, chunk `index` of `count`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkInfo {
    /// Base sample id this chunk was cut from.
    pub parent: usize,
    /// Position of this chunk within the parent (0-based).
    pub index: usize,
    /// Total chunks the parent was cut into.
    pub count: usize,
    /// Token offset of the chunk within the parent.
    pub start: usize,
    /// Tokens in the chunk.
    pub len: usize,
}

/// The split table produced with a plan: virtual sample ids `>= base`
/// map to chunks; ids `< base` are ordinary whole samples. Every layer
/// that prices or materializes samples resolves ids through this map,
/// so the `Plan`/dispatch/fold machinery needs no new id space.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SplitMap {
    base: usize,
    chunks: Vec<ChunkInfo>,
}

impl SplitMap {
    /// An empty map over a corpus of `base` samples (no splits).
    pub fn empty(base: usize) -> SplitMap {
        SplitMap { base, chunks: Vec::new() }
    }

    /// First virtual id (== the corpus size the map was built over).
    pub fn base(&self) -> usize {
        self.base
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    /// Total chunks across all split parents.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Is `id` a chunk virtual id?
    #[inline]
    pub fn is_chunk(&self, id: usize) -> bool {
        id >= self.base
    }

    /// The chunk behind virtual id `id` (panics on a base id).
    #[inline]
    pub fn chunk(&self, id: usize) -> &ChunkInfo {
        &self.chunks[id - self.base]
    }

    pub fn get(&self, id: usize) -> Option<&ChunkInfo> {
        if self.is_chunk(id) {
            self.chunks.get(id - self.base)
        } else {
            None
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &ChunkInfo> {
        self.chunks.iter()
    }

    /// Token count of `id`: the chunk's span, or `lens[id]` for a whole
    /// sample.
    #[inline]
    pub fn len_of(&self, id: usize, lens: &[usize]) -> usize {
        match self.get(id) {
            Some(c) => c.len,
            None => lens[id],
        }
    }

    /// Predicted compute cost of `id`: causal-prefix-aware
    /// [`CostModel::chunk_cost`] for a chunk, `sample_cost` for a whole
    /// sample. Identical to `sample_cost(lens[id])` when the map is
    /// empty — the split-disabled paths stay bit-identical.
    #[inline]
    pub fn cost_of(&self, id: usize, lens: &[usize], cost: &CostModel) -> f64 {
        match self.get(id) {
            Some(c) => cost.chunk_cost(c.start, c.start + c.len),
            None => cost.sample_cost(lens[id]),
        }
    }

    /// Register the chunks of one freshly split parent and return their
    /// virtual ids, in chunk-index order.
    pub fn push_parent(&mut self, chunks: Vec<ChunkInfo>) -> Vec<usize> {
        let first = self.base + self.chunks.len();
        let ids = (first..first + chunks.len()).collect();
        self.chunks.extend(chunks);
        ids
    }
}

/// Cut a sequence of `len` tokens into `count` contiguous chunks under
/// `mode`. Boundaries are strictly increasing (every chunk gets at
/// least one token); callers clamp `count <= len`.
pub fn chunk_boundaries(len: usize, count: usize, mode: SplitMode, cost: &CostModel) -> Vec<usize> {
    debug_assert!(count >= 1 && count <= len);
    let mut cuts = Vec::with_capacity(count + 1);
    cuts.push(0usize);
    for i in 1..count {
        let raw = match mode {
            // equal token spans
            SplitMode::Ring => (i as f64 * len as f64 / count as f64).round() as usize,
            // equal cost spans: cumulative cost to position x is
            // linear·x + quad·x²; invert it at i/count of the total
            SplitMode::Zigzag => {
                let target = cost.sample_cost(len) * i as f64 / count as f64;
                invert_cumulative_cost(target, cost).round() as usize
            }
        };
        // monotone clamp: leave room for the remaining chunks
        let lo = cuts[i - 1] + 1;
        let hi = len - (count - i);
        cuts.push(raw.clamp(lo, hi));
    }
    cuts.push(len);
    cuts
}

/// Solve `quad·x² + linear·x = target` for x ≥ 0.
fn invert_cumulative_cost(target: f64, cost: &CostModel) -> f64 {
    if cost.quad <= 0.0 {
        return target / cost.linear;
    }
    let (a, b) = (cost.quad, cost.linear);
    (-b + (b * b + 4.0 * a * target).sqrt()) / (2.0 * a)
}

/// Apply the split rule to one minibatch: any member whose cost exceeds
/// `frac` of the balanced per-device budget is replaced by its chunks'
/// virtual ids (registered in `map`). Returns the minibatch with split
/// parents substituted in place — order preserved, chunks in index
/// order — so the downstream packers see one flat id list.
pub fn split_minibatch(
    mb: &[usize],
    lens: &[usize],
    world: usize,
    frac: f64,
    mode: SplitMode,
    cost: &CostModel,
    map: &mut SplitMap,
) -> Vec<usize> {
    debug_assert!(frac > 0.0);
    let total: f64 = mb.iter().map(|&i| cost.sample_cost(lens[i])).sum();
    let threshold = frac * total / world as f64;
    let mut out = Vec::with_capacity(mb.len());
    for &id in mb {
        let c = cost.sample_cost(lens[id]);
        if c <= threshold || lens[id] < 2 {
            out.push(id);
            continue;
        }
        let count = ((c / threshold).ceil() as usize).clamp(2, world).min(lens[id]);
        if count < 2 {
            out.push(id);
            continue;
        }
        let cuts = chunk_boundaries(lens[id], count, mode, cost);
        let chunks = cuts
            .windows(2)
            .enumerate()
            .map(|(index, w)| ChunkInfo { parent: id, index, count, start: w[0], len: w[1] - w[0] })
            .collect();
        out.extend(map.push_parent(chunks));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PaperModel;

    fn cm() -> CostModel {
        CostModel::for_model(PaperModel::M1_5B)
    }

    #[test]
    fn ring_boundaries_equal_tokens() {
        let cuts = chunk_boundaries(1000, 4, SplitMode::Ring, &cm());
        assert_eq!(cuts, vec![0, 250, 500, 750, 1000]);
    }

    #[test]
    fn zigzag_boundaries_equalize_cost() {
        let c = cm();
        let len = 65_536;
        let cuts = chunk_boundaries(len, 4, SplitMode::Zigzag, &c);
        assert_eq!(cuts[0], 0);
        assert_eq!(*cuts.last().unwrap(), len);
        let costs: Vec<f64> = cuts.windows(2).map(|w| c.chunk_cost(w[0], w[1])).collect();
        let (lo, hi) = (costs.iter().cloned().fold(f64::MAX, f64::min), costs.iter().cloned().fold(0.0, f64::max));
        assert!(hi / lo < 1.001, "zigzag chunk costs must be near-equal: {costs:?}");
        // and the front chunk holds more tokens than the back chunk
        assert!(cuts[1] - cuts[0] > cuts[4] - cuts[3]);
    }

    #[test]
    fn boundaries_cover_without_gaps_even_tiny() {
        let c = cm();
        for len in [2usize, 3, 5, 7, 100] {
            for count in 1..=len.min(6) {
                for mode in [SplitMode::Ring, SplitMode::Zigzag] {
                    let cuts = chunk_boundaries(len, count, mode, &c);
                    assert_eq!(cuts.len(), count + 1);
                    assert_eq!(cuts[0], 0);
                    assert_eq!(*cuts.last().unwrap(), len);
                    assert!(cuts.windows(2).all(|w| w[1] > w[0]), "{len}/{count}/{mode}: {cuts:?}");
                }
            }
        }
    }

    #[test]
    fn no_split_when_under_budget() {
        let lens = vec![100usize; 8];
        let mut map = SplitMap::empty(lens.len());
        let mb: Vec<usize> = (0..8).collect();
        let out = split_minibatch(&mb, &lens, 4, 0.5, SplitMode::Ring, &cm(), &mut map);
        assert_eq!(out, mb);
        assert!(map.is_empty());
    }

    #[test]
    fn dominant_sequence_splits_conserving_tokens_and_cost() {
        let c = cm();
        let mut lens = vec![2048usize; 7];
        lens.push(65_536); // one dominant straggler
        let mut map = SplitMap::empty(lens.len());
        let mb: Vec<usize> = (0..8).collect();
        let out = split_minibatch(&mb, &lens, 4, 0.5, SplitMode::Zigzag, &c, &mut map);
        assert!(!map.is_empty());
        assert!(out.iter().all(|&i| i != 7), "the split parent must leave the minibatch");
        let chunk_ids: Vec<usize> = out.iter().copied().filter(|&i| map.is_chunk(i)).collect();
        let toks: usize = chunk_ids.iter().map(|&i| map.len_of(i, &lens)).sum();
        assert_eq!(toks, 65_536, "chunks must cover the parent exactly");
        let cost_sum: f64 = chunk_ids.iter().map(|&i| map.cost_of(i, &lens, &c)).sum();
        let rel = (cost_sum - c.sample_cost(65_536)).abs() / c.sample_cost(65_536);
        assert!(rel < 1e-12, "split must conserve cost: rel {rel}");
        // chunks are contiguous and in order
        let mut pos = 0usize;
        for &i in &chunk_ids {
            let ch = map.chunk(i);
            assert_eq!(ch.parent, 7);
            assert_eq!(ch.start, pos);
            pos += ch.len;
        }
    }

    #[test]
    fn chunk_count_capped_at_world() {
        let c = cm();
        let lens = vec![65_536usize]; // one sample: budget = cost/world
        let mut map = SplitMap::empty(1);
        split_minibatch(&[0], &lens, 4, 0.1, SplitMode::Ring, &c, &mut map);
        assert_eq!(map.n_chunks(), 4, "never more chunks than devices");
    }

    #[test]
    fn map_resolves_base_ids_unchanged() {
        let lens = vec![10usize, 20];
        let map = SplitMap::empty(2);
        let c = cm();
        assert_eq!(map.len_of(1, &lens), 20);
        assert_eq!(map.cost_of(1, &lens, &c), c.sample_cost(20));
        assert!(!map.is_chunk(1));
    }
}
