//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (shapes, dtypes, file names, initial parameters).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub block_params: usize,
    pub embed_params: usize,
    pub total_params: usize,
    pub seq_buckets: Vec<usize>,
    pub chunk: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub init_embed: PathBuf,
    pub init_blocks: Vec<PathBuf>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let name = j.req("name")?.as_str().ok_or(anyhow!("bad name"))?.to_string();
    let shape = j
        .req("shape")?
        .as_arr()
        .ok_or(anyhow!("bad shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or(anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.req("dtype")?.as_str() {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => return Err(anyhow!("unsupported dtype {other:?}")),
    };
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    /// Load `artifacts/<preset>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let m = j.req("model").map_err(|e| anyhow!("{e}"))?;
        let get = |k: &str| -> Result<usize> {
            m.req(k).map_err(|e| anyhow!("{e}"))?.as_usize().ok_or(anyhow!("model.{k} not a number"))
        };

        let mut artifacts = BTreeMap::new();
        for (k, v) in j.req("artifacts").map_err(|e| anyhow!("{e}"))?.as_obj().ok_or(anyhow!("artifacts not an object"))? {
            let file = v.req("file").map_err(|e| anyhow!("{e}"))?.as_str().ok_or(anyhow!("bad file"))?.to_string();
            let inputs = v.req("inputs").map_err(|e| anyhow!("{e}"))?.as_arr().unwrap_or(&[]).iter().map(tensor_spec).collect::<Result<Vec<_>>>()?;
            let outputs = v.req("outputs").map_err(|e| anyhow!("{e}"))?.as_arr().unwrap_or(&[]).iter().map(tensor_spec).collect::<Result<Vec<_>>>()?;
            artifacts.insert(k.clone(), ArtifactSpec { file, inputs, outputs });
        }

        let init = j.req("init").map_err(|e| anyhow!("{e}"))?;
        let init_embed = dir.join(init.req("embed").map_err(|e| anyhow!("{e}"))?.as_str().ok_or(anyhow!("bad init.embed"))?);
        let init_blocks = init
            .req("blocks")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or(anyhow!("bad init.blocks"))?
            .iter()
            .map(|b| Ok(dir.join(b.as_str().ok_or(anyhow!("bad block path"))?)))
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            preset: j.req("preset").map_err(|e| anyhow!("{e}"))?.as_str().unwrap_or("?").to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            n_layers: get("n_layers")?,
            max_seq: get("max_seq")?,
            block_params: get("block_params")?,
            embed_params: get("embed_params")?,
            total_params: get("total_params")?,
            seq_buckets: j
                .req("seq_buckets")
                .map_err(|e| anyhow!("{e}"))?
                .as_arr()
                .ok_or(anyhow!("bad seq_buckets"))?
                .iter()
                .map(|x| x.as_usize().ok_or(anyhow!("bad bucket")))
                .collect::<Result<Vec<_>>>()?,
            chunk: j.req("chunk").map_err(|e| anyhow!("{e}"))?.as_usize().ok_or(anyhow!("bad chunk"))?,
            artifacts,
            init_embed,
            init_blocks,
            dir,
        })
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(key).ok_or(anyhow!("artifact `{key}` not in manifest"))
    }

    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(key)?.file))
    }

    /// Smallest bucket that fits `tokens`; errors if none.
    pub fn bucket_for(&self, tokens: usize) -> Result<usize> {
        self.seq_buckets
            .iter()
            .copied()
            .find(|&s| s >= tokens)
            .ok_or(anyhow!("{tokens} tokens exceed the largest bucket {:?}", self.seq_buckets))
    }

    /// Load raw f32-LE initial parameters for layer (0 = embed).
    pub fn load_init(&self, layer: usize) -> Result<Vec<f32>> {
        let (path, want) = if layer == 0 {
            (&self.init_embed, self.embed_params)
        } else {
            (&self.init_blocks[layer - 1], self.block_params)
        };
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != want * 4 {
            return Err(anyhow!("{path:?}: expected {} bytes, got {}", want * 4, bytes.len()));
        }
        Ok(crate::comm::fold::f32_from_le_bytes(&bytes))
    }

    /// Flat lengths of every layer (0 = embed, 1..=L = blocks).
    pub fn layer_lens(&self) -> Vec<usize> {
        let mut v = vec![self.embed_params];
        v.extend(std::iter::repeat(self.block_params).take(self.n_layers));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
    }

    fn have_artifacts() -> bool {
        tiny_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_tiny_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.d_model, 64);
        assert_eq!(m.n_layers, 2);
        assert_eq!(m.layer_lens().len(), 3);
        assert!(m.artifacts.contains_key("block_fwd_s32"));
        assert_eq!(m.bucket_for(30).unwrap(), 32);
        assert_eq!(m.bucket_for(33).unwrap(), 64);
        assert!(m.bucket_for(1000).is_err());
    }

    #[test]
    fn init_sizes_match() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        assert_eq!(m.load_init(0).unwrap().len(), m.embed_params);
        assert_eq!(m.load_init(1).unwrap().len(), m.block_params);
    }

    #[test]
    fn load_init_bulk_decode_round_trips() {
        // load_init decodes via the bulk byte cast; pin it against the
        // scalar per-element decode on a synthetic init file.
        let vals: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.37).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let dir = std::env::temp_dir().join("ps_manifest_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("embed.f32");
        std::fs::write(&path, &bytes).unwrap();

        let scalar: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let bulk = crate::comm::fold::f32_from_le_bytes(&std::fs::read(&path).unwrap());
        assert_eq!(bulk.len(), vals.len());
        for (a, b) in bulk.iter().zip(&scalar) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn io_specs_parse() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        let bf = m.artifact("block_fwd_s32").unwrap();
        assert_eq!(bf.inputs.len(), 3);
        assert_eq!(bf.inputs[2].dtype, DType::I32);
        assert_eq!(bf.outputs[0].shape, vec![32, 64]);
    }
}
