//! The compute service: a dedicated thread owning the PJRT CPU client
//! and one compiled executable per artifact; device threads submit
//! execute requests over an mpsc channel and block on the reply.
//!
//! Rationale: the `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are
//! `Rc`-backed and must stay on one thread. Funneling execution through
//! a single in-order service also mirrors how a real accelerator
//! serializes kernel launches on a stream; on this single-core testbed
//! it costs nothing.
//!
//! Input/output payloads cross the channel as plain `Vec<f32>`/`Vec<i32>`
//! (Literals are also thread-bound); the service builds literals, runs
//! the executable, and decomposes the tuple reply.

use super::manifest::{ArtifactSpec, DType, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;

/// One tensor argument.
#[derive(Clone, Debug)]
pub enum Input {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Input {
    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
        }
    }
}

struct Request {
    artifact: String,
    inputs: Vec<Input>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

enum Msg {
    Call(Request),
    Shutdown,
}

/// Handle to the compute service; cheap to clone, one per device thread.
#[derive(Clone)]
pub struct ComputeService {
    tx: mpsc::Sender<Msg>,
}

/// Keeps the service thread alive; dropping it shuts the service down.
pub struct ServiceHost {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHost {
    pub fn handle(&self) -> ComputeService {
        ComputeService { tx: self.tx.clone() }
    }
}

impl Drop for ServiceHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ComputeService {
    /// Start the service for a manifest: loads + compiles EVERY artifact
    /// once (AOT), then serves calls until the host is dropped.
    pub fn start(manifest: &Manifest) -> Result<ServiceHost> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let man = manifest.clone();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(man, rx, ready_tx))
            .context("spawning pjrt service")?;
        ready_rx.recv().map_err(|_| anyhow!("service thread died during startup"))??;
        Ok(ServiceHost { tx, join: Some(join) })
    }

    /// Execute `artifact` with `inputs`; returns all outputs as f32 vecs.
    pub fn call(&self, artifact: &str, inputs: Vec<Input>) -> Result<Vec<Vec<f32>>> {
        let (reply, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Call(Request { artifact: artifact.to_string(), inputs, reply }))
            .map_err(|_| anyhow!("compute service is down"))?;
        rrx.recv().map_err(|_| anyhow!("compute service dropped the request"))?
    }
}

fn service_main(man: Manifest, rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let setup = || -> Result<(xla::PjRtClient, BTreeMap<String, (ArtifactSpec, xla::PjRtLoadedExecutable)>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = BTreeMap::new();
        for (key, spec) in &man.artifacts {
            let path = man.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
            exes.insert(key.clone(), (spec.clone(), exe));
        }
        Ok((client, exes))
    };
    let (client, exes) = match setup() {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => return,
            Msg::Call(req) => {
                let result = run_one(&client, &exes, &req);
                let _ = req.reply.send(result);
            }
        }
    }
}

fn run_one(
    client: &xla::PjRtClient,
    exes: &BTreeMap<String, (ArtifactSpec, xla::PjRtLoadedExecutable)>,
    req: &Request,
) -> Result<Vec<Vec<f32>>> {
    let (spec, exe) = exes.get(&req.artifact).ok_or(anyhow!("unknown artifact `{}`", req.artifact))?;
    if req.inputs.len() != spec.inputs.len() {
        return Err(anyhow!("{}: expected {} inputs, got {}", req.artifact, spec.inputs.len(), req.inputs.len()));
    }
    // §Perf + leak avoidance: host data goes straight to device buffers
    // (`buffer_from_host_buffer`) and runs through `execute_b`. The
    // published crate's literal-based `execute` shim `release()`s every
    // input device buffer without freeing it — a ~50 MB/microbatch leak
    // at engine scale (see EXPERIMENTS.md §Perf) — and pays an extra
    // host copy through the intermediate Literal.
    let mut input_bufs = Vec::with_capacity(req.inputs.len());
    for (ts, input) in spec.inputs.iter().zip(&req.inputs) {
        if ts.elems() != input.len() {
            return Err(anyhow!("{}: input `{}` expects {} elems, got {}", req.artifact, ts.name, ts.elems(), input.len()));
        }
        let buf = match (input, &ts.dtype) {
            (Input::F32(v), DType::F32) => client.buffer_from_host_buffer::<f32>(v, &ts.shape, None),
            (Input::I32(v), DType::I32) => client.buffer_from_host_buffer::<i32>(v, &ts.shape, None),
            _ => return Err(anyhow!("{}: input `{}` dtype mismatch", req.artifact, ts.name)),
        }
        .map_err(|e| anyhow!("{}: uploading `{}`: {e:?}", req.artifact, ts.name))?;
        input_bufs.push(buf);
    }
    let bufs = exe.execute_b::<xla::PjRtBuffer>(&input_bufs).map_err(|e| anyhow!("executing {}: {e:?}", req.artifact))?;
    let tuple = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
    let parts = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
    if parts.len() != spec.outputs.len() {
        return Err(anyhow!("{}: expected {} outputs, got {}", req.artifact, spec.outputs.len(), parts.len()));
    }
    parts
        .into_iter()
        .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn tiny() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts`");
            None
        }
    }

    #[test]
    fn embed_fwd_executes_with_correct_shapes() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        let s = man.seq_buckets[0];
        let emb = man.load_init(0).unwrap();
        let tokens = vec![1i32; s];
        let out = svc.call(&format!("embed_fwd_s{s}"), vec![Input::F32(emb), Input::I32(tokens)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), s * man.d_model);
        assert!(out[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn accum_chunk_matches_cpu() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        let c = man.chunk;
        let acc = vec![1.0f32; c];
        let g: Vec<f32> = (0..c).map(|i| (i % 7) as f32).collect();
        let out = svc
            .call("accum_chunk", vec![Input::F32(acc.clone()), Input::F32(g.clone()), Input::F32(vec![0.5])])
            .unwrap();
        for i in 0..c {
            assert!((out[0][i] - (acc[i] + 0.5 * g[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn input_arity_and_shape_errors() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        assert!(svc.call("accum_chunk", vec![]).is_err());
        assert!(svc.call("nope", vec![]).is_err());
        let bad = svc.call("accum_chunk", vec![Input::F32(vec![0.0; 3]), Input::F32(vec![0.0; 3]), Input::F32(vec![0.5])]);
        assert!(bad.is_err());
    }
}
