//! The compute service: a dedicated thread owning the PJRT CPU client
//! and one compiled executable per artifact; device threads submit
//! execute requests over an mpsc channel and block on the reply.
//!
//! Rationale: the `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are
//! `Rc`-backed and must stay on one thread. Funneling execution through
//! a single in-order service also mirrors how a real accelerator
//! serializes kernel launches on a stream; on this single-core testbed
//! it costs nothing.
//!
//! ## Zero-copy inputs
//!
//! Payloads cross the channel either as owned `Vec`s ([`Input::F32`] /
//! [`Input::I32`] — the caller is done with the data) or as shared
//! [`SharedSlice`]s ([`Input::F32Shared`] / [`Input::I32Shared`]) —
//! `Arc`-backed windows that let the engine hand the SAME gathered
//! parameter block or activation buffer to many consecutive calls with
//! no host-side copy. The service reads the slice directly into the
//! device buffer (`buffer_from_host_buffer`) and drops its clone of the
//! `Arc` BEFORE replying, so when `call` returns the caller observes a
//! refcount of 1 again and can recycle the buffer in place.

use super::manifest::{ArtifactSpec, DType, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

// Offline build: the PJRT bindings are provided by the in-tree stub
// (see its module docs for how to swap the real `xla` crate back in).
use crate::runtime::xla_stub as xla;

/// A shared window over an `Arc`-backed tensor: `data[start..start+len]`.
/// Cloning is refcount-only; the payload is never copied.
#[derive(Clone, Debug)]
pub struct SharedSlice<T> {
    pub data: Arc<[T]>,
    pub start: usize,
    pub len: usize,
}

impl<T> SharedSlice<T> {
    pub fn as_slice(&self) -> &[T] {
        &self.data[self.start..self.start + self.len]
    }
}

/// One tensor argument.
#[derive(Clone, Debug)]
pub enum Input {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Borrowed view of a shared f32 tensor (gathered params, activations).
    F32Shared(SharedSlice<f32>),
    /// Borrowed view of a shared i32 tensor (tokens, segment ids).
    I32Shared(SharedSlice<i32>),
}

impl Input {
    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
            Input::F32Shared(s) => s.len,
            Input::I32Shared(s) => s.len,
        }
    }

    /// Share the first `len` elements of an `Arc` tensor (zero-copy).
    pub fn shared_f32(data: &Arc<[f32]>, len: usize) -> Input {
        debug_assert!(len <= data.len());
        Input::F32Shared(SharedSlice { data: Arc::clone(data), start: 0, len })
    }

    /// Share a whole `Arc` f32 tensor (zero-copy).
    pub fn shared_f32_all(data: &Arc<[f32]>) -> Input {
        Input::shared_f32(data, data.len())
    }

    /// Share a whole `Arc` i32 tensor (zero-copy).
    pub fn shared_i32_all(data: &Arc<[i32]>) -> Input {
        Input::I32Shared(SharedSlice { data: Arc::clone(data), start: 0, len: data.len() })
    }
}

struct Request {
    artifact: String,
    inputs: Vec<Input>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

enum Msg {
    Call(Request),
    Shutdown,
}

/// Handle to the compute service; cheap to clone, one per device thread.
#[derive(Clone)]
pub struct ComputeService {
    tx: mpsc::Sender<Msg>,
}

/// Keeps the service thread alive; dropping it shuts the service down.
pub struct ServiceHost {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHost {
    pub fn handle(&self) -> ComputeService {
        ComputeService { tx: self.tx.clone() }
    }
}

impl Drop for ServiceHost {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ComputeService {
    /// Start the service for a manifest: loads + compiles EVERY artifact
    /// once (AOT), then serves calls until the host is dropped.
    pub fn start(manifest: &Manifest) -> Result<ServiceHost> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let man = manifest.clone();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(man, rx, ready_tx))
            .context("spawning pjrt service")?;
        ready_rx.recv().map_err(|_| anyhow!("service thread died during startup"))??;
        Ok(ServiceHost { tx, join: Some(join) })
    }

    /// Execute `artifact` with `inputs`; returns all outputs as f32 vecs.
    ///
    /// Synchronous: by the time this returns, the service has dropped
    /// every `Arc` clone inside `inputs` (the drop happens-before the
    /// reply send), so shared buffers are uniquely owned again.
    pub fn call(&self, artifact: &str, inputs: Vec<Input>) -> Result<Vec<Vec<f32>>> {
        let (reply, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Call(Request { artifact: artifact.to_string(), inputs, reply }))
            .map_err(|_| anyhow!("compute service is down"))?;
        rrx.recv().map_err(|_| anyhow!("compute service dropped the request"))?
    }
}

fn service_main(man: Manifest, rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let setup = || -> Result<(xla::PjRtClient, BTreeMap<String, (ArtifactSpec, xla::PjRtLoadedExecutable)>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = BTreeMap::new();
        for (key, spec) in &man.artifacts {
            let path = man.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
            exes.insert(key.clone(), (spec.clone(), exe));
        }
        Ok((client, exes))
    };
    let (client, exes) = match setup() {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => return,
            Msg::Call(req) => {
                let Request { artifact, inputs, reply } = req;
                let result = run_one(&client, &exes, &artifact, &inputs);
                // Release shared-input refcounts BEFORE the reply: the
                // caller recycles its Arc buffers as soon as `call`
                // returns, relying on observing strong_count == 1.
                drop(inputs);
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    client: &xla::PjRtClient,
    exes: &BTreeMap<String, (ArtifactSpec, xla::PjRtLoadedExecutable)>,
    artifact: &str,
    inputs: &[Input],
) -> Result<Vec<Vec<f32>>> {
    let (spec, exe) = exes.get(artifact).ok_or(anyhow!("unknown artifact `{artifact}`"))?;
    if inputs.len() != spec.inputs.len() {
        return Err(anyhow!("{}: expected {} inputs, got {}", artifact, spec.inputs.len(), inputs.len()));
    }
    // §Perf + leak avoidance: host data goes straight to device buffers
    // (`buffer_from_host_buffer`) and runs through `execute_b`. The
    // published crate's literal-based `execute` shim `release()`s every
    // input device buffer without freeing it — a ~50 MB/microbatch leak
    // at engine scale (see EXPERIMENTS.md §Perf) — and pays an extra
    // host copy through the intermediate Literal. Shared inputs upload
    // directly from the engine's Arc windows: the only copy on the whole
    // input path is the unavoidable host→device one.
    let mut input_bufs = Vec::with_capacity(inputs.len());
    for (ts, input) in spec.inputs.iter().zip(inputs) {
        if ts.elems() != input.len() {
            return Err(anyhow!("{}: input `{}` expects {} elems, got {}", artifact, ts.name, ts.elems(), input.len()));
        }
        let buf = match (input, &ts.dtype) {
            (Input::F32(v), DType::F32) => client.buffer_from_host_buffer::<f32>(v, &ts.shape, None),
            (Input::I32(v), DType::I32) => client.buffer_from_host_buffer::<i32>(v, &ts.shape, None),
            (Input::F32Shared(s), DType::F32) => {
                client.buffer_from_host_buffer::<f32>(s.as_slice(), &ts.shape, None)
            }
            (Input::I32Shared(s), DType::I32) => {
                client.buffer_from_host_buffer::<i32>(s.as_slice(), &ts.shape, None)
            }
            _ => return Err(anyhow!("{}: input `{}` dtype mismatch", artifact, ts.name)),
        }
        .map_err(|e| anyhow!("{}: uploading `{}`: {e:?}", artifact, ts.name))?;
        input_bufs.push(buf);
    }
    let bufs = exe.execute_b::<xla::PjRtBuffer>(&input_bufs).map_err(|e| anyhow!("executing {artifact}: {e:?}"))?;
    let tuple = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
    let parts = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
    if parts.len() != spec.outputs.len() {
        return Err(anyhow!("{}: expected {} outputs, got {}", artifact, spec.outputs.len(), parts.len()));
    }
    parts
        .into_iter()
        .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn tiny() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts`");
            None
        }
    }

    #[test]
    fn shared_slice_windows_without_copying() {
        let data: Arc<[f32]> = vec![0.0, 1.0, 2.0, 3.0, 4.0].into();
        let input = Input::shared_f32(&data, 3);
        assert_eq!(input.len(), 3);
        match &input {
            Input::F32Shared(s) => {
                assert_eq!(s.as_slice(), &[0.0, 1.0, 2.0]);
                // zero-copy: the view aliases the same allocation
                assert!(std::ptr::eq(s.as_slice().as_ptr(), data.as_ptr()));
            }
            _ => panic!("expected shared variant"),
        }
        assert_eq!(Arc::strong_count(&data), 2);
        drop(input);
        assert_eq!(Arc::strong_count(&data), 1);
    }

    #[test]
    fn shared_i32_covers_whole_tensor() {
        let data: Arc<[i32]> = vec![7, 8, 9].into();
        let input = Input::shared_i32_all(&data);
        assert_eq!(input.len(), 3);
        match input {
            Input::I32Shared(s) => assert_eq!(s.as_slice(), &[7, 8, 9]),
            _ => panic!("expected shared variant"),
        }
    }

    #[test]
    fn embed_fwd_executes_with_correct_shapes() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        let s = man.seq_buckets[0];
        let emb = man.load_init(0).unwrap();
        let tokens = vec![1i32; s];
        let out = svc.call(&format!("embed_fwd_s{s}"), vec![Input::F32(emb), Input::I32(tokens)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), s * man.d_model);
        assert!(out[0].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn accum_chunk_matches_cpu() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        let c = man.chunk;
        let acc = vec![1.0f32; c];
        let g: Vec<f32> = (0..c).map(|i| (i % 7) as f32).collect();
        let out = svc
            .call("accum_chunk", vec![Input::F32(acc.clone()), Input::F32(g.clone()), Input::F32(vec![0.5])])
            .unwrap();
        for i in 0..c {
            assert!((out[0][i] - (acc[i] + 0.5 * g[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn input_arity_and_shape_errors() {
        let Some(man) = tiny() else { return };
        let host = ComputeService::start(&man).unwrap();
        let svc = host.handle();
        assert!(svc.call("accum_chunk", vec![]).is_err());
        assert!(svc.call("nope", vec![]).is_err());
        let bad = svc.call("accum_chunk", vec![Input::F32(vec![0.0; 3]), Input::F32(vec![0.0; 3]), Input::F32(vec![0.5])]);
        assert!(bad.is_err());
    }
}
