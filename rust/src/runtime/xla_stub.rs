//! Build-time stub for the `xla` PJRT bindings.
//!
//! The container this repo builds in has no crates.io access and no
//! PJRT runtime, so [`service`](super::service) aliases this module as
//! `xla`. It mirrors exactly the API surface the service uses — client
//! construction, HLO loading/compilation, host→device buffer upload,
//! `execute_b`, and literal decomposition — with identical shapes and
//! error plumbing, but every entry point fails at [`PjRtClient::cpu`].
//!
//! That failure is reachable only when PJRT artifacts exist on disk
//! (`ComputeService::start` is the sole caller, and every test /
//! example self-skips when `artifacts/<preset>/manifest.json` is
//! absent), so an artifact-less build + test run is green end to end.
//!
//! To run REAL training on a networked machine: add the `xla` crate to
//! `Cargo.toml`, delete the `use crate::runtime::xla_stub as xla;`
//! alias in `service.rs`, and rebuild — no other source changes needed.

use std::borrow::Borrow;
use std::fmt;

const UNAVAILABLE: &str = "PJRT backend unavailable: built against the in-tree xla stub \
     (offline container). See rust/src/runtime/xla_stub.rs to enable the real backend.";

/// Error type matching the real crate's `{:?}`-formatted usage.
#[derive(Clone)]
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

/// Element types accepted by `buffer_from_host_buffer` / `to_vec`.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct Literal(());
pub struct HloModuleProto(());
pub struct XlaComputation(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let e = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(format!("{e:?}").contains("stub"));
    }
}
