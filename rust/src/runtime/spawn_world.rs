//! WireComm multi-process harness: workers as genuine OS processes.
//!
//! The trainer's `--transport uds` moves every mailbox byte through
//! kernel sockets, but its device *threads* still share the
//! `ParamStore` (one-sided gathers are shared-memory by design). This
//! harness closes the remaining honesty gap: [`spawn_world`] launches
//! `world` copies of the current executable (`odc wire-worker`), each
//! an isolated OS process owning one [`SocketTransport::endpoint`]
//! rank, and drives a deterministic scatter-accumulate whose reduction
//! is **bit-checked** on every rank — nothing can leak through shared
//! memory because there is none.
//!
//! The traffic is shaped to exercise both wire paths deliberately:
//! each rank scatters its per-destination vector as one oversized
//! slice (> `CHUNK_BYTES`, forcing the chunked multi-segment path) and
//! eight small slices (< `FUSION_BUDGET`, coalesced by fusion).
//! Endpoint mode delivers per-link FIFO with arbitrary cross-link
//! interleaving, so the protocol is order-tolerant: slices are keyed
//! by `(src, idx)` and folded in that order once complete — the same
//! id-keyed fold discipline the ODC daemons use.
//!
//! `odc wire-smoke --world 4` is the CI entry point; the job timeout
//! doubles as the hang detector (a wedged rendezvous, a lost wakeup,
//! or a framing bug all present as "workers never exit").

use crate::comm::fold::{f32_from_le_bytes, f32_to_le_bytes};
use crate::comm::socket::SocketTransport;
use crate::comm::transport::{frame, Transport, WireCodec, WireMsg};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Floats in slice 0 — 512 KiB on the wire, above `CHUNK_BYTES`.
const BIG: usize = 128 * 1024;
/// Floats per small slice — 16 KiB on the wire, below `FUSION_BUDGET`.
const SMALL: usize = 4 * 1024;
const SMALL_SLICES: usize = 8;
const SLICES: usize = 1 + SMALL_SLICES;
const VEC_LEN: usize = BIG + SMALL_SLICES * SMALL;

#[derive(Clone, Debug)]
enum SmokeMsg {
    /// Slice `idx` of `SLICES` of the sender's vector for this rank,
    /// as LE f32 bytes. Keyed by `(env.src, idx)` at the receiver.
    Slice { idx: u32, data: Vec<u8> },
    /// The sender has scattered its whole vector to this rank.
    Done,
    /// The sender's bit-checksum of its reduced vector (rank 0 audits).
    Sum { bits: u64 },
    /// Rank 0 verified everyone — workers may exit.
    Release,
}

impl WireMsg for SmokeMsg {
    fn is_barrier(&self) -> bool {
        !matches!(self, SmokeMsg::Slice { .. })
    }
    fn payload_bytes(&self) -> usize {
        match self {
            SmokeMsg::Slice { data, .. } => data.len(),
            _ => 0,
        }
    }
}

impl WireCodec for SmokeMsg {
    fn encode(&self, out: &mut Vec<u8>) -> bool {
        match self {
            SmokeMsg::Slice { idx, data } => {
                out.push(0);
                frame::put_u32(out, *idx);
                frame::put_bytes(out, data);
            }
            SmokeMsg::Done => out.push(1),
            SmokeMsg::Sum { bits } => {
                out.push(2);
                frame::put_u64(out, *bits);
            }
            SmokeMsg::Release => out.push(3),
        }
        true
    }
    fn decode(bytes: &[u8]) -> Option<SmokeMsg> {
        let mut r = frame::Reader::new(bytes.get(1..)?);
        let msg = match bytes.first()? {
            0 => SmokeMsg::Slice { idx: r.u32()?, data: r.bytes()? },
            1 => SmokeMsg::Done,
            2 => SmokeMsg::Sum { bits: r.u64()? },
            3 => SmokeMsg::Release,
            _ => return None,
        };
        if !r.done() {
            return None;
        }
        Some(msg)
    }
}

/// Element `i` of the vector rank `src` scatters to rank `dst` — a
/// pure function every rank can recompute, with values exact in f32
/// (k/256, k < 2^17) so the reduction has a unique bit pattern.
fn value(src: usize, dst: usize, i: usize) -> f32 {
    (((src * 1_000_003 + dst * 7_919 + i) % 65_521) as f32) * (1.0 / 256.0) - 128.0
}

fn bounds(idx: usize) -> (usize, usize) {
    if idx == 0 {
        (0, BIG)
    } else {
        (BIG + (idx - 1) * SMALL, BIG + idx * SMALL)
    }
}

/// The reduction rank `dst` must arrive at: sum over sources in src
/// order (the id-keyed fold order), checksummed by f32 bit pattern.
fn expected_bits(world: usize, dst: usize) -> u64 {
    let mut acc = vec![0f32; VEC_LEN];
    for src in 0..world {
        for (i, a) in acc.iter_mut().enumerate() {
            *a += value(src, dst, i);
        }
    }
    acc.iter().fold(0u64, |h, f| h.wrapping_add(f.to_bits() as u64))
}

fn run_worker(rank: usize, world: usize, dir: &str) -> Result<u64, String> {
    if dir.is_empty() {
        return Err("wire-worker needs --dir (spawned by `odc wire-smoke`)".into());
    }
    let t = SocketTransport::<SmokeMsg>::endpoint(rank, world, dir)
        .map_err(|e| format!("endpoint bind failed: {e}"))?;

    // scatter: one chunked big slice + fused small slices per dst
    for dst in 0..world {
        let vec: Vec<f32> = (0..VEC_LEN).map(|i| value(rank, dst, i)).collect();
        for idx in 0..SLICES {
            let (lo, hi) = bounds(idx);
            let mut data = Vec::with_capacity((hi - lo) * 4);
            f32_to_le_bytes(&mut data, &vec[lo..hi]);
            t.send(rank, dst, 0, SmokeMsg::Slice { idx: idx as u32, data })
                .map_err(|e| format!("slice push to {dst} failed: {e:?}"))?;
        }
        t.send(rank, dst, 0, SmokeMsg::Done).map_err(|e| format!("done to {dst} failed: {e:?}"))?;
    }

    // gather: order-tolerant collect keyed by (src, idx)
    let mut slices: BTreeMap<(usize, u32), Vec<u8>> = BTreeMap::new();
    let mut dones = 0usize;
    let mut sums: BTreeMap<usize, u64> = BTreeMap::new();
    let mut payload_bytes = 0u64;
    let mut released = false;
    let want_sums = if rank == 0 { world - 1 } else { 0 };
    while slices.len() < world * SLICES || dones < world || sums.len() < want_sums {
        let env = t.recv(rank).ok_or("transport closed mid-protocol")?;
        match env.msg {
            SmokeMsg::Slice { idx, data } => {
                payload_bytes += data.len() as u64;
                if slices.insert((env.src, idx), data).is_some() {
                    return Err(format!("duplicate slice ({}, {idx})", env.src));
                }
            }
            SmokeMsg::Done => dones += 1,
            SmokeMsg::Sum { bits } => {
                sums.insert(env.src, bits);
            }
            SmokeMsg::Release => released = true,
        }
    }

    // fold in (src, idx) order — deterministic under any arrival order
    let mut acc = vec![0f32; VEC_LEN];
    for ((src, idx), data) in &slices {
        let (lo, _) = bounds(*idx as usize);
        let piece = f32_from_le_bytes(data);
        debug_assert!(*src < world);
        for (i, p) in piece.iter().enumerate() {
            acc[lo + i] += p;
        }
    }
    let bits = acc.iter().fold(0u64, |h, f| h.wrapping_add(f.to_bits() as u64));
    if bits != expected_bits(world, rank) {
        return Err(format!("rank {rank} reduction mismatch: bits {bits:#x}"));
    }

    if rank == 0 {
        for (src, got) in &sums {
            let want = expected_bits(world, *src);
            if *got != want {
                return Err(format!("rank {src} reported bits {got:#x}, expected {want:#x}"));
            }
        }
        for dst in 1..world {
            t.send(0, dst, 0, SmokeMsg::Release)
                .map_err(|e| format!("release to {dst} failed: {e:?}"))?;
        }
    } else {
        t.send(rank, 0, 0, SmokeMsg::Sum { bits })
            .map_err(|e| format!("sum to rank 0 failed: {e:?}"))?;
        while !released {
            released = matches!(
                t.recv(rank).ok_or("transport closed awaiting release")?.msg,
                SmokeMsg::Release
            );
        }
    }
    Ok(payload_bytes)
}

/// Entry point of the hidden `odc wire-worker` subcommand.
pub fn worker_main(rank: usize, world: usize, dir: &str) -> i32 {
    match run_worker(rank, world, dir) {
        Ok(bytes) => {
            println!("wire-worker rank {rank}/{world} OK ({bytes} payload bytes reduced)");
            0
        }
        Err(e) => {
            eprintln!("wire-worker rank {rank}/{world} FAILED: {e}");
            1
        }
    }
}

/// Spawn `world` copies of `exe` as `wire-worker` OS processes sharing
/// a fresh rendezvous dir; fail if any exits nonzero or outlives the
/// deadline (killing the stragglers — the hang detector).
pub fn spawn_world(
    exe: &std::path::Path,
    world: usize,
    timeout: Duration,
) -> Result<(), String> {
    let dir = std::env::temp_dir().join(format!("odc-smoke-{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    let mut children = Vec::new();
    for rank in 0..world {
        let child = std::process::Command::new(exe)
            .arg("wire-worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--world")
            .arg(world.to_string())
            .arg("--dir")
            .arg(&dir)
            .spawn()
            .map_err(|e| format!("spawn rank {rank}: {e}"))?;
        children.push(child);
    }
    let deadline = Instant::now() + timeout;
    let mut statuses: Vec<Option<bool>> = vec![None; world];
    while statuses.iter().any(|s| s.is_none()) {
        for (rank, child) in children.iter_mut().enumerate() {
            if statuses[rank].is_none() {
                if let Ok(Some(st)) = child.try_wait() {
                    statuses[rank] = Some(st.success());
                }
            }
        }
        if statuses.iter().any(|s| s.is_none()) {
            if Instant::now() >= deadline {
                for child in children.iter_mut() {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                let _ = std::fs::remove_dir_all(&dir);
                return Err(format!("workers still running after {timeout:?} — hang detected"));
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    match statuses.iter().position(|s| *s == Some(false)) {
        Some(rank) => Err(format!("worker rank {rank} exited nonzero")),
        None => Ok(()),
    }
}

/// Entry point of the `odc wire-smoke` subcommand.
pub fn smoke_main(world: usize, timeout_s: u64) -> i32 {
    if world == 0 {
        eprintln!("wire-smoke needs --world >= 1");
        return 2;
    }
    let exe = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("wire-smoke: current_exe: {e}");
            return 1;
        }
    };
    match spawn_world(&exe, world, Duration::from_secs(timeout_s)) {
        Ok(()) => {
            println!(
                "wire-smoke OK: {world} OS-process workers, bit-checked reduction of {} floats/rank",
                VEC_LEN
            );
            0
        }
        Err(e) => {
            eprintln!("wire-smoke FAILED: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_codec_round_trips() {
        for msg in [
            SmokeMsg::Slice { idx: 3, data: vec![1, 2, 3, 4] },
            SmokeMsg::Done,
            SmokeMsg::Sum { bits: 0xDEAD_BEEF },
            SmokeMsg::Release,
        ] {
            let mut out = Vec::new();
            assert!(msg.encode(&mut out));
            let back = SmokeMsg::decode(&out).expect("decodes");
            assert_eq!(format!("{msg:?}"), format!("{back:?}"));
        }
    }

    #[test]
    fn slice_geometry_covers_the_vector_exactly() {
        let mut covered = 0usize;
        for idx in 0..SLICES {
            let (lo, hi) = bounds(idx);
            assert_eq!(lo, covered, "slices must tile contiguously");
            covered = hi;
        }
        assert_eq!(covered, VEC_LEN);
        // slice 0 exceeds the chunk threshold, small slices fuse
        assert!(BIG * 4 > crate::comm::socket::CHUNK_BYTES);
        assert!(SMALL * 4 < crate::comm::socket::FUSION_BUDGET);
    }

    /// The whole protocol, in-process: endpoint transports in threads
    /// (the OS-process path is `tests/` + CI's wire-smoke job — unit
    /// tests must not respawn the test binary).
    #[test]
    fn worker_protocol_bit_checks_across_endpoint_ranks() {
        let world = 3;
        let dir = std::env::temp_dir().join(format!("odc-smoke-unit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = dir.to_str().unwrap().to_string();
                std::thread::spawn(move || run_worker(rank, world, &dir))
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let res = h.join().expect("worker thread");
            assert!(res.is_ok(), "rank {rank}: {res:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
