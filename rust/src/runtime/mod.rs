//! PJRT runtime: loads the AOT artifacts (HLO text) and executes them
//! from the Rust hot path. Python is never involved at runtime.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (thread-bound), while
//! the FSDP engine runs one OS thread per device. [`service`] therefore
//! hosts the PJRT client + compiled executables on a dedicated *compute
//! service* thread — the analogue of a GPU's single in-order stream —
//! and device threads submit execute requests over a channel.
//!
//! [`spawn_world`] is the WireComm multi-process harness: workers as
//! separate OS processes over socket-transport endpoints, driven by
//! the hidden `odc wire-worker` / `odc wire-smoke` subcommands.

pub mod manifest;
pub mod service;
pub mod spawn_world;
pub mod xla_stub;

pub use manifest::Manifest;
pub use service::{ComputeService, Input, SharedSlice};
