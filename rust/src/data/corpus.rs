//! Synthetic tiny-corpus generator for the real training engine.
//!
//! Sequences are drawn from a seeded random *bigram language*: a fixed
//! stochastic transition table over the vocabulary. This gives the
//! convergence experiment (Fig 14) a learnable structure — a transformer
//! quickly drops below the uniform-entropy floor — while remaining fully
//! synthetic and reproducible.

use crate::util::rng::Rng;

/// A sample: token ids plus next-token targets (`targets[i] = tokens[i+1]`
/// semantics, with the final target wrapping to a fresh draw).
#[derive(Clone, Debug)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Sample {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Seeded bigram language over `vocab` tokens.
pub struct BigramLm {
    vocab: usize,
    /// For each token, `branch` candidate successors (the learnable rule).
    succ: Vec<Vec<i32>>,
}

impl BigramLm {
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xB16_9A4);
        let succ = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as i32).collect())
            .collect();
        BigramLm { vocab, succ }
    }

    /// Generate one sequence of `len` tokens (plus aligned targets).
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Sample {
        assert!(len >= 1);
        let mut tokens = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab as u64) as i32;
        for _ in 0..=len {
            tokens.push(cur);
            let succ = &self.succ[cur as usize];
            cur = succ[rng.below(succ.len() as u64) as usize];
        }
        let targets = tokens[1..].to_vec();
        tokens.truncate(len);
        Sample { tokens, targets }
    }

    /// Entropy floor of this language in nats (uniform over `branch`).
    pub fn entropy_floor(&self) -> f64 {
        (self.succ[0].len() as f64).ln()
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

/// Draw a dataset of samples with the given lengths.
pub fn make_dataset(lm: &BigramLm, lens: &[usize], rng: &mut Rng) -> Vec<Sample> {
    lens.iter().map(|&l| lm.sample(l.max(1), rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes() {
        let lm = BigramLm::new(128, 4, 0);
        let mut rng = Rng::new(1);
        let s = lm.sample(37, &mut rng);
        assert_eq!(s.tokens.len(), 37);
        assert_eq!(s.targets.len(), 37);
        assert!(s.tokens.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let lm = BigramLm::new(64, 3, 2);
        let mut rng = Rng::new(3);
        let s = lm.sample(20, &mut rng);
        assert_eq!(&s.tokens[1..], &s.targets[..19]);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // every observed (tok -> next) pair must come from the succ table
        let lm = BigramLm::new(32, 2, 5);
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let s = lm.sample(64, &mut rng);
            for i in 0..s.tokens.len() {
                let nxt = s.targets[i];
                assert!(lm.succ[s.tokens[i] as usize].contains(&nxt));
            }
        }
    }

    #[test]
    fn deterministic_language() {
        let a = BigramLm::new(64, 4, 9);
        let b = BigramLm::new(64, 4, 9);
        assert_eq!(a.succ, b.succ);
    }
}
