//! Workload + data substrates: sequence-length distributions (Fig 7) and
//! a synthetic token corpus for the real training engine.

pub mod corpus;
pub mod distributions;

pub use distributions::{sample_lengths, DistSpec};
