//! Sequence-length distributions fitted to Figure 7 of the paper.
//!
//! The paper evaluates on LongAlign (64K-max long-context SFT),
//! SWE-Smith (agent trajectories) and AIME GRPO rollouts. The raw
//! corpora are not available here (DESIGN.md §2), but the load-balancing
//! behaviour depends only on the *length distribution*, so each dataset
//! is modeled as a clipped log-normal whose parameters match the paper's
//! qualitative description: heavily long-tailed for the SFT sets, a
//! notably "less long-tailed" distribution for AIME (§5.2-b).

use crate::config::Dataset;
use crate::util::rng::Rng;

/// Clipped log-normal specification for one dataset.
#[derive(Clone, Copy, Debug)]
pub struct DistSpec {
    /// Median length (exp(mu) of the underlying normal).
    pub median: f64,
    /// Sigma of the underlying normal — the tail weight.
    pub sigma: f64,
    pub min_len: usize,
    pub max_len: usize,
}

impl DistSpec {
    pub fn for_dataset(d: Dataset) -> DistSpec {
        match d {
            // Parameters calibrated so the simulated Collective LB-Micro
            // bubble rates track Table 6 / Table 4 (see EXPERIMENTS.md).
            //
            // LongAlign: context-extension SFT, documents up to 64K,
            // strong long tail (bubble rates of 66%+ at minibs=1, Tab 6).
            Dataset::LongAlign => DistSpec { median: 10_000.0, sigma: 0.70, min_len: 32, max_len: 65_536 },
            // SWE-Smith: agent trajectories; long but less extreme tail
            // (Tab 6 shows lower bubble rates than LongAlign).
            Dataset::SweSmith => DistSpec { median: 6_500.0, sigma: 0.48, min_len: 64, max_len: 32_768 },
            // AIME GRPO rollouts: bounded generation budget, mildest tail
            // ("a less long-tailed sequence length distribution", §5.2).
            Dataset::Aime => DistSpec { median: 6_500.0, sigma: 0.25, min_len: 256, max_len: 16_384 },
        }
    }

    /// Rescale so the clip maximum becomes `max_len`, preserving the
    /// distribution *shape* — the paper's parametric-study "Max length"
    /// knob ("adjust each sample by uniformly truncating or repeating
    /// tokens at a fixed ratio", §5.3).
    pub fn rescaled_to(self, max_len: usize) -> DistSpec {
        let ratio = max_len as f64 / self.max_len as f64;
        DistSpec {
            median: self.median * ratio,
            sigma: self.sigma,
            min_len: ((self.min_len as f64 * ratio).round() as usize).max(1),
            max_len,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.lognormal(self.median.ln(), self.sigma);
        (x.round() as usize).clamp(self.min_len, self.max_len)
    }
}

/// Draw `n` sample lengths for a dataset (optionally rescaled).
pub fn sample_lengths(dataset: Dataset, max_len: Option<usize>, n: usize, rng: &mut Rng) -> Vec<usize> {
    let mut spec = DistSpec::for_dataset(dataset);
    if let Some(ml) = max_len {
        if ml != spec.max_len {
            spec = spec.rescaled_to(ml);
        }
    }
    (0..n).map(|_| spec.sample(rng)).collect()
}

/// Distribution summary used by the Fig 7 bench: (p50, p90, p99, max, mean).
pub fn summarize(lens: &[usize]) -> (f64, f64, f64, usize, f64) {
    let xs: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
    let p = |q| crate::util::stats::percentile(&xs, q);
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    (p(50.0), p(90.0), p(99.0), *lens.iter().max().unwrap(), mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draw(d: Dataset, n: usize) -> Vec<usize> {
        let mut rng = Rng::new(7);
        sample_lengths(d, None, n, &mut rng)
    }

    #[test]
    fn lengths_within_clip() {
        for d in [Dataset::LongAlign, Dataset::SweSmith, Dataset::Aime] {
            let spec = DistSpec::for_dataset(d);
            for l in draw(d, 5_000) {
                assert!(l >= spec.min_len && l <= spec.max_len, "{d}: {l}");
            }
        }
    }

    #[test]
    fn longalign_has_heavier_tail_than_aime() {
        let la = draw(Dataset::LongAlign, 20_000);
        let ai = draw(Dataset::Aime, 20_000);
        let (p50_la, _, p99_la, ..) = summarize(&la);
        let (p50_ai, _, p99_ai, ..) = summarize(&ai);
        // tail weight: p99/p50 markedly larger for LongAlign
        assert!(p99_la / p50_la > 2.0 * (p99_ai / p50_ai), "LongAlign tail should dominate");
    }

    #[test]
    fn rescale_shrinks_proportionally() {
        let spec = DistSpec::for_dataset(Dataset::LongAlign).rescaled_to(8192);
        assert_eq!(spec.max_len, 8192);
        assert!((spec.median - 1_250.0).abs() < 1.0); // 10000 / 8
        let mut rng = Rng::new(3);
        for _ in 0..2_000 {
            assert!(spec.sample(&mut rng) <= 8192);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(
            sample_lengths(Dataset::SweSmith, None, 100, &mut a),
            sample_lengths(Dataset::SweSmith, None, 100, &mut b)
        );
    }

    #[test]
    fn aime_mass_in_mid_range() {
        // RL rollouts cluster: most mass within [1k, 16k]
        let ai = draw(Dataset::Aime, 10_000);
        let frac = ai.iter().filter(|&&l| (1_000..=16_384).contains(&l)).count() as f64 / ai.len() as f64;
        assert!(frac > 0.95, "frac={frac}");
    }
}
