//! Minibatch timeline computation: the timing equations of §2.2 / §3.
//!
//! For one minibatch plan, computes the wall time and per-device busy
//! time under either communication scheme, including the per-layer
//! communication costs (Table 2 volumes over the topology bandwidths)
//! overlapped with compute (§6.1: communication volume is constant in s
//! while compute grows as O(s²), so long microbatches hide comm).
//!
//! Every equation here prices links exclusively through [`Topology`]
//! (`latency`, `intra_bw`, `inter_bw`), so measured WireComm
//! calibration (`crate::sim::run::WireCalib`, fed by
//! `cargo bench --bench wire_calib`) slots in by overriding the
//! topology fields before simulation — no formula changes, hand-set
//! guesses replaced by fitted alpha/beta (see `docs/transport.md`).

use crate::balance::cost::CostModel;
use crate::balance::dispatch::{lpt_order, micro_flops_split, pull_schedule_budgeted, queue_busy_split};
use crate::balance::packers::Plan;
use crate::balance::split::SplitMap;
use crate::comm::topology::Topology;
use crate::comm::transport::{FaultPlan, RetryPolicy};
use crate::comm::volume;
use crate::config::{CommScheme, PaperModel, Sharding, WireDtype};

/// Total parameter bytes of a model under a configured wire encoding —
/// the FastFold [`WireDtype`] makes the sim's historical "2 bytes per
/// element" pricing an explicit, configurable assumption.
pub fn model_bytes_dtype(model: PaperModel, dtype: WireDtype) -> f64 {
    dtype.bytes_per_elem() as f64 * model.params()
}

/// Per-layer parameter bytes for a model under a configured wire dtype.
pub fn layer_bytes_dtype(model: PaperModel, dtype: WireDtype) -> f64 {
    model_bytes_dtype(model, dtype) / model.layers() as f64
}

/// Per-layer parameter bytes for a model (bf16 — the historical sim
/// default, kept as the fixed-dtype entry point so every existing
/// caller and pin is untouched; see [`layer_bytes_dtype`]).
pub fn layer_bytes(model: PaperModel) -> f64 {
    layer_bytes_dtype(model, WireDtype::Bf16)
}

/// Communication seconds for ONE microbatch on one device: forward
/// gathers every layer once, backward gathers + reduce-scatters every
/// layer (Figure 4) => 3·L layer-ops.
pub fn micro_comm_time(model: PaperModel, scheme: CommScheme, sharding: Sharding, topo: &Topology) -> f64 {
    micro_comm_time_opt(model, scheme, sharding, topo, false)
}

/// `micro_comm_time` with the §6.2 hierarchical-gather optimization
/// toggle (meaningful for ODC full sharding across nodes only).
pub fn micro_comm_time_opt(
    model: PaperModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
) -> f64 {
    micro_comm_time_opt_dtype(model, scheme, sharding, topo, hierarchical, WireDtype::Bf16)
}

/// [`micro_comm_time_opt`] under a configured wire dtype: layer bytes
/// follow [`WireDtype::bytes_per_elem`] instead of the hardwired bf16
/// factor, so f32-wire runs price their doubled volume.
pub fn micro_comm_time_opt_dtype(
    model: PaperModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    dtype: WireDtype,
) -> f64 {
    let lb = layer_bytes_dtype(model, dtype);
    // CommScheme::Hybrid IS two-level sharding regardless of the
    // `sharding` field (the real backend has no full-shard mode).
    let per_op = match (sharding, scheme, hierarchical) {
        (Sharding::Hybrid, _, _) | (_, CommScheme::Hybrid, _) => volume::hybrid_layer_op_time(lb, topo),
        (Sharding::Full, CommScheme::Odc, true) => volume::hierarchical_layer_op_time(lb, topo),
        (Sharding::Full, odc_or_col, _) => volume::layer_op_time(odc_or_col == CommScheme::Odc, lb, topo),
    };
    3.0 * model.layers() as f64 * per_op
}

/// Hybrid sharding's per-minibatch epilogue: optimizer states live
/// across nodes (ZeRO++-style), so once per minibatch the node-level
/// gradients are reduce-scattered across nodes and fresh params
/// all-gathered back — 2 inter-node passes over the full model.
pub fn hybrid_step_overhead(model: PaperModel, topo: &Topology) -> f64 {
    hybrid_step_overhead_dtype(model, topo, WireDtype::Bf16)
}

/// [`hybrid_step_overhead`] under a configured wire dtype.
pub fn hybrid_step_overhead_dtype(model: PaperModel, topo: &Topology, dtype: WireDtype) -> f64 {
    hybrid_step_overhead_bytes(model_bytes_dtype(model, dtype), topo)
}

/// `hybrid_step_overhead` generalized over raw parameter bytes, so the
/// real engine (whose tiny presets are not paper models) can ask for
/// the prediction matching its own parameter count — `fig12_hybrid
/// --engine` prints this next to the measured step overhead.
pub fn hybrid_step_overhead_bytes(param_bytes: f64, topo: &Topology) -> f64 {
    if !topo.multi_node() {
        return 0.0;
    }
    let nodes = topo.nodes() as f64;
    // per node NIC moves (nodes-1)/nodes of the model, twice
    2.0 * (param_bytes * (nodes - 1.0) / nodes) / (topo.inter_bw * topo.devices_per_node as f64)
}

/// ElasticWorld recovery epilogue, generalized over raw parameter bytes
/// (the real engine's tiny presets are not paper models — fig12-style
/// predicted-vs-measured comparison needs its own byte count): the
/// rendezvous successor re-reads the dead owner's shard state from the
/// replicated store — parameters plus both Adam moment windows, three
/// shard-sized transfers — and re-dispatches each orphaned microbatch
/// (one op-setup latency apiece).
pub fn recovery_epilogue_bytes(
    param_bytes: f64,
    world: usize,
    topo: &Topology,
    orphans: usize,
) -> f64 {
    let shard = param_bytes / world.max(1) as f64;
    3.0 * shard / topo.intra_bw + orphans as f64 * topo.latency
}

/// [`recovery_epilogue_bytes`] for a paper model (bf16 parameters).
pub fn recovery_epilogue_s(model: PaperModel, world: usize, topo: &Topology, orphans: usize) -> f64 {
    recovery_epilogue_bytes(model_bytes_dtype(model, WireDtype::Bf16), world, topo, orphans)
}

/// ChaosComm pricing (the sim mirror of [`crate::comm::transport`]):
/// expected retransmissions and timeout stalls for one minibatch of
/// `micros` dispatched microbatches over `world` devices on a lossy
/// transport. The dominant lossy traffic is the scatter-accumulate push
/// stream — `micros × layers × world` payload messages per minibatch,
/// one per-server layer piece each — and a message retransmits
/// `drop/(1-drop)` extra times in expectation (geometric; the capped
/// ladder makes residual request-level loss negligible at transient
/// rates). Reordered/delayed messages are held one release window and
/// priced like a single backoff each.
///
/// Returns `(retries, retransmitted_bytes, stall_seconds)`: the first
/// two mirror the engine's `FaultStats` counters, the stall is the
/// expected wall addition (backoff sleeps + retransmitted volume over
/// the intra-node links, amortized across the world's parallel links).
pub fn fault_minibatch_overhead(
    model: PaperModel,
    world: usize,
    micros: usize,
    plan: &FaultPlan,
    policy: &RetryPolicy,
    topo: &Topology,
) -> (u64, u64, f64) {
    if plan.is_noop() || micros == 0 || world == 0 {
        return (0, 0, 0.0);
    }
    let msgs = (micros * model.layers() * world) as f64;
    let extra = plan.drop / (1.0 - plan.drop);
    let retries = (msgs * extra).round() as u64;
    let piece = layer_bytes(model) / world as f64;
    let bytes = (retries as f64 * piece).round() as u64;
    let backoff_s = policy.backoff_us(0) as f64 * 1e-6;
    let held = msgs * (plan.delay + plan.reorder);
    let stall = (retries as f64 * (backoff_s + piece / topo.intra_bw) + held * backoff_s)
        / world as f64;
    (retries, bytes, stall)
}

/// Result of scheduling a whole run under the AsyncPS admission rule
/// (see [`async_admission_schedule`]).
#[derive(Clone, Debug)]
pub struct AsyncSchedule {
    /// Wall seconds until the LAST minibatch's optimizer apply lands —
    /// the same finish line the synchronous accumulation uses.
    pub total_wall: f64,
    /// Worst observed admission staleness across (device, step) starts.
    pub staleness_max: u64,
    /// p99 of the same observations.
    pub staleness_p99: f64,
}

/// AsyncPS pricing: replay the per-minibatch timings as a free-running
/// bounded-staleness schedule instead of the synchronous
/// `Σ (wall + apply)` accumulation.
///
/// Device `d` may start minibatch `t` once (a) it finished its own
/// minibatch `t - 1` and (b) the optimizer apply of minibatch
/// `t - 1 - k` has landed — the engine's admission gate
/// (`ParamStore::wait_min_applies`). The apply of minibatch `t`
/// completes `apply_s` after the slowest device's pushes (the shard
/// servers fold the moment the quorum lands):
///
/// ```text
/// start(d,t)  = max(finish(d,t-1), A[t-1-k])
/// finish(d,t) = start(d,t) + dur(d,t)
/// A[t]        = max_d finish(d,t) + apply_s
/// ```
///
/// `dur(d,t) = walls[t] - (max_busy(t) - busy[t][d])`: the minibatch
/// wall minus the device's idle share, so the critical device carries
/// exactly the synchronous wall (exposed comm included) and faster
/// devices free-run into their admission window. With `k = 0` the gate
/// IS the synchronous barrier and `total_wall` degenerates to
/// `Σ (walls[t] + apply_s)` (up to float association); with `k ≥ 1`
/// every device overlaps the apply epilogue — and any step where it is
/// not the straggler — with its own next minibatch, which is where the
/// async throughput gain comes from. Observed staleness at a start is
/// `t` minus the number of applies that have landed by then, the same
/// quantity the engine's `TrainRun::staleness_p99` reports.
pub fn async_admission_schedule(
    walls: &[f64],
    busy: &[Vec<f64>],
    staleness: usize,
    apply_s: f64,
) -> AsyncSchedule {
    let steps = walls.len();
    let devices = busy.first().map_or(0, |b| b.len());
    if steps == 0 || devices == 0 {
        return AsyncSchedule { total_wall: 0.0, staleness_max: 0, staleness_p99: 0.0 };
    }
    let mut finish = vec![0.0f64; devices];
    let mut applies: Vec<f64> = Vec::with_capacity(steps);
    let mut obs: Vec<u64> = Vec::with_capacity(steps * devices);
    for t in 0..steps {
        let max_busy = busy[t].iter().cloned().fold(0.0f64, f64::max);
        let gate = if t > staleness { applies[t - 1 - staleness] } else { 0.0 };
        let mut step_max = 0.0f64;
        for d in 0..devices {
            let start = finish[d].max(gate);
            // Applies are monotone, so the landed count is a prefix.
            let landed = applies.iter().take_while(|&&a| a <= start).count();
            obs.push((t as u64).saturating_sub(landed as u64));
            let dur = walls[t] - (max_busy - busy[t][d]);
            finish[d] = start + dur.max(0.0);
            step_max = step_max.max(finish[d]);
        }
        applies.push(step_max + apply_s);
    }
    obs.sort_unstable();
    let idx = ((obs.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
    AsyncSchedule {
        total_wall: *applies.last().unwrap(),
        staleness_max: *obs.last().unwrap(),
        staleness_p99: obs[idx] as f64,
    }
}

/// Result of timing one minibatch.
#[derive(Clone, Debug)]
pub struct MinibatchTiming {
    /// Wall-clock seconds for the minibatch (excl. optimizer epilogue).
    pub wall: f64,
    /// Per-device busy seconds (compute ∪ exposed comm).
    pub busy: Vec<f64>,
}

/// Effective duration of one microbatch slot on one device: compute
/// overlapped with communication. An EMPTY slot still pays the full
/// communication time under collective (the device must join every
/// all-gather/reduce-scatter barrier) but costs nothing under ODC.
///
/// `compute.max(comm)` models FULL compute/communication overlap. On
/// the one-sided schemes the engine now earns this credit explicitly:
/// FastFold's streamed gathers post layer `l+1`'s gather while block
/// `l` computes (see `engine::trainer::GatherStream`), so the slot
/// pays whichever of the two is longer — exactly this expression. No
/// numeric change here; the engine caught up to the model.
fn slot_time(compute: f64, comm: f64, scheme: CommScheme, empty: bool) -> f64 {
    match (scheme, empty) {
        (CommScheme::Collective, true) => comm,
        (CommScheme::Odc | CommScheme::Hybrid, true) => 0.0,
        (_, false) => compute.max(comm),
    }
}

/// Time one minibatch under the given scheme (the heart of the sim).
pub fn time_minibatch(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
) -> MinibatchTiming {
    time_minibatch_opt(plan, lens, model, cost, scheme, sharding, topo, false)
}

/// `time_minibatch` with the hierarchical-gather toggle.
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_opt(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
) -> MinibatchTiming {
    time_minibatch_dispatch(plan, lens, model, cost, scheme, sharding, topo, hierarchical, &[], false)
}

/// The general timing entry point: `time_minibatch_opt` plus the
/// straggler/heterogeneity scenario and the dispatch policy.
///
/// * `speeds` — per-device relative compute speed (`1.0` = nominal,
///   `0.25` = a 4× straggler; empty = homogeneous). Compute stretches by
///   `1/speed`; communication is the network's time and does not.
/// * `queue` — price dynamic work-stealing dispatch
///   (`Balancer::Queue`): the plan's microbatches are pulled LPT-first
///   by whichever device frees up earliest (the engine's
///   `WorkQueue` dynamics on the cost model) instead of replaying the
///   static placement. Only meaningful for barrier-free schemes — the
///   config layer rejects `Queue`×`Collective` before simulation.
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_dispatch(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    speeds: &[f64],
    queue: bool,
) -> MinibatchTiming {
    let empty = SplitMap::empty(lens.len());
    time_minibatch_dispatch_split(
        plan, lens, model, cost, scheme, sharding, topo, hierarchical, speeds, queue, &empty,
    )
}

/// SeqSplit's per-minibatch rendezvous epilogue: every split parent's
/// chunk gradients meet in a cross-device partial reduction before the
/// ordinary micro fold ([`crate::comm`]'s per-sequence fold). Each
/// parent cut into `c` chunks costs `c − 1` extra shard-sized gradient
/// passes over the intra-node links — the chunks' payloads already
/// reached the shard servers through the per-micro scatter, the
/// reduction moves `(c − 1) · grad_bytes / world` per parent to fold
/// them — plus one op-setup latency per parent. Exposed (serial) time:
/// devices cannot start the optimizer epilogue until every parent's
/// gradient is whole.
pub fn seqsplit_reduce_epilogue_bytes(
    param_bytes: f64,
    world: usize,
    topo: &Topology,
    split: &SplitMap,
) -> f64 {
    if split.is_empty() {
        return 0.0;
    }
    let shard = param_bytes / world.max(1) as f64;
    let mut secs = 0.0;
    for info in split.iter() {
        if info.index == 0 {
            secs += (info.count - 1) as f64 * shard / topo.intra_bw + topo.latency;
        }
    }
    secs
}

/// [`seqsplit_reduce_epilogue_bytes`] for a paper model (bf16 grads).
pub fn seqsplit_reduce_epilogue_s(
    model: PaperModel,
    world: usize,
    topo: &Topology,
    split: &SplitMap,
) -> f64 {
    seqsplit_reduce_epilogue_bytes(model_bytes_dtype(model, WireDtype::Bf16), world, topo, split)
}

/// [`time_minibatch_dispatch`] under SeqSplit: chunk virtual ids are
/// priced by their causal-prefix-aware chunk cost through the
/// [`SplitMap`] (empty map = bit-identical to the unsplit path), the
/// queue path goes through the ONE shared makespan kernel
/// ([`queue_busy_split`] — also the bubble estimator's), and the
/// per-sequence rendezvous epilogue is added to the wall (not per-device
/// busy: it is exposed network time, reported as dispatch wait).
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_dispatch_split(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    speeds: &[f64],
    queue: bool,
    split: &SplitMap,
) -> MinibatchTiming {
    time_minibatch_dispatch_split_dtype(
        plan,
        lens,
        model,
        cost,
        scheme,
        sharding,
        topo,
        hierarchical,
        speeds,
        queue,
        split,
        WireDtype::Bf16,
    )
}

/// [`time_minibatch_dispatch_split`] under a configured wire dtype: the
/// per-micro comm slot is priced at the dtype's payload bytes
/// (`micro_comm_time_opt_dtype`). `Bf16` reproduces the fixed-dtype
/// entry point bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_dispatch_split_dtype(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    speeds: &[f64],
    queue: bool,
    split: &SplitMap,
    dtype: WireDtype,
) -> MinibatchTiming {
    let d = plan.devices();
    let comm = micro_comm_time_opt_dtype(model, scheme, sharding, topo, hierarchical, dtype);
    let m_max = plan.max_micro_count();
    let inv_speed = |dev: usize| 1.0 / speeds.get(dev).copied().unwrap_or(1.0);
    debug_assert!(
        split.is_empty() || scheme != CommScheme::Collective,
        "seq-split × Collective is rejected at config validation"
    );
    let epilogue = seqsplit_reduce_epilogue_s(model, d, topo, split);

    let micro_secs = |dev: usize, m: usize| -> (f64, bool) {
        match plan.micro[dev].get(m) {
            Some(mb) if !mb.is_empty() => (cost.seconds(micro_flops_split(mb, lens, cost, split)), false),
            Some(_) => (0.0, true),  // padded empty slot (collective)
            None => (0.0, true),     // device simply has fewer microbatches (ODC)
        }
    };

    if queue {
        debug_assert!(scheme != CommScheme::Collective, "Queue×Collective is rejected at config validation");
        // Work-stealing pull through THE shared split-aware makespan
        // kernel (`queue_busy_split` — the bubble estimator replays the
        // identical schedule, so the CLI's bubble and dispatch-wait
        // lines agree under splitting by construction) — a straggler
        // pulls less often and the fast devices absorb its share at
        // microbatch (now chunk) granularity.
        let busy = queue_busy_split(plan, lens, cost, split, |flops, dev| {
            slot_time(cost.seconds(flops) * inv_speed(dev), comm, scheme, false)
        });
        let wall = busy.iter().cloned().fold(0.0, f64::max) + epilogue;
        return MinibatchTiming { wall, busy };
    }

    let mut busy = vec![0.0f64; d];
    let wall = match scheme {
        CommScheme::Collective => {
            // eq. (1): lockstep over microbatch indices
            let mut t = 0.0;
            for m in 0..m_max {
                let mut step = 0.0f64;
                for (dev, b) in busy.iter_mut().enumerate() {
                    let (c, empty) = micro_secs(dev, m);
                    let s = slot_time(c * inv_speed(dev), comm, CommScheme::Collective, empty);
                    *b += s;
                    step = step.max(s);
                }
                t += step;
            }
            t
        }
        CommScheme::Odc | CommScheme::Hybrid => {
            // decoupled progress: each device runs only its own slots
            // (hybrid reduces are mailbox pushes too — no group lockstep)
            for (dev, b) in busy.iter_mut().enumerate() {
                for m in 0..plan.micro[dev].len() {
                    let (c, empty) = micro_secs(dev, m);
                    *b += slot_time(c * inv_speed(dev), comm, scheme, empty);
                }
            }
            busy.iter().cloned().fold(0.0, f64::max)
        }
    };

    MinibatchTiming { wall: wall + epilogue, busy }
}

/// Price one minibatch under elastic membership (the sim mirror of the
/// engine's ElasticWorld scenario): `dead[d]` devices are gone before
/// the minibatch starts — their share redistributed — and each entry of
/// `fails` is `(device, pulls)`: the device crashes during this
/// minibatch after completing `pulls` microbatches. The schedule is
/// the greedy earliest-free pull model over the plan's microbatches:
/// exact for `Balancer::Queue` (the engine's WorkQueue dynamics), and
/// an optimistic lower bound for static balancers, whose survivors
/// under `ElasticDispatch` only steal orphaned work — the sim lets
/// them rebalance everything. Only meaningful for barrier-free schemes
/// (config validation rejects elastic × Collective). The recovery
/// epilogue itself is priced separately by [`recovery_epilogue_s`].
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_failover(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    speeds: &[f64],
    dead: &[bool],
    fails: &[(usize, usize)],
) -> MinibatchTiming {
    time_minibatch_failover_dtype(
        plan,
        lens,
        model,
        cost,
        scheme,
        sharding,
        topo,
        hierarchical,
        speeds,
        dead,
        fails,
        WireDtype::Bf16,
    )
}

/// [`time_minibatch_failover`] under a configured wire dtype (see
/// [`time_minibatch_dispatch_split_dtype`]).
#[allow(clippy::too_many_arguments)]
pub fn time_minibatch_failover_dtype(
    plan: &Plan,
    lens: &[usize],
    model: PaperModel,
    cost: &CostModel,
    scheme: CommScheme,
    sharding: Sharding,
    topo: &Topology,
    hierarchical: bool,
    speeds: &[f64],
    dead: &[bool],
    fails: &[(usize, usize)],
    dtype: WireDtype,
) -> MinibatchTiming {
    debug_assert!(scheme != CommScheme::Collective, "elastic × Collective is rejected at config validation");
    let d = plan.devices();
    let comm = micro_comm_time_opt_dtype(model, scheme, sharding, topo, hierarchical, dtype);
    let inv_speed = |dev: usize| 1.0 / speeds.get(dev).copied().unwrap_or(1.0);
    let order = lpt_order(plan, lens, cost);
    // Per-device pull budget: dead devices pull nothing; a device
    // failing during this minibatch completes exactly its scheduled
    // pull count before crashing (its orphans land on survivors).
    let mut budget: Vec<usize> =
        (0..d).map(|dev| if dead.get(dev).copied().unwrap_or(false) { 0 } else { order.len() }).collect();
    for &(fdev, pulls) in fails {
        budget[fdev] = budget[fdev].min(pulls);
    }
    let busy = pull_schedule_budgeted(order.len(), d, &mut budget, |item, dev| {
        let (od, om) = order[item];
        let ls: Vec<usize> = plan.micro[od][om].iter().map(|&si| lens[si]).collect();
        slot_time(cost.seconds(cost.micro_cost(&ls)) * inv_speed(dev), comm, scheme, false)
    });
    let wall = busy.iter().cloned().fold(0.0, f64::max);
    MinibatchTiming { wall, busy }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::packers::Plan;

    fn topo8() -> Topology {
        Topology::paper(8, 8)
    }

    fn cost() -> CostModel {
        CostModel::for_model(PaperModel::M1_5B)
    }

    /// device0: one long sample; device1: one short sample.
    fn skew_plan() -> (Plan, Vec<usize>) {
        (Plan { micro: vec![vec![vec![0]], vec![vec![1]]] }, vec![60_000, 1_000])
    }

    #[test]
    fn collective_wall_is_max_of_slots() {
        let (plan, lens) = skew_plan();
        let c = cost();
        let topo = Topology::paper(2, 8);
        let t = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Collective, Sharding::Full, &topo);
        let comm = micro_comm_time(PaperModel::M1_5B, CommScheme::Collective, Sharding::Full, &topo);
        let long = c.seconds(c.micro_cost(&[60_000])).max(comm);
        assert!((t.wall - long).abs() < 1e-9);
    }

    #[test]
    fn odc_not_slower_than_collective_same_plan() {
        let (plan, lens) = skew_plan();
        let c = cost();
        let topo = Topology::paper(2, 8); // single node: comm times equal
        let tc = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Collective, Sharding::Full, &topo);
        let to = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo);
        assert!(to.wall <= tc.wall + 1e-12);
    }

    #[test]
    fn odc_skips_empty_slots_collective_pays_comm() {
        // device0 has 2 micros, device1 has 1 + empty padding
        let plan = Plan { micro: vec![vec![vec![0], vec![1]], vec![vec![2], vec![]]] };
        let lens = vec![30_000, 30_000, 30_000];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let comm = micro_comm_time(PaperModel::M1_5B, CommScheme::Collective, Sharding::Full, &topo);
        let tc = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Collective, Sharding::Full, &topo);
        let to = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo);
        // collective: device1's second slot still costs `comm`, and the
        // minibatch waits for max(slot) each index
        let slot = c.seconds(c.micro_cost(&[30_000])).max(comm);
        assert!((tc.wall - 2.0 * slot).abs() < 1e-9);
        assert!((to.wall - 2.0 * slot.max(0.0)).abs() < 1e-9 || to.wall <= tc.wall);
    }

    #[test]
    fn long_sequences_hide_comm() {
        // §6.1: comm per microbatch is constant, compute is O(s²)
        let topo = Topology::paper(32, 8);
        let c = CostModel::for_model(PaperModel::M7B);
        let comm = micro_comm_time(PaperModel::M7B, CommScheme::Odc, Sharding::Full, &topo);
        let compute_64k = c.seconds(c.micro_cost(&[65_536]));
        assert!(compute_64k > comm, "64K-token compute {compute_64k} should hide {comm}");
    }

    #[test]
    fn odc_comm_slower_multi_node() {
        let topo = Topology::paper(32, 8);
        let cc = micro_comm_time(PaperModel::M7B, CommScheme::Collective, Sharding::Full, &topo);
        let oc = micro_comm_time(PaperModel::M7B, CommScheme::Odc, Sharding::Full, &topo);
        assert!(oc > cc);
        // hybrid sharding removes the gap
        let hc = micro_comm_time(PaperModel::M7B, CommScheme::Odc, Sharding::Hybrid, &topo);
        assert!(hc < oc);
    }

    #[test]
    fn hybrid_overhead_zero_single_node() {
        assert_eq!(hybrid_step_overhead(PaperModel::M7B, &topo8()), 0.0);
        assert!(hybrid_step_overhead(PaperModel::M7B, &Topology::paper(16, 8)) > 0.0);
    }

    #[test]
    fn hybrid_scheme_equals_hybrid_sharding_comm() {
        // CommScheme::Hybrid prices comm exactly like Sharding::Hybrid.
        let topo = Topology::paper(32, 8);
        let a = micro_comm_time(PaperModel::M7B, CommScheme::Hybrid, Sharding::Full, &topo);
        let b = micro_comm_time(PaperModel::M7B, CommScheme::Odc, Sharding::Hybrid, &topo);
        assert_eq!(a, b);
    }

    #[test]
    fn hybrid_scheme_decouples_like_odc() {
        // No per-layer barrier: an empty padded slot costs nothing.
        let plan = Plan { micro: vec![vec![vec![0], vec![1]], vec![vec![2], vec![]]] };
        let lens = vec![30_000, 30_000, 30_000];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let th = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Hybrid, Sharding::Hybrid, &topo);
        let to = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Hybrid, &topo);
        assert_eq!(th.wall, to.wall);
        assert_eq!(th.busy, to.busy);
    }

    #[test]
    fn device_speed_stretches_compute_not_comm() {
        let (plan, lens) = skew_plan();
        let c = cost();
        let topo = Topology::paper(2, 8);
        let base = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[], false,
        );
        let skew = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[0.25, 1.0], false,
        );
        // device 0 holds the long (compute-bound) sample: 4× slower
        assert!((skew.busy[0] - 4.0 * base.busy[0]).abs() < 1e-9 * skew.busy[0]);
        assert_eq!(skew.busy[1], base.busy[1]);
        assert!(skew.wall >= base.wall);
    }

    #[test]
    fn empty_speeds_match_seed_timing_exactly() {
        let (plan, lens) = skew_plan();
        let c = cost();
        let topo = Topology::paper(2, 8);
        for scheme in [CommScheme::Collective, CommScheme::Odc] {
            let a = time_minibatch(&plan, &lens, PaperModel::M1_5B, &c, scheme, Sharding::Full, &topo);
            let b = time_minibatch_dispatch(
                &plan, &lens, PaperModel::M1_5B, &c, scheme, Sharding::Full, &topo, false, &[], false,
            );
            assert_eq!(a.wall, b.wall);
            assert_eq!(a.busy, b.busy);
        }
    }

    #[test]
    fn queue_dispatch_cuts_idle_under_straggler() {
        // 8 equal-cost singleton micros statically dealt 4+4 over 2
        // devices; device 0 runs at quarter speed. Static: dev0 takes
        // 4×4c while dev1 idles after 4c. Queue: dev1 absorbs most
        // micros and idle shrinks.
        let plan = Plan {
            micro: vec![
                (0..4).map(|i| vec![i]).collect(),
                (4..8).map(|i| vec![i]).collect(),
            ],
        };
        let lens = vec![30_000usize; 8];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let speeds = [0.25, 1.0];
        let stat = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &speeds, false,
        );
        let dyn_ = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &speeds, true,
        );
        let idle = |t: &MinibatchTiming| t.busy.iter().map(|b| t.wall - b).sum::<f64>();
        assert!(dyn_.wall < stat.wall, "queue {} should beat static {}", dyn_.wall, stat.wall);
        assert!(idle(&dyn_) < idle(&stat), "queue idle {} should be below static idle {}", idle(&dyn_), idle(&stat));
    }

    #[test]
    fn queue_dispatch_homogeneous_not_worse_than_static_lpt_balance() {
        // Uniform devices: queue = LPT list scheduling, which cannot be
        // worse than the static deal on this symmetric plan.
        let plan = Plan {
            micro: vec![
                vec![vec![0], vec![1], vec![2]],
                vec![vec![3]],
            ],
        };
        let lens = vec![20_000, 20_000, 20_000, 20_000];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let stat = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[], false,
        );
        let dyn_ = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[], true,
        );
        assert!(dyn_.wall <= stat.wall + 1e-12, "queue rebalances the 3-vs-1 deal");
    }

    #[test]
    fn failover_redistributes_dead_device_work() {
        // 4 equal micros dealt 2+2; device 0 dead before the minibatch:
        // everything lands on device 1, wall doubles vs the healthy run.
        let plan = Plan { micro: vec![vec![vec![0], vec![1]], vec![vec![2], vec![3]]] };
        let lens = vec![30_000usize; 4];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let healthy = time_minibatch_dispatch(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[], true,
        );
        let t = time_minibatch_failover(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[],
            &[true, false], &[],
        );
        assert_eq!(t.busy[0], 0.0, "a dead device does no work");
        assert!((t.wall - 2.0 * healthy.wall).abs() < 1e-9, "{} vs 2x {}", t.wall, healthy.wall);
    }

    #[test]
    fn failover_mid_minibatch_keeps_completed_pulls() {
        // Device 0 completes exactly one pull before crashing: its busy
        // time is one slot (the work it already delivered is kept —
        // exactly-once), device 1 absorbs the remaining three.
        let plan = Plan { micro: vec![vec![vec![0], vec![1]], vec![vec![2], vec![3]]] };
        let lens = vec![30_000usize; 4];
        let c = cost();
        let topo = Topology::paper(2, 8);
        let t = time_minibatch_failover(
            &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false, &[],
            &[false, false], &[(0, 1)],
        );
        assert!(t.busy[0] > 0.0);
        assert!((t.busy[1] - 3.0 * t.busy[0]).abs() < 1e-9, "{} vs 3x {}", t.busy[1], t.busy[0]);
        assert_eq!(t.wall, t.busy[1]);
    }

    #[test]
    fn recovery_epilogue_scales_with_state_and_orphans() {
        let topo = topo8();
        let base = recovery_epilogue_bytes(1e9, 4, &topo, 0);
        assert!(base > 0.0);
        assert!((recovery_epilogue_bytes(2e9, 4, &topo, 0) - 2.0 * base).abs() < 1e-12);
        assert!(recovery_epilogue_bytes(1e9, 4, &topo, 5) > base);
        assert!(recovery_epilogue_s(PaperModel::M1_5B, 8, &topo, 1) > 0.0);
    }

    #[test]
    fn dtype_pricing_doubles_under_f32_wire() {
        // The fixed-dtype entry points are bf16 wrappers — bit-identical
        // to their historical values — while the `_dtype` variants price
        // a configured encoding.
        let m = PaperModel::M7B;
        assert_eq!(layer_bytes(m), layer_bytes_dtype(m, WireDtype::Bf16));
        assert_eq!(layer_bytes_dtype(m, WireDtype::F32), 2.0 * layer_bytes(m));
        assert_eq!(model_bytes_dtype(m, WireDtype::Bf16), 2.0 * m.params());
        let topo = Topology::paper(16, 8);
        assert_eq!(
            hybrid_step_overhead_dtype(m, &topo, WireDtype::Bf16),
            hybrid_step_overhead(m, &topo)
        );
        assert_eq!(
            hybrid_step_overhead_dtype(m, &topo, WireDtype::F32),
            2.0 * hybrid_step_overhead(m, &topo)
        );
        let bf = micro_comm_time_opt(m, CommScheme::Odc, Sharding::Full, &topo, false);
        let f32c =
            micro_comm_time_opt_dtype(m, CommScheme::Odc, Sharding::Full, &topo, false, WireDtype::F32);
        assert_eq!(
            micro_comm_time_opt_dtype(m, CommScheme::Odc, Sharding::Full, &topo, false, WireDtype::Bf16),
            bf
        );
        assert!(f32c > bf, "f32 wire must price more volume than bf16");
    }

    #[test]
    fn overhead_bytes_scales_linearly() {
        let topo = Topology::paper(16, 8);
        let one = hybrid_step_overhead_bytes(1e9, &topo);
        let two = hybrid_step_overhead_bytes(2e9, &topo);
        assert!(one > 0.0);
        assert!((two - 2.0 * one).abs() < 1e-12);
        assert_eq!(hybrid_step_overhead_bytes(1e9, &topo8()), 0.0);
    }

    #[test]
    fn seqsplit_cuts_queue_wall_on_dominant_corpus() {
        // One sequence holds >40% of the minibatch's tokens: unsplit,
        // its device is the makespan no matter how the queue deals the
        // rest; split into ≤world chunks the work spreads and the wall
        // drops even after paying the rendezvous epilogue.
        use crate::balance::packers::{plan_run_split, PackOpts};
        use crate::balance::split::SplitMode;
        use crate::config::Balancer;
        use crate::util::rng::Rng;
        let c = cost();
        let mut lens = vec![2_000usize; 7];
        lens.push(60_000);
        let topo = Topology::paper(4, 8);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let (base_plans, empty) = plan_run_split(
            Balancer::Queue, &lens, 4, 2, 65_536, &c, &mut r1, PackOpts::default(), 0.0,
            SplitMode::Zigzag,
        );
        let (split_plans, map) = plan_run_split(
            Balancer::Queue, &lens, 4, 2, 65_536, &c, &mut r2, PackOpts::default(), 0.5,
            SplitMode::Zigzag,
        );
        assert!(empty.is_empty() && !map.is_empty());
        let t = |p: &Plan, m: &SplitMap| {
            time_minibatch_dispatch_split(
                p, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo, false,
                &[], true, m,
            )
        };
        let base: f64 = base_plans.iter().map(|p| t(p, &empty).wall).sum();
        let split: f64 = split_plans.iter().map(|p| t(p, &map).wall).sum();
        assert!(split < base, "split wall {split} must be strictly below unsplit {base}");
    }

    #[test]
    fn seqsplit_epilogue_prices_partial_reduce() {
        use crate::balance::split::ChunkInfo;
        let topo = topo8();
        let mut map = SplitMap::empty(4);
        assert_eq!(seqsplit_reduce_epilogue_bytes(1e9, 8, &topo, &map), 0.0);
        map.push_parent(
            (0..3).map(|i| ChunkInfo { parent: 0, index: i, count: 3, start: 100 * i, len: 100 }).collect(),
        );
        let one = seqsplit_reduce_epilogue_bytes(1e9, 8, &topo, &map);
        assert!(one > 0.0, "a split parent must price its rendezvous");
        map.push_parent(
            (0..2).map(|i| ChunkInfo { parent: 1, index: i, count: 2, start: 50 * i, len: 50 }).collect(),
        );
        let two = seqsplit_reduce_epilogue_bytes(1e9, 8, &topo, &map);
        assert!(two > one, "each parent adds its own partial-reduce bytes");
        // bytes scale linearly at fixed chunk structure (latency aside)
        let double = seqsplit_reduce_epilogue_bytes(2e9, 8, &topo, &map);
        assert!(double > two);
    }

    #[test]
    fn split_disabled_dispatch_identical_to_seed_path() {
        let (plan, lens) = skew_plan();
        let c = cost();
        let topo = Topology::paper(2, 8);
        let empty = SplitMap::empty(lens.len());
        for queue in [false, true] {
            let a = time_minibatch_dispatch(
                &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo,
                false, &[], queue,
            );
            let b = time_minibatch_dispatch_split(
                &plan, &lens, PaperModel::M1_5B, &c, CommScheme::Odc, Sharding::Full, &topo,
                false, &[], queue, &empty,
            );
            assert_eq!(a.wall, b.wall);
            assert_eq!(a.busy, b.busy);
        }
    }
}
