//! Testbed-scale simulator: regenerates the paper's evaluation numbers
//! (Tables 3–6, Figures 8–10, 12) for 1.5B–32B models on 8–32 "A100s".
//!
//! The simulator is a *deterministic timeline simulator* that implements
//! the paper's timing equations exactly:
//!
//! * **Collective** — eq. (1): every microbatch index is a rendezvous of
//!   all devices (the sum over per-layer maxima collapses to the
//!   per-microbatch maximum when per-layer times are proportional, which
//!   holds for a homogeneous layer stack — see `timeline::tests`).
//! * **ODC** — devices progress independently; the minibatch ends at the
//!   slowest device's finish time, plus the drain + optimizer epilogue.
//!
//! Compute times come from `balance::cost` (O(s) + O(s²)); communication
//! times come from `comm::volume` (Table 2 volumes over the `Topology`
//! bandwidths), overlapped with compute as in §6.1.

pub mod parametric;
pub mod run;
pub mod timeline;

pub use run::{simulate, RunResult, SimConfig};
