//! Parametric study (§5.3, Figure 10): acceleration ratio of ODC over
//! Collective (both with LB-Micro) as one factor varies from the golden
//! setting of Table 1.

use crate::config::{Balancer, CommScheme, ExperimentConfig};
use crate::sim::run::{simulate, SimConfig};

/// A single point: (x value, ODC/Collective throughput ratio).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub x: f64,
    pub ratio: f64,
}

/// Acceleration ratio for one config (ODC LB-Micro vs Collective LB-Micro).
pub fn acceleration_ratio(base: &ExperimentConfig) -> f64 {
    let mut col = base.clone();
    col.scheme = CommScheme::Collective;
    col.balancer = Balancer::LbMicro;
    let mut odc = base.clone();
    odc.scheme = CommScheme::Odc;
    odc.balancer = Balancer::LbMicro;
    let rc = simulate(&SimConfig::new(col));
    let ro = simulate(&SimConfig::new(odc));
    ro.samples_per_sec_per_device / rc.samples_per_sec_per_device
}

/// The four panels of Figure 10.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Factor {
    MinibatchSize,
    MaxLength,
    PackingRatio,
    Devices,
}

impl Factor {
    pub fn label(self) -> &'static str {
        match self {
            Factor::MinibatchSize => "minibatch size",
            Factor::MaxLength => "max length",
            Factor::PackingRatio => "packing ratio",
            Factor::Devices => "devices",
        }
    }

    pub fn default_grid(self) -> Vec<f64> {
        match self {
            Factor::MinibatchSize => vec![1.0, 2.0, 4.0, 8.0, 16.0],
            Factor::MaxLength => vec![8_192.0, 16_384.0, 32_768.0, 65_536.0],
            Factor::PackingRatio => vec![1.0, 2.0, 4.0, 8.0],
            Factor::Devices => vec![2.0, 4.0, 8.0, 16.0, 32.0],
        }
    }
}

/// Sweep one factor from the golden setting, holding the rest constant.
pub fn sweep(factor: Factor, grid: &[f64], steps: usize, seed: u64) -> Vec<SweepPoint> {
    grid.iter()
        .map(|&x| {
            let mut exp = ExperimentConfig::golden();
            exp.steps = steps;
            exp.seed = seed;
            match factor {
                Factor::MinibatchSize => exp.minibs = x as usize,
                Factor::MaxLength => exp.max_len = x as usize,
                Factor::PackingRatio => exp.packing_ratio = x,
                Factor::Devices => {
                    exp.devices = x as usize;
                    exp.devices_per_node = (x as usize).min(8);
                }
            }
            SweepPoint { x, ratio: acceleration_ratio(&exp) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ratios(f: Factor, grid: &[f64]) -> Vec<f64> {
        sweep(f, grid, 6, 11).into_iter().map(|p| p.ratio).collect()
    }

    #[test]
    fn ratio_above_one_at_golden() {
        let mut exp = ExperimentConfig::golden();
        exp.steps = 6;
        assert!(acceleration_ratio(&exp) > 1.0);
    }

    #[test]
    fn ratio_grows_with_max_length() {
        // Fig 10: longer sequences amplify O(s²) imbalance.
        let r = ratios(Factor::MaxLength, &[8_192.0, 65_536.0]);
        assert!(r[1] >= r[0] * 0.98, "{r:?}");
    }

    #[test]
    fn ratio_shrinks_with_packing_ratio() {
        // Fig 10: larger budgets give the baseline more packing freedom.
        let r = ratios(Factor::PackingRatio, &[1.0, 8.0]);
        assert!(r[1] <= r[0] + 0.02, "{r:?}");
    }

    #[test]
    fn ratio_grows_with_devices() {
        // Fig 10: more devices, more heterogeneity.
        let r = ratios(Factor::Devices, &[2.0, 32.0]);
        assert!(r[1] >= r[0] - 0.02, "{r:?}");
    }

    #[test]
    fn minibs_one_no_gain() {
        let r = ratios(Factor::MinibatchSize, &[1.0]);
        assert!((r[0] - 1.0).abs() < 0.05, "{r:?}");
    }
}
