//! End-to-end simulated runs: dataset draw → packing → timeline → metrics.
//!
//! One [`simulate`] call reproduces one cell of the paper's evaluation
//! grid (a model × dataset × method × minibatch-size combination) and
//! reports samples/s/device (Tables 3 & 5) plus the packing-estimated
//! bubble rate (Tables 4 & 6).

use crate::balance::bubble::estimate_bubble_dispatch_split;
use crate::balance::cost::CostModel;
use crate::balance::packers::{plan_run_split, PackOpts};
use crate::balance::split::SplitMode;
use crate::comm::topology::Topology;
use crate::comm::transport::{FaultPlan, RetryPolicy, TransportKind};
use crate::config::{
    Balancer, CommScheme, Dataset, ExperimentConfig, PaperModel, RunSpec, Sharding, WireDtype,
};
use crate::data::distributions::sample_lengths;
use crate::sim::timeline::{
    async_admission_schedule, fault_minibatch_overhead, hybrid_step_overhead_dtype,
    model_bytes_dtype, recovery_epilogue_s, time_minibatch_dispatch_split_dtype,
    time_minibatch_failover_dtype,
};
use crate::util::rng::Rng;

/// Simulation-specific knobs on top of the experiment cell.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub exp: ExperimentConfig,
    /// RL mode (Table 3): LB-Mini keeps equal per-device sample counts.
    pub rl_mode: bool,
    /// §6.2 ODC optimization: hierarchical (node-leader cached) gathers.
    pub hierarchical_gather: bool,
    /// Per-device relative compute speed — the straggler/heterogeneity
    /// perturbation mirroring `TrainerConfig::device_speed` (`1.0` =
    /// nominal, `0.25` = a 4× straggler; empty = homogeneous fleet).
    pub device_speed: Vec<f64>,
    /// ElasticWorld failure scenario, mirroring `TrainerConfig::fail_at`:
    /// `(device, step, micro)` — the device crashes during minibatch
    /// `step` after completing `micro` pulls. Its unfinished micros are
    /// re-dispatched to survivors at runtime and a priced recovery
    /// epilogue (state re-read + orphan re-dispatch) lands on that
    /// step's wall; later steps run on the shrunken world. Barrier-free
    /// schemes only — `simulate` panics under Collective, exactly like
    /// the trainer's validation error.
    pub fail_at: Vec<(usize, usize, usize)>,
    /// ChaosComm lossy-transport scenario, mirroring
    /// `TrainerConfig::fault_plan` (see [`FaultPlan`]). Transient loss
    /// (drop/dup/reorder/delay) is priced as expected retransmission
    /// stalls plus retransmitted volume; each `part=src:dst:step`
    /// partition escalates its src device into a derived ElasticWorld
    /// fail-stop at `step` (recovery epilogue, shrunken world, orphans
    /// re-dispatched) — exactly what the engine's suspicion counter
    /// does past the retry budget. Barrier-free schemes only;
    /// partitions additionally require ODC and exclude `fail_at`,
    /// matching the trainer's validation.
    pub fault_plan: FaultPlan,
    /// SeqSplit (`--seq-split`), mirroring `TrainerConfig::seq_split`:
    /// split any sequence whose predicted cost exceeds this fraction of
    /// the balanced per-device budget into context-parallel chunks. The
    /// timeline prices chunk compute through the split-aware makespan
    /// kernel plus a per-sequence partial-reduce epilogue on the wall
    /// (see `sim::timeline::seqsplit_reduce_epilogue_s`). `0.0`
    /// disables; requires a barrier-free scheme and an LB-Mini or Queue
    /// balancer, and cannot combine with `fail_at` / partitions here
    /// (the failover pricing path is split-unaware).
    pub seq_split: f64,
    /// Chunk-boundary rule: `Ring` = equal tokens, `Zigzag` = equal
    /// predicted cost.
    pub seq_split_mode: SplitMode,
    /// FastFold wire precision, mirroring `TrainerConfig::wire_dtype`.
    /// Defaults to `Bf16` — the sim's comm pricing has always assumed
    /// bf16 payloads, so the default reproduces every historical result
    /// bit-for-bit; `F32` doubles the priced per-micro payload bytes
    /// (and the reported `wire_bytes`). See `docs/wire_precision.md`.
    pub wire_dtype: WireDtype,
    /// WireComm measured link pricing (`--transport shm|uds` on the sim
    /// CLI): replaces the hand-set intra-node latency/bandwidth with
    /// the `alpha_us`/`beta_gbps` cell `benches/wire_calib.rs` measured
    /// into `BENCH_wire.json` for that transport. `None` (default)
    /// keeps the paper's hand-set topology pricing — every historical
    /// sim number is reproduced bit-for-bit. Inter-node pricing is
    /// untouched either way: both byte transports are same-host, so
    /// they can only calibrate the intra link.
    pub wire_calib: Option<WireCalib>,
    /// AsyncPS bounded staleness, mirroring `TrainerConfig::staleness`:
    /// `Some(k)` replaces the end-of-minibatch barrier with the SSP
    /// admission gate (a worker may start minibatch `t` once every shard
    /// server has applied through `t − k`), so a straggler's optimizer
    /// epilogue overlaps the fast devices' next compute phase.
    /// `Some(0)` prices the degenerate synchronous case — same total
    /// wall as `None` up to float association (the engine's k = 0 path
    /// is bit-identical; see `docs/asyncps.md`). `None` (default) keeps
    /// the synchronous accumulation, reproducing every historical sim
    /// number bit-for-bit. Requires ODC + LB-Mini/Queue, static
    /// membership, clean links, no seq_split — the shared `RunSpec`
    /// matrix rejects everything else.
    pub staleness: Option<usize>,
}

/// A measured (alpha, beta) link cost model: `t(bytes) = alpha_us µs +
/// bytes / (beta_gbps GB/s)` — the per-message setup cost and the
/// sustained large-message bandwidth the calibration bench fits by
/// least squares over the message-size sweep (the classic LogP-style
/// two-parameter wire model).
#[derive(Clone, Copy, Debug)]
pub struct WireCalib {
    /// Per-message setup cost, microseconds.
    pub alpha_us: f64,
    /// Sustained bandwidth, gigabytes per second.
    pub beta_gbps: f64,
}

impl WireCalib {
    /// Load the measured cell for `kind` from the repo's
    /// `BENCH_wire.json`. Errors when the file is missing, unmeasured
    /// (`measured: false` — the committed placeholder), malformed, or
    /// has no cell for this transport.
    pub fn load(kind: TransportKind) -> Result<WireCalib, String> {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_wire.json");
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let json = crate::util::json::Json::parse(&text).map_err(|e| format!("{path}: {e:?}"))?;
        if json.get("measured").and_then(|m| m.as_bool()) != Some(true) {
            return Err(format!(
                "{path} is the unmeasured placeholder (measured != true); run \
                 `cargo bench --bench wire_calib` to calibrate"
            ));
        }
        let cell = json
            .get("transports")
            .and_then(|t| t.get(&kind.to_string()))
            .ok_or_else(|| format!("{path} has no cell for transport `{kind}`"))?;
        let alpha_us = cell
            .get("alpha_us")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{path}: transport `{kind}` cell is missing alpha_us"))?;
        let beta_gbps = cell
            .get("beta_gbps")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{path}: transport `{kind}` cell is missing beta_gbps"))?;
        if !(alpha_us.is_finite() && alpha_us >= 0.0 && beta_gbps.is_finite() && beta_gbps > 0.0) {
            return Err(format!("{path}: transport `{kind}` calibration is out of range"));
        }
        Ok(WireCalib { alpha_us, beta_gbps })
    }

    /// Apply the measured pricing to a topology: alpha becomes the
    /// per-message latency, beta the intra-node bandwidth.
    pub fn apply(&self, topo: &mut Topology) {
        topo.latency = self.alpha_us * 1e-6;
        topo.intra_bw = self.beta_gbps * 1e9;
    }
}

impl SimConfig {
    pub fn new(exp: ExperimentConfig) -> Self {
        let rl_mode = exp_is_rl(&exp);
        SimConfig {
            exp,
            rl_mode,
            hierarchical_gather: false,
            device_speed: Vec::new(),
            fail_at: Vec::new(),
            fault_plan: FaultPlan::default(),
            seq_split: 0.0,
            seq_split_mode: SplitMode::Zigzag,
            wire_dtype: WireDtype::Bf16,
            wire_calib: None,
            staleness: None,
        }
    }
}

fn exp_is_rl(exp: &ExperimentConfig) -> bool {
    exp.dataset == Dataset::Aime
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    /// Samples per second per device — the paper's headline metric.
    pub samples_per_sec_per_device: f64,
    /// Packing-estimated bubble rate (Tables 4/6 definition),
    /// speed- and dispatch-aware: under `device_speed` skew or
    /// `Balancer::Queue` the estimate replays the perturbed schedule
    /// (`balance::bubble::estimate_bubble_dispatch`) so this line and
    /// `dispatch_wait_s` agree on what the devices actually did.
    pub bubble_rate: f64,
    /// Mean minibatch wall seconds.
    pub mean_minibatch_s: f64,
    /// Timeline device utilization: Σ busy / (wall × devices). The
    /// complement of time lost to barriers, stragglers and the
    /// optimizer epilogue — the quantity the bubble rate approximates
    /// from packing alone.
    pub device_utilization: f64,
    /// Predicted per-minibatch hybrid step overhead (cross-node
    /// optimizer-state exchange + replica refresh), in seconds; 0 under
    /// full sharding or on a single node. Reported so the real-engine
    /// mode of `fig12_hybrid` can print prediction vs measurement side
    /// by side.
    pub hybrid_step_overhead_s: f64,
    /// Total device-seconds spent idle waiting on the dispatch source
    /// (static plan or work queue) during the microbatch phases:
    /// Σ over minibatches of Σ_d (minibatch wall − busy_d). The absolute
    /// "bubble time" whose rate `device_utilization` approximates —
    /// what `Balancer::Queue` exists to shrink under skewed devices.
    pub dispatch_wait_s: f64,
    /// Predicted ElasticWorld recovery overhead (state re-read from the
    /// replicated store + orphan re-dispatch), summed over `fail_at`
    /// events and included in the wall; 0 without failures. The real
    /// trainer measures the same quantity as `TrainRun::recovery_s` —
    /// fig12-style predicted-vs-measured reporting. (The packing-based
    /// `bubble_rate` still describes the healthy schedule; failure
    /// steps are priced by the failover pull model.)
    pub recovery_s: f64,
    /// ChaosComm: expected retransmissions under the configured
    /// `fault_plan` — the sim mirror of the engine's
    /// `FaultStats::retries` counter (0 on a clean transport).
    pub retries: u64,
    /// ChaosComm: expected retransmitted payload volume in bytes
    /// (mirror of `FaultStats::retransmitted_bytes`).
    pub retransmitted_bytes: u64,
    /// ChaosComm: partitioned links escalated into ElasticWorld
    /// fail-stops, deduplicated by (src, dst) link (mirror of
    /// `FaultStats::escalations`).
    pub escalations: u64,
    /// FastFold: modeled pushed gradient wire volume over the run, in
    /// bytes at the configured `wire_dtype` — the sim mirror of
    /// `TrainRun::wire_bytes` (`HotpathStats::wire_bytes`). One-sided
    /// schemes encode each dispatched micro's full gradient once at
    /// push; Hybrid additionally prices the per-minibatch cross-node
    /// super-shard exchange (the same `(nodes-1)/nodes` volume term
    /// `hybrid_step_overhead_bytes` times); Collective reports 0,
    /// exactly like the engine's default `hotpath_stats`.
    pub wire_bytes: u64,
    /// FastFold: modeled server-side fold seconds over the run (f32
    /// master-accumulate traffic / `SIM_FOLD_GBPS`) — the sim mirror
    /// of `TrainRun::fold_s`. 0 under Collective.
    pub fold_s: f64,
    /// AsyncPS: 99th-percentile observed staleness at admission (how
    /// many applies behind the freshest shard a worker's pulled params
    /// were when it started a minibatch), over all (device, minibatch)
    /// admissions. Bounded above by the configured `k`; 0 under
    /// synchronous runs and in the k = 0 degenerate case. Sim mirror of
    /// `TrainRun::staleness_p99`.
    pub staleness_p99: f64,
    /// AsyncPS: whole-run samples/s under the staleness-admission
    /// schedule (`samples / total_wall`, NOT per device — the headline
    /// `samples_per_sec_per_device` already uses the async wall when
    /// staleness is configured). 0 under synchronous runs, where the
    /// metric would be redundant.
    pub async_throughput: f64,
    pub minibatches: usize,
    pub samples: usize,
}

/// Simulate `exp.steps` minibatches of the configured cell.
///
/// Panics on an invalid balancer × scheme combination
/// ([`ExperimentConfig::validate`]); CLI entry points validate first and
/// report the error instead.
pub fn simulate(cfg: &SimConfig) -> RunResult {
    let exp = &cfg.exp;
    if let Err(e) = exp.validate() {
        panic!("invalid experiment cell: {e}");
    }
    // Shared legality matrix — the SAME `RunSpec::validate` the trainer
    // consults, so a combination cannot be legal here and rejected there
    // (or vice versa). Sim-only constraints stay below.
    let spec = RunSpec {
        scheme: exp.scheme,
        balancer: exp.balancer,
        world: exp.devices,
        steps: exp.steps,
        devices_per_node: exp.devices_per_node,
        device_speed: cfg.device_speed.clone(),
        fail_at: cfg.fail_at.clone(),
        join_at: Vec::new(),
        fault_plan: cfg.fault_plan.clone(),
        seq_split: cfg.seq_split,
        wire_dtype: cfg.wire_dtype,
        transport: TransportKind::Inproc,
        staleness: cfg.staleness,
    };
    if let Err(e) = spec.validate() {
        panic!("invalid experiment cell: {e}");
    }
    // Sim-only: the failover pricing path is split-unaware — the trainer
    // permits a crash on a device that hosts no chunks (placement is
    // known after planning), but the pricing model cannot re-dispatch a
    // chunked micro.
    if cfg.seq_split != 0.0 {
        assert!(
            cfg.fail_at.is_empty() && cfg.fault_plan.partition.is_empty(),
            "invalid experiment cell: seq_split cannot combine with fail_at or partitions in \
             the simulator — the failover pricing path is split-unaware (the trainer permits a \
             crash on a device that hosts no chunks; see docs/seqsplit.md)"
        );
    }
    // Fail-stop triples for the pricing loop: a partitioned link
    // escalates its src at the first touch past the retry budget (min
    // step per src, zero completed pulls — the whole plan row
    // re-dispatches to survivors), exactly the schedule
    // `spec.derived_fails()` fed into the validated membership.
    let mut fail_at = cfg.fail_at.clone();
    for &(src, _dst, step) in &cfg.fault_plan.partition {
        match fail_at.iter_mut().find(|f| f.0 == src) {
            Some(f) => f.1 = f.1.min(step),
            None => fail_at.push((src, step, 0)),
        }
    }
    let queue_dispatch = exp.balancer == Balancer::Queue;
    let cost = CostModel::for_model(exp.model);
    let mut topo = Topology::paper(exp.devices, exp.devices_per_node);
    if let Some(calib) = &cfg.wire_calib {
        calib.apply(&mut topo);
    }
    let mut rng = Rng::new(exp.seed);

    // Draw enough samples for `steps` minibatches.
    let n_samples = exp.steps * exp.devices * exp.minibs;
    let lens = sample_lengths(exp.dataset, Some(exp.max_len), n_samples, &mut rng);

    let opts = PackOpts { lb_mini_equal_size: cfg.rl_mode };
    let mut plan_rng = rng.fork(1);
    // seq_split == 0.0 delegates to the seed packer with an empty map —
    // every downstream path is bit-identical to the pre-SeqSplit sim.
    let (plans, split) = plan_run_split(
        exp.balancer,
        &lens,
        exp.devices,
        exp.minibs,
        exp.max_tokens_per_micro(),
        &cost,
        &mut plan_rng,
        opts,
        cfg.seq_split,
        cfg.seq_split_mode,
    );

    let step_overhead = hybrid_overhead(exp, &topo, cfg.wire_dtype);
    let retry_policy = RetryPolicy::default();
    let mut total_wall = 0.0;
    let mut total_busy = 0.0;
    let mut dispatch_wait = 0.0;
    let mut bubble_busy = 0.0;
    let mut bubble_total = 0.0;
    let mut recovery_total = 0.0;
    let mut retries = 0u64;
    let mut retransmitted_bytes = 0u64;
    let mut total_micros = 0usize;
    let mut dead = vec![false; exp.devices];
    let mut samples = 0usize;
    // Per-step (wall, per-device busy) snapshots for the AsyncPS
    // admission schedule — only collected when staleness is configured.
    let mut async_steps: Vec<(f64, Vec<f64>)> = Vec::new();
    for (step, plan) in plans.iter().enumerate() {
        let fails_now: Vec<(usize, usize)> =
            fail_at.iter().filter(|f| f.1 == step).map(|f| (f.0, f.2)).collect();
        let elastic = !fails_now.is_empty() || dead.iter().any(|&x| x);
        let t = if elastic {
            time_minibatch_failover_dtype(
                plan,
                &lens,
                exp.model,
                &cost,
                exp.scheme,
                exp.sharding,
                &topo,
                cfg.hierarchical_gather,
                &cfg.device_speed,
                &dead,
                &fails_now,
                cfg.wire_dtype,
            )
        } else {
            time_minibatch_dispatch_split_dtype(
                plan,
                &lens,
                exp.model,
                &cost,
                exp.scheme,
                exp.sharding,
                &topo,
                cfg.hierarchical_gather,
                &cfg.device_speed,
                queue_dispatch,
                &split,
                cfg.wire_dtype,
            )
        };
        // Idle time counts devices alive at the step's start (a device
        // failing mid-minibatch was alive; a long-dead one has no seat).
        dispatch_wait += t
            .busy
            .iter()
            .enumerate()
            .filter(|&(dev, _)| !dead[dev])
            .map(|(_, b)| (t.wall - b).max(0.0))
            .sum::<f64>();
        // Recovery epilogue: the successor re-reads the dead owner's
        // replicated state and re-dispatches its orphaned micros. The
        // orphan count is estimated from the static plan row (under
        // Queue the actual count depends on runtime pull interleaving);
        // a device whose work ran dry before its fail pull orphans
        // nothing and pays only the state re-read.
        let mut step_recovery = 0.0;
        for &(fdev, pulls) in &fails_now {
            let orphans = plan.micro[fdev].len().saturating_sub(pulls);
            step_recovery += recovery_epilogue_s(exp.model, exp.devices, &topo, orphans);
            dead[fdev] = true;
        }
        recovery_total += step_recovery;
        // ChaosComm pricing: every dispatched micro's scatter stream pays
        // the expected retransmission stall under the lossy transport.
        let micros: usize =
            plan.micro.iter().map(|row| row.iter().filter(|m| !m.is_empty()).count()).sum();
        let (step_retries, step_bytes, fault_stall) = fault_minibatch_overhead(
            exp.model,
            exp.devices,
            micros,
            &cfg.fault_plan,
            &retry_policy,
            &topo,
        );
        retries += step_retries;
        retransmitted_bytes += step_bytes;
        total_micros += micros;
        total_wall += t.wall + ADAM_EPILOGUE_S + step_overhead + step_recovery + fault_stall;
        total_busy += t.busy.iter().sum::<f64>();
        if cfg.staleness.is_some() {
            async_steps.push((t.wall, t.busy.clone()));
        }
        // Speed- and dispatch-aware packing estimate, so the bubble
        // rate and dispatch_wait_s tell one consistent story (failure
        // steps: the estimate still describes the healthy schedule).
        let b = estimate_bubble_dispatch_split(
            plan,
            &lens,
            &cost,
            exp.scheme,
            &cfg.device_speed,
            queue_dispatch,
            &split,
        );
        bubble_busy += b.busy.iter().sum::<f64>();
        bubble_total += b.total;
        // A split parent appears as `count` chunk vids but is still ONE
        // sample — count it once, at its first chunk (identical to
        // `sample_count()` when the map is empty).
        samples += plan
            .iter_samples()
            .filter(|&i| split.get(i).map_or(true, |c| c.index == 0))
            .count();
    }

    let mut links: Vec<(usize, usize)> =
        cfg.fault_plan.partition.iter().map(|&(s, t, _)| (s, t)).collect();
    links.sort_unstable();
    links.dedup();
    let escalations = links.len() as u64;

    // AsyncPS: replace the synchronous sum-of-(wall + epilogue) with the
    // staleness-admission schedule. A device's next minibatch starts as
    // soon as its own work is done AND every shard has applied through
    // t − 1 − k, so a straggler's epilogue overlaps the fast devices'
    // compute instead of gating the whole fleet. Legality (validated
    // above) guarantees no faults/fails/splits here, so the recovery and
    // stall terms the sync accumulator carries are all zero. k = 0
    // reproduces the synchronous wall up to float association (the
    // additions happen per-device rather than in one running sum).
    let mut staleness_p99 = 0.0;
    let mut async_throughput = 0.0;
    if let Some(k) = cfg.staleness {
        let walls: Vec<f64> = async_steps.iter().map(|s| s.0).collect();
        let busy: Vec<Vec<f64>> = async_steps.iter().map(|s| s.1.clone()).collect();
        let sched = async_admission_schedule(&walls, &busy, k, ADAM_EPILOGUE_S + step_overhead);
        total_wall = sched.total_wall;
        staleness_p99 = sched.staleness_p99;
        async_throughput = samples as f64 / total_wall.max(1e-12);
    }

    let d = exp.devices as f64;
    let bubble_rate = if bubble_total > 0.0 { 1.0 - bubble_busy / (d * bubble_total) } else { 0.0 };
    let device_utilization =
        if total_wall > 0.0 { (total_busy / (total_wall * d)).clamp(0.0, 1.0) } else { 0.0 };
    let (wire_bytes, fold_s) =
        hotpath_model(exp, &topo, cfg.wire_dtype, total_micros, plans.len());
    RunResult {
        label: exp.label(),
        samples_per_sec_per_device: samples as f64 / (total_wall.max(1e-12) * d),
        bubble_rate,
        mean_minibatch_s: total_wall / plans.len().max(1) as f64,
        device_utilization,
        hybrid_step_overhead_s: step_overhead,
        dispatch_wait_s: dispatch_wait,
        recovery_s: recovery_total,
        retries,
        retransmitted_bytes,
        escalations,
        wire_bytes,
        fold_s,
        staleness_p99,
        async_throughput,
        minibatches: plans.len(),
        samples,
    }
}

/// Sharded elementwise AdamW epilogue, ~ms-scale.
const ADAM_EPILOGUE_S: f64 = 0.002;

/// Modeled server-side fold throughput in GB/s of f32 master-accumulate
/// traffic, used only for `RunResult::fold_s` — the chunk-parallel
/// kernel's ballpark on the `benches/fold_kernel.rs` shapes. The engine
/// measures the real quantity (`TrainRun::fold_s`); the sim's number
/// exists for fig12-style predicted-vs-measured comparison, not as a
/// calibrated model.
const SIM_FOLD_GBPS: f64 = 12.0;

/// FastFold hotpath mirror: modeled (wire_bytes, fold_s) for the run —
/// see the `RunResult` field docs for the volume model. `micros` is the
/// total dispatched (non-empty) microbatch count across all steps.
fn hotpath_model(
    exp: &ExperimentConfig,
    topo: &Topology,
    dtype: WireDtype,
    micros: usize,
    minibatches: usize,
) -> (u64, f64) {
    if exp.scheme == CommScheme::Collective {
        // Collective has no mailbox fold and no encoded payloads — the
        // engine's default `hotpath_stats` reports zeros there too.
        return (0, 0.0);
    }
    let push = model_bytes_dtype(exp.model, dtype);
    let mut wire = micros as f64 * push;
    // Each pushed gradient element lands in one f32 master accumulate.
    let mut fold_elems = micros as f64 * exp.model.params();
    if exp.scheme == CommScheme::Hybrid && topo.multi_node() {
        let nodes = topo.nodes() as f64;
        // Cross level: once per minibatch the node-folded super-shards
        // cross node boundaries — the same (nodes-1)/nodes volume term
        // `hybrid_step_overhead_bytes` prices — and fold again into the
        // cross-level masters.
        wire += minibatches as f64 * push * (nodes - 1.0) / nodes;
        fold_elems += minibatches as f64 * exp.model.params() * (nodes - 1.0) / nodes;
    }
    let fold_s = fold_elems * 4.0 / (SIM_FOLD_GBPS * 1e9);
    (wire.round() as u64, fold_s)
}

/// Hybrid sharding's per-minibatch cross-node optimizer-state exchange:
/// applies both to the legacy `Sharding::Hybrid` analytic toggle and to
/// the real two-level scheme (`CommScheme::Hybrid`).
fn hybrid_overhead(exp: &ExperimentConfig, topo: &Topology, dtype: WireDtype) -> f64 {
    if exp.sharding == Sharding::Hybrid || exp.scheme == CommScheme::Hybrid {
        hybrid_step_overhead_dtype(exp.model, topo, dtype)
    } else {
        0.0
    }
}

/// Convenience: simulate a (scheme, balancer) pair against the paper's
/// standard cell layout.
pub fn simulate_cell(
    model: PaperModel,
    dataset: Dataset,
    scheme: CommScheme,
    balancer: Balancer,
    minibs: usize,
    devices: usize,
    steps: usize,
    seed: u64,
) -> RunResult {
    let exp = ExperimentConfig {
        model,
        dataset,
        scheme,
        balancer,
        sharding: Sharding::Full,
        minibs,
        devices,
        devices_per_node: 8,
        packing_ratio: 1.0,
        max_len: match dataset {
            Dataset::LongAlign => 65_536,
            Dataset::SweSmith => 32_768,
            Dataset::Aime => 16_384,
        },
        steps,
        seed,
    };
    simulate(&SimConfig::new(exp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(scheme: CommScheme, balancer: Balancer, minibs: usize) -> RunResult {
        simulate_cell(PaperModel::M1_5B, Dataset::LongAlign, scheme, balancer, minibs, 8, 8, 7)
    }

    #[test]
    fn odc_beats_collective_with_packing() {
        // The headline: ODC LB-Micro > Collective LB-Micro at minibs 4–8.
        for minibs in [4, 8] {
            let col = quick(CommScheme::Collective, Balancer::LbMicro, minibs);
            let odc = quick(CommScheme::Odc, Balancer::LbMicro, minibs);
            assert!(
                odc.samples_per_sec_per_device > col.samples_per_sec_per_device,
                "minibs={minibs}: odc {} <= col {}",
                odc.samples_per_sec_per_device,
                col.samples_per_sec_per_device
            );
        }
    }

    #[test]
    fn all_methods_similar_at_minibs_one() {
        // §5.2: "All methods perform similarly when the minibatch size is
        // one, since ODC synchronizes after every sample."
        let col = quick(CommScheme::Collective, Balancer::LbMicro, 1);
        let odc = quick(CommScheme::Odc, Balancer::LbMicro, 1);
        let rel = (odc.samples_per_sec_per_device - col.samples_per_sec_per_device).abs()
            / col.samples_per_sec_per_device;
        assert!(rel < 0.05, "rel diff {rel}");
    }

    #[test]
    fn lb_mini_at_least_matches_lb_micro_small_minibs() {
        let micro = quick(CommScheme::Odc, Balancer::LbMicro, 2);
        let mini = quick(CommScheme::Odc, Balancer::LbMini, 2);
        assert!(mini.samples_per_sec_per_device >= micro.samples_per_sec_per_device * 0.97);
    }

    #[test]
    fn bubble_rate_decreases_with_minibs() {
        // Table 6 trend: bubble rate falls as minibatch size grows.
        let b2 = quick(CommScheme::Collective, Balancer::LbMicro, 2).bubble_rate;
        let b8 = quick(CommScheme::Collective, Balancer::LbMicro, 8).bubble_rate;
        assert!(b8 < b2, "b8 {b8} should be < b2 {b2}");
    }

    #[test]
    fn native_worst_in_rl() {
        // Fig 9: LB-Micro is substantially faster than verl Native.
        let native =
            simulate_cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Collective, Balancer::VerlNative, 8, 8, 8, 3);
        let micro =
            simulate_cell(PaperModel::M1_5B, Dataset::Aime, CommScheme::Collective, Balancer::LbMicro, 8, 8, 8, 3);
        assert!(micro.samples_per_sec_per_device > native.samples_per_sec_per_device);
    }

    #[test]
    fn deterministic() {
        let a = quick(CommScheme::Odc, Balancer::LbMini, 4);
        let b = quick(CommScheme::Odc, Balancer::LbMini, 4);
        assert_eq!(a.samples_per_sec_per_device, b.samples_per_sec_per_device);
    }

    #[test]
    fn hierarchical_gather_helps_short_context_multinode() {
        // §6.2 ablation: node-leader caching recovers exposed inter-node
        // comm when sequences are too short to hide it.
        let mut exp = ExperimentConfig::golden();
        exp.devices = 32;
        exp.max_len = 8_192;
        exp.scheme = CommScheme::Odc;
        exp.steps = 8;
        let mut flat = SimConfig::new(exp.clone());
        flat.hierarchical_gather = false;
        let mut hier = SimConfig::new(exp);
        hier.hierarchical_gather = true;
        assert!(
            simulate(&hier).samples_per_sec_per_device >= simulate(&flat).samples_per_sec_per_device,
            "hierarchical gather must not hurt"
        );
    }

    #[test]
    fn utilization_is_a_meaningful_fraction() {
        for scheme in [CommScheme::Collective, CommScheme::Odc] {
            let r = quick(scheme, Balancer::LbMicro, 4);
            assert!(
                r.device_utilization > 0.0 && r.device_utilization <= 1.0,
                "{scheme}: utilization {} out of range",
                r.device_utilization
            );
        }
    }

    #[test]
    fn utilization_deterministic_and_reported() {
        let a = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        let b = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        assert_eq!(a.device_utilization, b.device_utilization);
    }

    #[test]
    fn counts_match_request() {
        let r = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        assert_eq!(r.minibatches, 8);
        assert_eq!(r.samples, 8 * 8 * 4);
    }

    fn multinode_short(scheme: CommScheme) -> RunResult {
        let exp = ExperimentConfig {
            model: PaperModel::M1_5B,
            dataset: Dataset::LongAlign,
            scheme,
            balancer: Balancer::LbMicro,
            sharding: Sharding::Full,
            minibs: 4,
            devices: 16,
            devices_per_node: 8,
            packing_ratio: 1.0,
            max_len: 8_192,
            steps: 8,
            seed: 5,
        };
        simulate(&SimConfig::new(exp))
    }

    #[test]
    fn hybrid_scheme_beats_flat_odc_on_short_context_multinode() {
        // Fig 12's claim: when microbatches are too short to hide ODC's
        // inter-node traffic, two-level sharding wins despite paying the
        // optimizer-state exchange at every step.
        let odc = multinode_short(CommScheme::Odc);
        let hyb = multinode_short(CommScheme::Hybrid);
        assert!(
            hyb.samples_per_sec_per_device > odc.samples_per_sec_per_device,
            "hybrid {} <= odc {}",
            hyb.samples_per_sec_per_device,
            odc.samples_per_sec_per_device
        );
    }

    fn skewed(balancer: Balancer) -> RunResult {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = balancer;
        exp.devices = 4;
        exp.devices_per_node = 4;
        exp.minibs = 8;
        exp.steps = 8;
        exp.seed = 7;
        let mut cfg = SimConfig::new(exp);
        cfg.device_speed = vec![0.25, 1.0, 1.0, 1.0]; // one 4× straggler
        simulate(&cfg)
    }

    #[test]
    fn queue_beats_static_lb_mini_under_straggler() {
        // The DynDispatch headline: with a 4×-slow device, runtime pulls
        // shrink both the idle time and the minibatch wall relative to
        // the statically balanced plan of the SAME packing.
        let stat = skewed(Balancer::LbMini);
        let dyn_ = skewed(Balancer::Queue);
        assert!(
            dyn_.dispatch_wait_s < stat.dispatch_wait_s,
            "queue wait {} should be strictly below static wait {}",
            dyn_.dispatch_wait_s,
            stat.dispatch_wait_s
        );
        assert!(
            dyn_.samples_per_sec_per_device > stat.samples_per_sec_per_device,
            "queue throughput {} should beat static {}",
            dyn_.samples_per_sec_per_device,
            stat.samples_per_sec_per_device
        );
        assert!(dyn_.device_utilization > stat.device_utilization);
        assert!(
            dyn_.bubble_rate < stat.bubble_rate,
            "the speed-aware bubble estimate must agree with the wait metric: {} vs {}",
            dyn_.bubble_rate,
            stat.bubble_rate
        );
    }

    #[test]
    fn dispatch_wait_consistent_with_utilization() {
        // wait = (1 - util·…) in absolute device-seconds: both come from
        // the same timeline, so the reconstruction must agree up to the
        // epilogue/overhead terms that utilization includes and the
        // microbatch-phase wait excludes.
        let r = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        assert!(r.dispatch_wait_s >= 0.0);
        let total_device_s = r.mean_minibatch_s * r.minibatches as f64 * 8.0;
        assert!(r.dispatch_wait_s <= total_device_s, "{} > {}", r.dispatch_wait_s, total_device_s);
    }

    #[test]
    fn dispatch_wait_deterministic() {
        let a = skewed(Balancer::Queue);
        let b = skewed(Balancer::Queue);
        assert_eq!(a.dispatch_wait_s, b.dispatch_wait_s);
        assert_eq!(a.samples_per_sec_per_device, b.samples_per_sec_per_device);
    }

    fn elastic(fail_at: Vec<(usize, usize, usize)>) -> RunResult {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = Balancer::LbMini;
        exp.devices = 4;
        exp.devices_per_node = 4;
        exp.minibs = 4;
        exp.steps = 6;
        exp.seed = 7;
        let mut cfg = SimConfig::new(exp);
        cfg.fail_at = fail_at;
        simulate(&cfg)
    }

    #[test]
    fn failure_costs_throughput_and_reports_recovery() {
        let healthy = elastic(vec![]);
        assert_eq!(healthy.recovery_s, 0.0);
        let failed = elastic(vec![(0, 2, 1)]);
        assert!(failed.recovery_s > 0.0, "a failure must price a recovery epilogue");
        assert!(
            failed.samples_per_sec_per_device < healthy.samples_per_sec_per_device,
            "losing a device must cost throughput: {} vs {}",
            failed.samples_per_sec_per_device,
            healthy.samples_per_sec_per_device
        );
        assert_eq!(failed.samples, healthy.samples, "every sample still trains exactly once");
        assert_eq!(failed.minibatches, healthy.minibatches, "all steps complete");
    }

    #[test]
    fn failure_scenario_deterministic() {
        let a = elastic(vec![(1, 1, 0)]);
        let b = elastic(vec![(1, 1, 0)]);
        assert_eq!(a.samples_per_sec_per_device, b.samples_per_sec_per_device);
        assert_eq!(a.recovery_s, b.recovery_s);
        assert_eq!(a.dispatch_wait_s, b.dispatch_wait_s);
    }

    #[test]
    #[should_panic(expected = "barrier-free")]
    fn fail_at_under_collective_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Collective;
        exp.balancer = Balancer::LbMicro;
        exp.steps = 2;
        let mut cfg = SimConfig::new(exp);
        cfg.fail_at = vec![(0, 1, 0)];
        let _ = simulate(&cfg);
    }

    #[test]
    #[should_panic(expected = "barrier-free")]
    fn queue_under_collective_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Collective;
        exp.balancer = Balancer::Queue;
        exp.steps = 1;
        let _ = simulate(&SimConfig::new(exp));
    }

    fn lossy(plan: &str) -> RunResult {
        // Same cell as `elastic(vec![])` so clean-plan results compare
        // bit-for-bit against the fault-free baseline.
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = Balancer::LbMini;
        exp.devices = 4;
        exp.devices_per_node = 4;
        exp.minibs = 4;
        exp.steps = 6;
        exp.seed = 7;
        let mut cfg = SimConfig::new(exp);
        cfg.fault_plan = FaultPlan::parse(plan).expect("fault plan parses");
        simulate(&cfg)
    }

    #[test]
    fn noop_fault_plan_prices_nothing() {
        // A seed-only plan is a no-op: zero counters, wall bit-identical
        // to the fault-free baseline of the same cell.
        let base = elastic(vec![]);
        let r = lossy("seed=1");
        assert_eq!(r.retries, 0);
        assert_eq!(r.retransmitted_bytes, 0);
        assert_eq!(r.escalations, 0);
        assert_eq!(r.samples_per_sec_per_device, base.samples_per_sec_per_device);
        assert_eq!(r.recovery_s, 0.0);
    }

    #[test]
    fn transient_loss_prices_retries_and_costs_throughput() {
        let clean = elastic(vec![]);
        let r = lossy("drop=0.08,dup=0.05,reorder=0.05,seed=11");
        assert!(r.retries > 0, "8% drop must price retransmissions");
        assert!(r.retransmitted_bytes > 0);
        assert_eq!(r.escalations, 0, "transient loss never escalates");
        assert_eq!(r.recovery_s, 0.0);
        assert!(
            r.samples_per_sec_per_device < clean.samples_per_sec_per_device,
            "retransmission stalls must cost throughput: {} vs {}",
            r.samples_per_sec_per_device,
            clean.samples_per_sec_per_device
        );
        assert_eq!(r.samples, clean.samples, "transient loss never drops samples");
        let again = lossy("drop=0.08,dup=0.05,reorder=0.05,seed=11");
        assert_eq!(r.retries, again.retries);
        assert_eq!(r.retransmitted_bytes, again.retransmitted_bytes);
        assert_eq!(r.samples_per_sec_per_device, again.samples_per_sec_per_device);
    }

    #[test]
    fn partition_escalates_into_elastic_recovery() {
        // A fully partitioned link past the retry budget becomes a
        // derived fail-stop: ElasticWorld epilogue priced, orphans
        // re-dispatched, every sample still trains exactly once.
        let clean = elastic(vec![]);
        let r = lossy("drop=0.05,seed=3,part=0:2:2");
        assert_eq!(r.escalations, 1);
        assert!(r.recovery_s > 0.0, "escalation must price the ElasticWorld epilogue");
        assert!(r.samples_per_sec_per_device < clean.samples_per_sec_per_device);
        assert_eq!(r.samples, clean.samples, "orphans re-dispatch; every sample trains");
        assert_eq!(r.minibatches, clean.minibatches, "all steps complete");
        let again = lossy("drop=0.05,seed=3,part=0:2:2");
        assert_eq!(r.recovery_s, again.recovery_s);
        assert_eq!(r.samples_per_sec_per_device, again.samples_per_sec_per_device);
    }

    #[test]
    #[should_panic(expected = "barrier-free")]
    fn lossy_collective_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Collective;
        exp.balancer = Balancer::LbMicro;
        exp.steps = 1;
        let mut cfg = SimConfig::new(exp);
        cfg.fault_plan = FaultPlan::parse("drop=0.1").expect("fault plan parses");
        let _ = simulate(&cfg);
    }

    #[test]
    #[should_panic(expected = "partitions require")]
    fn hybrid_partition_rejected_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Hybrid;
        exp.balancer = Balancer::LbMicro;
        exp.steps = 2;
        let mut cfg = SimConfig::new(exp);
        cfg.fault_plan = FaultPlan::parse("drop=0.05,part=0:1:1").expect("fault plan parses");
        let _ = simulate(&cfg);
    }

    #[test]
    fn hybrid_transient_loss_is_priced() {
        // Hybrid supports the transient fault classes (no partitions):
        // counters populate and the run stays deterministic.
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Hybrid;
        exp.balancer = Balancer::LbMicro;
        exp.devices = 8;
        exp.devices_per_node = 4;
        exp.minibs = 4;
        exp.steps = 4;
        let mut cfg = SimConfig::new(exp);
        cfg.fault_plan = FaultPlan::parse("drop=0.06,dup=0.03,seed=5").expect("fault plan parses");
        let a = simulate(&cfg);
        assert!(a.retries > 0);
        assert_eq!(a.escalations, 0);
        let b = simulate(&cfg);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.samples_per_sec_per_device, b.samples_per_sec_per_device);
    }

    fn seqsplit_cell(seq_split: f64, scheme: CommScheme, balancer: Balancer) -> SimConfig {
        let exp = ExperimentConfig {
            model: PaperModel::M1_5B,
            dataset: Dataset::LongAlign,
            scheme,
            balancer,
            sharding: Sharding::Full,
            minibs: 2,
            devices: 4,
            devices_per_node: 4,
            packing_ratio: 1.0,
            max_len: 65_536,
            steps: 6,
            seed: 7,
        };
        let mut cfg = SimConfig::new(exp);
        cfg.seq_split = seq_split;
        cfg
    }

    #[test]
    fn seqsplit_deterministic_and_conserves_samples() {
        let a = simulate(&seqsplit_cell(0.5, CommScheme::Odc, Balancer::Queue));
        let b = simulate(&seqsplit_cell(0.5, CommScheme::Odc, Balancer::Queue));
        assert_eq!(a.samples_per_sec_per_device, b.samples_per_sec_per_device);
        assert_eq!(a.dispatch_wait_s, b.dispatch_wait_s);
        // a split parent is still ONE sample: chunking never changes the
        // trained-sample count
        let base = simulate(&seqsplit_cell(0.0, CommScheme::Odc, Balancer::Queue));
        assert_eq!(a.samples, base.samples);
        assert_eq!(a.minibatches, base.minibatches);
    }

    #[test]
    #[should_panic(expected = "barrier-free")]
    fn seqsplit_under_collective_panics_in_sim() {
        let _ = simulate(&seqsplit_cell(0.5, CommScheme::Collective, Balancer::LbMicro));
    }

    #[test]
    #[should_panic(expected = "LB-Mini or Queue")]
    fn seqsplit_under_synchronized_k_balancer_panics_in_sim() {
        let _ = simulate(&seqsplit_cell(0.5, CommScheme::Odc, Balancer::LbMicro));
    }

    #[test]
    #[should_panic(expected = "split-unaware")]
    fn seqsplit_with_fail_at_panics_in_sim() {
        let mut cfg = seqsplit_cell(0.5, CommScheme::Odc, Balancer::LbMini);
        cfg.fail_at = vec![(0, 2, 1)];
        let _ = simulate(&cfg);
    }

    #[test]
    fn wire_dtype_defaults_bf16_and_f32_doubles_reported_wire() {
        // The default must keep every historical sim number intact: the
        // pricing path has always assumed bf16 payloads.
        let cfg = SimConfig::new(ExperimentConfig::golden());
        assert_eq!(cfg.wire_dtype, WireDtype::Bf16);

        let mk = |dtype: WireDtype| {
            let mut exp = ExperimentConfig::golden();
            exp.scheme = CommScheme::Odc;
            exp.balancer = Balancer::LbMini;
            exp.devices = 4;
            exp.devices_per_node = 4;
            exp.minibs = 4;
            exp.steps = 4;
            let mut cfg = SimConfig::new(exp);
            cfg.wire_dtype = dtype;
            simulate(&cfg)
        };
        let bf = mk(WireDtype::Bf16);
        let f32c = mk(WireDtype::F32);
        // Identical packing → identical micro count → exactly 2× bytes.
        assert_eq!(f32c.wire_bytes, 2 * bf.wire_bytes);
        assert!(bf.wire_bytes > 0);
        // f32 payloads can only slow the comm slots down.
        assert!(f32c.samples_per_sec_per_device <= bf.samples_per_sec_per_device);
        // The fold runs on f32 masters either way — dtype-invariant.
        assert_eq!(bf.fold_s, f32c.fold_s);
        assert!(bf.fold_s > 0.0);
    }

    #[test]
    fn hotpath_mirror_zero_under_collective_and_deterministic() {
        let col = quick(CommScheme::Collective, Balancer::LbMicro, 4);
        assert_eq!(col.wire_bytes, 0, "Collective has no encoded pushes");
        assert_eq!(col.fold_s, 0.0, "Collective has no mailbox fold");
        let a = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        let b = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        assert!(a.wire_bytes > 0);
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.fold_s, b.fold_s);
        // Hybrid multinode pays the cross level on top of the intra push.
        let hyb = multinode_short(CommScheme::Hybrid);
        let odc = multinode_short(CommScheme::Odc);
        assert!(
            hyb.wire_bytes > odc.wire_bytes,
            "cross-level super-shard exchange must add wire volume: {} vs {}",
            hyb.wire_bytes,
            odc.wire_bytes
        );
    }

    #[test]
    fn hybrid_step_overhead_reported_multinode_only() {
        let multi = multinode_short(CommScheme::Hybrid);
        assert!(multi.hybrid_step_overhead_s > 0.0);
        let flat = multinode_short(CommScheme::Odc);
        assert_eq!(flat.hybrid_step_overhead_s, 0.0);
        let single = quick(CommScheme::Odc, Balancer::LbMicro, 4);
        assert_eq!(single.hybrid_step_overhead_s, 0.0);
    }

    fn async_cell(staleness: Option<usize>, speed: Vec<f64>) -> RunResult {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = Balancer::Queue;
        exp.devices = 4;
        exp.devices_per_node = 4;
        exp.minibs = 8;
        exp.steps = 8;
        exp.seed = 7;
        let mut cfg = SimConfig::new(exp);
        cfg.device_speed = speed;
        cfg.staleness = staleness;
        simulate(&cfg)
    }

    #[test]
    fn staleness_zero_degenerates_to_the_synchronous_wall() {
        // k = 0's admission gate IS the barrier; only the association of
        // the wall additions differs (per-device running maxima vs one
        // running sum), so the walls agree to ~ulp-scale relative error.
        // The BIT-identity pin for k = 0 lives in the engine
        // (tests/async_prop.rs), where both paths run the same fold.
        let sync = async_cell(None, vec![0.25, 1.0, 1.0, 1.0]);
        let k0 = async_cell(Some(0), vec![0.25, 1.0, 1.0, 1.0]);
        let sync_wall = sync.mean_minibatch_s * sync.minibatches as f64;
        let k0_wall = k0.mean_minibatch_s * k0.minibatches as f64;
        assert!(
            (sync_wall - k0_wall).abs() <= 1e-9 * sync_wall,
            "k = 0 wall {} must reproduce the synchronous wall {}",
            k0_wall,
            sync_wall
        );
        assert_eq!(k0.staleness_p99, 0.0, "no admission can observe staleness under k = 0");
        assert!(k0.async_throughput > 0.0);
        assert_eq!(sync.async_throughput, 0.0, "sync runs don't report the async metric");
        assert_eq!(sync.staleness_p99, 0.0);
    }

    #[test]
    fn staleness_overlaps_the_straggler_and_strictly_gains() {
        // The AsyncPS headline: with a persistent 4× straggler, k = 2
        // lets the fast devices run ahead through the admission window
        // instead of idling at every barrier — strictly higher
        // throughput than the synchronous schedule of the SAME packing,
        // and the observed staleness stays within the bound.
        let sync = async_cell(None, vec![0.25, 1.0, 1.0, 1.0]);
        let k2 = async_cell(Some(2), vec![0.25, 1.0, 1.0, 1.0]);
        assert!(
            k2.samples_per_sec_per_device > sync.samples_per_sec_per_device,
            "staleness-2 throughput {} must beat sync {}",
            k2.samples_per_sec_per_device,
            sync.samples_per_sec_per_device
        );
        assert!(k2.staleness_p99 <= 2.0, "p99 {} exceeds the bound", k2.staleness_p99);
        // Deterministic: same cell, same numbers.
        let again = async_cell(Some(2), vec![0.25, 1.0, 1.0, 1.0]);
        assert_eq!(k2.samples_per_sec_per_device, again.samples_per_sec_per_device);
        assert_eq!(k2.staleness_p99, again.staleness_p99);
    }

    #[test]
    fn staleness_widens_monotonically() {
        // A wider admission window can only help (or tie): each k's
        // schedule dominates the (k-1) schedule pointwise.
        let speeds = vec![0.25, 1.0, 1.0, 1.0];
        let mut prev = async_cell(Some(0), speeds.clone()).samples_per_sec_per_device;
        for k in 1..4 {
            let cur = async_cell(Some(k), speeds.clone()).samples_per_sec_per_device;
            assert!(cur >= prev, "k={k} throughput {cur} regressed below k-1 {prev}");
            prev = cur;
        }
    }

    #[test]
    #[should_panic(expected = "barrier-free")]
    fn staleness_under_collective_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Collective;
        exp.balancer = Balancer::LbMicro;
        let mut cfg = SimConfig::new(exp);
        cfg.staleness = Some(2);
        let _ = simulate(&cfg);
    }

    #[test]
    #[should_panic(expected = "requires the odc scheme")]
    fn staleness_under_hybrid_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Hybrid;
        exp.balancer = Balancer::LbMini;
        let mut cfg = SimConfig::new(exp);
        cfg.staleness = Some(1);
        let _ = simulate(&cfg);
    }

    #[test]
    #[should_panic(expected = "static membership")]
    fn staleness_with_fail_at_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = Balancer::LbMini;
        let mut cfg = SimConfig::new(exp);
        cfg.staleness = Some(1);
        cfg.fail_at = vec![(0, 2, 1)];
        let _ = simulate(&cfg);
    }

    #[test]
    #[should_panic(expected = "LB-Mini or Queue")]
    fn staleness_under_synchronized_k_balancer_panics_in_sim() {
        let mut exp = ExperimentConfig::golden();
        exp.scheme = CommScheme::Odc;
        exp.balancer = Balancer::LbMicro;
        let mut cfg = SimConfig::new(exp);
        cfg.staleness = Some(1);
        let _ = simulate(&cfg);
    }
}
