//! The `CommBackend` trait: what the FSDP engine drives, and the single
//! seam where Collective and ODC differ.
//!
//! Call protocol (per device thread):
//!
//! ```text
//! for each minibatch:
//!   for each local microbatch (collective: padded to equal count):
//!     for layer in 0..L:        gather_params(dev, layer, buf)   # fwd
//!     for layer in (0..L).rev:  gather_params(dev, layer, buf)   # bwd
//!                               reduce_grad(dev, layer, grad, w)
//!   end_minibatch(dev)                 # grads complete after this
//!   for layer in 0..L: take_grad_shard(dev, layer, g); adam; write shard
//!   end_step(dev)                      # params republished
//! ```
//!
//! `Collective` implements gather/reduce with per-layer barriers (the
//! paper's Figure 1); `Odc` implements them with one-sided reads and
//! mailbox pushes, so the ONLY synchronization is `end_minibatch` /
//! `end_step` (Figure 2).

use super::shared::ShardedParam;
use std::sync::Arc;

/// Parameter store shared by engine and backends: one sharded flat
/// vector per layer (layer 0 = embedding, 1..=L = blocks).
pub struct ParamStore {
    pub layers: Vec<Arc<ShardedParam>>,
}

impl ParamStore {
    pub fn new(layer_lens: &[usize], world: usize) -> Self {
        ParamStore { layers: layer_lens.iter().map(|&l| Arc::new(ShardedParam::new(l, world))).collect() }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn max_padded_len(&self) -> usize {
        self.layers.iter().map(|l| l.padded_len()).max().unwrap_or(0)
    }
}

pub trait CommBackend: Send + Sync {
    fn world(&self) -> usize;

    /// Materialize the full (logical-length) parameters of `layer` into
    /// `out`. FSDP all-gather / ODC gather.
    fn gather_params(&self, dev: usize, layer: usize, out: &mut [f32]);

    /// Whether `gather_params` results may be cached for the remainder
    /// of the minibatch (paper §6.2 parameter caching). True only for
    /// one-sided backends: params are phase-immutable for everyone, but
    /// a collective gather is ALSO a rendezvous, so eliding one would
    /// change the synchronization structure (and desynchronize the
    /// barrier schedule). Default: not cacheable.
    fn gathers_cacheable(&self) -> bool {
        false
    }

    /// Contribute a full-layer gradient with aggregation weight `weight`.
    /// FSDP reduce-scatter / ODC scatter-accumulate. `grad` has the
    /// layer's PADDED length (tail zeros).
    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32);

    /// Blocks until every device's gradients for this minibatch are fully
    /// accumulated (ODC: until all clients pushed + daemon drained;
    /// Collective: a plain barrier — accumulation was synchronous).
    fn end_minibatch(&self, dev: usize);

    /// Copy out + reset the accumulated gradient shard for `layer`.
    /// Only valid between `end_minibatch` and `end_step`.
    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]);

    /// Barrier after the optimizer update: params are republished and the
    /// next minibatch may start gathering.
    fn end_step(&self, dev: usize);

    /// Human-readable scheme name (reports/logs).
    fn name(&self) -> &'static str;
}
