//! The `CommBackend` trait: what the FSDP engine drives, and the single
//! seam where Collective and ODC differ.
//!
//! Call protocol (per device thread):
//!
//! ```text
//! for each minibatch:
//!   for each dispatched microbatch (static plan or runtime queue pull;
//!                                   collective: padded to equal count):
//!     for layer in 0..L:        gather_params(dev, layer, buf)   # fwd
//!     for layer in (0..L).rev:  gather_params(dev, layer, buf)   # bwd
//!                               reduce_grad(dev, layer, grad, w, micro)
//!   end_minibatch(dev)                 # grads complete after this
//!   for layer in 0..L: take_grad_shard(dev, layer, g); adam; write shard
//!   end_step(dev)                      # params republished
//! ```
//!
//! `Collective` implements gather/reduce with per-layer barriers (the
//! paper's Figure 1); `Odc` implements them with one-sided reads and
//! mailbox pushes, so the ONLY synchronization is `end_minibatch` /
//! `end_step` (Figure 2).

use super::membership::OptReplica;
use super::shared::ShardedParam;
use super::transport::FaultStats;
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// FastFold hot-path counters: cumulative bytes pushed over the wire
/// (post-encoding, so `WireDtype::Bf16` shows the real halving) and
/// cumulative nanoseconds spent inside the daemon-side fold kernels.
/// Zero on backends without an explicit wire/fold stage (`Collective`
/// folds synchronously inside its rendezvous and is not instrumented).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HotpathStats {
    /// Encoded payload bytes pushed by `reduce_grad`/`reduce_grad_seq`
    /// (and the hybrid cross-group epilogue).
    pub wire_bytes: u64,
    /// Wall nanoseconds spent in flush-time fold kernels across all
    /// daemon threads (sums over threads, so it can exceed wall time).
    pub fold_ns: u64,
}

/// Parameter store shared by engine and backends: one sharded flat
/// vector per layer (layer 0 = embedding, 1..=L = blocks).
///
/// Alongside the parameter windows it holds the **replicated optimizer
/// moments** ([`OptReplica`], one per layer in the same padded layout)
/// — the classical PS fault-tolerance substrate: shard owners publish
/// their Adam state every step, so a rendezvous successor or a late
/// joiner recovers the exact bytes (see [`super::membership`]).
pub struct ParamStore {
    pub layers: Vec<Arc<ShardedParam>>,
    /// Replicated Adam `m`/`v` windows, indexed like `layers`. Zeroed
    /// at construction — which IS the correct step-0 state. Written
    /// only under elastic membership schedules (a static run never
    /// reads them back, so its optimizer phase skips the publish; the
    /// zero-filled windows themselves are lazily paged and cost no
    /// steady-state traffic).
    pub opt: Vec<Arc<OptReplica>>,
    /// AsyncPS per-shard version clocks: `clock.applies[shard]` counts
    /// optimizer applies published for that shard (a shard at version
    /// `v` carries the parameters produced by minibatches `0..v`).
    /// Every optimizer path bumps its shard's clock after writing the
    /// fresh parameters back, so versions exist under every scheme;
    /// only the bounded-staleness admission gate ever *waits* on them.
    clock: ShardClock,
    /// Per-shard writer gates: an AsyncPS shard server holds the write
    /// side while rewriting its shard's slices across all layers, and
    /// free-running gathers take the read side — so a `k>0` worker
    /// never observes a half-written shard. Synchronous paths skip the
    /// gates entirely (the minibatch barrier already separates writers
    /// from readers).
    gates: Vec<RwLock<()>>,
}

impl ParamStore {
    pub fn new(layer_lens: &[usize], world: usize) -> Self {
        let layers: Vec<Arc<ShardedParam>> =
            layer_lens.iter().map(|&l| Arc::new(ShardedParam::new(l, world))).collect();
        let opt = layers.iter().map(|l| Arc::new(OptReplica::new(l.padded_len()))).collect();
        ParamStore {
            layers,
            opt,
            clock: ShardClock::new(world),
            gates: (0..world).map(|_| RwLock::new(())).collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn max_padded_len(&self) -> usize {
        self.layers.iter().map(|l| l.padded_len()).max().unwrap_or(0)
    }

    /// Publish one optimizer apply for `shard`: bump its version clock
    /// and wake every admission waiter. Call AFTER the fresh parameters
    /// (and any replicated optimizer state) are written back.
    pub fn publish_apply(&self, shard: usize) {
        self.clock.publish(shard);
    }

    /// Current version of `shard` (number of published applies).
    pub fn applies(&self, shard: usize) -> u64 {
        self.clock.applies(shard)
    }

    /// The slowest shard's version — what the staleness admission rule
    /// gates on.
    pub fn min_applies(&self) -> u64 {
        self.clock.min_applies()
    }

    /// Block until every shard has published at least `target` applies;
    /// returns the observed minimum at wake (≥ `target`). `target = t-k`
    /// is the bounded-staleness admission gate for minibatch `t`; with
    /// `k = 0` this is exactly the synchronous end-of-step barrier
    /// condition (all shards applied minibatch `t-1`).
    pub fn wait_min_applies(&self, target: u64) -> u64 {
        self.clock.wait_min(target)
    }

    /// Take `shard`'s writer gate for the span of an optimizer write.
    pub fn shard_write(&self, shard: usize) -> RwLockWriteGuard<'_, ()> {
        self.gates[shard].write().unwrap()
    }

    /// Take `shard`'s reader gate for the span of a free-running gather.
    pub fn shard_read(&self, shard: usize) -> RwLockReadGuard<'_, ()> {
        self.gates[shard].read().unwrap()
    }
}

/// The AsyncPS version clock: one monotonically increasing apply
/// counter per shard under a single mutex (shard count = world, tiny),
/// with a condvar so bounded-staleness admission can sleep instead of
/// spinning on the slowest server.
struct ShardClock {
    applies: Mutex<Vec<u64>>,
    advanced: Condvar,
}

impl ShardClock {
    fn new(world: usize) -> Self {
        ShardClock { applies: Mutex::new(vec![0; world.max(1)]), advanced: Condvar::new() }
    }

    fn publish(&self, shard: usize) {
        let mut a = self.applies.lock().unwrap();
        a[shard] += 1;
        self.advanced.notify_all();
    }

    fn applies(&self, shard: usize) -> u64 {
        self.applies.lock().unwrap()[shard]
    }

    fn min_applies(&self) -> u64 {
        self.applies.lock().unwrap().iter().copied().min().unwrap_or(0)
    }

    fn wait_min(&self, target: u64) -> u64 {
        let mut a = self.applies.lock().unwrap();
        loop {
            let min = a.iter().copied().min().unwrap_or(0);
            if min >= target {
                return min;
            }
            a = self.advanced.wait(a).unwrap();
        }
    }
}

/// What a backend's `gather_params` is, structurally — and therefore how
/// long its results may be reused (paper §6.2 parameter caching).
///
/// The levels mirror the communication hierarchy rather than being a
/// plain on/off switch so the engine can reason per level: a two-level
/// backend's microbatch-phase gathers are cacheable even though its
/// cross-group epilogue traffic (gradient exchange + replica refresh)
/// must never be.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherPolicy {
    /// Every gather is a whole-world rendezvous (Collective): params are
    /// phase-immutable for everyone, but eliding a gather would change
    /// the synchronization structure being measured and desynchronize
    /// the barrier schedule. Never reuse.
    Rendezvous,
    /// One-sided reads of phase-immutable params (ODC): any gather taken
    /// during the microbatch phase is bit-identical for the rest of the
    /// minibatch. Cacheable until `end_step`.
    OneSided,
    /// Two-level (Hybrid): gathers are one-sided intra-group reads of
    /// the node group's replica — cacheable per minibatch exactly like
    /// [`GatherPolicy::OneSided`] — while the cross-group epilogue runs
    /// entirely inside the backend at `end_minibatch`/`end_step` and
    /// must bypass the cache (the replica refresh is what *invalidates*
    /// it).
    TwoLevelIntra,
}

impl GatherPolicy {
    /// Whether gather results may be reused for the rest of the
    /// minibatch (invalidate at `end_step` in every cacheable case).
    pub fn cacheable(self) -> bool {
        !matches!(self, GatherPolicy::Rendezvous)
    }
}

/// Key space for SeqSplit's per-sequence fold: a reconstituted sequence
/// gradient enters the ordinary micro fold under `SEQ_KEY_BASE + seq`,
/// far above any real microbatch id, so folded sequences sort after all
/// regular micros deterministically. A minibatch has at most thousands
/// of micros and sequence ids are corpus indices — both fit with >30
/// bits to spare.
pub const SEQ_KEY_BASE: u64 = 1 << 62;

/// The synthetic micro-fold key for split sequence `seq`.
#[inline]
pub fn seq_micro_key(seq: u64) -> u64 {
    SEQ_KEY_BASE + seq
}

pub trait CommBackend: Send + Sync {
    fn world(&self) -> usize;

    /// Materialize the full (logical-length) parameters of `layer` into
    /// `out`. FSDP all-gather / ODC gather.
    fn gather_params(&self, dev: usize, layer: usize, out: &mut [f32]);

    /// Structural classification of `gather_params` — the engine derives
    /// per-level cacheability from this. Default: rendezvous (uncached).
    fn gather_policy(&self) -> GatherPolicy {
        GatherPolicy::Rendezvous
    }

    /// Whether `gather_params` results may be cached for the remainder
    /// of the minibatch (paper §6.2 parameter caching). Derived from
    /// [`CommBackend::gather_policy`]; kept as a convenience for call
    /// sites that only need the boolean.
    fn gathers_cacheable(&self) -> bool {
        self.gather_policy().cacheable()
    }

    /// Contribute a full-layer gradient with aggregation weight `weight`.
    /// FSDP reduce-scatter / ODC scatter-accumulate. `grad` has the
    /// layer's PADDED length (tail zeros).
    ///
    /// `micro` is the GLOBAL microbatch id within the current minibatch
    /// (`balance::dispatch::MicroAssignment::id`): the one-sided
    /// backends buffer contributions and fold them in `micro` order at
    /// the flush, so the reduction is bit-identical to a single device
    /// replaying the microbatches in id order — regardless of which
    /// device ran which microbatch, or when (the property that makes
    /// work-stealing dispatch semantically free). `Collective` folds
    /// synchronously inside its barriers and ignores the id.
    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32, micro: u64);

    /// SeqSplit's per-sequence rendezvous: contribute the gradient of ONE
    /// chunk of a split sequence (`chunk` of `count`, cut from parent
    /// sample `seq`). The one-sided backends buffer chunk pieces
    /// separately and, at the minibatch flush, partially reduce each
    /// sequence's chunks in chunk-index order FIRST, then feed the
    /// reconstituted per-sequence gradient into the ordinary micro fold
    /// under the synthetic key `SEQ_KEY_BASE + seq` — id-keyed exactly
    /// like [`CommBackend::reduce_grad`], so any dispatch interleaving
    /// of the chunks stays bit-deterministic. `weight` is the chunk's
    /// aggregation weight within the sequence — `1.0` from the trainer
    /// (chunk losses are token sums, and the global `1/ntok`
    /// normalization happens at the optimizer), arbitrary in tests.
    ///
    /// Default: delegate each chunk straight into the micro fold under
    /// its own synthetic key — linear, deterministic, and sufficient for
    /// backends with synchronous folds; the one-sided backends override
    /// this with the true buffered rendezvous.
    fn reduce_grad_seq(
        &self,
        dev: usize,
        layer: usize,
        grad: &[f32],
        weight: f32,
        seq: u64,
        chunk: u32,
        _count: u32,
    ) {
        // (seq, chunk) packed so no two chunks of any sequences collide
        self.reduce_grad(dev, layer, grad, weight, seq_micro_key(seq << 16 | chunk as u64));
    }

    /// Blocks until every device's gradients for this minibatch are fully
    /// accumulated (ODC: until all clients pushed + daemon drained;
    /// Collective: a plain barrier — accumulation was synchronous).
    fn end_minibatch(&self, dev: usize);

    /// Copy out + reset the accumulated gradient shard for `layer`.
    /// Only valid between `end_minibatch` and `end_step`.
    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]);

    /// Barrier after the optimizer update: params are republished and the
    /// next minibatch may start gathering.
    fn end_step(&self, dev: usize);

    /// Human-readable scheme name (reports/logs).
    fn name(&self) -> &'static str;

    // ---- ElasticWorld hooks (see `comm::membership`) -------------------
    //
    // Only meaningful on one-sided backends constructed with a
    // non-static membership schedule; config validation guarantees the
    // engine never calls them on `Collective` (whose per-layer
    // rendezvous cannot survive a dead rank — the structural contrast
    // the elastic scenario exists to measure).

    /// Complete the current minibatch for an orphaned shard: flush its
    /// (still-running) daemon so the caller can `take_grad_shard(shard,
    /// ..)` the fold. Called by the rendezvous successor between its own
    /// `end_minibatch` and `end_step`, once per orphaned shard per step.
    fn flush_shard(&self, _shard: usize) {
        unreachable!("flush_shard requires a one-sided backend with elastic membership")
    }

    /// Block a late joiner until its join step's boundary: every barrier
    /// round of earlier steps has completed, so the parameter windows
    /// and replicated optimizer state it is about to read are settled.
    /// No-op for founding members and static schedules.
    fn await_join(&self, _dev: usize) {}

    // ---- AsyncPS hooks (see `comm::async_ps`) --------------------------

    /// AsyncPS server tier: block until shard `shard`'s gradient fold for
    /// minibatch `mb` is complete (its full live quorum pushed and the
    /// daemon folded), staging the result for `take_grad_shard(shard,
    /// ..)`. Driven by the engine's per-shard server thread — workers
    /// never call this; they run ahead under the staleness bound while
    /// the server applies the optimizer at its own pace.
    fn server_flush(&self, _shard: usize, _mb: usize) {
        unreachable!("server_flush requires the AsyncPs backend")
    }

    // ---- ChaosComm hooks (see `comm::transport`) -----------------------

    /// Whether `dev` has escalated an unreachable link: its retry budget
    /// was exhausted past the suspicion threshold, so it must crash out
    /// through the elastic path (`report_failed` → ring-successor
    /// takeover → orphan re-pull) instead of wedging a rendezvous.
    /// Always false on reliable transports.
    fn link_escalated(&self, _dev: usize) -> bool {
        false
    }

    /// Transport-level fault counters (retries, retransmitted bytes,
    /// link escalations) accumulated so far. Zero on reliable transports.
    fn fault_stats(&self) -> FaultStats {
        FaultStats::default()
    }

    // ---- FastFold hooks (see `comm::fold`) -----------------------------

    /// Hot-path counters (encoded wire bytes, fold kernel time)
    /// accumulated so far. Zero on backends without an explicit
    /// wire/fold stage.
    fn hotpath_stats(&self) -> HotpathStats {
        HotpathStats::default()
    }
}
