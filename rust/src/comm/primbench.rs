//! Communication-primitive microbenchmark (paper Figure 11).
//!
//! Measures the achieved bandwidth of the four primitives over the REAL
//! shared-memory backends — gather / scatter-accumulate (ODC) vs
//! all-gather / reduce-scatter (collective) — across device counts, "for
//! fairness ... launched synchronously: each device issues operations in
//! the same order, with barriers inserted before and after each
//! primitive" (§5.4). Inter-node behaviour (this testbed is one shared-
//! memory "node") is reported from the Appendix D analytic model by the
//! fig11 bench target.

use super::backend::{CommBackend, ParamStore};
use super::collective::CollectiveComm;
use super::odc::OdcComm;
use std::sync::{Arc, Barrier};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct PrimResult {
    pub name: &'static str,
    pub devices: usize,
    /// Full-buffer size in bytes.
    pub bytes: usize,
    /// Mean seconds per operation (max over devices).
    pub secs: f64,
    /// Algorithm bandwidth: moved volume per client / time, GB/s.
    pub gbps: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Primitive {
    AllGather,
    ReduceScatter,
    Gather,
    ScatterAccumulate,
}

impl Primitive {
    pub fn label(self) -> &'static str {
        match self {
            Primitive::AllGather => "all-gather",
            Primitive::ReduceScatter => "reduce-scatter",
            Primitive::Gather => "gather",
            Primitive::ScatterAccumulate => "scatter-accumulate",
        }
    }

    pub fn is_odc(self) -> bool {
        matches!(self, Primitive::Gather | Primitive::ScatterAccumulate)
    }
}

/// Run one primitive `iters` times on `world` device threads over a
/// buffer of `elems` f32s; returns the per-op timing of the slowest
/// device (the completion time the paper plots).
pub fn bench_primitive(prim: Primitive, world: usize, elems: usize, iters: usize) -> PrimResult {
    let params = Arc::new(ParamStore::new(&[elems], world));
    let backend: Arc<dyn CommBackend> = if prim.is_odc() {
        Arc::new(OdcComm::new(Arc::clone(&params), world))
    } else {
        Arc::new(CollectiveComm::new(Arc::clone(&params), world))
    };
    let sync = Arc::new(Barrier::new(world));
    let padded = params.layers[0].padded_len();

    let per_dev_secs: Vec<f64> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for dev in 0..world {
            let backend = Arc::clone(&backend);
            let sync = Arc::clone(&sync);
            handles.push(s.spawn(move || {
                let mut out = vec![0.0f32; padded];
                let grad = vec![1.0f32; padded];
                let mut shard = vec![0.0f32; padded / world];
                let mut total = 0.0;
                for _ in 0..iters {
                    sync.wait(); // fairness: synchronized launch (§5.4)
                    let t0 = Instant::now();
                    match prim {
                        Primitive::AllGather | Primitive::Gather => {
                            backend.gather_params(dev, 0, &mut out);
                        }
                        Primitive::ReduceScatter | Primitive::ScatterAccumulate => {
                            backend.reduce_grad(dev, 0, &grad, 1.0, dev as u64);
                            backend.end_minibatch(dev);
                            backend.take_grad_shard(dev, 0, &mut shard);
                            backend.end_step(dev);
                        }
                    }
                    total += t0.elapsed().as_secs_f64();
                    sync.wait(); // barrier after each primitive (§5.4)
                }
                std::hint::black_box(&out);
                total / iters as f64
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let secs = per_dev_secs.iter().cloned().fold(0.0, f64::max);
    let bytes = padded * 4;
    // per-client moved volume is (D-1)/D of the buffer for both schemes
    let moved = bytes as f64 * (world as f64 - 1.0) / world as f64;
    PrimResult { name: prim.label(), devices: world, bytes, secs, gbps: moved / secs / 1e9 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_primitives_complete_and_report() {
        for prim in [
            Primitive::AllGather,
            Primitive::Gather,
            Primitive::ReduceScatter,
            Primitive::ScatterAccumulate,
        ] {
            let r = bench_primitive(prim, 2, 1 << 12, 2);
            assert!(r.secs > 0.0, "{prim:?}");
            assert!(r.gbps > 0.0, "{prim:?}");
            assert_eq!(r.devices, 2);
        }
    }

    #[test]
    fn gather_scales_with_devices() {
        // Just a smoke check that 4-device runs work (scheduling noise on
        // a 1-core box makes real bandwidth assertions meaningless here).
        let r = bench_primitive(Primitive::Gather, 4, 1 << 12, 2);
        assert_eq!(r.devices, 4);
        assert!(r.secs > 0.0);
    }
}
