//! Baseline backend: collective all-gather / reduce-scatter with
//! per-layer synchronization barriers (paper Figure 1).
//!
//! Every `gather_params` and `reduce_grad` is a rendezvous of ALL
//! devices — the source of the straggler stalls the paper measures. The
//! data movement itself is plain shared-memory copies; what we model
//! faithfully is the *synchronization structure*: no device can pass a
//! layer boundary until the slowest one arrives.

use super::backend::{CommBackend, ParamStore};
use super::shared::SharedBuf;
use std::sync::{Arc, Barrier, Mutex};

pub struct CollectiveComm {
    world: usize,
    params: Arc<ParamStore>,
    /// Per-device full-layer gradient staging slot (reduce-scatter input).
    stage: Vec<SharedBuf>,
    /// Aggregation weight published alongside each stage slot.
    stage_weight: Vec<Mutex<f32>>,
    /// Per-device accumulated gradient shards, one per layer.
    acc: Vec<Mutex<Vec<Vec<f32>>>>,
    barrier: Barrier,
}

impl CollectiveComm {
    pub fn new(params: Arc<ParamStore>, world: usize) -> Self {
        let max_len = params.max_padded_len();
        let acc = (0..world)
            .map(|_| Mutex::new(params.layers.iter().map(|l| vec![0.0; l.shard_len]).collect()))
            .collect();
        CollectiveComm {
            world,
            stage: (0..world).map(|_| SharedBuf::new(max_len)).collect(),
            stage_weight: (0..world).map(|_| Mutex::new(0.0)).collect(),
            acc,
            params,
            barrier: Barrier::new(world),
        }
    }
}

impl CommBackend for CollectiveComm {
    fn world(&self) -> usize {
        self.world
    }

    fn gather_params(&self, _dev: usize, layer: usize, out: &mut [f32]) {
        // all-gather entry barrier: nobody reads until everyone arrives
        self.barrier.wait();
        let p = &self.params.layers[layer];
        let n = p.padded_len().min(out.len());
        p.buf.read(0, &mut out[..n]);
        // exit barrier: nobody proceeds (and later mutates) until all read
        self.barrier.wait();
    }

    // The fold happens synchronously inside the barrier pair in (peer
    // asc) order — the barrier schedule IS the ordering, so the global
    // microbatch id is irrelevant here.
    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32, _micro: u64) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        // publish my contribution
        self.stage[dev].write(0, grad);
        *self.stage_weight[dev].lock().unwrap() = weight;
        self.barrier.wait();
        // scatter phase: accumulate MY shard from every peer's slot
        let range = p.shard_range(dev);
        let mut chunk = vec![0.0f32; range.len()];
        let mut acc = self.acc[dev].lock().unwrap();
        for peer in 0..self.world {
            self.stage[peer].read(range.start, &mut chunk);
            let w = *self.stage_weight[peer].lock().unwrap();
            if w != 0.0 {
                for (a, &c) in acc[layer].iter_mut().zip(&chunk) {
                    *a += w * c;
                }
            }
        }
        drop(acc);
        // exit barrier: slots may be overwritten next call
        self.barrier.wait();
    }

    fn end_minibatch(&self, _dev: usize) {
        self.barrier.wait();
    }

    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]) {
        let mut acc = self.acc[dev].lock().unwrap();
        out.copy_from_slice(&acc[layer]);
        acc[layer].fill(0.0);
    }

    fn end_step(&self, _dev: usize) {
        self.barrier.wait();
    }

    fn name(&self) -> &'static str {
        "collective"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// 3 devices, 1 layer of logical length 7 (padded 9). Each device
    /// contributes grad = dev+1 everywhere; reduced shard must be
    /// sum(w_d * (d+1)).
    #[test]
    fn reduce_scatter_sums_contributions() {
        let world = 3;
        let params = Arc::new(ParamStore::new(&[7], world));
        let comm = Arc::new(CollectiveComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let grad = vec![(dev + 1) as f32; 9];
                    comm.reduce_grad(dev, 0, &grad, 1.0, 0);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 3];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    for &v in &shard {
                        assert_eq!(v, 6.0); // 1 + 2 + 3
                    }
                });
            }
        });
    }

    #[test]
    fn gather_returns_current_params() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[6], world));
        let vals: Vec<f32> = (0..6).map(|i| i as f32).collect();
        params.layers[0].init_from(&vals);
        let comm = Arc::new(CollectiveComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                let want = vals.clone();
                s.spawn(move || {
                    let mut out = vec![0.0; 6];
                    comm.gather_params(dev, 0, &mut out);
                    assert_eq!(out, want);
                });
            }
        });
    }

    #[test]
    fn weighted_reduce() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[4], world));
        let comm = Arc::new(CollectiveComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let grad = vec![1.0f32; 4];
                    let w = if dev == 0 { 0.25 } else { 0.75 };
                    comm.reduce_grad(dev, 0, &grad, w, 0);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 2];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    for &v in &shard {
                        assert!((v - 1.0).abs() < 1e-6);
                    }
                });
            }
        });
    }

    #[test]
    fn take_resets_accumulator() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[4], world));
        let comm = Arc::new(CollectiveComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    comm.reduce_grad(dev, 0, &[1.0; 4], 1.0, 0);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 2];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    assert_eq!(shard, vec![2.0, 2.0]);
                    comm.take_grad_shard(dev, 0, &mut shard);
                    assert_eq!(shard, vec![0.0, 0.0], "second take sees reset");
                });
            }
        });
    }
}
