//! Shared-memory substrate standing in for the paper's RDMA windows.
//!
//! The paper's ODC uses CUDA-IPC (intra-node) and NVSHMEM (inter-node):
//! *one-sided* reads/writes of peer GPU memory that do not interrupt the
//! peer's compute. The closest CPU analogue is a plain shared buffer
//! accessed without locks. [`SharedBuf`] is exactly that: an
//! `UnsafeCell` window with `read`/`write` ops whose safety contract is
//! the same *phase discipline* real FSDP relies on:
//!
//! * parameter windows are only written at the optimizer step, inside a
//!   barrier-delimited phase in which no device reads them;
//! * gradient staging slots are written only by their owning device and
//!   read by peers only between the surrounding barriers;
//! * ODC mailboxes transfer ownership through a channel, so a message's
//!   payload is never aliased.
//!
//! ## The phase timeline (what downstream optimizations may assume)
//!
//! ```text
//!  end_step ──────────────── end_minibatch ────────────── end_step
//!     │   microbatch phase        │     optimizer phase      │
//!     │   params READ-ONLY        │     params WRITTEN,      │
//!     │   (gathers, pushes)       │     owner-shard-disjoint │
//!     │   prefetch l+1 ∥ compute l│                          │
//! ```
//!
//! The *prefetch* row is FastFold's streamed gathers: because params
//! are read-only for the whole microbatch phase, a gather of layer
//! `l+1` issued while layer `l` computes returns the same bytes it
//! would at use time — the trainer's prefetch worker runs it
//! concurrently and deposits the buffer in the gather cache
//! ([`super::gather_cache::GatherCache::adopt_prefetch`]). Streaming is
//! an overlap-only change: it never adds, removes, or reorders the
//! synchronizing calls.
//!
//! Under the two-level hybrid backend ([`super::hybrid::HybridComm`])
//! the same timeline holds at BOTH levels, with the epilogues nested:
//!
//! ```text
//!  end_step ─────────────────── end_minibatch ──────────────── end_step
//!     │   microbatch phase           │ intra │ cross │ optimizer │refresh│
//!     │   replica READ-ONLY          │ group │ shard │ global    │replica│
//!     │   (intra gathers, pushes)    │ fold  │ push  │ WRITTEN   │WRITTEN│
//! ```
//!
//! * group replicas are read-only during the microbatch phase and only
//!   written in the *refresh* sub-phase between `end_step`'s two
//!   barriers (each member writes its own super-shard — disjoint);
//! * `end_minibatch` first completes the intra-group fold (group
//!   rendezvous), then the cross-group shard exchange — the ONLY
//!   cross-group synchronization outside `end_step`.
//!
//! Under SeqSplit (`--seq-split`, see `docs/seqsplit.md`) the minibatch
//! flush gains a *chunk rendezvous* sub-step at the head of the fold,
//! still strictly inside the existing phase boundaries:
//!
//! ```text
//!  … microbatch phase ───────────── end_minibatch ─────────────── …
//!     chunk pushes (reduce_grad_seq)   │ seq fold │ micro fold │
//!     buffered per (seq, chunk,        │ chunks → │ sequences  │
//!     client), NO extra barrier        │ sequence │ join by id │
//! ```
//!
//! Each split sequence's chunk gradients are partially reduced in chunk-
//! index order FIRST (the per-sequence fold), and the reconstituted
//! gradient then enters the ordinary id-keyed micro fold under its
//! synthetic key (`SEQ_KEY_BASE + seq`). Chunks may have run on any
//! devices in any order — the rendezvous is data buffered at the daemon,
//! not a new barrier, so the free-running property and both caching
//! arguments above are untouched. Under Hybrid the seq fold happens at
//! the *intra* level per group; chunks split across groups meet as group
//! partials in the cross-level sum.
//!
//! Two subsystems lean on this timeline beyond plain read/write safety:
//!
//! * [`super::gather_cache::GatherCache`] (§6.2 parameter caching):
//!   because parameter windows cannot change between two `end_step`
//!   barriers, any gather of a layer taken during the microbatch phase
//!   is valid — bit-identical — for the REST of that minibatch. The
//!   cache must be invalidated at `end_step` (owners republish), and is
//!   only legal for one-sided backends (see
//!   [`super::backend::CommBackend::gathers_cacheable`]).
//! * [`super::arena::PayloadArena`] (Appendix B per-client buffers):
//!   `end_minibatch` drains every daemon before any device enters the
//!   next microbatch phase, so a pair's in-flight payloads are bounded
//!   by a single minibatch's pushes — arenas stop growing after warm-up.
//!
//! ## The fault timeline (ChaosComm — `super::transport`)
//!
//! Under a lossy transport ([`super::transport::FaultyTransport`]) the
//! phase timeline gains an ack/retry/escalation sub-structure INSIDE
//! the microbatch phase; the phase boundaries themselves never move:
//!
//! ```text
//!  push ──ack timeout──▶ retransmit ──▶ … ──▶ delivered   (transient)
//!    │        (capped exponential backoff, ≤ max_retries)
//!    └──all retransmits lost──▶ suspicion += 1
//!            └──suspicion ≥ threshold──▶ link ESCALATED:
//!                 retract in-flight micro → flush held links
//!                 → report_failed → ElasticWorld takeover
//! ```
//!
//! * **retries stay inside one push**: a retransmit re-sends the same
//!   payload buffer — it never re-acquires from the arena, so the
//!   in-flight bound above survives arbitrary transient loss;
//! * **duplicates die at the receiver**: the transport reassembles a
//!   per-link exactly-once in-order stream (seq dedup), so the id-keyed
//!   fold never sees a replayed piece — daemon-side (micro, client)
//!   dedup is belt and braces only;
//! * **barriers flush limbo**: control-plane messages (`Done`, `Flush`,
//!   `Retract`, shutdown) are never held for reorder/delay and push any
//!   held data messages of their link ahead of themselves, so every
//!   minibatch boundary drains the link — held pieces cannot leak
//!   across `end_minibatch`;
//! * **escalation is all-or-nothing per micro**: a device that loses a
//!   piece retracts the micro's delivered siblings before crashing out,
//!   so a survivor's re-run folds exactly once (see `docs/faults.md`).
//!
//! ## The wire timeline (WireComm — `super::ring`, `super::socket`)
//!
//! Under a byte-moving transport (`--transport shm|uds`) each send
//! gains an encode → move → decode sub-structure, again strictly
//! inside the phase boundaries:
//!
//! ```text
//!  send ──▶ ticket claimed ──▶ encode (frame) ──▶ fuse? chunk? ──▶ move
//!                                                                   │
//!  deliver ◀── ticket-ordered stash ◀── decode ◀── reassemble ◀─────┘
//! ```
//!
//! * **tickets reproduce the mailbox**: a per-destination ticket is
//!   claimed atomically at send time and delivery is strictly
//!   ticket-ordered, so every daemon observes the SAME total arrival
//!   order it would under the in-process mailbox — which is why the
//!   transport matrix asserts bit-identity, not tolerance;
//! * **local-only control rides a ticketed lane**: messages that cannot
//!   cross a process boundary (flush handshakes carrying channel
//!   senders) take a local lane that merges by the same ticket order,
//!   after flushing any frames fused ahead of them;
//! * **fusion and chunking are invisible**: small same-(dst, micro)
//!   frames coalesce below the fusion budget and oversized frames split
//!   at the chunk size, but frames are reassembled before decode — the
//!   daemon sees whole messages in ticket order, full stop;
//! * **one-sided reads stay shared-memory**: gathers read `SharedBuf`
//!   windows directly on every transport (both are same-host), so the
//!   wire carries only the push-side mailbox traffic.
//!
//! See `docs/transport.md` for the frame format, the ring's memory
//! layout, and the calibration loop that feeds the measured alpha/beta
//! back into the simulator's link pricing.
//!
//! ## The async timeline (AsyncPS — `super::async_ps`)
//!
//! Under the bounded-staleness tier (`--staleness k`) the GLOBAL
//! barrier-delimited optimizer phase above dissolves into per-shard
//! apply windows, and the phase discipline becomes per-shard instead of
//! per-world:
//!
//! ```text
//!  worker d:  … mb t-1 … ──▶ ADMIT(t): wait min_applies ≥ t-k ──▶ mb t …
//!                                │ (re-pull params: versions ≥ t-k)
//!  server s:  ──────── quorum(mb t-1) ──▶ fold ▶ apply ▶ publish ────▶
//!                       (shard s WRITTEN under its own gate,
//!                        while workers run mb t, t+1, … t+k)
//! ```
//!
//! * **the write lock moves into the shard**: each shard-server daemon
//!   applies the optimizer under its per-shard gate the moment its
//!   minibatch quorum lands ([`super::backend::ParamStore::shard_write`]),
//!   so "params READ-ONLY during the microbatch phase" narrows to
//!   "params of shard *s* are stable between *s*'s applies" — which is
//!   why the minibatch-scoped [`super::gather_cache::GatherCache`] must
//!   be invalidated per admission, not per `end_step`;
//! * **staleness is bounded at admission, not delivery**: a worker
//!   enters minibatch `t` only after every shard has applied minibatch
//!   `t - k` ([`super::backend::ParamStore::wait_min_applies`]), so no
//!   gather can observe parameters more than `k` applies old, under any
//!   schedule;
//! * **`k = 0` IS the synchronous timeline**: admission then waits for
//!   all applies of `t - 1`, which reproduces the global optimizer
//!   phase exactly — same fold order (sorted (micro, client) per
//!   layer), same bytes (`tests/async_prop.rs` pins bit-identity across
//!   transports);
//! * **composition narrows**: the fault/wire sub-structures above slot
//!   in unchanged (the tier is mailbox traffic like any other), but
//!   elastic membership and fault-plan escalation are rejected at
//!   config time — both assume the global barrier the tier removes (see
//!   `docs/asyncps.md` and `RunSpec::validate`).
//!
//! Violating the discipline is a logic bug in the coordinator, not in
//! this substrate — mirroring how real RDMA gives you no protection
//! either. The engine's integration tests (engine vs single-device
//! oracle, Collective vs ODC equivalence, cached-vs-uncached gather
//! bit-equality, the `chaos_prop` lossy-transport soak) are the guard.

use std::cell::UnsafeCell;

/// One-sided shared window of f32s (RDMA-region analogue).
pub struct SharedBuf {
    data: UnsafeCell<Box<[f32]>>,
}

// SAFETY: concurrent access is governed by the phase discipline described
// in the module docs; all actual loads/stores go through raw pointers in
// `read`/`write` and never create overlapping &mut.
unsafe impl Sync for SharedBuf {}
unsafe impl Send for SharedBuf {}

impl SharedBuf {
    pub fn new(len: usize) -> Self {
        SharedBuf { data: UnsafeCell::new(vec![0.0; len].into_boxed_slice()) }
    }

    pub fn len(&self) -> usize {
        unsafe { (&*self.data.get()).len() }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One-sided read: copy `out.len()` values starting at `offset`.
    #[inline]
    pub fn read(&self, offset: usize, out: &mut [f32]) {
        let src = unsafe { &*self.data.get() };
        assert!(offset + out.len() <= src.len(), "read out of window");
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr().add(offset), out.as_mut_ptr(), out.len());
        }
    }

    /// One-sided write: copy `data` into the window at `offset`.
    #[inline]
    pub fn write(&self, offset: usize, data: &[f32]) {
        let dst = unsafe { &mut *self.data.get() };
        assert!(offset + data.len() <= dst.len(), "write out of window");
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dst.as_mut_ptr().add(offset), data.len());
        }
    }

    /// Accumulate `data * weight` into the window (server-side daemon
    /// op), through the shared FastFold kernel ([`super::fold::axpy`])
    /// so every accumulate site in the system vectorizes identically.
    #[inline]
    pub fn accumulate(&self, offset: usize, data: &[f32], weight: f32) {
        let dst = unsafe { &mut *self.data.get() };
        assert!(offset + data.len() <= dst.len(), "accumulate out of window");
        super::fold::axpy(&mut dst[offset..offset + data.len()], data, weight);
    }

    /// Zero a range (grad reset at minibatch boundary).
    pub fn clear(&self, offset: usize, len: usize) {
        let dst = unsafe { &mut *self.data.get() };
        dst[offset..offset + len].fill(0.0);
    }
}

/// A flat layer parameter vector sharded across `world` devices
/// (FSDP's flat-parameter + shard layout). The stored buffer is padded
/// so every device owns an equal-length shard.
pub struct ShardedParam {
    pub buf: SharedBuf,
    pub logical_len: usize,
    pub shard_len: usize,
    pub world: usize,
}

impl ShardedParam {
    pub fn new(logical_len: usize, world: usize) -> Self {
        let shard_len = logical_len.div_ceil(world);
        ShardedParam {
            buf: SharedBuf::new(shard_len * world),
            logical_len,
            shard_len,
            world,
        }
    }

    pub fn padded_len(&self) -> usize {
        self.shard_len * self.world
    }

    /// Padded index range owned by device `dev`.
    pub fn shard_range(&self, dev: usize) -> std::ops::Range<usize> {
        let lo = dev * self.shard_len;
        lo..lo + self.shard_len
    }

    /// Initialize from a logical (unpadded) vector.
    pub fn init_from(&self, values: &[f32]) {
        assert_eq!(values.len(), self.logical_len);
        self.buf.write(0, values);
        if self.padded_len() > self.logical_len {
            self.buf.clear(self.logical_len, self.padded_len() - self.logical_len);
        }
    }

    /// Read the full logical vector (gather target).
    pub fn read_logical(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.logical_len);
        self.buf.read(0, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn read_write_roundtrip() {
        let b = SharedBuf::new(16);
        b.write(4, &[1.0, 2.0, 3.0]);
        let mut out = [0.0; 3];
        b.read(4, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn accumulate_adds_weighted() {
        let b = SharedBuf::new(4);
        b.write(0, &[1.0, 1.0, 1.0, 1.0]);
        b.accumulate(0, &[2.0, 4.0, 6.0, 8.0], 0.5);
        let mut out = [0.0; 4];
        b.read(0, &mut out);
        assert_eq!(out, [2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "read out of window")]
    fn read_bounds_checked() {
        let b = SharedBuf::new(4);
        let mut out = [0.0; 3];
        b.read(2, &mut out);
    }

    #[test]
    fn sharded_param_padding() {
        let p = ShardedParam::new(10, 4);
        assert_eq!(p.shard_len, 3);
        assert_eq!(p.padded_len(), 12);
        assert_eq!(p.shard_range(0), 0..3);
        assert_eq!(p.shard_range(3), 9..12);
        let vals: Vec<f32> = (0..10).map(|i| i as f32).collect();
        p.init_from(&vals);
        let mut out = vec![0.0; 10];
        p.read_logical(&mut out);
        assert_eq!(out, vals);
        // padding is zeroed
        let mut pad = [9.9; 2];
        p.buf.read(10, &mut pad);
        assert_eq!(pad, [0.0, 0.0]);
    }

    #[test]
    fn concurrent_disjoint_writes() {
        // Phase discipline: disjoint shard writes from multiple threads.
        let p = Arc::new(ShardedParam::new(64, 4));
        std::thread::scope(|s| {
            for dev in 0..4 {
                let p = Arc::clone(&p);
                s.spawn(move || {
                    let r = p.shard_range(dev);
                    let vals = vec![dev as f32 + 1.0; r.len()];
                    p.buf.write(r.start, &vals);
                });
            }
        });
        let mut out = vec![0.0; 64];
        p.read_logical(&mut out);
        for dev in 0..4 {
            for i in p.shard_range(dev) {
                assert_eq!(out[i], dev as f32 + 1.0);
            }
        }
    }
}
