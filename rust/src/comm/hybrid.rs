//! Hybrid two-level backend — the paper's §6.1 hybrid sharding
//! (ZeRO++-style) landed in the REAL engine, not just the simulator.
//!
//! Layout: params/grads are sharded **within a node group** while
//! optimizer-state ownership stays sharded **across all devices**:
//!
//! * Every group (the [`GroupMap`]'s analogue of a node) holds a full
//!   **replica** of each layer, laid out identically to the global
//!   [`ParamStore`] and divided into `group_size` contiguous
//!   *super-shards*; member `j` of every group owns super-shard `j`.
//!   Because `world % group_size == 0`, super-shard `j` covers exactly
//!   the global optimizer shards of devices
//!   `j*n_groups .. (j+1)*n_groups` — intra- and cross-level ranges
//!   align with no re-slicing.
//! * The global `ParamStore` keeps its usual `world`-way sharding: it is
//!   the **optimizer level**. Device `d` owns global shard `d` exactly
//!   as under ODC/Collective, so the trainer's sharded-AdamW epilogue is
//!   unchanged.
//!
//! Protocol (two levels, cross-group synchronization ONLY at
//! `end_minibatch`/`end_step`):
//!
//! * `gather_params` — a one-sided **intra-group** read of the group's
//!   replica. Never leaves the node, which is the entire point of hybrid
//!   sharding (the NVSwitch/NIC bandwidth asymmetry). One-sided +
//!   phase-immutable ⇒ cacheable per minibatch
//!   ([`GatherPolicy::TwoLevelIntra`]).
//! * `reduce_grad` — intra-group scatter-accumulate: the client splits
//!   its full-layer gradient into `group_size` super-shards and pushes
//!   each piece to the owning group member's mailbox (per-(server,
//!   group-local-client) [`ArenaMatrix`] arenas keep the path
//!   allocation-free and uncontended). No barrier ⇒ group members may
//!   run *different microbatch counts* (LB-Mini stays legal).
//! * `end_minibatch` — two epilogues. **Intra**: the client broadcasts
//!   `IntraDone` to its group and flushes its own daemon, obtaining the
//!   group-partial super-shard (the node-level reduce-scatter).
//!   **Cross**: it slices that super-shard into global optimizer shards
//!   and pushes each piece to its owner's mailbox — ODC-style one-sided
//!   pushes over the (owner, group) arena matrix; the owner's daemon
//!   folds one piece per group per layer. This is the only inter-node
//!   gradient traffic: `param_bytes/group_size` per device instead of
//!   ODC's `(world-group_size)·shard` per *microbatch*.
//! * `end_step` — global barrier (optimizer shards republished), then
//!   each member refreshes its super-shard of its group's replica from
//!   the global store (the cross-node param all-gather the simulator's
//!   `hybrid_step_overhead` prices), then a second barrier so nobody
//!   gathers a half-fresh replica.
//!
//! ## Determinism
//!
//! Both hybrid daemons buffer payloads and fold them at flush time in a
//! **fixed order**: intra pieces by (global microbatch id asc,
//! group-local client asc) — the dispatch layer's canonical plan order
//! ([`crate::balance::dispatch`]), a pure function of the plan that no
//! placement or timing can perturb — and cross pieces by group asc.
//! With a single group the id order is exactly the flattened plan order,
//! so a single-group hybrid run is **bit-identical** to the
//! single-device oracle (asserted by `tests/engine_equivalence.rs`) —
//! under static AND work-queue dispatch, including skewed device speeds.
//! Multi-group runs are deterministic across repetitions under STATIC
//! dispatch (each group's partial is a fold from zero, so only the
//! cross-level bracketing differs from the oracle's sequential fold —
//! float noise bounded by the usual equivalence tolerance). Under
//! work-queue dispatch with multiple groups, WHICH group computes a
//! microbatch's partial is decided by runtime pull timing, so the
//! cross-level bracketing is placement-dependent: still exact as a sum
//! and within the equivalence tolerance, but NOT bit-reproducible
//! across runs — the one Queue combination where timing can move
//! low-order bits (see the legality notes in `balance`'s module docs).
//!
//! ## Elastic membership
//!
//! Under a non-static [`Membership`] schedule the recovery rules of
//! [`super::membership`] apply at BOTH levels: the intra fold quorum is
//! the group's live member count, a dead/dormant member's intra flush +
//! cross pushes + replica-refresh slice are driven by its in-group
//! rendezvous driver ([`Membership::driven_by`]), its global optimizer
//! shard is adopted by the global ring successor via
//! [`CommBackend::flush_shard`], and the `end_step` barrier pair
//! follows the live quorum. Every group must keep one completing
//! member per step ([`Membership::validate_groups`]).
//!
//! Buffering-until-flush is a deliberate memory-for-exactness trade:
//! eager per-client partial accumulators would cap memory at
//! O(group_size × layers) but change the float bracketing across
//! clients (`(P0+P1)` instead of the sequential `((g00+g01)+g10)+g11`),
//! forfeiting oracle bit-identity. In-flight payloads per pair stay
//! bounded by one minibatch's pushes — the same bound the ODC arenas
//! already live with — and the arenas stop growing after warm-up
//! (asserted under adversarial skew in `comm_stress`).

use super::arena::{ArenaMatrix, ArenaStats, PayloadArena};
use super::backend::{seq_micro_key, CommBackend, GatherPolicy, HotpathStats, ParamStore};
use super::fold::{self, FoldPiece, PieceData, WireDtype};
use super::membership::{Membership, MembershipBarrier};
use super::shared::SharedBuf;
use super::topology::GroupMap;
use super::ring::RingTransport;
use super::socket::SocketTransport;
use super::transport::{
    frame, FaultPlan, FaultStats, FaultyTransport, InProcTransport, RetryPolicy, SendError,
    Transport, TransportKind, WireCodec, WireMsg,
};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone)]
enum Msg {
    /// One super-shard gradient piece for this server's intra-group
    /// shard of `layer`, pushed by group-local `client` for global
    /// microbatch `micro` (the fold key); `data` is the ENCODED wire
    /// image (the backend's [`WireDtype`]) and returns to the (server,
    /// client) intra arena once folded.
    IntraAccum { layer: usize, micro: u64, weight: f32, client: usize, data: Vec<u8> },
    /// A group member (global device id `client`) has finished every
    /// microbatch of the minibatch. The id lets the daemon count the
    /// intra quorum per sender, ignoring a stray Done from a member the
    /// membership schedule says does not complete this minibatch (e.g.
    /// one that escalated a dead link mid-broadcast).
    IntraDone { client: usize },
    /// Crash-out compensation: group-local `client` escalated before
    /// delivering `micro` to every super-shard owner, so the landed
    /// pieces must be discarded — the dispatch layer re-runs the whole
    /// microbatch on a survivor (all-or-nothing per microbatch).
    IntraRetract { micro: u64, client: usize },
    /// One super-shard piece of a SEQUENCE CHUNK (SeqSplit): chunk
    /// `chunk` of `count`, cut from parent sample `seq`. Buffered apart
    /// from the micro pieces; the intra fold partially reduces each
    /// sequence's chunks in chunk-index order FIRST and feeds the result
    /// into the id-keyed fold under `seq_micro_key(seq)`. Chunks whose
    /// devices sit in DIFFERENT groups meet at the cross level instead
    /// — group partials sum linearly, so the total is exact either way.
    IntraSeqAccum { layer: usize, seq: u64, chunk: u32, count: u32, weight: f32, client: usize, data: Vec<u8> },
    /// SeqSplit arm of the crash-out compensation: discard the buffered
    /// piece of chunk (`seq`, `chunk`) from group-local `client`.
    IntraSeqRetract { seq: u64, chunk: u32, client: usize },
    /// The colocated worker asks for the group-partial super-shards; the
    /// daemon replies once all `group_size` members are done.
    IntraFlush { reply: mpsc::Sender<Vec<Vec<f32>>> },
    /// `group`'s partial sum over this owner's global optimizer shard of
    /// `layer`, encoded under the backend's [`WireDtype`]; `data` returns
    /// to the (owner, group) cross arena.
    CrossAccum { layer: usize, group: usize, data: Vec<u8> },
    /// A group's covering member has pushed all its pieces to this owner.
    CrossDone,
    /// The colocated worker asks for the fully-reduced optimizer shards;
    /// the daemon replies once all `n_groups` groups delivered.
    CrossFlush { reply: mpsc::Sender<Vec<Vec<f32>>> },
    Shutdown,
}

impl WireMsg for Msg {
    /// Everything except the two gradient payloads is control plane:
    /// Done/Retract/Flush/Shutdown are never held back for reordering or
    /// delay and flush a link's limbo ahead of themselves, so a
    /// minibatch's in-flight pieces always land before the rendezvous
    /// that folds them (and a retract always lands after the piece it
    /// cancels — per-link FIFO).
    fn is_barrier(&self) -> bool {
        !matches!(
            self,
            Msg::IntraAccum { .. } | Msg::IntraSeqAccum { .. } | Msg::CrossAccum { .. }
        )
    }

    fn payload_bytes(&self) -> usize {
        // payloads are already encoded wire bytes, so their length IS
        // the priced volume — bf16 halves it automatically
        match self {
            Msg::IntraAccum { data, .. }
            | Msg::IntraSeqAccum { data, .. }
            | Msg::CrossAccum { data, .. } => data.len(),
            _ => 0,
        }
    }
}

impl WireCodec for Msg {
    fn encode(&self, out: &mut Vec<u8>) -> bool {
        match self {
            Msg::IntraAccum { layer, micro, weight, client, data } => {
                out.push(0);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *micro);
                frame::put_f32(out, *weight);
                frame::put_u64(out, *client as u64);
                frame::put_bytes(out, data);
            }
            Msg::IntraDone { client } => {
                out.push(1);
                frame::put_u64(out, *client as u64);
            }
            Msg::IntraRetract { micro, client } => {
                out.push(2);
                frame::put_u64(out, *micro);
                frame::put_u64(out, *client as u64);
            }
            Msg::IntraSeqAccum { layer, seq, chunk, count, weight, client, data } => {
                out.push(3);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *seq);
                frame::put_u32(out, *chunk);
                frame::put_u32(out, *count);
                frame::put_f32(out, *weight);
                frame::put_u64(out, *client as u64);
                frame::put_bytes(out, data);
            }
            Msg::IntraSeqRetract { seq, chunk, client } => {
                out.push(4);
                frame::put_u64(out, *seq);
                frame::put_u32(out, *chunk);
                frame::put_u64(out, *client as u64);
            }
            Msg::CrossAccum { layer, group, data } => {
                out.push(5);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *group as u64);
                frame::put_bytes(out, data);
            }
            Msg::CrossDone => out.push(6),
            // the two Flush variants carry mpsc reply channels — a
            // process-local rendezvous on a self-link by construction;
            // they ride the transport's ticketed local lane
            Msg::IntraFlush { .. } | Msg::CrossFlush { .. } => return false,
            Msg::Shutdown => out.push(7),
        }
        true
    }

    fn decode(bytes: &[u8]) -> Option<Msg> {
        let mut r = frame::Reader::new(bytes.get(1..)?);
        let msg = match bytes.first()? {
            0 => Msg::IntraAccum {
                layer: r.u64()? as usize,
                micro: r.u64()?,
                weight: r.f32()?,
                client: r.u64()? as usize,
                data: r.bytes()?,
            },
            1 => Msg::IntraDone { client: r.u64()? as usize },
            2 => Msg::IntraRetract { micro: r.u64()?, client: r.u64()? as usize },
            3 => Msg::IntraSeqAccum {
                layer: r.u64()? as usize,
                seq: r.u64()?,
                chunk: r.u32()?,
                count: r.u32()?,
                weight: r.f32()?,
                client: r.u64()? as usize,
                data: r.bytes()?,
            },
            4 => Msg::IntraSeqRetract { seq: r.u64()?, chunk: r.u32()?, client: r.u64()? as usize },
            5 => Msg::CrossAccum {
                layer: r.u64()? as usize,
                group: r.u64()? as usize,
                data: r.bytes()?,
            },
            6 => Msg::CrossDone,
            7 => Msg::Shutdown,
            _ => return None,
        };
        if !r.done() {
            return None;
        }
        Some(msg)
    }
}

/// A buffered intra piece's payload: the encoded wire image as pushed
/// (returns to its pusher's arena after the fold), or an already-decoded
/// f32 partial reconstituted by the SeqSplit rendezvous (plain heap —
/// dropped after the fold).
enum Payload {
    Wire(Vec<u8>),
    Folded(Vec<f32>),
}

impl Payload {
    /// Borrow as a fold input under the backend's wire encoding.
    fn piece_data(&self, wire: WireDtype) -> PieceData<'_> {
        match self {
            Payload::Wire(b) => PieceData::Wire(b, wire),
            Payload::Folded(v) => PieceData::F32(v),
        }
    }
}

/// One buffered intra-level piece awaiting the id-keyed group fold.
struct IntraPiece {
    micro: u64,
    client: usize,
    weight: f32,
    data: Payload,
}

/// One buffered intra-level SEQUENCE-CHUNK piece (SeqSplit) awaiting its
/// per-sequence rendezvous at the intra fold.
struct SeqPiece {
    seq: u64,
    chunk: u32,
    count: u32,
    client: usize,
    weight: f32,
    data: Vec<u8>,
}

/// SeqSplit's intra-level per-sequence rendezvous, mirroring the ODC
/// fold exactly: sort by (seq, chunk, client), fold each sequence's
/// chunks into a fresh f32 accumulator in chunk-index order (decode
/// fused into the accumulate; every chunk's wire payload returns to its
/// pusher's arena immediately), and hand each reconstituted sequence
/// back as an ordinary [`IntraPiece`] keyed `seq_micro_key(seq)` with
/// weight 1. Chunks of a sequence that ran in another group are folded
/// by THAT group's daemons; the partials meet at the cross level, where
/// group sums add linearly — exact as a sum, and bit-identical whenever
/// all chunks share a group (in particular the single-group oracle
/// case).
fn fold_seq_layer(
    seqs: &mut Vec<SeqPiece>,
    len: usize,
    arenas: &[Arc<PayloadArena>],
    wire: WireDtype,
) -> Vec<IntraPiece> {
    seqs.sort_by_key(|p| (p.seq, p.chunk, p.client));
    let mut out: Vec<IntraPiece> = Vec::new();
    for p in seqs.drain(..) {
        let key = seq_micro_key(p.seq);
        if !matches!(out.last(), Some(last) if last.micro == key) {
            debug_assert!(p.count >= 2);
            out.push(IntraPiece {
                micro: key,
                client: p.client,
                weight: 1.0,
                data: Payload::Folded(vec![0.0; len]),
            });
        }
        let last = out.last_mut().expect("accumulator just ensured");
        let acc = match &mut last.data {
            Payload::Folded(v) => v,
            Payload::Wire(_) => unreachable!("seq accumulators are always Folded"),
        };
        let piece = FoldPiece { weight: p.weight, data: PieceData::Wire(&p.data, wire) };
        fold::fold_pieces(acc, std::slice::from_ref(&piece), 1);
        arenas[p.client].release(p.data);
    }
    out
}

/// Per-daemon mutable state: buffered payloads of the minibatch in
/// flight, plus completion counters for both levels.
struct DaemonState {
    group_size: usize,
    n_groups: usize,
    /// Elastic schedule: the intra fold's quorum is the group's live
    /// member count at the daemon's own minibatch index.
    membership: Arc<Membership>,
    /// First global device id of this daemon's node group.
    group_start: usize,
    /// This daemon's minibatch index (increments at each intra fold).
    intra_mb: usize,
    /// Intra super-shard length per layer (`padded_len / group_size`).
    super_lens: Vec<usize>,
    /// Global optimizer shard length per layer.
    shard_lens: Vec<usize>,
    /// `[layer]` → buffered pieces, folded id-keyed at the flush.
    pending_intra: Vec<Vec<IntraPiece>>,
    /// `[layer]` → buffered SeqSplit chunk pieces, rendezvoused
    /// per-sequence at the intra fold before the id-keyed fold runs.
    pending_seq: Vec<Vec<SeqPiece>>,
    intra_done: usize,
    intra_flush: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    /// `[layer][group]` → exactly one encoded partial per minibatch.
    pending_cross: Vec<Vec<Option<Vec<u8>>>>,
    cross_done: usize,
    cross_flush: Option<mpsc::Sender<Vec<Vec<f32>>>>,
    /// Payload element encoding on the wire (FastFold).
    wire: WireDtype,
    /// Worker count for the chunk-parallel flush folds.
    fold_threads: usize,
}

impl DaemonState {
    #[allow(clippy::too_many_arguments)]
    fn new(
        super_lens: Vec<usize>,
        shard_lens: Vec<usize>,
        membership: Arc<Membership>,
        group_start: usize,
        group_size: usize,
        n_groups: usize,
        wire: WireDtype,
        fold_threads: usize,
    ) -> Self {
        let n_layers = super_lens.len();
        DaemonState {
            group_size,
            n_groups,
            membership,
            group_start,
            intra_mb: 0,
            pending_intra: (0..n_layers).map(|_| Vec::new()).collect(),
            pending_seq: (0..n_layers).map(|_| Vec::new()).collect(),
            pending_cross: (0..n_layers).map(|_| vec![None; n_groups]).collect(),
            super_lens,
            shard_lens,
            intra_done: 0,
            intra_flush: None,
            cross_done: 0,
            cross_flush: None,
            wire,
            fold_threads,
        }
    }

    /// Intra fold quorum for the current minibatch: group members that
    /// complete it (a member crashing mid-minibatch, or not yet joined,
    /// never sends `IntraDone` and is not waited for).
    fn expected_intra(&self) -> usize {
        self.membership
            .expected_done_among(self.group_start..self.group_start + self.group_size, self.intra_mb)
    }

    /// Fold the intra-level pieces in (global microbatch id asc, client
    /// asc) order — the canonical plan order, deterministic regardless
    /// of arrival interleaving AND of which device ran which microbatch
    /// — returning one group-partial super-shard per layer and releasing
    /// every payload to its (server, client) arena. Stable sort: a
    /// same-key tie can only come from one client's sequential pushes,
    /// whose channel-FIFO order is preserved.
    fn fold_intra(&mut self, arenas: &[Arc<PayloadArena>]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.super_lens.len());
        for (layer, &len) in self.super_lens.iter().enumerate() {
            // SeqSplit rendezvous first: reconstituted sequence partials
            // join the id-keyed fold under their synthetic keys.
            let folded = fold_seq_layer(&mut self.pending_seq[layer], len, arenas, self.wire);
            self.pending_intra[layer].extend(folded);
            let pieces = &mut self.pending_intra[layer];
            pieces.sort_by_key(|p| (p.micro, p.client));
            let mut acc = vec![0.0f32; len];
            let inputs: Vec<FoldPiece> = pieces
                .iter()
                .map(|p| FoldPiece { weight: p.weight, data: p.data.piece_data(self.wire) })
                .collect();
            fold::fold_pieces(&mut acc, &inputs, self.fold_threads);
            drop(inputs);
            for p in pieces.drain(..) {
                if let Payload::Wire(b) = p.data {
                    arenas[p.client].release(b);
                }
            }
            out.push(acc);
        }
        out
    }

    /// Fold the cross-level partials in group order — the fixed
    /// cross-level bracketing, chunk-parallel with per-element order
    /// identical to the scalar pass — returning the fully-reduced
    /// optimizer shard per layer.
    fn fold_cross(&mut self, arenas: &[Arc<PayloadArena>]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.shard_lens.len());
        for (layer, &len) in self.shard_lens.iter().enumerate() {
            let mut acc = vec![0.0f32; len];
            let taken: Vec<Vec<u8>> = (0..self.n_groups)
                .map(|group| {
                    self.pending_cross[layer][group]
                        .take()
                        .expect("every group delivers exactly one partial per layer")
                })
                .collect();
            let inputs: Vec<FoldPiece> = taken
                .iter()
                .map(|data| FoldPiece { weight: 1.0, data: PieceData::Wire(data, self.wire) })
                .collect();
            fold::fold_pieces(&mut acc, &inputs, self.fold_threads);
            drop(inputs);
            for (group, data) in taken.into_iter().enumerate() {
                arenas[group].release(data);
            }
            out.push(acc);
        }
        out
    }
}

/// The two-level accumulation daemon: one per device, serving both the
/// intra-group scatter-accumulate and the cross-group epilogue for the
/// shards this device owns at each level.
fn daemon_loop(
    me: usize,
    transport: Arc<dyn Transport<Msg>>,
    mut st: DaemonState,
    intra_arenas: Vec<Arc<PayloadArena>>,
    cross_arenas: Vec<Arc<PayloadArena>>,
    fold_ns: Arc<AtomicU64>,
) {
    loop {
        let msg = match transport.recv(me) {
            Some(env) => env.msg,
            None => return,
        };
        match msg {
            Msg::IntraAccum { layer, micro, weight, client, data } => {
                // Idempotence belt-and-braces on top of the transport's
                // seq dedup: the fold key (micro, client) is unique per
                // layer per minibatch, so a duplicate is free to drop.
                if st.pending_intra[layer].iter().any(|p| p.micro == micro && p.client == client) {
                    intra_arenas[client].release(data);
                } else {
                    st.pending_intra[layer]
                        .push(IntraPiece { micro, client, weight, data: Payload::Wire(data) });
                }
            }
            Msg::IntraDone { client } => {
                // Count only members the schedule says complete this
                // minibatch — a stray Done from an escalated member must
                // not push the counter past the quorum equality check.
                if st.membership.completes(client, st.intra_mb) {
                    st.intra_done += 1;
                }
            }
            Msg::IntraSeqAccum { layer, seq, chunk, count, weight, client, data } => {
                // idempotent like IntraAccum: (seq, chunk, client) unique
                if st.pending_seq[layer]
                    .iter()
                    .any(|p| p.seq == seq && p.chunk == chunk && p.client == client)
                {
                    intra_arenas[client].release(data);
                } else {
                    st.pending_seq[layer].push(SeqPiece { seq, chunk, count, client, weight, data });
                }
            }
            Msg::IntraRetract { micro, client } => {
                for layer in 0..st.pending_intra.len() {
                    if let Some(i) = st.pending_intra[layer]
                        .iter()
                        .position(|p| p.micro == micro && p.client == client)
                    {
                        let p = st.pending_intra[layer].swap_remove(i);
                        if let Payload::Wire(b) = p.data {
                            intra_arenas[p.client].release(b);
                        }
                    }
                }
            }
            Msg::IntraSeqRetract { seq, chunk, client } => {
                for layer in 0..st.pending_seq.len() {
                    if let Some(i) = st.pending_seq[layer]
                        .iter()
                        .position(|p| p.seq == seq && p.chunk == chunk && p.client == client)
                    {
                        let p = st.pending_seq[layer].swap_remove(i);
                        intra_arenas[p.client].release(p.data);
                    }
                }
            }
            Msg::IntraFlush { reply } => st.intra_flush = Some(reply),
            Msg::CrossAccum { layer, group, data } => {
                // Exactly one partial per (layer, group): a duplicate is
                // discarded, its payload returned to the cross arena.
                if st.pending_cross[layer][group].is_some() {
                    cross_arenas[group].release(data);
                } else {
                    st.pending_cross[layer][group] = Some(data);
                }
            }
            Msg::CrossDone => st.cross_done += 1,
            Msg::CrossFlush { reply } => st.cross_flush = Some(reply),
            Msg::Shutdown => return,
        }
        if st.intra_done == st.expected_intra() {
            if let Some(reply) = st.intra_flush.take() {
                let t0 = Instant::now();
                let out = st.fold_intra(&intra_arenas);
                fold_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                // A group member that crashed during this minibatch has
                // pushed its last piece: release its arena column.
                for (local, arena) in intra_arenas.iter().enumerate() {
                    if st.membership.fails_during(st.group_start + local, st.intra_mb) {
                        arena.retire();
                    }
                }
                st.intra_done = 0;
                st.intra_mb += 1;
                let _ = reply.send(out);
            }
        }
        if st.cross_done == st.n_groups {
            if let Some(reply) = st.cross_flush.take() {
                let t0 = Instant::now();
                let out = st.fold_cross(&cross_arenas);
                fold_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                st.cross_done = 0;
                let _ = reply.send(out);
            }
        }
    }
}

pub struct HybridComm {
    world: usize,
    groups: GroupMap,
    params: Arc<ParamStore>,
    /// Per-group full-model replicas, `replicas[group][layer]`, each in
    /// the global padded layout.
    replicas: Vec<Vec<SharedBuf>>,
    /// The typed envelope transport carrying every mailbox message for
    /// both levels ([`crate::comm::transport`]): reliable in-process by
    /// default, or a seeded [`FaultyTransport`] under a fault plan.
    transport: Arc<dyn Transport<Msg>>,
    /// Fully-reduced optimizer shards returned at the minibatch boundary
    /// (written by the owner, or by a rendezvous successor's
    /// `flush_shard` for an orphaned shard).
    taken: Vec<Mutex<Option<Vec<Vec<f32>>>>>,
    barrier: MembershipBarrier,
    membership: Arc<Membership>,
    /// Per-device current step (advanced at `end_step`; a joiner fast-
    /// forwards in `await_join`) — selects the membership row that
    /// decides whose group-level epilogue duties this device drives.
    step_ctr: Vec<AtomicUsize>,
    daemons: Mutex<Vec<JoinHandle<()>>>,
    /// Intra-level arenas indexed `[server][group-local client]`.
    intra_arenas: ArenaMatrix,
    /// Cross-level arenas indexed `[owner][group]`.
    cross_arenas: ArenaMatrix,
    /// Per-device scratch for the end_step replica refresh (sized to the
    /// largest super-shard; steady-state allocation-free).
    refresh_scratch: Vec<Mutex<Vec<f32>>>,
    /// Set when a device's retry budget on some link is exhausted
    /// ([`SendError::Unreachable`]): the device must crash out through
    /// the trainer's elastic path instead of wedging a rendezvous.
    escalated: Vec<AtomicBool>,
    /// Payload element encoding on the wire (FastFold).
    wire: WireDtype,
    /// Intra-level error-feedback residuals, `[dev][layer]`, the layer's
    /// full padded length (sliced per super-shard at the push). Empty
    /// under `F32`.
    intra_residuals: Vec<Vec<Mutex<Vec<f32>>>>,
    /// Cross-level error-feedback residuals, `[dev][layer]`, one
    /// super-shard length — keyed by the super-shard's OWNING member
    /// (group, j), so a rendezvous driver pushing on a dead member's
    /// behalf continues that member's residual stream. Empty under
    /// `F32`.
    cross_residuals: Vec<Vec<Mutex<Vec<f32>>>>,
    /// Total encoded gradient bytes pushed (intra + seq + cross).
    wire_bytes: Arc<AtomicU64>,
    /// Total nanoseconds the daemons spent in flush folds.
    fold_ns: Arc<AtomicU64>,
}

impl HybridComm {
    /// Two-level backend over `world` devices in groups of `group_size`.
    /// Requires `world % group_size == 0` (validate with
    /// [`GroupMap`]-style checks first when driven from config) and a
    /// `ParamStore` whose parameters are already initialized — the group
    /// replicas are seeded from it here.
    pub(crate) fn new(params: Arc<ParamStore>, world: usize, group_size: usize) -> Self {
        HybridComm::with_membership(params, Arc::new(Membership::all_live(world)), group_size)
    }

    /// Two-level backend over an elastic membership schedule (see
    /// [`crate::comm::membership`]): intra-group fold quorums, the
    /// step barrier pair, epilogue driving for dead/dormant members and
    /// replica-refresh adoption all follow the schedule. Requires every
    /// group to keep a completing member at every step
    /// ([`Membership::validate_groups`] — the trainer checks). With a
    /// static schedule this is exactly [`HybridComm::new`].
    pub(crate) fn with_membership(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
    ) -> Self {
        HybridComm::with_wire(params, membership, group_size, WireDtype::F32)
    }

    /// Two-level backend with a configured wire encoding: `F32` keeps
    /// every fold bit-identical; `Bf16` halves pushed bytes at both
    /// levels with per-stream error feedback (see
    /// `docs/wire_precision.md`).
    pub(crate) fn with_wire(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        HybridComm::with_transport(
            params,
            membership,
            group_size,
            Arc::new(InProcTransport::new(world)),
            wire,
        )
    }

    /// Hybrid over a lossy transport: both levels' mailbox traffic
    /// crosses a [`FaultyTransport`] driven by `plan`. Transient loss is
    /// absorbed by the retransmit ladder and receiver reassembly
    /// (bit-identity preserved); a link partitioned past the retry
    /// budget escalates into the elastic machinery (see
    /// [`CommBackend::link_escalated`]).
    pub(crate) fn with_faults(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
        plan: FaultPlan,
        policy: RetryPolicy,
    ) -> Self {
        HybridComm::with_faults_wire(params, membership, group_size, plan, policy, WireDtype::F32)
    }

    /// [`HybridComm::with_faults`] with a configured wire encoding — the
    /// retransmit ladder replays the SAME encoded payload, so fault
    /// tolerance and wire precision compose without interaction.
    pub(crate) fn with_faults_wire(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
        plan: FaultPlan,
        policy: RetryPolicy,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        HybridComm::with_transport(
            params,
            membership,
            group_size,
            Arc::new(FaultyTransport::new(world, plan, policy)),
            wire,
        )
    }

    /// Build the full transport stack from a [`TransportKind`]: the
    /// byte-moving base (`inproc` mailbox, `shm` ring, or `uds`
    /// sockets), optionally wrapped in the chaos layer — both levels'
    /// traffic crosses the same stack. This is the trainer's
    /// `--transport` entry point; ticket-sequenced delivery keeps the
    /// training bytes identical across all three bases under static
    /// dispatch (see `comm/ring.rs`).
    pub(crate) fn with_stack(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
        wire: WireDtype,
        kind: TransportKind,
        faults: Option<(FaultPlan, RetryPolicy)>,
    ) -> std::io::Result<Self> {
        let world = membership.world();
        let base: Arc<dyn Transport<Msg>> = match kind {
            TransportKind::Inproc => Arc::new(InProcTransport::new(world)),
            TransportKind::Shm => Arc::new(RingTransport::new(world)),
            TransportKind::Uds => Arc::new(SocketTransport::bind_world(world)?),
        };
        let transport: Arc<dyn Transport<Msg>> = match faults {
            Some((plan, policy)) => Arc::new(FaultyTransport::over(base, plan, policy)),
            None => base,
        };
        Ok(HybridComm::with_transport(params, membership, group_size, transport, wire))
    }

    fn with_transport(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        group_size: usize,
        transport: Arc<dyn Transport<Msg>>,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        let groups = GroupMap::new(world, group_size);
        let n_groups = groups.n_groups();
        let super_lens: Vec<usize> =
            params.layers.iter().map(|l| l.padded_len() / group_size).collect();
        let shard_lens: Vec<usize> = params.layers.iter().map(|l| l.shard_len).collect();

        // Arena capacities are ENCODED byte lengths: bf16 halves the
        // resident payload memory at both levels.
        let mut intra_caps: Vec<usize> = super_lens.iter().map(|&l| wire.bytes_for(l)).collect();
        intra_caps.push(intra_caps.iter().copied().max().unwrap_or(0));
        let intra_arenas = ArenaMatrix::new(world, group_size, &intra_caps);
        let mut cross_caps: Vec<usize> = shard_lens.iter().map(|&l| wire.bytes_for(l)).collect();
        cross_caps.push(cross_caps.iter().copied().max().unwrap_or(0));
        let cross_arenas = ArenaMatrix::new(world, n_groups, &cross_caps);

        // Seed every group's replica from the (initialized) global store.
        let replicas: Vec<Vec<SharedBuf>> = (0..n_groups)
            .map(|_| {
                params
                    .layers
                    .iter()
                    .map(|p| {
                        let buf = SharedBuf::new(p.padded_len());
                        let mut tmp = vec![0.0f32; p.padded_len()];
                        p.buf.read(0, &mut tmp);
                        buf.write(0, &tmp);
                        buf
                    })
                    .collect()
            })
            .collect();

        let max_super = super_lens.iter().copied().max().unwrap_or(0);
        let fold_threads = fold::default_fold_threads();
        let fold_ns = Arc::new(AtomicU64::new(0));
        let mut daemons = Vec::with_capacity(world);
        for dev in 0..world {
            let st = DaemonState::new(
                super_lens.clone(),
                shard_lens.clone(),
                Arc::clone(&membership),
                groups.group_of(dev) * group_size,
                group_size,
                n_groups,
                wire,
                fold_threads,
            );
            let intra_row = intra_arenas.row(dev);
            let cross_row = cross_arenas.row(dev);
            let link = Arc::clone(&transport);
            let ns = Arc::clone(&fold_ns);
            daemons.push(std::thread::spawn(move || {
                daemon_loop(dev, link, st, intra_row, cross_row, ns)
            }));
        }
        let intra_residuals = (0..world)
            .map(|_| {
                params
                    .layers
                    .iter()
                    .map(|l| {
                        Mutex::new(match wire {
                            WireDtype::F32 => Vec::new(),
                            WireDtype::Bf16 => vec![0.0; l.padded_len()],
                        })
                    })
                    .collect()
            })
            .collect();
        let cross_residuals = (0..world)
            .map(|_| {
                params
                    .layers
                    .iter()
                    .map(|l| {
                        Mutex::new(match wire {
                            WireDtype::F32 => Vec::new(),
                            WireDtype::Bf16 => vec![0.0; l.padded_len() / group_size],
                        })
                    })
                    .collect()
            })
            .collect();
        HybridComm {
            world,
            groups,
            params,
            replicas,
            transport,
            taken: (0..world).map(|_| Mutex::new(None)).collect(),
            barrier: MembershipBarrier::new(Arc::clone(&membership), 2),
            membership,
            step_ctr: (0..world).map(|_| AtomicUsize::new(0)).collect(),
            daemons: Mutex::new(daemons),
            intra_arenas,
            cross_arenas,
            refresh_scratch: (0..world).map(|_| Mutex::new(vec![0.0f32; max_super])).collect(),
            escalated: (0..world).map(|_| AtomicBool::new(false)).collect(),
            wire,
            intra_residuals,
            cross_residuals,
            wire_bytes: Arc::new(AtomicU64::new(0)),
            fold_ns,
        }
    }

    /// The cross-group epilogue for super-shard `j` of `group`: slice
    /// the group-partial into global optimizer shards and push each
    /// piece to its owner's mailbox, then notify the owners. Called by
    /// the member owning `j` — or, when that member is dead or not yet
    /// joined, by its in-group rendezvous driver on its behalf.
    fn cross_push(&self, src: usize, group: usize, j: usize, partial: &[Vec<f32>]) {
        let n_groups = self.groups.n_groups();
        // The residual stream is keyed by the super-shard's OWNING member
        // (group, j) — not the pusher — so a rendezvous driver continues
        // a dead member's stream instead of corrupting its own.
        let stream = self.groups.member(group, j);
        for (layer, p) in self.params.layers.iter().enumerate() {
            let k = p.shard_len;
            let mut residual = self.cross_residuals[stream][layer].lock().unwrap();
            for t in 0..n_groups {
                let owner = j * n_groups + t;
                let mut data =
                    self.cross_arenas.arena(owner, group).acquire(self.wire.bytes_for(k));
                let src_slice = &partial[layer][t * k..(t + 1) * k];
                match self.wire {
                    WireDtype::F32 => fold::encode(&mut data, src_slice, self.wire),
                    WireDtype::Bf16 => fold::encode_ef(
                        &mut data,
                        src_slice,
                        &mut residual[t * k..(t + 1) * k],
                        self.wire,
                    ),
                }
                self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
                self.send(src, owner, 0, Msg::CrossAccum { layer, group, data });
            }
        }
        for t in 0..n_groups {
            self.send(src, j * n_groups + t, 0, Msg::CrossDone);
        }
    }

    /// Fire-and-continue send: transient loss past the retry budget on a
    /// rendezvous path marks the sender escalated — the trainer crashes
    /// it out through the elastic machinery rather than wedging a fold.
    fn send(&self, src: usize, dst: usize, micro: u64, msg: Msg) {
        match self.transport.send(src, dst, micro, msg) {
            Ok(()) | Err(SendError::Lost { .. }) => {}
            Err(SendError::Unreachable) => self.escalated[src].store(true, Ordering::Relaxed),
        }
    }

    pub fn group_map(&self) -> GroupMap {
        self.groups
    }

    /// Summed intra-level (within-group scatter-accumulate) arena
    /// counters.
    pub fn intra_arena_stats(&self) -> ArenaStats {
        self.intra_arenas.stats()
    }

    /// Summed cross-level (optimizer-shard epilogue) arena counters.
    pub fn cross_arena_stats(&self) -> ArenaStats {
        self.cross_arenas.stats()
    }

    /// Both levels merged (the `OdcComm::arena_stats` analogue).
    pub fn arena_stats(&self) -> ArenaStats {
        let mut total = self.intra_arena_stats();
        total.merge(self.cross_arena_stats());
        total
    }
}

impl CommBackend for HybridComm {
    fn world(&self) -> usize {
        self.world
    }

    fn gather_params(&self, dev: usize, layer: usize, out: &mut [f32]) {
        // One-sided intra-group read of the group replica: phase
        // discipline makes the replica immutable during the microbatch
        // phase (it is only written inside end_step's barrier pair).
        // Under a lossy transport each member's super-shard read runs
        // the retransmit ladder (deadline + capped backoff, priced into
        // FaultStats); budget exhaustion marks the link escalated.
        let group = self.groups.group_of(dev);
        let s = self.params.layers[layer].padded_len() / self.groups.group_size;
        for j in 0..self.groups.group_size {
            let peer = self.groups.member(group, j);
            if self.transport.one_sided(dev, peer, self.wire.bytes_for(s)).is_err() {
                self.escalated[dev].store(true, Ordering::Relaxed);
            }
        }
        let buf = &self.replicas[group][layer];
        let n = buf.len().min(out.len());
        buf.read(0, &mut out[..n]);
    }

    fn gather_policy(&self) -> GatherPolicy {
        GatherPolicy::TwoLevelIntra
    }

    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32, micro: u64) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        if weight == 0.0 {
            return; // idle slot: nothing to send, nothing to wait for
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // crashing out: push nothing more, the trainer re-runs
        }
        let group = self.groups.group_of(dev);
        let me = self.groups.local_index(dev);
        let s = p.padded_len() / self.groups.group_size;
        let mut lost = false;
        let mut residual = self.intra_residuals[dev][layer].lock().unwrap();
        for j in 0..self.groups.group_size {
            let server = self.groups.member(group, j);
            let mut data = self.intra_arenas.arena(server, me).acquire(self.wire.bytes_for(s));
            let src = &grad[j * s..(j + 1) * s];
            match self.wire {
                WireDtype::F32 => fold::encode(&mut data, src, self.wire),
                WireDtype::Bf16 => {
                    fold::encode_ef(&mut data, src, &mut residual[j * s..(j + 1) * s], self.wire)
                }
            }
            self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
            let msg = Msg::IntraAccum { layer, micro, weight, client: me, data };
            if self.transport.send(dev, server, micro, msg).is_err() {
                lost = true;
            }
        }
        drop(residual);
        if lost {
            // All-or-nothing per microbatch: a piece is gone for good, so
            // retract every landed sibling (the retract is a barrier
            // message — per-link FIFO puts it after the piece it cancels)
            // and crash out; the dispatcher re-runs `micro` on a survivor
            // exactly once. flush_links first lands any still-held pieces
            // of COMPLETED microbatches so their folds stay whole.
            self.escalated[dev].store(true, Ordering::Relaxed);
            self.transport.flush_links(dev);
            for j in 0..self.groups.group_size {
                let server = self.groups.member(group, j);
                let _ = self
                    .transport
                    .send(dev, server, micro, Msg::IntraRetract { micro, client: me });
            }
        }
    }

    fn reduce_grad_seq(
        &self,
        dev: usize,
        layer: usize,
        grad: &[f32],
        weight: f32,
        seq: u64,
        chunk: u32,
        count: u32,
    ) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        if weight == 0.0 {
            return;
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // crashing out: push nothing more, the trainer re-runs
        }
        let group = self.groups.group_of(dev);
        let me = self.groups.local_index(dev);
        let s = p.padded_len() / self.groups.group_size;
        let mut lost = false;
        let mut residual = self.intra_residuals[dev][layer].lock().unwrap();
        for j in 0..self.groups.group_size {
            let server = self.groups.member(group, j);
            let mut data = self.intra_arenas.arena(server, me).acquire(self.wire.bytes_for(s));
            let src = &grad[j * s..(j + 1) * s];
            match self.wire {
                WireDtype::F32 => fold::encode(&mut data, src, self.wire),
                WireDtype::Bf16 => {
                    fold::encode_ef(&mut data, src, &mut residual[j * s..(j + 1) * s], self.wire)
                }
            }
            self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
            let msg = Msg::IntraSeqAccum { layer, seq, chunk, count, weight, client: me, data };
            if self.transport.send(dev, server, seq_micro_key(seq), msg).is_err() {
                lost = true;
            }
        }
        drop(residual);
        if lost {
            // all-or-nothing per chunk, mirroring `reduce_grad`
            self.escalated[dev].store(true, Ordering::Relaxed);
            self.transport.flush_links(dev);
            for j in 0..self.groups.group_size {
                let server = self.groups.member(group, j);
                let _ = self.transport.send(
                    dev,
                    server,
                    seq_micro_key(seq),
                    Msg::IntraSeqRetract { seq, chunk, client: me },
                );
            }
        }
    }

    fn end_minibatch(&self, dev: usize) {
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // crashing out: the trainer reports the failure next
        }
        let step = self.step_ctr[dev].load(Ordering::Relaxed);
        let group = self.groups.group_of(dev);
        let j = self.groups.local_index(dev);

        // ---- intra epilogue: node-level reduce-scatter completes ----
        for peer in self.groups.members(group) {
            self.send(dev, peer, 0, Msg::IntraDone { client: dev });
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            // Escalated mid-broadcast: bail before blocking on a flush
            // this device may no longer satisfy. Daemons ignore the
            // already-landed Dones through the quorum filter.
            return;
        }
        let (tx, rx) = mpsc::channel();
        self.send(dev, dev, 0, Msg::IntraFlush { reply: tx });
        let partial = rx.recv().expect("intra flush");

        // ---- cross epilogue: ship optimizer-shard pieces to owners ----
        // Super-shard j covers global owners j*n_groups..(j+1)*n_groups;
        // piece t of the super-shard is owner (j*n_groups + t)'s shard.
        self.cross_push(dev, group, j, &partial);

        // ---- drive dead/dormant group members' epilogues ----
        // Their daemons hold real group partials (every member's pushes
        // scatter to ALL the group's super-shards), but nobody is left
        // to flush them or ship the pieces: the in-group rendezvous
        // driver does, BEFORE blocking on its own cross flush — every
        // owner's cross quorum stays whole and nothing deadlocks.
        for m in self.membership.driven_by(dev, self.groups.members(group), step) {
            let (tx, rx) = mpsc::channel();
            self.send(dev, m, 0, Msg::IntraFlush { reply: tx });
            let pm = rx.recv().expect("driven intra flush");
            self.cross_push(dev, group, self.groups.local_index(m), &pm);
        }

        // ---- wait for every group's partial of MY optimizer shard ----
        let (tx, rx) = mpsc::channel();
        self.send(dev, dev, 0, Msg::CrossFlush { reply: tx });
        let grads = rx.recv().expect("cross flush");
        *self.taken[dev].lock().unwrap() = Some(grads);
    }

    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]) {
        let slot = self.taken[dev].lock().unwrap();
        let grads = slot.as_ref().expect("take_grad_shard before end_minibatch");
        out.copy_from_slice(&grads[layer]);
    }

    fn end_step(&self, dev: usize) {
        let step = self.step_ctr[dev].fetch_add(1, Ordering::Relaxed);
        let next = step + 1;
        // Barrier 1: every live device has republished its optimizer
        // shard into the global store (quorum = the step's completers).
        self.barrier.wait();
        // Replica refresh: pull my super-shard of every layer from the
        // global store into my group's replica — the cross-node param
        // all-gather the simulator's hybrid_step_overhead prices
        // ((n_groups-1)/n_groups of these reads cross node boundaries).
        // A dead or dormant member's slice is refreshed by its in-group
        // driver: live members gather the WHOLE replica, so every slice
        // must stay fresh no matter who owns it.
        let group = self.groups.group_of(dev);
        let mut scratch = self.refresh_scratch[dev].lock().unwrap();
        let mut locals = vec![self.groups.local_index(dev)];
        for m in self.membership.driven_by(dev, self.groups.members(group), step) {
            locals.push(self.groups.local_index(m));
        }
        let n_groups = self.groups.n_groups();
        for j in locals {
            for (layer, p) in self.params.layers.iter().enumerate() {
                // Super-shard j spans the global shards of owners
                // j*n_groups..(j+1)*n_groups: price one one-sided read
                // per owner through the transport's retry ladder.
                for t in 0..n_groups {
                    let bytes = self.wire.bytes_for(p.shard_len);
                    if self.transport.one_sided(dev, j * n_groups + t, bytes).is_err() {
                        self.escalated[dev].store(true, Ordering::Relaxed);
                    }
                }
                let s = p.padded_len() / self.groups.group_size;
                let buf = &mut scratch[..s];
                p.buf.read(j * s, buf);
                self.replicas[group][layer].write(j * s, buf);
            }
        }
        drop(scratch);
        // Barrier 2: nobody gathers until every replica is fresh.
        self.barrier.wait();
        // Step-scoped faults (partitions) activate at the boundary.
        self.transport.note_step(dev, next);
    }

    fn flush_shard(&self, shard: usize) {
        // The global rendezvous successor adopts the orphaned shard:
        // the dead device's daemon still received every group's cross
        // pieces (its in-group driver shipped the ones the dead worker
        // would have), so its cross quorum completes like any other.
        let (tx, rx) = mpsc::channel();
        self.send(shard, shard, 0, Msg::CrossFlush { reply: tx });
        let grads = rx.recv().expect("orphan cross flush");
        *self.taken[shard].lock().unwrap() = Some(grads);
    }

    fn await_join(&self, dev: usize) {
        let join = self.membership.joins_at(dev);
        // Fast-forward the step counter past the steps sat out, then
        // block until the join boundary: the previous step's refresh
        // barrier has completed, so the group replica (and the
        // replicated optimizer state about to be read) are settled.
        self.step_ctr[dev].store(join, Ordering::Relaxed);
        self.transport.note_step(dev, join);
        self.barrier.await_step_start(join);
    }

    fn link_escalated(&self, dev: usize) -> bool {
        self.escalated[dev].load(Ordering::Relaxed)
    }

    fn fault_stats(&self) -> FaultStats {
        self.transport.stats()
    }

    fn hotpath_stats(&self) -> HotpathStats {
        HotpathStats {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            fold_ns: self.fold_ns.load(Ordering::Relaxed),
        }
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }
}

impl Drop for HybridComm {
    fn drop(&mut self) {
        for dev in 0..self.world {
            // Self-link (never partitioned; the ladder absorbs any
            // transient drop), so the daemon always hears it.
            let _ = self.transport.send(dev, dev, 0, Msg::Shutdown);
        }
        for d in self.daemons.lock().unwrap().drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(lens: &[usize], world: usize) -> Arc<ParamStore> {
        let params = Arc::new(ParamStore::new(lens, world));
        for (l, p) in params.layers.iter().enumerate() {
            let vals: Vec<f32> = (0..p.logical_len).map(|i| (l * 1000 + i) as f32).collect();
            p.init_from(&vals);
        }
        params
    }

    #[test]
    fn gather_reads_group_replica() {
        let params = store(&[8], 4);
        let comm = HybridComm::new(Arc::clone(&params), 4, 2);
        let mut out = vec![0.0f32; 8];
        for dev in 0..4 {
            comm.gather_params(dev, 0, &mut out);
            let mut want = vec![0.0f32; 8];
            params.layers[0].read_logical(&mut want);
            assert_eq!(out, want, "dev {dev}");
        }
        assert_eq!(comm.gather_policy(), GatherPolicy::TwoLevelIntra);
        assert!(comm.gathers_cacheable());
    }

    /// The two-level reduction computes the same global sum as a flat
    /// scheme: every device's contribution reaches every owner exactly
    /// once, through its group's partial.
    #[test]
    fn two_level_reduction_sums_across_groups() {
        let world = 4;
        let params = Arc::new(ParamStore::new(&[12], world));
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    // device pushes (dev+1) twice — two microbatches
                    let grad = vec![(dev + 1) as f32; 12];
                    comm.reduce_grad(dev, 0, &grad, 1.0, (2 * dev) as u64);
                    comm.reduce_grad(dev, 0, &grad, 1.0, (2 * dev + 1) as u64);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0f32; 3];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    for &v in &shard {
                        assert_eq!(v, 20.0); // 2 * (1 + 2 + 3 + 4)
                    }
                    comm.end_step(dev);
                });
            }
        });
    }

    /// LB-Mini regime: unequal microbatch counts, both within and across
    /// groups, over several minibatches — correct sums, no deadlock.
    #[test]
    fn unequal_counts_across_groups_many_minibatches() {
        let world = 4;
        let params = Arc::new(ParamStore::new(&[10], world));
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for step in 0..5 {
                        let pushes = 1 + (dev + step) % 4;
                        for m in 0..pushes {
                            comm.reduce_grad(dev, 0, &vec![1.0f32; 12], 1.0, (4 * dev + m) as u64);
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 3];
                        comm.take_grad_shard(dev, 0, &mut g);
                        let want: usize = (0..world).map(|d| 1 + (d + step) % 4).sum();
                        for &v in &g {
                            assert_eq!(v, want as f32, "step {step}");
                        }
                        comm.end_step(dev);
                    }
                });
            }
        });
    }

    #[test]
    fn weighted_pushes_cross_group() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[2], world));
        // group_size 1: every device its own group — the pure cross path
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 1));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    comm.reduce_grad(dev, 0, &[1.0, 1.0], if dev == 0 { 0.5 } else { 2.0 }, dev as u64);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0f32; 1];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    assert!((shard[0] - 2.5).abs() < 1e-6);
                    comm.end_step(dev);
                });
            }
        });
    }

    /// Replica refresh: optimizer-shard writes published at end_step are
    /// visible to every group's gathers on the next minibatch.
    #[test]
    fn end_step_refreshes_every_replica() {
        let world = 4;
        let params = Arc::new(ParamStore::new(&[8], world));
        params.layers[0].init_from(&[1.0; 8]);
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
        let store = Arc::clone(&params);
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                let store = Arc::clone(&store);
                s.spawn(move || {
                    let p = &store.layers[0];
                    let mut buf = vec![0.0f32; p.padded_len()];
                    for step in 0..3 {
                        comm.gather_params(dev, 0, &mut buf);
                        assert!(
                            buf.iter().all(|&x| (x - (1.0 + step as f32)).abs() < 1e-6),
                            "dev {dev} step {step}: saw {buf:?}"
                        );
                        comm.end_minibatch(dev); // zero pushes: empty fold
                        let r = p.shard_range(dev);
                        p.buf.write(r.start, &vec![2.0 + step as f32; r.len()]);
                        comm.end_step(dev);
                    }
                });
            }
        });
    }

    /// Cross-level pieces per (owner, group) pair per minibatch equal
    /// the layer count, which the prealloc covers — the epilogue never
    /// heap-allocates. Intra pieces are held until the flush, so within
    /// one push per layer per minibatch the intra arenas are
    /// allocation-free too.
    #[test]
    fn arenas_allocation_free_within_prealloc() {
        let world = 4;
        let params = Arc::new(ParamStore::new(&[30, 12], world));
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                let store = Arc::clone(&params);
                s.spawn(move || {
                    for _step in 0..10 {
                        for (l, p) in store.layers.iter().enumerate() {
                            comm.reduce_grad(dev, l, &vec![1.0f32; p.padded_len()], 1.0, dev as u64);
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; store.layers[0].shard_len];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                    }
                });
            }
        });
        let intra = comm.intra_arena_stats();
        let cross = comm.cross_arena_stats();
        // per minibatch: every device pushes 2 layers × group_size
        // intra pieces, and sends 2 layers × n_groups cross pieces
        assert_eq!(intra.acquires, (10 * world * 2 * 2) as u64);
        assert_eq!(cross.acquires, (10 * world * 2 * 2) as u64);
        assert_eq!(intra.fresh_allocs, 0, "intra path must stay inside the prealloc");
        assert_eq!(cross.fresh_allocs, 0, "cross path must stay inside the prealloc");
        // all payloads back home after the final drain
        let total = comm.arena_stats();
        assert_eq!(total.resident, (world * 2 * 3 + world * 2 * 3) as u64);
    }

    /// SeqSplit chunks pushed from devices in DIFFERENT groups meet at
    /// the cross level: each group folds its own chunk subset into a
    /// partial keyed `seq_micro_key(seq)`, and the cross sum over group
    /// partials reconstitutes the whole-sequence gradient exactly.
    #[test]
    fn seq_chunks_across_groups_sum_exactly() {
        let world = 4;
        let params = Arc::new(ParamStore::new(&[12], world));
        let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    // dev 0 (group 0) and dev 2 (group 1) hold the two
                    // chunks of sequence 0; devs 1 and 3 run nothing
                    match dev {
                        0 => comm.reduce_grad_seq(dev, 0, &[4.0; 12], 0.5, 0, 0, 2),
                        2 => comm.reduce_grad_seq(dev, 0, &[8.0; 12], 0.5, 0, 1, 2),
                        _ => {}
                    }
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0f32; 3];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    for &v in &shard {
                        assert_eq!(v, 6.0, "dev {dev}: 0.5*4 + 0.5*8"); // exact in f32
                    }
                    comm.end_step(dev);
                });
            }
        });
    }

    /// Single-group seq fold is keyed by chunk INDEX, not push order:
    /// catastrophic-cancellation values expose any ordering difference.
    /// A whole-sample micro pushed alongside folds before the
    /// reconstituted sequence (SEQ_KEY_BASE sorts above real ids).
    #[test]
    fn seq_fold_single_group_chunk_order_invariant() {
        let run = |scrambled: bool| -> Vec<Vec<f32>> {
            let world = 2;
            let params = Arc::new(ParamStore::new(&[8], world));
            let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for dev in 0..world {
                    let comm = Arc::clone(&comm);
                    handles.push(s.spawn(move || {
                        if dev == 0 {
                            // chunks 0 and 2 of sequence 5, in either order
                            let pushes: [(u32, f32); 2] =
                                if scrambled { [(2, -1e8), (0, 1e8)] } else { [(0, 1e8), (2, -1e8)] };
                            for (chunk, val) in pushes {
                                comm.reduce_grad_seq(dev, 0, &[val; 8], 1.0, 5, chunk, 3);
                            }
                        } else {
                            comm.reduce_grad_seq(dev, 0, &[1.0; 8], 1.0, 5, 1, 3);
                            comm.reduce_grad(dev, 0, &[2.0; 8], 1.0, 0); // whole sample
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 4];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                        g
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a, b, "seq fold must not depend on chunk push order");
        // (1e8 + 1.0) + -1e8 == 0.0 in f32 only if folded in chunk order
        for shard in &a {
            for &v in shard {
                assert_eq!(v, 2.0, "seq folds to 0.0, plus the whole sample's 2.0");
            }
        }
    }

    /// Multi-group runs are deterministic across repetitions: the
    /// daemons fold buffered pieces in a fixed order, so thread timing
    /// cannot change a single bit.
    #[test]
    fn repeated_runs_bit_identical() {
        let run = || -> Vec<Vec<f32>> {
            let world = 4;
            let params = Arc::new(ParamStore::new(&[17], world));
            let comm = Arc::new(HybridComm::new(Arc::clone(&params), world, 2));
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for dev in 0..world {
                    let comm = Arc::clone(&comm);
                    handles.push(s.spawn(move || {
                        for m in 0..(1 + dev) {
                            let grad: Vec<f32> = (0..20)
                                .map(|i| ((dev * 31 + m * 7 + i) % 13) as f32 * 0.37)
                                .collect();
                            comm.reduce_grad(dev, 0, &grad, 1.0, (8 * dev + m) as u64);
                        }
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 5];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                        g
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "hybrid reduction must be bit-deterministic");
    }
}
