//! ChaosComm — the typed transport layer under the ODC mailboxes.
//!
//! Every point-to-point message in the scatter-accumulate protocol now
//! travels as an [`Envelope`]: payload plus source rank, a per-(src,dst)
//! **link sequence number**, and the global microbatch id it belongs to.
//! Two implementations of the [`Transport`] trait exist:
//!
//! * [`InProcTransport`] — the original in-process mailbox path
//!   (one mpsc channel per destination daemon), refactored behind the
//!   trait with **zero behavior change**: reliable, in-order, no faults.
//! * [`FaultyTransport`] — a deterministic seeded wrapper that injects
//!   per-link **drop / duplicate / reorder / delay** according to a
//!   declarative [`FaultPlan`], and models the retry machinery a real
//!   wire transport would run:
//!
//!   - **drop** → the modeled ack timeout fires and the sender
//!     retransmits the *same* sequence number under a capped
//!     exponential backoff ladder ([`RetryPolicy`]), so a transiently
//!     lossy link still delivers exactly once;
//!   - **duplicate** → a second copy of the same sequence number is
//!     put on the wire; the receiver-side reassembly discards it;
//!   - **reorder / delay** → the envelope is held in a per-link limbo
//!     and released after later traffic on the same link; the
//!     receiver-side per-link reassembly buffers out-of-order arrivals
//!     until the gap fills, restoring in-order delivery.
//!
//! The receiver therefore hands its daemon an **exactly-once, in-order
//! per-link stream** regardless of the fault plan — the daemon fold
//! and quorum logic upstack is semantically unchanged (it keeps its
//! own id-keyed dedup as belt and braces).
//!
//! **Escalation.** A link whose request exhausts the retry budget
//! raises a per-link *suspicion counter* (an exhausted ladder weighs
//! +2); the message is reported lost ([`SendError::Lost`]) and the
//! sender carries on. Healthy deliveries *decay* suspicion by a
//! saturating −1 rather than resetting it: a flapping link that
//! alternates one success with one exhausted ladder still drifts
//! upward and eventually escalates, while an isolated loss on a
//! genuinely healthy link decays back to zero. A lost request consumes
//! no link sequence number — nothing was ever put on the wire — so the
//! receiver's reassembly cursor never waits on a permanent hole. Once
//! suspicion reaches [`RetryPolicy::suspicion_threshold`] the link is
//! declared [`SendError::Unreachable`] (counted once in
//! [`FaultStats::escalations`]) and the backend escalates the sending
//! device into the existing ElasticWorld failure machinery
//! (`report_failed` → ring-successor takeover → orphan re-pull).
//!
//! **Determinism.** Fault decisions consume a per-link RNG (forked from
//! `FaultPlan::seed` in fixed link order) strictly in per-link send
//! order. Each link has a single sending thread in this codebase, so a
//! fixed seed replays the exact same fault schedule independent of
//! cross-link thread interleaving. Backoff sleeps are timing-only and
//! never ordering-relevant.
//!
//! **Control plane.** Rendezvous messages (`Done`/`Flush`/`Shutdown`
//! variants — [`WireMsg::is_barrier`]) may be dropped or duplicated
//! (the ladder and dedup absorb that) but are never held in limbo, and
//! they flush any limbo ahead of themselves: a reorder can therefore
//! never stall a minibatch epilogue. Flush *reply* channels stay plain
//! mpsc — they model local completion, not network traffic.
//!
//! **Byte-moving siblings (WireComm).** Two further implementations
//! live next door: [`crate::comm::ring::RingTransport`] (same-host
//! shared-memory SPSC slot rings) and
//! [`crate::comm::socket::SocketTransport`] (UDS with TCP-loopback
//! fallback). Both serialize envelopes through [`WireCodec`] into the
//! [`frame`] byte format and deliver them in global per-destination
//! *ticket* order, reproducing the in-process mailbox's arrival order
//! exactly — which is why every backend stays bit-identical under
//! `--transport shm|uds` (see `docs/transport.md`). [`TransportKind`]
//! is the config-level selector; [`FaultyTransport::over`] layers the
//! chaos machinery on any of them.

use crate::util::rng::Rng;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Payload contract for messages crossing a [`Transport`].
///
/// `Clone` is required so the faulty wrapper can put duplicates on the
/// wire; with the reliable transport nothing is ever cloned.
pub trait WireMsg: Send + Clone + 'static {
    /// Control-plane rendezvous message (Done/Flush/Shutdown): never
    /// held in limbo, and flushes held envelopes ahead of itself.
    fn is_barrier(&self) -> bool {
        false
    }
    /// Payload bytes, for retransmission accounting.
    fn payload_bytes(&self) -> usize {
        0
    }
}

/// A typed message on the wire: payload + link-level framing.
#[derive(Clone)]
pub struct Envelope<M> {
    /// Sending rank (link identity is `(src, dst)`).
    pub src: usize,
    /// Per-(src,dst) link sequence number, assigned at send time and
    /// **reused verbatim on retransmission** — the dedup key.
    pub seq: u64,
    /// Global microbatch id the payload belongs to (0 if n/a).
    pub micro: u64,
    /// The payload.
    pub msg: M,
}

/// Config-level selector for the transport under the one-sided
/// backends (`--transport {inproc,shm,uds}`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// The original in-process mailbox path (one mpsc per rank).
    #[default]
    Inproc,
    /// Same-host shared-memory SPSC ring buffers
    /// ([`crate::comm::ring::RingTransport`]).
    Shm,
    /// Unix-domain sockets with TCP-loopback fallback
    /// ([`crate::comm::socket::SocketTransport`]).
    Uds,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "mpsc" => Some(TransportKind::Inproc),
            "shm" | "ring" => Some(TransportKind::Shm),
            "uds" | "socket" | "tcp" => Some(TransportKind::Uds),
            _ => None,
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Shm => "shm",
            TransportKind::Uds => "uds",
        })
    }
}

/// Byte serialization for messages crossing a *byte-moving* transport
/// (the shared-memory ring and the socket transport). The in-process
/// mailbox never encodes anything — this trait is only required when a
/// backend is constructed over `--transport shm|uds`.
pub trait WireCodec: WireMsg {
    /// Append this message's byte image to `out` and return `true`, or
    /// return `false` (leaving `out` untouched) when the message is
    /// **local-only** — it carries process-local handles (e.g. a flush
    /// reply channel) and must ride the transport's ticketed local
    /// lane instead of the wire. Local-only messages are only ever
    /// sent on self-links.
    fn encode(&self, out: &mut Vec<u8>) -> bool;
    /// Inverse of [`WireCodec::encode`]; `None` on a malformed image.
    fn decode(bytes: &[u8]) -> Option<Self>
    where
        Self: Sized;
}

/// The length-free envelope frame shared by the byte-moving transports:
/// `[ticket u64][src u64][seq u64][micro u64][payload…]`, all
/// little-endian, payload = [`WireCodec::encode`] image. Transports add
/// their own outer framing (slot fragments on the ring, a `u32` length
/// prefix + chunk flag on the stream socket). The *ticket* is the
/// global per-destination enqueue number that restores the in-process
/// mailbox's total arrival order at the receiver.
pub mod frame {
    use super::{Envelope, WireCodec};

    pub const HEADER: usize = 32;

    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
        put_u64(out, b.len() as u64);
        out.extend_from_slice(b);
    }

    /// Cursor over a received byte image; every getter returns `None`
    /// past the end, so malformed frames fail decode instead of
    /// panicking the daemon.
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Reader { buf, pos: 0 }
        }

        fn take(&mut self, n: usize) -> Option<&'a [u8]> {
            let end = self.pos.checked_add(n)?;
            let s = self.buf.get(self.pos..end)?;
            self.pos = end;
            Some(s)
        }

        pub fn u32(&mut self) -> Option<u32> {
            Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
        }

        pub fn u64(&mut self) -> Option<u64> {
            Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
        }

        pub fn f32(&mut self) -> Option<f32> {
            Some(f32::from_le_bytes(self.take(4)?.try_into().ok()?))
        }

        pub fn bytes(&mut self) -> Option<Vec<u8>> {
            let n = self.u64()? as usize;
            Some(self.take(n)?.to_vec())
        }

        pub fn done(&self) -> bool {
            self.pos == self.buf.len()
        }
    }

    /// Encode `env` under `ticket`; `None` when the payload is
    /// local-only and must not cross a byte wire.
    pub fn encode<M: WireCodec>(ticket: u64, env: &Envelope<M>) -> Option<Vec<u8>> {
        let mut out = Vec::with_capacity(HEADER + env.msg.payload_bytes() + 64);
        put_u64(&mut out, ticket);
        put_u64(&mut out, env.src as u64);
        put_u64(&mut out, env.seq);
        put_u64(&mut out, env.micro);
        if env.msg.encode(&mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Decode one frame image back into `(ticket, envelope)`.
    pub fn decode<M: WireCodec>(bytes: &[u8]) -> Option<(u64, Envelope<M>)> {
        if bytes.len() < HEADER {
            return None;
        }
        let mut r = Reader::new(bytes);
        let ticket = r.u64()?;
        let src = r.u64()? as usize;
        let seq = r.u64()?;
        let micro = r.u64()?;
        let msg = M::decode(&bytes[HEADER..])?;
        Some((ticket, Envelope { src, seq, micro, msg }))
    }
}

/// Terminal send outcomes on a lossy link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendError {
    /// Retry budget exhausted; the message is lost and the peer is now
    /// suspected (`suspicion` failures so far). The sender may keep
    /// going — subsequent traffic on healthy links is unaffected.
    Lost { suspicion: u32 },
    /// Suspicion crossed the threshold: the link is declared dead.
    /// The sending device must escalate into ElasticWorld.
    Unreachable,
}

/// Retry ladder parameters for the modeled ack/retransmit machinery.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retransmissions allowed per request before it counts as lost.
    pub max_retries: u32,
    /// First backoff step (doubles per retransmit).
    pub base_delay_us: u64,
    /// Backoff cap.
    pub max_delay_us: u64,
    /// Lost requests tolerated on a link before it is declared
    /// unreachable and escalated. The default is 1: with the retry
    /// budget already exhausted, a request-level loss on a healthy
    /// plan is astronomically unlikely (`drop^(1+max_retries)`), so
    /// the first exhausted budget is itself the suspicion signal —
    /// raising the threshold trades faster recovery for tolerance of
    /// pathological transients, at the cost of the lost requests.
    pub suspicion_threshold: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 8, base_delay_us: 20, max_delay_us: 1_000, suspicion_threshold: 1 }
    }
}

impl RetryPolicy {
    /// Capped exponential backoff before retransmit number `attempt`.
    pub fn backoff_us(&self, attempt: u32) -> u64 {
        self.base_delay_us
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.max_delay_us)
    }
}

/// Declarative per-link fault schedule, config-parsed like `fail_at`.
///
/// Probabilities apply independently to every (src,dst) link;
/// `partition` lists links that drop **every** envelope from a given
/// step on — the path that exercises escalation.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Per-attempt drop probability (the modeled ack timeout fires).
    pub drop: f64,
    /// Probability a delivered envelope is duplicated on the wire.
    pub dup: f64,
    /// Probability a data envelope is swapped behind the next send.
    pub reorder: f64,
    /// Probability a data envelope is held for 2–4 later sends.
    pub delay: f64,
    /// Seed for the per-link fault RNGs.
    pub seed: u64,
    /// `(src, dst, step)`: from `step` on, link src→dst drops
    /// everything — past the retry budget this escalates.
    pub partition: Vec<(usize, usize, usize)>,
}

impl FaultPlan {
    /// True when the plan injects nothing at all.
    pub fn is_noop(&self) -> bool {
        self.drop == 0.0
            && self.dup == 0.0
            && self.reorder == 0.0
            && self.delay == 0.0
            && self.partition.is_empty()
    }

    /// Validate rates and partition entries.
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in
            [("drop", self.drop), ("dup", self.dup), ("reorder", self.reorder), ("delay", self.delay)]
        {
            if !p.is_finite() || !(0.0..1.0).contains(&p) {
                return Err(format!(
                    "fault-plan {name}={p} must be a probability in [0, 1) \
                     (use part=src:dst:step for a full partition)"
                ));
            }
        }
        for &(src, dst, _) in &self.partition {
            if src == dst {
                return Err(format!("fault-plan partition {src}:{dst} is a self-link"));
            }
        }
        Ok(())
    }

    /// Parse the CLI/config grammar: comma-separated `key=value` with
    /// keys `drop|dup|reorder|delay|seed` and repeatable
    /// `part=src:dst:step` triples. Empty input = no faults.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        if s.trim().is_empty() {
            return Ok(plan);
        }
        for entry in s.split(',') {
            let entry = entry.trim();
            let (key, val) = entry
                .split_once('=')
                .ok_or_else(|| format!("fault-plan entry `{entry}` is not key=value"))?;
            let rate = |v: &str| {
                v.parse::<f64>().map_err(|_| format!("fault-plan {key} `{v}` is not a number"))
            };
            match key {
                "drop" => plan.drop = rate(val)?,
                "dup" => plan.dup = rate(val)?,
                "reorder" => plan.reorder = rate(val)?,
                "delay" => plan.delay = rate(val)?,
                "seed" => {
                    plan.seed = val
                        .parse::<u64>()
                        .map_err(|_| format!("fault-plan seed `{val}` is not a u64"))?;
                }
                "part" => {
                    let nums: Vec<usize> = val
                        .split(':')
                        .map(|p| p.parse::<usize>())
                        .collect::<Result<_, _>>()
                        .map_err(|_| format!("fault-plan part expects src:dst:step, got `{val}`"))?;
                    if nums.len() != 3 {
                        return Err(format!("fault-plan part expects src:dst:step, got `{val}`"));
                    }
                    plan.partition.push((nums[0], nums[1], nums[2]));
                }
                _ => return Err(format!("fault-plan key `{key}` unknown (drop|dup|reorder|delay|seed|part)")),
            }
        }
        plan.validate()?;
        Ok(plan)
    }
}

/// Snapshot of a transport's fault counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Retransmissions performed (modeled ack timeouts fired).
    pub retries: u64,
    /// Bytes put on the wire beyond first transmission (retransmits +
    /// duplicates).
    pub retransmitted_bytes: u64,
    /// Links escalated to unreachable (suspicion crossed threshold).
    pub escalations: u64,
}

/// Point-to-point message transport between ranks.
pub trait Transport<M: WireMsg>: Send + Sync {
    /// Rank count.
    fn world(&self) -> usize;
    /// Send `msg` from `src` to `dst`'s daemon. The reliable transport
    /// never fails; the faulty one reports terminal outcomes.
    fn send(&self, src: usize, dst: usize, micro: u64, msg: M) -> Result<(), SendError>;
    /// Raw framed send of an **already-sequenced** envelope: limbo
    /// releases, duplicates and barrier flushes re-put an envelope on
    /// the wire without assigning a fresh link sequence number.
    /// Implementations must deliver it to `dst` at its enqueue
    /// position (the byte transports stamp their delivery ticket
    /// here). [`Transport::send`] is `send_env` plus seq assignment.
    fn send_env(&self, dst: usize, env: Envelope<M>);
    /// Blocking receive of the next in-order envelope for `dst`
    /// (single consumer per rank). `None` once all senders are gone.
    fn recv(&self, dst: usize) -> Option<Envelope<M>>;
    /// One-sided read of `bytes` from `dst`'s memory by `src` (gathers,
    /// replica refresh): returns the retries spent, or the terminal
    /// error on a dead link. The read itself always succeeds
    /// in-process; the faulty transport prices and counts the ladder.
    fn one_sided(&self, src: usize, dst: usize, bytes: usize) -> Result<u32, SendError>;
    /// Advance `src`'s step counter (gates step-scoped partitions).
    fn note_step(&self, _src: usize, _step: usize) {}
    /// Deliver everything `src` still holds in limbo on any link — the
    /// crash-out path: a device escalating into ElasticWorld must first
    /// land its completed microbatches' delayed pieces, or the fold
    /// would miss work the dispatcher considers resolved.
    fn flush_links(&self, _src: usize) {}
    /// Fault counters (zero for the reliable transport).
    fn stats(&self) -> FaultStats {
        FaultStats::default()
    }
}

/// The original mailbox path: one mpsc channel per destination rank,
/// reliable and in-order. Sequence numbers are still assigned per link
/// so the framing is identical to the faulty path.
pub struct InProcTransport<M> {
    world: usize,
    tx: Vec<Mutex<mpsc::Sender<Envelope<M>>>>,
    rx: Vec<Mutex<mpsc::Receiver<Envelope<M>>>>,
    seq: Vec<AtomicU64>,
}

impl<M: WireMsg> InProcTransport<M> {
    pub fn new(world: usize) -> Self {
        let mut tx = Vec::with_capacity(world);
        let mut rx = Vec::with_capacity(world);
        for _ in 0..world {
            let (t, r) = mpsc::channel();
            tx.push(Mutex::new(t));
            rx.push(Mutex::new(r));
        }
        let seq = (0..world * world).map(|_| AtomicU64::new(0)).collect();
        InProcTransport { world, tx, rx, seq }
    }
}

impl<M: WireMsg> Transport<M> for InProcTransport<M> {
    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, src: usize, dst: usize, micro: u64, msg: M) -> Result<(), SendError> {
        let seq = self.seq[src * self.world + dst].fetch_add(1, Ordering::Relaxed);
        self.send_env(dst, Envelope { src, seq, micro, msg });
        Ok(())
    }

    fn send_env(&self, dst: usize, env: Envelope<M>) {
        self.tx[dst].lock().unwrap().send(env).expect("daemon alive");
    }

    fn recv(&self, dst: usize) -> Option<Envelope<M>> {
        self.rx[dst].lock().unwrap().recv().ok()
    }

    fn one_sided(&self, _src: usize, _dst: usize, _bytes: usize) -> Result<u32, SendError> {
        Ok(0)
    }
}

/// Per-link sender-side fault state, locked per link so fault
/// decisions consume the link RNG strictly in send order.
struct Link<M> {
    rng: Rng,
    next_seq: u64,
    /// Held (delayed/reordered) envelopes: `(release_after, env)` —
    /// released once `next_seq` passes `release_after`.
    limbo: Vec<(u64, Envelope<M>)>,
    suspicion: u32,
    escalated: bool,
}

/// Per-destination receiver reassembly: one expected-seq cursor and an
/// out-of-order buffer per source link, plus the in-order ready queue.
struct RecvState<M> {
    ready: VecDeque<Envelope<M>>,
    expected: Vec<u64>,
    ooo: Vec<BTreeMap<u64, Envelope<M>>>,
}

/// Deterministic lossy wrapper over any inner [`Transport`] (the
/// in-process mailbox by default — see [`FaultyTransport::over`] for
/// chaos layered on a byte-moving transport): injects the [`FaultPlan`]
/// per link, runs the retransmit ladder, and reassembles an
/// exactly-once in-order stream on the receiver side.
pub struct FaultyTransport<M> {
    inner: Arc<dyn Transport<M>>,
    world: usize,
    plan: FaultPlan,
    policy: RetryPolicy,
    links: Vec<Mutex<Link<M>>>,
    recv_state: Vec<Mutex<RecvState<M>>>,
    step: Vec<AtomicUsize>,
    retries: AtomicU64,
    retransmitted_bytes: AtomicU64,
    escalations: AtomicU64,
}

impl<M: WireMsg> FaultyTransport<M> {
    pub fn new(world: usize, plan: FaultPlan, policy: RetryPolicy) -> Self {
        FaultyTransport::over(Arc::new(InProcTransport::new(world)), plan, policy)
    }

    /// Layer the chaos machinery on an arbitrary inner transport — the
    /// chaos-over-ring/socket soak path. The wrapper owns sequence
    /// assignment and reassembly; the inner transport only ever sees
    /// [`Transport::send_env`] with the wrapper's seqs, so its own
    /// delivery order (ticketed on the byte transports) is the
    /// reassembly input exactly as the mpsc arrival order is in-proc.
    pub fn over(inner: Arc<dyn Transport<M>>, plan: FaultPlan, policy: RetryPolicy) -> Self {
        plan.validate().expect("fault plan validated at config time");
        let world = inner.world();
        let mut root = Rng::new(plan.seed ^ 0xC4A0_5C0D);
        let links = (0..world * world)
            .map(|li| {
                Mutex::new(Link {
                    rng: root.fork(li as u64),
                    next_seq: 0,
                    limbo: Vec::new(),
                    suspicion: 0,
                    escalated: false,
                })
            })
            .collect();
        let recv_state = (0..world)
            .map(|_| {
                Mutex::new(RecvState {
                    ready: VecDeque::new(),
                    expected: vec![0; world],
                    ooo: (0..world).map(|_| BTreeMap::new()).collect(),
                })
            })
            .collect();
        FaultyTransport {
            inner,
            world,
            plan,
            policy,
            links,
            recv_state,
            step: (0..world).map(|_| AtomicUsize::new(0)).collect(),
            retries: AtomicU64::new(0),
            retransmitted_bytes: AtomicU64::new(0),
            escalations: AtomicU64::new(0),
        }
    }

    /// Envelopes currently parked in sender limbo or receiver
    /// out-of-order buffers, summed over every link — the bounded-memory
    /// observable: after a full drain to a barrier it must be zero.
    pub fn buffered_envelopes(&self) -> usize {
        let held: usize = self.links.iter().map(|l| l.lock().unwrap().limbo.len()).sum();
        let ooo: usize = self
            .recv_state
            .iter()
            .map(|st| st.lock().unwrap().ooo.iter().map(|m| m.len()).sum::<usize>())
            .sum();
        held + ooo
    }

    fn partitioned(&self, src: usize, dst: usize) -> bool {
        let now = self.step[src].load(Ordering::Relaxed);
        self.plan.partition.iter().any(|&(s, d, st)| s == src && d == dst && now >= st)
    }

    /// Run the drop/retransmit ladder for one request on a locked link.
    /// Returns retries spent on success, or the terminal error.
    fn ladder(&self, link: &mut Link<M>, partitioned: bool, bytes: usize) -> Result<u32, SendError> {
        for attempt in 0..=self.policy.max_retries {
            let dropped = partitioned || link.rng.f64() < self.plan.drop;
            if !dropped {
                // healthy traffic DECAYS suspicion — never resets it. A
                // hard reset let a flapping link alternate one success
                // with one exhausted ladder forever without crossing the
                // threshold; weighing an exhausted ladder +2 against a
                // −1 decay makes even strict 1:1 flapping drift upward
                // and escalate, while an isolated loss on a healthy
                // link decays back to zero within two deliveries.
                link.suspicion = link.suspicion.saturating_sub(1);
                return Ok(attempt);
            }
            if attempt == self.policy.max_retries {
                break;
            }
            // modeled ack timeout: retransmit under capped backoff
            self.retries.fetch_add(1, Ordering::Relaxed);
            self.retransmitted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            let us = self.policy.backoff_us(attempt);
            if us > 0 {
                std::thread::sleep(Duration::from_micros(us));
            }
        }
        link.suspicion += 2;
        if link.suspicion >= self.policy.suspicion_threshold {
            if !link.escalated {
                link.escalated = true;
                self.escalations.fetch_add(1, Ordering::Relaxed);
            }
            Err(SendError::Unreachable)
        } else {
            Err(SendError::Lost { suspicion: link.suspicion })
        }
    }

    /// Release limbo entries whose hold expired, in seq order.
    fn release_due(&self, dst: usize, link: &mut Link<M>) {
        if link.limbo.is_empty() {
            return;
        }
        let cur = link.next_seq;
        let mut due: Vec<Envelope<M>> = Vec::new();
        let mut keep: Vec<(u64, Envelope<M>)> = Vec::with_capacity(link.limbo.len());
        for (release_after, env) in link.limbo.drain(..) {
            if release_after < cur {
                due.push(env);
            } else {
                keep.push((release_after, env));
            }
        }
        link.limbo = keep;
        due.sort_by_key(|e| e.seq);
        for env in due {
            self.inner.send_env(dst, env);
        }
    }
}

impl<M: WireMsg> Transport<M> for FaultyTransport<M> {
    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, src: usize, dst: usize, micro: u64, msg: M) -> Result<(), SendError> {
        let world = self.world;
        let partitioned = self.partitioned(src, dst);
        let mut link = self.links[src * world + dst].lock().unwrap();
        if link.escalated {
            return Err(SendError::Unreachable);
        }
        let bytes = msg.payload_bytes();
        let barrier = msg.is_barrier();
        if barrier {
            // control plane: flush everything held on this link first
            let mut held: Vec<Envelope<M>> =
                link.limbo.drain(..).map(|(_, e)| e).collect();
            held.sort_by_key(|e| e.seq);
            for e in held {
                self.inner.send_env(dst, e);
            }
        }
        // The ladder runs BEFORE a sequence number is consumed: a lost
        // request never made it onto the wire, so it must not burn a
        // seq. (It used to — the permanent hole stalled the receiver's
        // reassembly cursor and every later envelope on the link piled
        // up in the out-of-order buffer without bound.)
        self.ladder(&mut link, partitioned, bytes)?;
        let seq = link.next_seq;
        link.next_seq += 1;
        let env = Envelope { src, seq, micro, msg };
        // on the wire: maybe duplicate (receiver reassembly discards it)
        if self.plan.dup > 0.0 && link.rng.f64() < self.plan.dup {
            self.retransmitted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            self.inner.send_env(dst, env.clone());
        }
        // data plane only: maybe hold in limbo (reorder/delay)
        let hold: u64 = if barrier {
            0
        } else if self.plan.reorder > 0.0 && link.rng.f64() < self.plan.reorder {
            1
        } else if self.plan.delay > 0.0 && link.rng.f64() < self.plan.delay {
            2 + link.rng.below(3)
        } else {
            0
        };
        if hold > 0 {
            let release_after = seq + hold;
            link.limbo.push((release_after, env));
        } else {
            self.inner.send_env(dst, env);
        }
        self.release_due(dst, &mut link);
        Ok(())
    }

    fn recv(&self, dst: usize) -> Option<Envelope<M>> {
        // single consumer per rank: holding the reassembly lock across
        // the blocking inner recv is uncontended by construction
        let mut st = self.recv_state[dst].lock().unwrap();
        loop {
            if let Some(env) = st.ready.pop_front() {
                return Some(env);
            }
            let env = self.inner.recv(dst)?;
            let s = env.src;
            if env.seq < st.expected[s] {
                continue; // duplicate: this seq was already delivered
            }
            if env.seq > st.expected[s] {
                st.ooo[s].insert(env.seq, env); // gap: buffer until it fills
                continue;
            }
            st.expected[s] += 1;
            st.ready.push_back(env);
            // the gap may have unblocked buffered successors
            while let Some(e) = st.ooo[s].remove(&st.expected[s]) {
                st.expected[s] += 1;
                st.ready.push_back(e);
            }
            // prune below the delivered watermark: a duplicate of an
            // already-delivered seq that was buffered while the gap was
            // open would otherwise sit in the map forever
            let wm = st.expected[s];
            if st.ooo[s].first_key_value().is_some_and(|(&k, _)| k < wm) {
                st.ooo[s] = st.ooo[s].split_off(&wm);
            }
        }
    }

    fn send_env(&self, dst: usize, env: Envelope<M>) {
        // a pre-sequenced envelope from an outer layer passes straight
        // through: chaos is injected once, at this layer's `send`
        self.inner.send_env(dst, env);
    }

    fn one_sided(&self, src: usize, dst: usize, bytes: usize) -> Result<u32, SendError> {
        let world = self.world;
        let partitioned = self.partitioned(src, dst);
        let mut link = self.links[src * world + dst].lock().unwrap();
        if link.escalated {
            return Err(SendError::Unreachable);
        }
        self.ladder(&mut link, partitioned, bytes)
    }

    fn note_step(&self, src: usize, step: usize) {
        self.step[src].store(step, Ordering::Relaxed);
    }

    fn flush_links(&self, src: usize) {
        let world = self.world;
        for dst in 0..world {
            let mut link = self.links[src * world + dst].lock().unwrap();
            let mut held: Vec<Envelope<M>> = link.limbo.drain(..).map(|(_, e)| e).collect();
            held.sort_by_key(|e| e.seq);
            for e in held {
                self.inner.send_env(dst, e);
            }
        }
    }

    fn stats(&self) -> FaultStats {
        FaultStats {
            retries: self.retries.load(Ordering::Relaxed),
            retransmitted_bytes: self.retransmitted_bytes.load(Ordering::Relaxed),
            escalations: self.escalations.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    enum TMsg {
        Data(u64),
        Done,
    }

    impl WireMsg for TMsg {
        fn is_barrier(&self) -> bool {
            matches!(self, TMsg::Done)
        }
        fn payload_bytes(&self) -> usize {
            8
        }
    }

    /// Drive `n` data messages + a Done barrier over link 0→1 and
    /// return the delivered data values in arrival order.
    fn drive(t: &dyn Transport<TMsg>, n: u64) -> Vec<u64> {
        for i in 0..n {
            t.send(0, 1, i, TMsg::Data(i)).expect("transient plan never loses a message");
        }
        t.send(0, 1, n, TMsg::Done).expect("barrier delivered");
        let mut got = Vec::new();
        loop {
            let env = t.recv(1).expect("senders alive");
            assert_eq!(env.src, 0);
            match env.msg {
                TMsg::Data(v) => got.push(v),
                TMsg::Done => break,
            }
        }
        got
    }

    #[test]
    fn inproc_delivers_in_order() {
        let t = InProcTransport::<TMsg>::new(2);
        let got = drive(&t, 50);
        assert_eq!(got, (0..50).collect::<Vec<_>>());
        assert_eq!(t.stats(), FaultStats::default());
    }

    #[test]
    fn faulty_with_empty_plan_is_transparent() {
        let t = FaultyTransport::<TMsg>::new(2, FaultPlan::default(), RetryPolicy::default());
        let got = drive(&t, 50);
        assert_eq!(got, (0..50).collect::<Vec<_>>());
        assert_eq!(t.stats(), FaultStats::default());
    }

    fn chaos_plan() -> FaultPlan {
        FaultPlan {
            drop: 0.10,
            dup: 0.30,
            reorder: 0.30,
            delay: 0.20,
            seed: 0xFA15,
            partition: Vec::new(),
        }
    }

    #[test]
    fn lossy_link_reassembles_exactly_once_in_order() {
        let t = FaultyTransport::<TMsg>::new(2, chaos_plan(), RetryPolicy::default());
        let got = drive(&t, 200);
        assert_eq!(got, (0..200).collect::<Vec<_>>(), "drop/dup/reorder/delay must be invisible");
        let s = t.stats();
        assert!(s.retries > 0, "a 10% drop rate over 200 sends must retransmit");
        assert!(s.retransmitted_bytes > 0);
        assert_eq!(s.escalations, 0);
    }

    #[test]
    fn fixed_seed_replays_identically() {
        let run = || {
            let t = FaultyTransport::<TMsg>::new(2, chaos_plan(), RetryPolicy::default());
            let got = drive(&t, 120);
            (got, t.stats())
        };
        assert_eq!(run(), run(), "same seed, same fault schedule, same counters");
    }

    #[test]
    fn partition_escalates_after_suspicion_threshold() {
        let plan = FaultPlan { partition: vec![(0, 1, 0)], ..FaultPlan::default() };
        let policy = RetryPolicy {
            base_delay_us: 1,
            max_delay_us: 4,
            suspicion_threshold: 3,
            ..RetryPolicy::default()
        };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        // an exhausted ladder weighs +2, so a fully dead link crosses a
        // threshold of 3 on its second lost request
        assert_eq!(t.send(0, 1, 0, TMsg::Data(0)), Err(SendError::Lost { suspicion: 2 }));
        assert_eq!(t.send(0, 1, 1, TMsg::Data(1)), Err(SendError::Unreachable));
        assert_eq!(t.stats().escalations, 1);
        // dead links fail fast from here on; healthy links are untouched
        assert_eq!(t.send(0, 1, 3, TMsg::Data(3)), Err(SendError::Unreachable));
        assert_eq!(t.stats().escalations, 1);
        assert!(t.send(1, 0, 0, TMsg::Data(9)).is_ok());
    }

    #[test]
    fn flapping_link_eventually_escalates() {
        // Regression: `suspicion = 0` on any healthy delivery let a link
        // that alternates one success with one exhausted retry ladder
        // flap forever below any threshold. Under decay (+2 per
        // exhausted ladder, −1 per success) the ~1:1 mix here drifts
        // upward and must escalate well within the send budget.
        let plan = FaultPlan { drop: 0.5, seed: 77, ..FaultPlan::default() };
        let policy = RetryPolicy {
            max_retries: 0, // every drop is an exhausted ladder
            base_delay_us: 0,
            max_delay_us: 0,
            suspicion_threshold: 8,
        };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        let mut escalated = false;
        for i in 0..10_000u64 {
            match t.send(0, 1, i, TMsg::Data(i)) {
                Err(SendError::Unreachable) => {
                    escalated = true;
                    break;
                }
                Ok(()) | Err(SendError::Lost { .. }) => {}
            }
        }
        assert!(escalated, "a 1:1 flapping link must cross the suspicion threshold");
        assert_eq!(t.stats().escalations, 1);

        // …while a mostly-healthy link (rare isolated losses) decays
        // back down and never escalates spuriously.
        let plan = FaultPlan { drop: 0.05, seed: 78, ..FaultPlan::default() };
        let policy = RetryPolicy {
            max_retries: 0,
            base_delay_us: 0,
            max_delay_us: 0,
            suspicion_threshold: 8,
        };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        for i in 0..10_000u64 {
            assert_ne!(
                t.send(0, 1, i, TMsg::Data(i)),
                Err(SendError::Unreachable),
                "isolated losses on a healthy link must decay, not accumulate"
            );
        }
        assert_eq!(t.stats().escalations, 0);
    }

    #[test]
    fn adversarial_reorder_keeps_reassembly_bounded() {
        // 10k envelopes through a plan that loses ~10% outright
        // (max_retries=0 ⇒ every drop is an exhausted ladder) while
        // reordering/delaying/duplicating much of the rest. Lost
        // requests consume no seq, so the receiver cursor never waits
        // on a permanent hole; after the final barrier drains, no
        // envelope may remain parked in limbo or the ooo buffers.
        let plan = FaultPlan {
            drop: 0.10,
            dup: 0.30,
            reorder: 0.35,
            delay: 0.25,
            seed: 0xB0B,
            partition: Vec::new(),
        };
        let policy = RetryPolicy {
            max_retries: 0,
            base_delay_us: 0,
            max_delay_us: 0,
            suspicion_threshold: u32::MAX, // lossy, never escalating
        };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        const N: u64 = 10_000;
        let mut delivered_expect = Vec::new();
        for i in 0..N {
            if t.send(0, 1, i, TMsg::Data(i)).is_ok() {
                delivered_expect.push(i);
            }
        }
        // barrier: flushes limbo ahead of itself; retry until it lands
        while t.send(0, 1, N, TMsg::Done).is_err() {}
        let mut got = Vec::new();
        loop {
            let env = t.recv(1).expect("sender alive");
            match env.msg {
                TMsg::Data(v) => got.push(v),
                TMsg::Done => break,
            }
        }
        assert_eq!(got, delivered_expect, "every non-lost envelope exactly once, in order");
        assert_eq!(
            t.buffered_envelopes(),
            0,
            "reassembly state must drain to zero after the barrier — unbounded ooo growth"
        );
    }

    #[test]
    fn step_scoped_partition_waits_for_its_step() {
        let plan = FaultPlan { partition: vec![(0, 1, 2)], ..FaultPlan::default() };
        let policy = RetryPolicy { base_delay_us: 1, max_delay_us: 4, ..RetryPolicy::default() };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        assert!(t.send(0, 1, 0, TMsg::Data(0)).is_ok(), "link healthy before its step");
        t.note_step(0, 2);
        assert!(t.send(0, 1, 1, TMsg::Data(1)).is_err(), "partition active from step 2");
    }

    #[test]
    fn one_sided_prices_the_same_ladder() {
        let plan = FaultPlan { drop: 0.5, seed: 3, ..FaultPlan::default() };
        let policy = RetryPolicy { base_delay_us: 1, max_delay_us: 2, ..RetryPolicy::default() };
        let t = FaultyTransport::<TMsg>::new(2, plan, policy);
        let mut spent = 0u32;
        for _ in 0..50 {
            spent += t.one_sided(0, 1, 1024).expect("50% drop with 8 retries succeeds");
        }
        assert!(spent > 0, "half the reads must have retried");
        assert_eq!(t.stats().retries as u32, spent);
    }

    #[test]
    fn parse_round_trips_the_cli_grammar() {
        let p = FaultPlan::parse("drop=0.05, dup=0.02,reorder=0.02,delay=0.01,seed=9,part=0:1:2")
            .unwrap();
        assert_eq!(
            p,
            FaultPlan {
                drop: 0.05,
                dup: 0.02,
                reorder: 0.02,
                delay: 0.01,
                seed: 9,
                partition: vec![(0, 1, 2)],
            }
        );
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("drop=1.5").is_err(), "rates are probabilities");
        assert!(FaultPlan::parse("drop=NaN").is_err(), "NaN rejected at parse time");
        assert!(FaultPlan::parse("part=0:0:1").is_err(), "self-link partition rejected");
        assert!(FaultPlan::parse("jitter=0.1").is_err(), "unknown keys rejected");
        assert!(FaultPlan::parse("part=0:1").is_err(), "partition arity enforced");
    }

    #[test]
    fn backoff_ladder_is_capped_exponential() {
        let p = RetryPolicy::default();
        assert_eq!(p.backoff_us(0), p.base_delay_us);
        assert_eq!(p.backoff_us(1), 2 * p.base_delay_us);
        assert!(p.backoff_us(30) <= p.max_delay_us);
    }

    impl WireCodec for TMsg {
        fn encode(&self, out: &mut Vec<u8>) -> bool {
            match self {
                TMsg::Data(v) => {
                    out.push(0);
                    frame::put_u64(out, *v);
                }
                TMsg::Done => out.push(1),
            }
            true
        }
        fn decode(bytes: &[u8]) -> Option<TMsg> {
            let mut r = frame::Reader::new(&bytes[1..]);
            match bytes.first()? {
                0 => Some(TMsg::Data(r.u64()?)),
                1 => Some(TMsg::Done),
                _ => None,
            }
        }
    }

    #[test]
    fn frame_codec_round_trips() {
        let env = Envelope { src: 3, seq: 41, micro: 7, msg: TMsg::Data(0xDEAD_BEEF) };
        let bytes = frame::encode(99, &env).expect("Data is wire-encodable");
        let (ticket, back) = frame::decode::<TMsg>(&bytes).expect("well-formed frame");
        assert_eq!(ticket, 99);
        assert_eq!((back.src, back.seq, back.micro), (3, 41, 7));
        assert_eq!(back.msg, TMsg::Data(0xDEAD_BEEF));
        assert!(frame::decode::<TMsg>(&bytes[..frame::HEADER - 1]).is_none(), "truncated header");
        assert!(frame::decode::<TMsg>(&bytes[..frame::HEADER]).is_none(), "truncated payload");
    }

    #[test]
    fn transport_kind_parses_the_cli_grammar() {
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::Inproc));
        assert_eq!(TransportKind::parse("shm"), Some(TransportKind::Shm));
        assert_eq!(TransportKind::parse("ring"), Some(TransportKind::Shm));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("UDS"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("rdma"), None);
        assert_eq!(TransportKind::default().to_string(), "inproc");
    }
}
