//! AsyncPS backend: the classical parameter-server throughput regime
//! the paper deliberately stops short of — dedicated shard servers,
//! free-running workers, bounded staleness.
//!
//! The synchronous ODC backend ties three things to the end of every
//! minibatch: the gradient fold (quorum of `Done`s), the optimizer
//! apply, and the `end_step` barrier that readmits every worker at
//! once. AsyncPS decouples them:
//!
//! * Each device's daemon is a **shard server** that buffers gradient
//!   pieces *per minibatch* (`Msg::Accum` carries the minibatch index
//!   `mb`) — so traffic from minibatch `t+1` can arrive while `t` is
//!   still folding. The synchronous daemon counts one cumulative quorum
//!   because the barrier guarantees no cross-minibatch overlap; here
//!   that guarantee is gone, so the protocol tags everything.
//! * The engine runs one **server thread** per shard driving
//!   [`CommBackend::server_flush`]`(shard, mb)` → fold → Adam →
//!   parameter write-back → [`ParamStore::publish_apply`]. Workers
//!   never wait for it.
//! * Workers are **admission-gated**, not barriered: before minibatch
//!   `t` a worker blocks in [`ParamStore::wait_min_applies`]`(t - k)`
//!   until the slowest shard has applied minibatch `t-k-1`'s fold.
//!   `k = 0` demands every shard has applied `t-1` — exactly the
//!   synchronous barrier condition — and because the fold itself is the
//!   same id-keyed `(micro, client)` sort over the same pieces, a
//!   `k = 0` run is **bit-identical** to synchronous ODC
//!   (`tests/async_prop.rs` pins it). `k > 0` lets fast workers run up
//!   to `k` minibatches ahead of the slowest apply — the classical
//!   bounded-staleness contract (SSP): no worker ever computes on
//!   parameters older than `k` applies behind its own minibatch index.
//!
//! Gathers stay one-sided and cacheable, but each shard slice is read
//! under the [`ParamStore`] per-shard reader gate: at `k > 0` a server
//! may rewrite its shard while a worker gathers, and the gate is what
//! keeps a gather from observing a half-written shard (a *stale* shard
//! is the contract; a *torn* one is not). Determinism scope: `k = 0`
//! bit-identical; `k > 0` schedule-dependent by design — which
//! minibatch's params a worker sees depends on real timing, exactly
//! the throughput-vs-freshness trade the staleness ablation measures.
//!
//! Legality (enforced by `RunSpec::validate` before anything is built):
//! ODC scheme only, LB-Mini/Queue balancers, static membership, clean
//! transport — no fail/join events, no fault plans, no seq-split. The
//! wire dtype and byte transports (`shm`/`uds`) compose freely.

use super::arena::{ArenaMatrix, ArenaStats, PayloadArena};
use super::backend::{CommBackend, GatherPolicy, HotpathStats, ParamStore};
use super::fold::{self, FoldPiece, PieceData, WireDtype};
use super::ring::RingTransport;
use super::socket::SocketTransport;
use super::transport::{
    frame, InProcTransport, Transport, TransportKind, WireCodec, WireMsg,
};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone)]
enum Msg {
    /// One gradient piece for this server's shard of `layer`, pushed by
    /// `client` for global microbatch `micro` OF MINIBATCH `mb`. Unlike
    /// the synchronous protocol the minibatch index is on the wire:
    /// without a barrier, pieces of `mb+1` can land while `mb` is still
    /// folding, and the server files each into its minibatch bucket.
    Accum { mb: u64, layer: usize, micro: u64, weight: f32, client: usize, data: Vec<u8> },
    /// `client` finished every microbatch of minibatch `mb`. Tagged for
    /// the same reason: Dones for different minibatches interleave.
    Done { mb: u64, client: usize },
    /// The shard's server thread asks for minibatch `mb`'s completed
    /// fold; the daemon replies once all `world` clients are done with
    /// `mb`. Rides the ticketed local lane (self-link only).
    Flush { mb: u64, reply: mpsc::Sender<Vec<Vec<f32>>> },
    Shutdown,
}

impl WireMsg for Msg {
    fn is_barrier(&self) -> bool {
        !matches!(self, Msg::Accum { .. })
    }
    fn payload_bytes(&self) -> usize {
        match self {
            Msg::Accum { data, .. } => data.len(),
            _ => 0,
        }
    }
}

impl WireCodec for Msg {
    fn encode(&self, out: &mut Vec<u8>) -> bool {
        match self {
            Msg::Accum { mb, layer, micro, weight, client, data } => {
                out.push(0);
                frame::put_u64(out, *mb);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *micro);
                frame::put_f32(out, *weight);
                frame::put_u64(out, *client as u64);
                frame::put_bytes(out, data);
            }
            Msg::Done { mb, client } => {
                out.push(1);
                frame::put_u64(out, *mb);
                frame::put_u64(out, *client as u64);
            }
            // Flush carries an mpsc reply channel — process-local by
            // nature, it rides the transport's ticketed local lane.
            Msg::Flush { .. } => return false,
            Msg::Shutdown => out.push(2),
        }
        true
    }

    fn decode(bytes: &[u8]) -> Option<Msg> {
        let mut r = frame::Reader::new(bytes.get(1..)?);
        let msg = match bytes.first()? {
            0 => Msg::Accum {
                mb: r.u64()?,
                layer: r.u64()? as usize,
                micro: r.u64()?,
                weight: r.f32()?,
                client: r.u64()? as usize,
                data: r.bytes()?,
            },
            1 => Msg::Done { mb: r.u64()?, client: r.u64()? as usize },
            2 => Msg::Shutdown,
            _ => return None,
        };
        if !r.done() {
            return None;
        }
        Some(msg)
    }
}

/// A buffered piece awaiting its minibatch's fold.
struct Piece {
    micro: u64,
    client: usize,
    weight: f32,
    data: Vec<u8>,
}

/// Everything a server has buffered for one in-flight minibatch.
struct MbState {
    /// Per-layer pieces, folded `(micro, client)`-keyed at the flush.
    pending: Vec<Vec<Piece>>,
    /// Clients done with this minibatch (static world: quorum = world).
    done: usize,
    /// The server thread's flush request, parked until the quorum.
    reply: Option<mpsc::Sender<Vec<Vec<f32>>>>,
}

impl MbState {
    fn new(layers: usize) -> Self {
        MbState { pending: (0..layers).map(|_| Vec::new()).collect(), done: 0, reply: None }
    }
}

pub struct AsyncPs {
    world: usize,
    /// The staleness bound `k`: how many minibatches a worker may run
    /// ahead of the slowest shard's apply. Admission itself lives in
    /// the trainer (`ParamStore::wait_min_applies`); the backend keeps
    /// the bound for reporting and asserts.
    staleness: usize,
    params: Arc<ParamStore>,
    transport: Arc<dyn Transport<Msg>>,
    /// Folded gradients staged by `server_flush`, consumed by the shard
    /// server thread's `take_grad_shard`.
    taken: Vec<Mutex<Option<Vec<Vec<f32>>>>>,
    daemons: Mutex<Vec<JoinHandle<()>>>,
    /// Payload arenas indexed `[server][client]`. In-flight payloads
    /// grow to ~(k+1) minibatches per pair — the arena grows on demand
    /// past its single-minibatch prealloc and keeps the buffers
    /// thereafter, so steady state is still allocation-free.
    arenas: ArenaMatrix,
    /// Each worker's current minibatch index (the `mb` its pushes are
    /// tagged with); advanced by its own `end_minibatch`.
    cur_mb: Vec<AtomicUsize>,
    wire: WireDtype,
    /// Error-feedback residuals, `[dev][layer]` (empty under `F32`).
    residuals: Vec<Vec<Mutex<Vec<f32>>>>,
    wire_bytes: Arc<AtomicU64>,
    fold_ns: Arc<AtomicU64>,
}

impl AsyncPs {
    /// Build over a byte transport. `pub(crate)`: construct through
    /// [`crate::comm::CommStack`] — the builder is the only public
    /// door, and it enforces the legality matrix (static membership,
    /// no faults) before this runs.
    pub(crate) fn with_stack(
        params: Arc<ParamStore>,
        world: usize,
        staleness: usize,
        wire: WireDtype,
        kind: TransportKind,
    ) -> std::io::Result<Self> {
        let transport: Arc<dyn Transport<Msg>> = match kind {
            TransportKind::Inproc => Arc::new(InProcTransport::new(world)),
            TransportKind::Shm => Arc::new(RingTransport::new(world)),
            TransportKind::Uds => Arc::new(SocketTransport::bind_world(world)?),
        };
        let shard_lens: Vec<usize> = params.layers.iter().map(|l| l.shard_len).collect();
        let mut caps: Vec<usize> = shard_lens.iter().map(|&l| wire.bytes_for(l)).collect();
        caps.push(caps.iter().copied().max().unwrap_or(0));
        let arenas = ArenaMatrix::new(world, world, &caps);
        let fold_threads = fold::default_fold_threads();
        let fold_ns = Arc::new(AtomicU64::new(0));
        let mut daemons = Vec::with_capacity(world);
        for server in 0..world {
            let lens = shard_lens.clone();
            let row = arenas.row(server);
            let link = Arc::clone(&transport);
            let ns = Arc::clone(&fold_ns);
            daemons.push(std::thread::spawn(move || {
                server_loop(server, link, lens, world, row, wire, fold_threads, ns)
            }));
        }
        let residuals = (0..world)
            .map(|_| {
                params
                    .layers
                    .iter()
                    .map(|l| {
                        Mutex::new(match wire {
                            WireDtype::F32 => Vec::new(),
                            WireDtype::Bf16 => vec![0.0; l.padded_len()],
                        })
                    })
                    .collect()
            })
            .collect();
        Ok(AsyncPs {
            world,
            staleness,
            params,
            transport,
            taken: (0..world).map(|_| Mutex::new(None)).collect(),
            daemons: Mutex::new(daemons),
            arenas,
            cur_mb: (0..world).map(|_| AtomicUsize::new(0)).collect(),
            wire,
            residuals,
            wire_bytes: Arc::new(AtomicU64::new(0)),
            fold_ns,
        })
    }

    /// The configured staleness bound `k`.
    pub fn staleness(&self) -> usize {
        self.staleness
    }

    /// Summed payload-arena counters (tests): the push path stays
    /// allocation-free once the (k+1)-minibatch working set is warm.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arenas.stats()
    }
}

/// Fold one layer's pieces in `(micro, client)` order — the SAME pure
/// ordering rule as the synchronous daemon, which is what makes the
/// `k = 0` degenerate case bit-identical — and send every payload home.
fn fold_layer(
    pieces: &mut Vec<Piece>,
    len: usize,
    arenas: &[Arc<PayloadArena>],
    wire: WireDtype,
    threads: usize,
) -> Vec<f32> {
    pieces.sort_by_key(|p| (p.micro, p.client));
    let mut acc = vec![0.0f32; len];
    let inputs: Vec<FoldPiece> = pieces
        .iter()
        .map(|p| FoldPiece { weight: p.weight, data: PieceData::Wire(&p.data, wire) })
        .collect();
    fold::fold_pieces(&mut acc, &inputs, threads);
    drop(inputs);
    for p in pieces.drain(..) {
        arenas[p.client].release(p.data);
    }
    acc
}

/// The shard-server daemon: a per-minibatch bucketed state machine.
/// Unlike the synchronous daemon it never counts a cumulative quorum —
/// every message names its minibatch, buckets are folded and retired
/// independently, and any number may be in flight at once (bounded by
/// the admission gate to k+1 in practice).
#[allow(clippy::too_many_arguments)]
fn server_loop(
    me: usize,
    transport: Arc<dyn Transport<Msg>>,
    shard_lens: Vec<usize>,
    world: usize,
    arenas: Vec<Arc<PayloadArena>>,
    wire: WireDtype,
    fold_threads: usize,
    fold_ns: Arc<AtomicU64>,
) {
    let mut inflight: BTreeMap<u64, MbState> = BTreeMap::new();
    loop {
        let msg = match transport.recv(me) {
            Some(env) => env.msg,
            None => return,
        };
        let touched = match msg {
            Msg::Accum { mb, layer, micro, weight, client, data } => {
                let st = inflight.entry(mb).or_insert_with(|| MbState::new(shard_lens.len()));
                // idempotent (belt and braces over transport dedup):
                // (micro, client) identifies a push within a minibatch
                if st.pending[layer].iter().any(|p| p.micro == micro && p.client == client) {
                    arenas[client].release(data);
                } else {
                    st.pending[layer].push(Piece { micro, client, weight, data });
                }
                mb
            }
            Msg::Done { mb, client } => {
                debug_assert!(client < world);
                let st = inflight.entry(mb).or_insert_with(|| MbState::new(shard_lens.len()));
                st.done += 1;
                mb
            }
            Msg::Flush { mb, reply } => {
                let st = inflight.entry(mb).or_insert_with(|| MbState::new(shard_lens.len()));
                st.reply = Some(reply);
                mb
            }
            Msg::Shutdown => return,
        };
        let ready = inflight
            .get(&touched)
            .map(|st| st.done == world && st.reply.is_some())
            .unwrap_or(false);
        if ready {
            let mut st = inflight.remove(&touched).expect("bucket just checked");
            let t0 = Instant::now();
            let out: Vec<Vec<f32>> = st
                .pending
                .iter_mut()
                .zip(&shard_lens)
                .map(|(pieces, &len)| fold_layer(pieces, len, &arenas, wire, fold_threads))
                .collect();
            fold_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let _ = st.reply.take().expect("bucket just checked").send(out);
        }
    }
}

impl CommBackend for AsyncPs {
    fn world(&self) -> usize {
        self.world
    }

    fn gather_params(&self, dev: usize, layer: usize, out: &mut [f32]) {
        // One-sided read, per shard slice under the owner's reader
        // gate: at k > 0 a shard server may be writing its slice back
        // concurrently, and the gate keeps each shard's bytes whole
        // (stale-but-consistent — the SSP contract). At k = 0 the gates
        // are uncontended and the read is the synchronous one.
        let p = &self.params.layers[layer];
        for server in 0..self.world {
            let r = p.shard_range(server);
            let bytes = self.wire.bytes_for(r.len());
            let _ = self.transport.one_sided(dev, server, bytes);
            let n = r.end.min(out.len());
            if r.start < n {
                let _gate = self.params.shard_read(server);
                p.buf.read(r.start, &mut out[r.start..n]);
            }
        }
    }

    fn gather_policy(&self) -> GatherPolicy {
        // Same shape as ODC: one-sided reads, cacheable within the
        // minibatch. The admission gate took the place of end_step as
        // the cache-invalidation boundary (the trainer invalidates per
        // minibatch in the async loop).
        GatherPolicy::OneSided
    }

    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32, micro: u64) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        if weight == 0.0 {
            return;
        }
        let mb = self.cur_mb[dev].load(Ordering::Relaxed) as u64;
        let mut residual = self.residuals[dev][layer].lock().unwrap();
        for server in 0..self.world {
            let r = p.shard_range(server);
            let mut data = self.arenas.arena(server, dev).acquire(self.wire.bytes_for(r.len()));
            let src = &grad[r.clone()];
            match self.wire {
                WireDtype::F32 => fold::encode(&mut data, src, self.wire),
                WireDtype::Bf16 => fold::encode_ef(&mut data, src, &mut residual[r], self.wire),
            }
            self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
            let msg = Msg::Accum { mb, layer, micro, weight, client: dev, data };
            self.transport.send(dev, server, micro, msg).expect("async-ps transport is clean");
        }
    }

    fn end_minibatch(&self, dev: usize) {
        // NON-blocking, the point of the tier: broadcast Done for the
        // current minibatch and move on. The shard servers fold when
        // their quorum lands; this worker is admission-gated at the TOP
        // of its next minibatch, not barriered at the bottom of this
        // one.
        let mb = self.cur_mb[dev].load(Ordering::Relaxed) as u64;
        for server in 0..self.world {
            self.transport
                .send(dev, server, 0, Msg::Done { mb, client: dev })
                .expect("async-ps transport is clean");
        }
        self.cur_mb[dev].fetch_add(1, Ordering::Relaxed);
    }

    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]) {
        let slot = self.taken[dev].lock().unwrap();
        let grads = slot.as_ref().expect("take_grad_shard before server_flush");
        out.copy_from_slice(&grads[layer]);
    }

    fn end_step(&self, _dev: usize) {
        // No step barrier — that's the tier's entire reason to exist.
        // Readmission happens through ParamStore::wait_min_applies at
        // the top of the worker's next minibatch.
    }

    fn server_flush(&self, shard: usize, mb: usize) {
        let (tx, rx) = mpsc::channel();
        self.transport
            .send(shard, shard, 0, Msg::Flush { mb: mb as u64, reply: tx })
            .expect("async-ps transport is clean");
        let grads = rx.recv().expect("shard server flush");
        *self.taken[shard].lock().unwrap() = Some(grads);
    }

    fn hotpath_stats(&self) -> HotpathStats {
        HotpathStats {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            fold_ns: self.fold_ns.load(Ordering::Relaxed),
        }
    }

    fn name(&self) -> &'static str {
        "async-ps"
    }
}

impl Drop for AsyncPs {
    fn drop(&mut self) {
        for server in 0..self.world {
            let _ = self.transport.send(server, server, 0, Msg::Shutdown);
        }
        for d in self.daemons.lock().unwrap().drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(world: usize, lens: &[usize], k: usize) -> (Arc<ParamStore>, Arc<AsyncPs>) {
        let params = Arc::new(ParamStore::new(lens, world));
        let comm = Arc::new(
            AsyncPs::with_stack(
                Arc::clone(&params),
                world,
                k,
                WireDtype::F32,
                TransportKind::Inproc,
            )
            .unwrap(),
        );
        (params, comm)
    }

    #[test]
    fn per_mb_buckets_fold_independently() {
        // Two minibatches fully in flight before ANY flush: each bucket
        // folds its own pieces — the synchronous daemon's cumulative
        // quorum counter would hopelessly conflate these.
        let world = 2;
        let (_params, comm) = mk(world, &[4], 1);
        for dev in 0..world {
            comm.reduce_grad(dev, 0, &[1.0; 4], 1.0, dev as u64);
            comm.end_minibatch(dev); // advances dev's cur_mb to 1
        }
        for dev in 0..world {
            comm.reduce_grad(dev, 0, &[10.0; 4], 1.0, dev as u64);
            comm.end_minibatch(dev);
        }
        for shard in 0..world {
            comm.server_flush(shard, 0);
            let mut g = vec![0.0; 2];
            comm.take_grad_shard(shard, 0, &mut g);
            assert_eq!(g, vec![2.0; 2], "mb 0: 1.0 from each of 2 clients");
            comm.server_flush(shard, 1);
            comm.take_grad_shard(shard, 0, &mut g);
            assert_eq!(g, vec![20.0; 2], "mb 1: 10.0 from each of 2 clients");
        }
    }

    #[test]
    fn fold_keyed_by_micro_id_not_push_order() {
        // Same determinism pin as the synchronous daemon: values chosen
        // so an arrival-order fold would differ in f32.
        let world = 2;
        let run = |push_order: &[(usize, u64, f32)]| -> Vec<Vec<f32>> {
            let (_params, comm) = mk(world, &[4], 0);
            for &(client, micro, val) in push_order {
                comm.reduce_grad(client, 0, &[val; 4], 1.0, micro);
            }
            for dev in 0..world {
                comm.end_minibatch(dev);
            }
            (0..world)
                .map(|shard| {
                    comm.server_flush(shard, 0);
                    let mut g = vec![0.0f32; 2];
                    comm.take_grad_shard(shard, 0, &mut g);
                    g
                })
                .collect()
        };
        let in_order = run(&[(0, 0, 1e8), (1, 1, 1.0), (0, 2, -1e8)]);
        let scrambled = run(&[(0, 2, -1e8), (0, 0, 1e8), (1, 1, 1.0)]);
        assert_eq!(in_order, scrambled, "push order must not change a bit");
        for shard in &in_order {
            assert_eq!(shard, &vec![0.0f32; 2], "(1e8 + 1.0) + (-1e8) == 0.0 in f32");
        }
    }

    #[test]
    fn late_flush_request_parks_until_quorum() {
        // Flush arriving before the last Done must park, not reply
        // early with a partial fold.
        let world = 2;
        let (_params, comm) = mk(world, &[4], 0);
        comm.reduce_grad(0, 0, &[3.0; 4], 1.0, 0);
        comm.end_minibatch(0);
        let c2 = Arc::clone(&comm);
        let waiter = std::thread::spawn(move || {
            c2.server_flush(0, 0); // parks: client 1 not done yet
            let mut g = vec![0.0; 2];
            c2.take_grad_shard(0, 0, &mut g);
            g
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        comm.reduce_grad(1, 0, &[4.0; 4], 1.0, 1);
        comm.end_minibatch(1);
        assert_eq!(waiter.join().unwrap(), vec![7.0; 2]);
    }

    #[test]
    fn shard_clock_gates_and_wakes() {
        let params = Arc::new(ParamStore::new(&[8], 2));
        assert_eq!(params.min_applies(), 0);
        params.publish_apply(0);
        assert_eq!(params.applies(0), 1);
        assert_eq!(params.min_applies(), 0, "shard 1 still at 0");
        let p2 = Arc::clone(&params);
        let waiter = std::thread::spawn(move || p2.wait_min_applies(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        params.publish_apply(1);
        assert_eq!(waiter.join().unwrap(), 1);
        assert_eq!(params.wait_min_applies(0), 1, "already-met target returns observed min");
    }
}
