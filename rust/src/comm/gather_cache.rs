//! Minibatch-scoped parameter-gather cache — the paper's §6.2 parameter
//! caching optimization, landed in the REAL trainer (the simulator's
//! `hierarchical_gather` models the same idea analytically).
//!
//! Parameters are immutable from the `end_step` barrier until the next
//! optimizer phase (the phase discipline documented in
//! [`crate::comm::shared`]), so within one minibatch every gather of a
//! layer returns identical bytes. The seed trainer nevertheless
//! re-gathered every block layer twice per MICROBATCH (forward +
//! backward recompute); with `m` microbatches that is `2m` full-layer
//! copies where one suffices. The cache gathers each layer at most once
//! per minibatch into an `Arc<[f32]>` slot and hands out refcount
//! clones — zero-copy for every subsequent use, including handing the
//! same block straight to PJRT via [`crate::runtime::Input::F32Shared`].
//!
//! The cache is only legal for backends whose `gather_params` is
//! one-sided — the backend states this structurally via
//! [`CommBackend::gather_policy`]. Under `Collective`
//! ([`GatherPolicy::Rendezvous`]) every gather is a whole-world
//! rendezvous, so skipping one would both change the synchronization
//! structure being measured and desynchronize the barrier schedule; a
//! disabled cache still owns the reusable buffers (steady-state
//! allocation-free) but performs the backend gather on every call,
//! preserving the seed call sequence exactly. The two-level hybrid
//! backend ([`GatherPolicy::TwoLevelIntra`]) caches exactly like ODC for
//! its intra-group gathers, while its cross-group epilogue (gradient
//! exchange + replica refresh) runs entirely inside the backend and
//! never routes through this cache — the refresh at `end_step` is
//! precisely the event `invalidate` accounts for.
//!
//! FastFold's streamed gathers build on the same phase immutability: a
//! prefetch worker may gather layer `l+1` while the device computes
//! layer `l` and deposit the result via [`GatherCache::adopt_prefetch`];
//! the bytes are bit-identical to a synchronous gather, and the first
//! use of a prefetched slot is counted as a miss so [`CacheStats`] are
//! invariant to whether streaming is on.

use super::backend::{CommBackend, GatherPolicy, ParamStore};
use std::sync::Arc;

/// Counters proving cache behaviour in tests and benches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Calls answered from the cache (no backend gather, no copy).
    pub hits: u64,
    /// Calls that performed a real backend gather.
    pub misses: u64,
    /// Buffer allocations (first touch per layer; steady state: none).
    pub fresh_allocs: u64,
}

struct Slot {
    /// Reusable gather target; `None` only before first use.
    buf: Option<Arc<[f32]>>,
    /// Whether `buf` holds this minibatch's gather of the layer.
    valid: bool,
    /// `buf` was filled by a prefetch worker ([`GatherCache::adopt_prefetch`])
    /// and has not been handed out yet. The first `gather` of such a slot
    /// still counts as a miss — a real backend gather DID happen for that
    /// request, just early — so [`CacheStats`] stay identical whether
    /// streaming is on or off.
    prefetched: bool,
}

/// Per-device-thread gather cache (single-threaded by construction: each
/// device owns one, mirroring per-device cache memory on a real node).
pub struct GatherCache {
    dev: usize,
    policy: GatherPolicy,
    padded_lens: Vec<usize>,
    slots: Vec<Slot>,
    stats: CacheStats,
}

impl GatherCache {
    /// Boolean convenience constructor: `enabled` maps to
    /// [`GatherPolicy::OneSided`] / [`GatherPolicy::Rendezvous`].
    pub fn new(params: &ParamStore, dev: usize, enabled: bool) -> Self {
        let policy = if enabled { GatherPolicy::OneSided } else { GatherPolicy::Rendezvous };
        Self::for_policy(params, dev, policy)
    }

    /// Cache honouring the backend's structural gather classification
    /// (pass [`CommBackend::gather_policy`], downgraded to
    /// `Rendezvous` when the engine disables caching by config).
    pub fn for_policy(params: &ParamStore, dev: usize, policy: GatherPolicy) -> Self {
        let padded_lens: Vec<usize> = params.layers.iter().map(|l| l.padded_len()).collect();
        GatherCache {
            dev,
            policy,
            slots: padded_lens
                .iter()
                .map(|_| Slot { buf: None, valid: false, prefetched: false })
                .collect(),
            padded_lens,
            stats: CacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.policy.cacheable()
    }

    /// The per-level cacheability this cache was built with.
    pub fn policy(&self) -> GatherPolicy {
        self.policy
    }

    /// The full padded parameters of `layer`, gathering through
    /// `backend` only on a miss (or always, when disabled). The returned
    /// `Arc` aliases the cache slot: dropping it before the next
    /// minibatch keeps the slot uniquely owned and reusable in place.
    pub fn gather(&mut self, backend: &dyn CommBackend, layer: usize) -> Arc<[f32]> {
        let enabled = self.policy.cacheable();
        let slot = &mut self.slots[layer];
        if enabled && slot.valid {
            if slot.prefetched {
                // First use of a streamed gather: the backend gather
                // happened (in the prefetch worker), so this is a miss.
                slot.prefetched = false;
                self.stats.misses += 1;
            } else {
                self.stats.hits += 1;
            }
            return Arc::clone(slot.buf.as_ref().expect("valid slot holds a buffer"));
        }
        // Reuse the slot allocation when uniquely owned; otherwise (a
        // caller still holds last minibatch's Arc) allocate fresh.
        let mut buf = match slot.buf.take() {
            Some(b) if Arc::strong_count(&b) == 1 => b,
            _ => {
                self.stats.fresh_allocs += 1;
                vec![0.0f32; self.padded_lens[layer]].into()
            }
        };
        backend.gather_params(self.dev, layer, Arc::get_mut(&mut buf).expect("uniquely owned"));
        self.stats.misses += 1;
        let out = Arc::clone(&buf);
        slot.buf = Some(buf);
        slot.valid = enabled;
        out
    }

    /// Whether a streamed (prefetched) gather of `layer` would be
    /// adopted right now: caching must be enabled and the slot must not
    /// already hold this minibatch's bytes. The trainer's prefetch loop
    /// consults this before posting a request so it never performs a
    /// backend gather the cache would discard.
    pub fn wants_prefetch(&self, layer: usize) -> bool {
        self.policy.cacheable() && !self.slots[layer].valid
    }

    /// Adopt a gather performed ahead of time by a prefetch worker
    /// (FastFold streamed gathers). Legal only because params are
    /// phase-immutable: a prefetch taken any time after `end_step` is
    /// bit-identical to one taken at use. Ignored (buffer dropped) when
    /// the slot is already valid or caching is disabled, so racing a
    /// synchronous gather is harmless.
    pub fn adopt_prefetch(&mut self, layer: usize, buf: Arc<[f32]>) {
        if !self.wants_prefetch(layer) {
            return;
        }
        debug_assert_eq!(buf.len(), self.padded_lens[layer]);
        let slot = &mut self.slots[layer];
        slot.buf = Some(buf);
        slot.valid = true;
        slot.prefetched = true;
    }

    /// Invalidate every slot. Call right after `end_step`: owners have
    /// republished their shards, so cached bytes are stale.
    pub fn invalidate(&mut self) {
        for slot in &mut self.slots {
            slot.valid = false;
            slot.prefetched = false;
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::OdcComm;

    fn store(lens: &[usize], world: usize) -> Arc<ParamStore> {
        let params = Arc::new(ParamStore::new(lens, world));
        for (l, p) in params.layers.iter().enumerate() {
            let vals: Vec<f32> = (0..p.logical_len).map(|i| (l * 1000 + i) as f32).collect();
            p.init_from(&vals);
        }
        params
    }

    #[test]
    fn cached_gather_is_bit_identical_to_direct() {
        let params = store(&[10, 7], 2);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut cache = GatherCache::new(&params, 0, true);
        for layer in 0..2 {
            let mut direct = vec![0.0f32; params.layers[layer].padded_len()];
            comm.gather_params(0, layer, &mut direct);
            for _ in 0..3 {
                let cached = cache.gather(&comm, layer);
                assert_eq!(&cached[..], &direct[..], "layer {layer}");
            }
        }
        let s = cache.stats();
        assert_eq!(s.misses, 2, "one real gather per layer");
        assert_eq!(s.hits, 4);
        assert_eq!(s.fresh_allocs, 2, "one buffer per layer, ever");
    }

    #[test]
    fn invalidate_rereads_updated_params() {
        let params = store(&[6], 1);
        let comm = OdcComm::new(Arc::clone(&params), 1);
        let mut cache = GatherCache::new(&params, 0, true);
        let before = cache.gather(&comm, 0);
        assert_eq!(before[0], 0.0);
        drop(before);
        params.layers[0].init_from(&[9.0; 6]);
        // without invalidation: stale by design (params "immutable")
        assert_eq!(cache.gather(&comm, 0)[0], 0.0);
        cache.invalidate();
        assert_eq!(cache.gather(&comm, 0)[0], 9.0);
        // slot allocation was reused, not reallocated
        assert_eq!(cache.stats().fresh_allocs, 1);
    }

    #[test]
    fn disabled_cache_gathers_every_call_but_reuses_buffer() {
        let params = store(&[8], 2);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut cache = GatherCache::new(&params, 1, false);
        for _ in 0..5 {
            let g = cache.gather(&comm, 0);
            assert_eq!(g[0], 0.0);
        }
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 5, "disabled cache must preserve the seed gather sequence");
        assert_eq!(s.fresh_allocs, 1, "but still reuse its buffer");
    }

    #[test]
    fn policy_levels_map_to_cacheability() {
        let params = store(&[4], 2);
        for (policy, cached) in [
            (GatherPolicy::Rendezvous, false),
            (GatherPolicy::OneSided, true),
            (GatherPolicy::TwoLevelIntra, true),
        ] {
            let cache = GatherCache::for_policy(&params, 0, policy);
            assert_eq!(cache.enabled(), cached, "{policy:?}");
            assert_eq!(cache.policy(), policy);
        }
    }

    #[test]
    fn prefetched_slot_counts_first_use_as_miss_then_hits() {
        let params = store(&[10], 2);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut cache = GatherCache::new(&params, 0, true);
        assert!(cache.wants_prefetch(0));
        let mut pre = vec![0.0f32; params.layers[0].padded_len()];
        comm.gather_params(0, 0, &mut pre);
        cache.adopt_prefetch(0, pre.clone().into());
        assert!(!cache.wants_prefetch(0), "valid slot must not re-prefetch");
        let mut direct = vec![0.0f32; params.layers[0].padded_len()];
        comm.gather_params(0, 0, &mut direct);
        for i in 0..3 {
            let g = cache.gather(&comm, 0);
            assert_eq!(&g[..], &direct[..], "use {i}");
        }
        let s = cache.stats();
        assert_eq!(s.misses, 1, "adopted prefetch IS the layer's one real gather");
        assert_eq!(s.hits, 2);
        assert_eq!(s.fresh_allocs, 0, "prefetch buffers are allocated by the stream");
        cache.invalidate();
        assert!(cache.wants_prefetch(0), "invalidate re-arms prefetching");
    }

    #[test]
    fn adopt_is_ignored_when_slot_valid_or_cache_disabled() {
        let params = store(&[6], 1);
        let comm = OdcComm::new(Arc::clone(&params), 1);
        let mut cache = GatherCache::new(&params, 0, true);
        let first = cache.gather(&comm, 0);
        cache.adopt_prefetch(0, vec![99.0f32; params.layers[0].padded_len()].into());
        assert_eq!(&cache.gather(&comm, 0)[..], &first[..], "late prefetch must be dropped");
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, fresh_allocs: 1 });

        let mut off = GatherCache::new(&params, 0, false);
        assert!(!off.wants_prefetch(0));
        off.adopt_prefetch(0, vec![99.0f32; params.layers[0].padded_len()].into());
        assert_eq!(&off.gather(&comm, 0)[..], &first[..]);
        assert_eq!(off.stats().misses, 1, "disabled cache still gathers every call");
    }

    #[test]
    fn outstanding_reference_forces_fresh_alloc_not_corruption() {
        let params = store(&[4], 1);
        let comm = OdcComm::new(Arc::clone(&params), 1);
        let mut cache = GatherCache::new(&params, 0, true);
        let held = cache.gather(&comm, 0);
        let snapshot: Vec<f32> = held.to_vec();
        cache.invalidate();
        params.layers[0].init_from(&[5.0; 4]);
        let fresh = cache.gather(&comm, 0);
        assert_eq!(&held[..], &snapshot[..], "held Arc must never be mutated underneath");
        assert_eq!(fresh[0], 5.0);
        assert_eq!(cache.stats().fresh_allocs, 2);
    }
}
