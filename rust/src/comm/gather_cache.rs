//! Minibatch-scoped parameter-gather cache — the paper's §6.2 parameter
//! caching optimization, landed in the REAL trainer (the simulator's
//! `hierarchical_gather` models the same idea analytically).
//!
//! Parameters are immutable from the `end_step` barrier until the next
//! optimizer phase (the phase discipline documented in
//! [`crate::comm::shared`]), so within one minibatch every gather of a
//! layer returns identical bytes. The seed trainer nevertheless
//! re-gathered every block layer twice per MICROBATCH (forward +
//! backward recompute); with `m` microbatches that is `2m` full-layer
//! copies where one suffices. The cache gathers each layer at most once
//! per minibatch into an `Arc<[f32]>` slot and hands out refcount
//! clones — zero-copy for every subsequent use, including handing the
//! same block straight to PJRT via [`crate::runtime::Input::F32Shared`].
//!
//! The cache is only legal for backends whose `gather_params` is
//! one-sided — the backend states this structurally via
//! [`CommBackend::gather_policy`]. Under `Collective`
//! ([`GatherPolicy::Rendezvous`]) every gather is a whole-world
//! rendezvous, so skipping one would both change the synchronization
//! structure being measured and desynchronize the barrier schedule; a
//! disabled cache still owns the reusable buffers (steady-state
//! allocation-free) but performs the backend gather on every call,
//! preserving the seed call sequence exactly. The two-level hybrid
//! backend ([`GatherPolicy::TwoLevelIntra`]) caches exactly like ODC for
//! its intra-group gathers, while its cross-group epilogue (gradient
//! exchange + replica refresh) runs entirely inside the backend and
//! never routes through this cache — the refresh at `end_step` is
//! precisely the event `invalidate` accounts for.

use super::backend::{CommBackend, GatherPolicy, ParamStore};
use std::sync::Arc;

/// Counters proving cache behaviour in tests and benches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Calls answered from the cache (no backend gather, no copy).
    pub hits: u64,
    /// Calls that performed a real backend gather.
    pub misses: u64,
    /// Buffer allocations (first touch per layer; steady state: none).
    pub fresh_allocs: u64,
}

struct Slot {
    /// Reusable gather target; `None` only before first use.
    buf: Option<Arc<[f32]>>,
    /// Whether `buf` holds this minibatch's gather of the layer.
    valid: bool,
}

/// Per-device-thread gather cache (single-threaded by construction: each
/// device owns one, mirroring per-device cache memory on a real node).
pub struct GatherCache {
    dev: usize,
    policy: GatherPolicy,
    padded_lens: Vec<usize>,
    slots: Vec<Slot>,
    stats: CacheStats,
}

impl GatherCache {
    /// Boolean convenience constructor: `enabled` maps to
    /// [`GatherPolicy::OneSided`] / [`GatherPolicy::Rendezvous`].
    pub fn new(params: &ParamStore, dev: usize, enabled: bool) -> Self {
        let policy = if enabled { GatherPolicy::OneSided } else { GatherPolicy::Rendezvous };
        Self::for_policy(params, dev, policy)
    }

    /// Cache honouring the backend's structural gather classification
    /// (pass [`CommBackend::gather_policy`], downgraded to
    /// `Rendezvous` when the engine disables caching by config).
    pub fn for_policy(params: &ParamStore, dev: usize, policy: GatherPolicy) -> Self {
        let padded_lens: Vec<usize> = params.layers.iter().map(|l| l.padded_len()).collect();
        GatherCache {
            dev,
            policy,
            slots: padded_lens.iter().map(|_| Slot { buf: None, valid: false }).collect(),
            padded_lens,
            stats: CacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.policy.cacheable()
    }

    /// The per-level cacheability this cache was built with.
    pub fn policy(&self) -> GatherPolicy {
        self.policy
    }

    /// The full padded parameters of `layer`, gathering through
    /// `backend` only on a miss (or always, when disabled). The returned
    /// `Arc` aliases the cache slot: dropping it before the next
    /// minibatch keeps the slot uniquely owned and reusable in place.
    pub fn gather(&mut self, backend: &dyn CommBackend, layer: usize) -> Arc<[f32]> {
        let enabled = self.policy.cacheable();
        let slot = &mut self.slots[layer];
        if enabled && slot.valid {
            self.stats.hits += 1;
            return Arc::clone(slot.buf.as_ref().expect("valid slot holds a buffer"));
        }
        // Reuse the slot allocation when uniquely owned; otherwise (a
        // caller still holds last minibatch's Arc) allocate fresh.
        let mut buf = match slot.buf.take() {
            Some(b) if Arc::strong_count(&b) == 1 => b,
            _ => {
                self.stats.fresh_allocs += 1;
                vec![0.0f32; self.padded_lens[layer]].into()
            }
        };
        backend.gather_params(self.dev, layer, Arc::get_mut(&mut buf).expect("uniquely owned"));
        self.stats.misses += 1;
        let out = Arc::clone(&buf);
        slot.buf = Some(buf);
        slot.valid = enabled;
        out
    }

    /// Invalidate every slot. Call right after `end_step`: owners have
    /// republished their shards, so cached bytes are stale.
    pub fn invalidate(&mut self) {
        for slot in &mut self.slots {
            slot.valid = false;
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::OdcComm;

    fn store(lens: &[usize], world: usize) -> Arc<ParamStore> {
        let params = Arc::new(ParamStore::new(lens, world));
        for (l, p) in params.layers.iter().enumerate() {
            let vals: Vec<f32> = (0..p.logical_len).map(|i| (l * 1000 + i) as f32).collect();
            p.init_from(&vals);
        }
        params
    }

    #[test]
    fn cached_gather_is_bit_identical_to_direct() {
        let params = store(&[10, 7], 2);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut cache = GatherCache::new(&params, 0, true);
        for layer in 0..2 {
            let mut direct = vec![0.0f32; params.layers[layer].padded_len()];
            comm.gather_params(0, layer, &mut direct);
            for _ in 0..3 {
                let cached = cache.gather(&comm, layer);
                assert_eq!(&cached[..], &direct[..], "layer {layer}");
            }
        }
        let s = cache.stats();
        assert_eq!(s.misses, 2, "one real gather per layer");
        assert_eq!(s.hits, 4);
        assert_eq!(s.fresh_allocs, 2, "one buffer per layer, ever");
    }

    #[test]
    fn invalidate_rereads_updated_params() {
        let params = store(&[6], 1);
        let comm = OdcComm::new(Arc::clone(&params), 1);
        let mut cache = GatherCache::new(&params, 0, true);
        let before = cache.gather(&comm, 0);
        assert_eq!(before[0], 0.0);
        drop(before);
        params.layers[0].init_from(&[9.0; 6]);
        // without invalidation: stale by design (params "immutable")
        assert_eq!(cache.gather(&comm, 0)[0], 0.0);
        cache.invalidate();
        assert_eq!(cache.gather(&comm, 0)[0], 9.0);
        // slot allocation was reused, not reallocated
        assert_eq!(cache.stats().fresh_allocs, 1);
    }

    #[test]
    fn disabled_cache_gathers_every_call_but_reuses_buffer() {
        let params = store(&[8], 2);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut cache = GatherCache::new(&params, 1, false);
        for _ in 0..5 {
            let g = cache.gather(&comm, 0);
            assert_eq!(g[0], 0.0);
        }
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 5, "disabled cache must preserve the seed gather sequence");
        assert_eq!(s.fresh_allocs, 1, "but still reuse its buffer");
    }

    #[test]
    fn policy_levels_map_to_cacheability() {
        let params = store(&[4], 2);
        for (policy, cached) in [
            (GatherPolicy::Rendezvous, false),
            (GatherPolicy::OneSided, true),
            (GatherPolicy::TwoLevelIntra, true),
        ] {
            let cache = GatherCache::for_policy(&params, 0, policy);
            assert_eq!(cache.enabled(), cached, "{policy:?}");
            assert_eq!(cache.policy(), policy);
        }
    }

    #[test]
    fn outstanding_reference_forces_fresh_alloc_not_corruption() {
        let params = store(&[4], 1);
        let comm = OdcComm::new(Arc::clone(&params), 1);
        let mut cache = GatherCache::new(&params, 0, true);
        let held = cache.gather(&comm, 0);
        let snapshot: Vec<f32> = held.to_vec();
        cache.invalidate();
        params.layers[0].init_from(&[5.0; 4]);
        let fresh = cache.gather(&comm, 0);
        assert_eq!(&held[..], &snapshot[..], "held Arc must never be mutated underneath");
        assert_eq!(fresh[0], 5.0);
        assert_eq!(cache.stats().fresh_allocs, 2);
    }
}
