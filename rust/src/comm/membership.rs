//! ElasticWorld: fault-tolerant elastic membership for the one-sided
//! backends — the classical parameter-server property that collective
//! FSDP structurally cannot offer (one dead rank deadlocks the next
//! all-gather, while a dead PS client simply stops pushing).
//!
//! ## Failure model
//!
//! A device is a *worker* (its pull/compute thread) plus a *shard
//! server* (the accumulation daemon owning its parameter/optimizer
//! shard). A **crash** kills the worker mid-minibatch: it stops pulling
//! microbatches, never sends its end-of-minibatch `Done`, and never
//! reaches another barrier. The shard server is infrastructure — like a
//! real PS server process it survives the worker (its state is exactly
//! the replicated store the paradigm is built around), and a surviving
//! worker *adopts* it. A **join** is the reverse transition: a device
//! that sat out the early steps enters at a minibatch boundary, takes
//! its shard back from the adopter, and recovers its optimizer state
//! from the replicated store.
//!
//! The schedule is declared up front ([`Membership::with_schedule`],
//! driven by `TrainerConfig::fail_at` / `join_at`), which keeps every
//! recovery decision a *pure function of (device, step)* — no
//! heartbeat races, no two survivors adopting the same shard, and the
//! same rendezvous answer on every thread. The runtime dynamics (which
//! microbatches the dead device actually held, who re-runs them) stay
//! dynamic in the dispatch layer
//! ([`crate::balance::dispatch::ElasticDispatch`]).
//!
//! ## Recovery timeline (one failure, ODC)
//!
//! ```text
//!  step s (fail step)
//!  ─ worker d crashes between pulls ──────────────────────────────────
//!    d's completed micros: already pushed, kept in every daemon's
//!      id-keyed buffer (exactly-once: they are NOT re-run)
//!    d's in-flight + unpulled micros: orphaned to the dispatch layer,
//!      re-pulled by survivors (exactly-once: they run exactly once)
//!  ─ end_minibatch ───────────────────────────────────────────────────
//!    every daemon folds with `expected_done(s)` clients (d dropped
//!      from the fold quorum); d's payload arenas are released
//!  ─ optimizer phase ─────────────────────────────────────────────────
//!    rendezvous successor = first completing device after d in ring
//!      order ([`Membership::owner_of`]) flushes d's daemon
//!      (`CommBackend::flush_shard`), recovers d's shard params + Adam
//!      moments from the replicated store ([`OptReplica`], written by
//!      every owner every step), and applies the update for BOTH shards
//!  ─ end_step ────────────────────────────────────────────────────────
//!    barrier quorum shrinks to the live membership
//!      ([`MembershipBarrier`]); steps > s repeat the adoption
//! ```
//!
//! A join at step `j` is the mirror image: the joiner blocks on
//! [`MembershipBarrier::await_step_start`] until step `j-1` fully
//! ends, reads its shard's params + moments from the replicated store
//! (bit-identical to what the adopter just published), and the
//! ownership map flips back — making a late join bit-identical to a
//! fresh run at the larger world size (pinned by
//! `tests/engine_equivalence.rs`).
//!
//! Because the one-sided daemons fold gradient pieces keyed by global
//! microbatch id — never by arrival or placement — re-running a dead
//! device's microbatches on survivors cannot move a single bit: the
//! elastic run reduces exactly what the healthy run reduces.

use super::shared::SharedBuf;
use std::sync::{Condvar, Mutex};

/// The elastic membership schedule: which devices are alive at which
/// step, and the deterministic rendezvous rule deciding who serves a
/// dead or not-yet-joined device's shard.
///
/// Terminology used throughout:
/// * a device **completes** step `s` when it runs the step end to end
///   (reaches `end_minibatch` and `end_step`);
/// * a device **fails during** step `s` when it crashes mid-minibatch
///   in `s`: it may contribute early pushes but completes only steps
///   `< s`;
/// * a device is **absent** at step `s` when it has not yet joined or
///   failed in an earlier step — it contributes nothing at all.
#[derive(Clone, Debug)]
pub struct Membership {
    world: usize,
    /// First step each device participates in (0 = founding member).
    join_step: Vec<usize>,
    /// `Some(s)` = the device crashes during step `s`.
    fail_step: Vec<Option<usize>>,
}

impl Membership {
    /// The trivial schedule: every device alive for every step.
    pub fn all_live(world: usize) -> Membership {
        Membership { world, join_step: vec![0; world], fail_step: vec![None; world] }
    }

    /// Membership from join/fail events. `joins` are `(device, step)` —
    /// the device's first participating step; `fails` are `(device,
    /// step)` — the step the device crashes during. Structural errors
    /// (out-of-range device, duplicates, fail before join) are caught
    /// here; step-coverage errors need the run length and are caught by
    /// [`Membership::validate`].
    pub fn with_schedule(
        world: usize,
        joins: &[(usize, usize)],
        fails: &[(usize, usize)],
    ) -> Result<Membership, String> {
        let mut m = Membership::all_live(world);
        for &(dev, step) in joins {
            if dev >= world {
                return Err(format!("join device {dev} out of range (world {world})"));
            }
            if step == 0 {
                // 0 is the founding-membership sentinel: accepting it as
                // an "event" would make duplicate detection
                // order-dependent.
                return Err(format!(
                    "device {dev} joins at step 0 — that is the founding membership; drop the event"
                ));
            }
            if m.join_step[dev] != 0 {
                return Err(format!("device {dev} has more than one join event"));
            }
            m.join_step[dev] = step;
        }
        for &(dev, step) in fails {
            if dev >= world {
                return Err(format!("fail device {dev} out of range (world {world})"));
            }
            if m.fail_step[dev].is_some() {
                return Err(format!("device {dev} has more than one fail event"));
            }
            if step < m.join_step[dev] {
                return Err(format!("device {dev} fails at step {step} before joining"));
            }
            m.fail_step[dev] = Some(step);
        }
        Ok(m)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// No joins and no fails: the schedule degenerates to the seed
    /// engine's fixed world.
    pub fn is_static(&self) -> bool {
        self.join_step.iter().all(|&j| j == 0) && self.fail_step.iter().all(|f| f.is_none())
    }

    /// First step `dev` participates in (0 = founding member).
    pub fn joins_at(&self, dev: usize) -> usize {
        self.join_step[dev]
    }

    /// Whether `dev` crashes mid-minibatch during `step`.
    pub fn fails_during(&self, dev: usize, step: usize) -> bool {
        self.fail_step[dev] == Some(step)
    }

    /// Whether `dev` runs `step` end to end (reaches both the
    /// minibatch fold quorum and the step barrier).
    pub fn completes(&self, dev: usize, step: usize) -> bool {
        self.join_step[dev] <= step && !matches!(self.fail_step[dev], Some(f) if step >= f)
    }

    /// Whether `dev` contributes nothing at all to `step`: not yet
    /// joined, or already dead before the step started. (A device
    /// failing DURING `step` is not absent — it pulls until it crashes.)
    pub fn absent(&self, dev: usize, step: usize) -> bool {
        self.join_step[dev] > step || self.fail_step[dev].is_some_and(|f| f < step)
    }

    /// Fold/barrier quorum for `step`: how many devices complete it.
    pub fn expected_done(&self, step: usize) -> usize {
        (0..self.world).filter(|&d| self.completes(d, step)).count()
    }

    /// Quorum restricted to a contiguous device range (a hybrid node
    /// group): how many of `devs` complete `step`.
    pub fn expected_done_among(&self, devs: std::ops::Range<usize>, step: usize) -> usize {
        devs.filter(|&d| self.completes(d, step)).count()
    }

    /// Lowest-id device completing `step` (well-defined whenever
    /// [`Membership::validate`] passed).
    pub fn first_completing(&self, step: usize) -> usize {
        (0..self.world).find(|&d| self.completes(d, step)).expect("at least one live device")
    }

    /// THE rendezvous rule: who serves shard `shard` at `step`. The
    /// shard's own device when it completes the step; otherwise the
    /// first completing device after it in ring order — a pure function
    /// of (shard, step) every thread computes identically, so exactly
    /// one survivor adopts an orphaned shard and none race for it.
    pub fn owner_of(&self, shard: usize, step: usize) -> usize {
        for k in 0..self.world {
            let d = (shard + k) % self.world;
            if self.completes(d, step) {
                return d;
            }
        }
        panic!("no completing device at step {step} (validate the schedule first)")
    }

    /// Shards `dev` serves at `step`: its own plus any adopted via the
    /// ring rule. Empty when `dev` does not complete the step.
    pub fn shards_owned_by(&self, dev: usize, step: usize) -> Vec<usize> {
        if !self.completes(dev, step) {
            return Vec::new();
        }
        (0..self.world).filter(|&s| self.owner_of(s, step) == dev).collect()
    }

    /// Ring-scoped variant of the rendezvous rule: the members of
    /// `devs` (a contiguous range — a hybrid node group, or the whole
    /// world) that do NOT complete `step` and whose first completing
    /// ring successor *within the range* is `me`. These are the peers
    /// whose group-level epilogue duties `me` drives.
    pub fn driven_by(&self, me: usize, devs: std::ops::Range<usize>, step: usize) -> Vec<usize> {
        let base = devs.start;
        let n = devs.len();
        devs.filter(|&m| {
                if self.completes(m, step) {
                    return false;
                }
                // first completing member after m in the range's ring
                for k in 1..n {
                    let d = base + (m - base + k) % n;
                    if self.completes(d, step) {
                        return d == me;
                    }
                }
                false
            })
            .collect()
    }

    /// Run-length checks: every step of `0..steps` must keep at least
    /// one completing device (someone has to drive recovery and the
    /// barriers), and every scheduled event must land inside the run.
    pub fn validate(&self, steps: usize) -> Result<(), String> {
        for (dev, &j) in self.join_step.iter().enumerate() {
            if j >= steps && j != 0 {
                return Err(format!("device {dev} joins at step {j}, beyond the {steps}-step run"));
            }
        }
        for (dev, f) in self.fail_step.iter().enumerate() {
            if let Some(f) = f {
                if *f >= steps {
                    return Err(format!(
                        "device {dev} fails at step {f}, beyond the {steps}-step run"
                    ));
                }
            }
        }
        for step in 0..steps {
            if self.expected_done(step) == 0 {
                return Err(format!("no device completes step {step}: nothing can drive recovery"));
            }
        }
        Ok(())
    }

    /// Group-tiled variant of [`Membership::validate`] for the hybrid
    /// backend: every node group needs a completing member at every
    /// step, because intra-group duties (the group fold, the cross
    /// pushes of a dead member's super-shard, the replica refresh) can
    /// only be adopted within the group that holds the replica.
    pub fn validate_groups(&self, group_size: usize, steps: usize) -> Result<(), String> {
        if group_size == 0 || self.world % group_size != 0 {
            return Err(format!("group size {group_size} does not tile world {}", self.world));
        }
        for g in 0..self.world / group_size {
            let devs = g * group_size..(g + 1) * group_size;
            for step in 0..steps {
                if self.expected_done_among(devs.clone(), step) == 0 {
                    return Err(format!(
                        "node group {g} has no completing member at step {step}: its replica \
                         and super-shards would be unrecoverable"
                    ));
                }
            }
        }
        Ok(())
    }
}

/// A barrier whose per-round quorum follows the membership schedule: a
/// crashed device never arrives (and is not waited for), a joiner is
/// counted from its join step on. `rounds_per_step` maps barrier rounds
/// to steps (ODC's `end_step` waits once per step, Hybrid's twice).
pub struct MembershipBarrier {
    membership: std::sync::Arc<Membership>,
    rounds_per_step: usize,
    state: Mutex<BarrierState>,
    cond: Condvar,
}

struct BarrierState {
    /// Completed rounds so far (monotone; round `r` belongs to step
    /// `r / rounds_per_step`).
    round: usize,
    arrived: usize,
}

impl MembershipBarrier {
    pub fn new(membership: std::sync::Arc<Membership>, rounds_per_step: usize) -> Self {
        assert!(rounds_per_step >= 1);
        MembershipBarrier {
            membership,
            rounds_per_step,
            state: Mutex::new(BarrierState { round: 0, arrived: 0 }),
            cond: Condvar::new(),
        }
    }

    /// Arrive at the current round; blocks until the round's quorum
    /// (the devices completing its step) has arrived.
    pub fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        let round = st.round;
        st.arrived += 1;
        let expected = self.membership.expected_done(round / self.rounds_per_step);
        if st.arrived >= expected {
            st.arrived = 0;
            st.round += 1;
            self.cond.notify_all();
        } else {
            while st.round == round {
                st = self.cond.wait(st).unwrap();
            }
        }
    }

    /// Block until every barrier round of steps `< step` has completed,
    /// WITHOUT arriving — the joiner's entry synchronization: after
    /// this returns, step `step - 1`'s parameters (and replicated
    /// optimizer state) are fully republished and nothing is mid-phase.
    pub fn await_step_start(&self, step: usize) {
        let target = step * self.rounds_per_step;
        let mut st = self.state.lock().unwrap();
        while st.round < target {
            st = self.cond.wait(st).unwrap();
        }
    }
}

/// Replicated per-layer optimizer moments (classical PS fault
/// tolerance): every shard owner publishes its Adam `m`/`v` windows
/// after each step, so a rendezvous successor (or a late joiner) can
/// recover the exact state and continue bit-identically.
///
/// Laid out like the parameter windows (padded, `shard_len * world`),
/// under the same phase discipline: written only in the optimizer
/// phase by the shard's unique owner, read only by the next owner
/// after an ownership handoff that a barrier round separates.
pub struct OptReplica {
    pub m: SharedBuf,
    pub v: SharedBuf,
}

impl OptReplica {
    pub fn new(padded_len: usize) -> Self {
        OptReplica { m: SharedBuf::new(padded_len), v: SharedBuf::new(padded_len) }
    }

    /// Owner-side replication: publish the shard's moments at `offset`.
    pub fn publish(&self, offset: usize, m: &[f32], v: &[f32]) {
        self.m.write(offset, m);
        self.v.write(offset, v);
    }

    /// Successor/joiner-side recovery: read the shard's moments back.
    pub fn recover(&self, offset: usize, m: &mut [f32], v: &mut [f32]) {
        self.m.read(offset, m);
        self.v.read(offset, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn static_schedule_is_all_live() {
        let m = Membership::all_live(4);
        assert!(m.is_static());
        for step in 0..5 {
            assert_eq!(m.expected_done(step), 4);
            for d in 0..4 {
                assert!(m.completes(d, step));
                assert!(!m.absent(d, step));
                assert_eq!(m.owner_of(d, step), d);
                assert_eq!(m.shards_owned_by(d, step), vec![d]);
            }
        }
    }

    #[test]
    fn fail_shrinks_quorum_and_reowns_shard() {
        let m = Membership::with_schedule(4, &[], &[(1, 2)]).unwrap();
        assert!(!m.is_static());
        // steps 0..2: everyone completes
        assert_eq!(m.expected_done(1), 4);
        assert!(m.completes(1, 1));
        // step 2: device 1 fails DURING it — participates, never completes
        assert!(m.fails_during(1, 2));
        assert!(!m.completes(1, 2));
        assert!(!m.absent(1, 2));
        assert_eq!(m.expected_done(2), 3);
        // step 3+: gone entirely
        assert!(m.absent(1, 3));
        // ring successor 2 adopts shard 1 from the fail step on
        assert_eq!(m.owner_of(1, 2), 2);
        assert_eq!(m.shards_owned_by(2, 2), vec![1, 2]);
        assert_eq!(m.shards_owned_by(1, 2), Vec::<usize>::new());
        assert_eq!(m.first_completing(2), 0);
    }

    #[test]
    fn ring_rule_wraps() {
        let m = Membership::with_schedule(3, &[], &[(2, 0)]).unwrap();
        // shard 2's successor wraps to device 0
        assert_eq!(m.owner_of(2, 0), 0);
        assert_eq!(m.shards_owned_by(0, 0), vec![0, 2]);
    }

    #[test]
    fn join_flips_ownership_back() {
        let m = Membership::with_schedule(2, &[(1, 2)], &[]).unwrap();
        assert!(m.absent(1, 0));
        assert_eq!(m.expected_done(1), 1);
        assert_eq!(m.owner_of(1, 1), 0, "pre-join the founding member adopts the shard");
        assert_eq!(m.expected_done(2), 2);
        assert_eq!(m.owner_of(1, 2), 1, "ownership reverts at the join boundary");
        assert_eq!(m.joins_at(1), 2);
    }

    #[test]
    fn driven_by_is_scoped_to_the_range() {
        // world 4 in groups of 2; device 1 fails during step 0
        let m = Membership::with_schedule(4, &[], &[(1, 0)]).unwrap();
        assert_eq!(m.driven_by(0, 0..2, 0), vec![1], "group peer adopts the duties");
        assert_eq!(m.driven_by(2, 2..4, 0), Vec::<usize>::new());
        assert_eq!(m.driven_by(0, 0..4, 0), vec![1]);
        assert_eq!(m.driven_by(2, 0..4, 0), Vec::<usize>::new(), "ring stops at the first completer");
    }

    #[test]
    fn schedule_validation_catches_structural_errors() {
        assert!(Membership::with_schedule(2, &[(5, 1)], &[]).is_err());
        assert!(Membership::with_schedule(2, &[(1, 0)], &[]).is_err(), "join at step 0 is not an event");
        assert!(Membership::with_schedule(2, &[], &[(0, 0), (0, 1)]).is_err());
        assert!(Membership::with_schedule(2, &[(1, 3)], &[(1, 1)]).is_err(), "fail before join");
        let all_dead = Membership::with_schedule(2, &[], &[(0, 1), (1, 1)]).unwrap();
        let err = all_dead.validate(3).unwrap_err();
        assert!(err.contains("no device completes"), "unexpected: {err}");
        let late = Membership::with_schedule(2, &[], &[(0, 9)]).unwrap();
        assert!(late.validate(3).is_err());
    }

    #[test]
    fn group_validation_needs_a_live_member_per_group() {
        let m = Membership::with_schedule(4, &[], &[(2, 1), (3, 1)]).unwrap();
        assert!(m.validate(3).is_ok(), "globally fine: group 0 survives");
        let err = m.validate_groups(2, 3).unwrap_err();
        assert!(err.contains("no completing member"), "unexpected: {err}");
        assert!(m.validate_groups(4, 3).is_ok(), "one big group keeps a live member");
    }

    #[test]
    fn barrier_shrinks_to_live_quorum() {
        // world 3, device 2 fails during step 0: rounds complete with 2
        // arrivals from step 0 on — no deadlock waiting for the dead.
        let m = Arc::new(Membership::with_schedule(3, &[], &[(2, 0)]).unwrap());
        let b = Arc::new(MembershipBarrier::new(Arc::clone(&m), 1));
        std::thread::scope(|s| {
            for _dev in 0..2 {
                let b = Arc::clone(&b);
                s.spawn(move || {
                    for _step in 0..3 {
                        b.wait();
                    }
                });
            }
        });
        // and a late observer sees all three rounds done
        b.await_step_start(3);
    }

    #[test]
    fn barrier_admits_joiner_at_its_step() {
        let m = Arc::new(Membership::with_schedule(2, &[(1, 1)], &[]).unwrap());
        let b = Arc::new(MembershipBarrier::new(Arc::clone(&m), 1));
        std::thread::scope(|s| {
            let b0 = Arc::clone(&b);
            s.spawn(move || {
                // founding member: steps 0, 1
                b0.wait();
                b0.wait();
            });
            let b1 = Arc::clone(&b);
            s.spawn(move || {
                // joiner: blocks until step 0 fully ends, then arrives
                b1.await_step_start(1);
                b1.wait();
            });
        });
    }

    #[test]
    fn opt_replica_roundtrip() {
        let r = OptReplica::new(8);
        r.publish(2, &[1.0, 2.0], &[3.0, 4.0]);
        let (mut m, mut v) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        r.recover(2, &mut m, &mut v);
        assert_eq!(m, vec![1.0, 2.0]);
        assert_eq!(v, vec![3.0, 4.0]);
    }
}
