//! Cluster topology model: the paper's testbed is A100 nodes with
//! NVSwitch inside a node and 800 Gbps RoCE RDMA between nodes.
//!
//! [`GroupMap`] is the topology's device→node-group assignment in the
//! exact form the real engine needs: the hybrid two-level backend
//! ([`crate::comm::HybridComm`]) shards params/grads within a group and
//! exchanges optimizer-level gradients across groups, so it requires
//! groups that tile the device set exactly (unlike the analytic
//! simulator, which tolerates a ragged last node).

/// Bandwidths in bytes/second.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub devices: usize,
    pub devices_per_node: usize,
    /// Per-GPU NVSwitch bandwidth (A100: 600 GB/s bidirectional; we use
    /// the ~250 GB/s achievable unidirectional busbw).
    pub intra_bw: f64,
    /// Per-GPU share of the node's inter-node NIC (800 Gbps per node
    /// = 100 GB/s, / 8 GPUs = 12.5 GB/s per GPU).
    pub inter_bw: f64,
    /// Per-message latency (seconds) — RDMA op setup cost.
    pub latency: f64,
}

impl Topology {
    pub fn paper(devices: usize, devices_per_node: usize) -> Topology {
        let dpn = devices_per_node.min(devices).max(1);
        Topology {
            devices,
            devices_per_node: dpn,
            intra_bw: 250e9,
            inter_bw: 100e9 / dpn as f64,
            latency: 10e-6,
        }
    }

    pub fn nodes(&self) -> usize {
        self.devices.div_ceil(self.devices_per_node)
    }

    #[inline]
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.devices_per_node
    }

    #[inline]
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn multi_node(&self) -> bool {
        self.nodes() > 1
    }

    /// The device→group assignment of this topology, when the node size
    /// tiles the device set exactly (the hybrid backend's requirement).
    pub fn group_map(&self) -> Option<GroupMap> {
        if self.devices_per_node > 0 && self.devices % self.devices_per_node == 0 {
            Some(GroupMap::new(self.devices, self.devices_per_node))
        } else {
            None
        }
    }
}

/// Device→node-group assignment: `devices` split into contiguous groups
/// of exactly `group_size` (the real engine's analogue of a node).
///
/// Every mapping the two-level protocol needs lives here so the backend,
/// trainer, and tests agree on one source of truth: which group a device
/// belongs to, its local index within the group, and the global ids of a
/// group's members.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupMap {
    pub devices: usize,
    pub group_size: usize,
}

impl GroupMap {
    /// Panics unless `1 <= group_size <= devices` and the groups tile
    /// the device set exactly — callers that cannot guarantee this
    /// (e.g. CLI-driven configs) must validate first.
    pub fn new(devices: usize, group_size: usize) -> GroupMap {
        assert!(devices >= 1, "need at least one device");
        assert!(
            (1..=devices).contains(&group_size),
            "group size {group_size} outside 1..={devices}"
        );
        assert_eq!(
            devices % group_size,
            0,
            "hybrid groups must tile the device set exactly ({devices} % {group_size} != 0)"
        );
        GroupMap { devices, group_size }
    }

    pub fn n_groups(&self) -> usize {
        self.devices / self.group_size
    }

    #[inline]
    pub fn group_of(&self, dev: usize) -> usize {
        dev / self.group_size
    }

    /// Position of `dev` within its group (0..group_size).
    #[inline]
    pub fn local_index(&self, dev: usize) -> usize {
        dev % self.group_size
    }

    /// Global device id of member `local` of `group`.
    #[inline]
    pub fn member(&self, group: usize, local: usize) -> usize {
        group * self.group_size + local
    }

    /// Global device ids of a group's members.
    pub fn members(&self, group: usize) -> std::ops::Range<usize> {
        let lo = group * self.group_size;
        lo..lo + self.group_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_math() {
        let t = Topology::paper(32, 8);
        assert_eq!(t.nodes(), 4);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert!(t.same_node(9, 15));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn single_node_when_small() {
        let t = Topology::paper(8, 8);
        assert_eq!(t.nodes(), 1);
        assert!(!t.multi_node());
    }

    #[test]
    fn inter_slower_than_intra() {
        let t = Topology::paper(16, 8);
        assert!(t.inter_bw < t.intra_bw / 2.0);
    }

    #[test]
    fn group_map_indexing() {
        let g = GroupMap::new(8, 4);
        assert_eq!(g.n_groups(), 2);
        assert_eq!(g.group_of(3), 0);
        assert_eq!(g.group_of(4), 1);
        assert_eq!(g.local_index(5), 1);
        assert_eq!(g.member(1, 3), 7);
        assert_eq!(g.members(1), 4..8);
        // degenerate shapes both work: one group, and per-device groups
        assert_eq!(GroupMap::new(4, 4).n_groups(), 1);
        assert_eq!(GroupMap::new(4, 1).n_groups(), 4);
        assert_eq!(GroupMap::new(4, 1).local_index(3), 0);
    }

    #[test]
    #[should_panic(expected = "tile the device set exactly")]
    fn group_map_rejects_ragged_groups() {
        GroupMap::new(6, 4);
    }

    #[test]
    fn topology_exposes_group_map_only_when_exact() {
        assert_eq!(Topology::paper(32, 8).group_map(), Some(GroupMap::new(32, 8)));
        assert!(Topology::paper(12, 8).group_map().is_none());
    }
}
