//! Cluster topology model: the paper's testbed is A100 nodes with
//! NVSwitch inside a node and 800 Gbps RoCE RDMA between nodes.

/// Bandwidths in bytes/second.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub devices: usize,
    pub devices_per_node: usize,
    /// Per-GPU NVSwitch bandwidth (A100: 600 GB/s bidirectional; we use
    /// the ~250 GB/s achievable unidirectional busbw).
    pub intra_bw: f64,
    /// Per-GPU share of the node's inter-node NIC (800 Gbps per node
    /// = 100 GB/s, / 8 GPUs = 12.5 GB/s per GPU).
    pub inter_bw: f64,
    /// Per-message latency (seconds) — RDMA op setup cost.
    pub latency: f64,
}

impl Topology {
    pub fn paper(devices: usize, devices_per_node: usize) -> Topology {
        let dpn = devices_per_node.min(devices).max(1);
        Topology {
            devices,
            devices_per_node: dpn,
            intra_bw: 250e9,
            inter_bw: 100e9 / dpn as f64,
            latency: 10e-6,
        }
    }

    pub fn nodes(&self) -> usize {
        self.devices.div_ceil(self.devices_per_node)
    }

    #[inline]
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.devices_per_node
    }

    #[inline]
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn multi_node(&self) -> bool {
        self.nodes() > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_math() {
        let t = Topology::paper(32, 8);
        assert_eq!(t.nodes(), 4);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert!(t.same_node(9, 15));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn single_node_when_small() {
        let t = Topology::paper(8, 8);
        assert_eq!(t.nodes(), 1);
        assert!(!t.multi_node());
    }

    #[test]
    fn inter_slower_than_intra() {
        let t = Topology::paper(16, 8);
        assert!(t.inter_bw < t.intra_bw / 2.0);
    }
}
