//! Per-client communication volume (paper Table 2) + the analytic comm
//! time model shared by the simulator and the hybrid-sharding analysis.
//!
//! Notation (Appendix D): D = total devices, G = devices per node,
//! K = per-device shard size in bytes. Both schemes move the same total
//! volume, (D-1)·K per client, but ODC's point-to-point pattern forgoes
//! the hierarchical ring: its inter-node share is (D-G)·K instead of the
//! ring's (D-1)·K/G.

use super::topology::Topology;

/// Bytes a single client sends/receives for one collective-equivalent op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Volume {
    pub intra: f64,
    pub inter: f64,
}

impl Volume {
    pub fn total(&self) -> f64 {
        self.intra + self.inter
    }
}

/// Ring all-gather (and, symmetrically, ring reduce-scatter): each client
/// moves (D-1)/D of the full buffer; a hierarchical ring sends only 1/G
/// of that across nodes.
pub fn collective_ring(d: usize, g: usize, k: f64) -> Volume {
    let (df, gf) = (d as f64, g as f64);
    if d <= g {
        // single node: everything is intra
        return Volume { intra: (df - 1.0) * k, inter: 0.0 };
    }
    Volume {
        intra: (gf - 1.0) / gf * (df - 1.0) * k,
        inter: (df - 1.0) / gf * k,
    }
}

/// ODC gather / scatter-accumulate: a client talks to every peer
/// directly — (G-1) peers intra-node, (D-G) peers on other nodes.
pub fn odc_p2p(d: usize, g: usize, k: f64) -> Volume {
    let (df, gf) = (d as f64, g as f64);
    if d <= g {
        return Volume { intra: (df - 1.0) * k, inter: 0.0 };
    }
    Volume { intra: (gf - 1.0) * k, inter: (df - gf) * k }
}

/// §6.2 "ODC-specific Optimizations": hierarchical gather. A shard from
/// a remote node is fetched across the network ONCE per node (by the
/// first requester) and re-served intra-node from that peer's cache,
/// "effectively creating a hierarchical communication path similar to
/// topology-aware collectives". Per-client amortized volumes:
///   inter: (D-G)·K / G      (the node's G clients share each fetch)
///   intra: (G-1)·K + (D-G)·K·(G-1)/G   (local shards + redistribution)
pub fn odc_hierarchical(d: usize, g: usize, k: f64) -> Volume {
    let (df, gf) = (d as f64, g as f64);
    if d <= g {
        return Volume { intra: (df - 1.0) * k, inter: 0.0 };
    }
    Volume {
        intra: (gf - 1.0) * k + (df - gf) * k * (gf - 1.0) / gf,
        inter: (df - gf) * k / gf,
    }
}

/// Time for one client to complete an op of per-device shard size
/// `k_bytes`, assuming intra and inter phases overlap (both schemes
/// pipeline chunks): t = max(intra/bw_intra, inter/bw_inter) + latency.
pub fn op_time(vol: Volume, topo: &Topology) -> f64 {
    let t_intra = vol.intra / topo.intra_bw;
    let t_inter = vol.inter / topo.inter_bw;
    t_intra.max(t_inter) + topo.latency
}

/// Convenience: per-client time of a full-layer all-gather under the
/// given scheme. `layer_bytes` is the FULL layer size; each device holds
/// layer_bytes/D.
pub fn layer_op_time(odc: bool, layer_bytes: f64, topo: &Topology) -> f64 {
    let k = layer_bytes / topo.devices as f64;
    let vol = if odc {
        odc_p2p(topo.devices, topo.devices_per_node, k)
    } else {
        collective_ring(topo.devices, topo.devices_per_node, k)
    };
    op_time(vol, topo)
}

/// Per-client time of a full-layer gather with the §6.2 hierarchical
/// (node-leader caching) optimization enabled.
pub fn hierarchical_layer_op_time(layer_bytes: f64, topo: &Topology) -> f64 {
    let k = layer_bytes / topo.devices as f64;
    op_time(odc_hierarchical(topo.devices, topo.devices_per_node, k), topo)
}

/// Hybrid (ZeRO++-style) sharding: params/grads sharded only within the
/// node, so gather/scatter-accumulate never leaves the node. Per-client
/// shard is layer_bytes/G.
pub fn hybrid_layer_op_time(layer_bytes: f64, topo: &Topology) -> f64 {
    let g = topo.devices_per_node;
    let k = layer_bytes / g as f64;
    let vol = Volume { intra: (g as f64 - 1.0) * k, inter: 0.0 };
    op_time(vol, topo)
}

#[cfg(test)]
mod tests {
    use super::*;

    const K: f64 = 1e6;

    #[test]
    fn table2_totals_match() {
        // Both schemes move (D-1)*K per client — Table 2's "Total" column.
        for (d, g) in [(8, 8), (16, 8), (32, 8), (64, 8)] {
            let c = collective_ring(d, g, K);
            let o = odc_p2p(d, g, K);
            let want = (d as f64 - 1.0) * K;
            assert!((c.total() - want).abs() < 1e-6, "ring total d={d}");
            assert!((o.total() - want).abs() < 1e-6, "odc total d={d}");
        }
    }

    #[test]
    fn table2_ring_split() {
        // D=16, G=8: intra = 7/8*15K, inter = 15/8*K
        let c = collective_ring(16, 8, K);
        assert!((c.intra - 7.0 / 8.0 * 15.0 * K).abs() < 1e-6);
        assert!((c.inter - 15.0 / 8.0 * K).abs() < 1e-6);
    }

    #[test]
    fn table2_odc_split() {
        // D=16, G=8: intra = 7K, inter = 8K
        let o = odc_p2p(16, 8, K);
        assert!((o.intra - 7.0 * K).abs() < 1e-6);
        assert!((o.inter - 8.0 * K).abs() < 1e-6);
    }

    #[test]
    fn odc_more_inter_node_traffic() {
        // The paper's Appendix D point: ODC shifts volume to the slow links.
        for d in [16, 32, 64] {
            let c = collective_ring(d, 8, K);
            let o = odc_p2p(d, 8, K);
            assert!(o.inter > c.inter, "d={d}");
        }
    }

    #[test]
    fn single_node_identical() {
        let c = collective_ring(8, 8, K);
        let o = odc_p2p(8, 8, K);
        assert_eq!(c, o);
        assert_eq!(c.inter, 0.0);
    }

    #[test]
    fn odc_slower_across_nodes_comparable_within() {
        // Fig 11's shape: comparable intra-node, slower inter-node.
        let single = Topology::paper(8, 8);
        let multi = Topology::paper(32, 8);
        let layer = 1e9;
        let (c1, o1) = (layer_op_time(false, layer, &single), layer_op_time(true, layer, &single));
        assert!((c1 - o1).abs() / c1 < 0.05, "intra-node should be comparable");
        let (c4, o4) = (layer_op_time(false, layer, &multi), layer_op_time(true, layer, &multi));
        assert!(o4 > 1.5 * c4, "ODC should be clearly slower cross-node: {o4} vs {c4}");
    }

    #[test]
    fn hierarchical_gather_cuts_inter_traffic_by_g() {
        // §6.2: node-leader caching divides inter-node volume by G.
        let o = odc_p2p(32, 8, K);
        let h = odc_hierarchical(32, 8, K);
        assert!((h.inter - o.inter / 8.0).abs() < 1e-6);
        assert!(h.inter < o.inter);
    }

    #[test]
    fn hierarchical_closes_gap_to_collective() {
        let topo = Topology::paper(32, 8);
        let layer = 1e9;
        let ring = layer_op_time(false, layer, &topo);
        let p2p = layer_op_time(true, layer, &topo);
        let hier = hierarchical_layer_op_time(layer, &topo);
        assert!(hier < p2p, "hierarchical {hier} should beat flat p2p {p2p}");
        assert!(hier < 2.0 * ring, "hierarchical should be within 2x of the ring");
    }

    #[test]
    fn hierarchical_single_node_identical_to_p2p() {
        assert_eq!(odc_hierarchical(8, 8, K), odc_p2p(8, 8, K));
    }

    #[test]
    fn hybrid_removes_inter_traffic() {
        let topo = Topology::paper(32, 8);
        let layer = 1e9;
        let h = hybrid_layer_op_time(layer, &topo);
        let full_odc = layer_op_time(true, layer, &topo);
        assert!(h < full_odc, "hybrid should beat full-shard ODC cross-node");
    }
}
