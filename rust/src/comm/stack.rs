//! `CommStack`: the one public door for constructing a comm backend.
//!
//! The one-sided backends grew a five-deep constructor ladder
//! (`new` → `with_membership` → `with_wire` → `with_faults` →
//! `with_faults_wire` → `with_stack`) as membership, wire dtypes,
//! fault plans and byte transports landed one PR at a time. Every new
//! orthogonal knob doubled the ladder; call sites mixed rungs; and the
//! AsyncPS tier adds yet another axis (the staleness bound) that the
//! ladder cannot express without four more rungs. This builder
//! collapses the ladder:
//!
//! ```ignore
//! let comm = CommStack::builder(params, world)
//!     .membership(membership)     // default: static all-live world
//!     .wire(WireDtype::Bf16)      // default: F32
//!     .transport(TransportKind::Shm) // default: Inproc
//!     .faults(plan, policy)       // default: clean links
//!     .staleness(2)               // default: synchronous
//!     .build(CommScheme::Odc)?;   // -> Arc<dyn CommBackend>
//! ```
//!
//! `build(scheme)` routes to the right concrete backend —
//! notably `Odc` + `.staleness(k)` selects [`AsyncPs`], the
//! bounded-staleness parameter-server tier, while `Odc` without it
//! stays the synchronous [`OdcComm`] — and rejects illegal stacks
//! (staleness under a barriered scheme, faults under staleness, …)
//! before any daemon spawns. Tests and benches that need a concrete
//! handle (arena stats, link escalation) use the typed terminals
//! [`CommStack::build_odc`] / [`CommStack::build_hybrid`] /
//! [`CommStack::build_async`] / [`CommStack::build_collective`].
//!
//! The ladder constructors still exist as `pub(crate)` shims for the
//! backends' own unit tests; outside `comm` this builder is the only
//! way to get a backend, so the legality matrix cannot be bypassed.

use super::async_ps::AsyncPs;
use super::backend::{CommBackend, ParamStore};
use super::collective::CollectiveComm;
use super::fold::WireDtype;
use super::hybrid::HybridComm;
use super::membership::Membership;
use super::odc::OdcComm;
use super::transport::{FaultPlan, RetryPolicy, TransportKind};
use crate::config::CommScheme;
use std::io;
use std::sync::Arc;

/// Builder for every comm backend. See the module docs; obtain one via
/// [`CommStack::builder`].
pub struct CommStack {
    params: Arc<ParamStore>,
    membership: Arc<Membership>,
    wire: WireDtype,
    transport: TransportKind,
    faults: Option<(FaultPlan, RetryPolicy)>,
    /// `Some(k)` engages the AsyncPS tier with staleness bound `k`.
    /// `Some(0)` still runs the async machinery (per-mb buckets,
    /// admission gate) — it *degenerates to* synchronous, bit-identical
    /// by `tests/async_prop.rs`, rather than routing around it.
    staleness: Option<usize>,
    group_size: Option<usize>,
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidInput, msg)
}

impl CommStack {
    /// Start a stack over `params` with a static all-live `world`:
    /// in-process transport, f32 wire, clean links, synchronous.
    pub fn builder(params: Arc<ParamStore>, world: usize) -> CommStack {
        CommStack {
            params,
            membership: Arc::new(Membership::all_live(world)),
            wire: WireDtype::F32,
            transport: TransportKind::Inproc,
            faults: None,
            staleness: None,
            group_size: None,
        }
    }

    /// Elastic membership schedule (replaces the all-live default; the
    /// schedule's world supersedes the builder's).
    pub fn membership(mut self, m: Arc<Membership>) -> Self {
        self.membership = m;
        self
    }

    /// Wire payload precision for gradient pushes.
    pub fn wire(mut self, wire: WireDtype) -> Self {
        self.wire = wire;
        self
    }

    /// Byte transport under the one-sided backends.
    pub fn transport(mut self, kind: TransportKind) -> Self {
        self.transport = kind;
        self
    }

    /// Deterministic fault injection + retry ladder on every link.
    pub fn faults(mut self, plan: FaultPlan, policy: RetryPolicy) -> Self {
        self.faults = Some((plan, policy));
        self
    }

    /// Engage the AsyncPS bounded-staleness tier (ODC scheme only).
    /// `k = 0` keeps workers synchronous-equivalent but still runs the
    /// async protocol — the bit-identity degenerate case.
    pub fn staleness(mut self, k: usize) -> Self {
        self.staleness = Some(k);
        self
    }

    /// Intra-node group size for the two-level hybrid backend.
    pub fn groups(mut self, group_size: usize) -> Self {
        self.group_size = Some(group_size);
        self
    }

    /// Route to the backend for `scheme`, type-erased (the trainer's
    /// door). Illegal stacks fail here, before any daemon spawns.
    pub fn build(self, scheme: CommScheme) -> io::Result<Arc<dyn CommBackend>> {
        match scheme {
            CommScheme::Collective => Ok(self.build_collective()?),
            CommScheme::Odc if self.staleness.is_some() => Ok(self.build_async()?),
            CommScheme::Odc => Ok(self.build_odc()?),
            CommScheme::Hybrid => Ok(self.build_hybrid()?),
        }
    }

    /// Typed terminal: synchronous one-sided ODC.
    pub fn build_odc(self) -> io::Result<Arc<OdcComm>> {
        if let Some(k) = self.staleness {
            return Err(bad(format!(
                "staleness {k} selects the AsyncPs backend — use build(CommScheme::Odc) or \
                 build_async(), not the synchronous build_odc() terminal"
            )));
        }
        Ok(Arc::new(OdcComm::with_stack(
            self.params,
            self.membership,
            self.wire,
            self.transport,
            self.faults,
        )?))
    }

    /// Typed terminal: the AsyncPS bounded-staleness tier. Requires
    /// `.staleness(k)`, a static membership, and clean links — the
    /// fault retry/escalation machinery and the elastic join/fail
    /// choreography are both synchronous-at-minibatch by construction.
    pub fn build_async(self) -> io::Result<Arc<AsyncPs>> {
        let k = self.staleness.ok_or_else(|| {
            bad("build_async() without .staleness(k) — the bound is not optional".to_string())
        })?;
        if self.faults.is_some() {
            return Err(bad(format!(
                "staleness {k} cannot compose with a fault plan: retransmit escalation hands a \
                 dead link to the elastic recovery path, which is synchronous machinery"
            )));
        }
        if !self.membership.is_static() {
            return Err(bad(format!(
                "staleness {k} requires a static membership: join/fail choreography rendezvouses \
                 at minibatch boundaries the async tier no longer has"
            )));
        }
        Ok(Arc::new(AsyncPs::with_stack(
            self.params,
            self.membership.world(),
            k,
            self.wire,
            self.transport,
        )?))
    }

    /// Typed terminal: two-level hybrid sharding. Requires `.groups(n)`.
    pub fn build_hybrid(self) -> io::Result<Arc<HybridComm>> {
        if let Some(k) = self.staleness {
            return Err(bad(format!(
                "staleness {k} requires the odc scheme: hybrid's cross-group optimizer epilogue \
                 is a per-step rendezvous, synchronous by construction"
            )));
        }
        let group_size = self.group_size.ok_or_else(|| {
            bad("hybrid needs .groups(devices_per_node) on the CommStack builder".to_string())
        })?;
        Ok(Arc::new(HybridComm::with_stack(
            self.params,
            self.membership,
            group_size,
            self.wire,
            self.transport,
            self.faults,
        )?))
    }

    /// Typed terminal: the baseline collective. Rejects every
    /// barrier-free knob — there is nothing to attach them to.
    pub fn build_collective(self) -> io::Result<Arc<CollectiveComm>> {
        if let Some(k) = self.staleness {
            return Err(bad(format!(
                "staleness {k} requires a barrier-free scheme: Collective's per-layer rendezvous \
                 IS a staleness-0 barrier"
            )));
        }
        if self.faults.is_some() {
            return Err(bad(
                "fault plans require a barrier-free scheme (a dropped collective message stalls \
                 every rank at the next rendezvous)"
                    .to_string(),
            ));
        }
        if self.transport != TransportKind::Inproc {
            return Err(bad(format!(
                "--transport {} requires a one-sided scheme: Collective has no mailbox daemons \
                 to move bytes between",
                self.transport
            )));
        }
        if !self.membership.is_static() {
            return Err(bad(
                "elastic membership requires a barrier-free scheme (Collective's rendezvous \
                 deadlocks on a dead rank)"
                    .to_string(),
            ));
        }
        let world = self.membership.world();
        Ok(Arc::new(CollectiveComm::new(self.params, world)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(world: usize) -> Arc<ParamStore> {
        Arc::new(ParamStore::new(&[8], world))
    }

    #[test]
    fn builder_routes_every_scheme() {
        let comm = CommStack::builder(params(2), 2).build(CommScheme::Odc).unwrap();
        assert_eq!(comm.name(), "odc");
        let comm = CommStack::builder(params(2), 2)
            .staleness(1)
            .build(CommScheme::Odc)
            .unwrap();
        assert_eq!(comm.name(), "async-ps");
        let comm = CommStack::builder(params(2), 2)
            .groups(2)
            .build(CommScheme::Hybrid)
            .unwrap();
        assert_eq!(comm.name(), "hybrid");
        let comm = CommStack::builder(params(2), 2).build(CommScheme::Collective).unwrap();
        assert_eq!(comm.name(), "collective");
    }

    #[test]
    fn staleness_zero_still_selects_async_backend() {
        // Some(0) must run the async machinery (that's the bit-identity
        // degenerate case), not silently route back to sync ODC.
        let comm = CommStack::builder(params(2), 2)
            .staleness(0)
            .build(CommScheme::Odc)
            .unwrap();
        assert_eq!(comm.name(), "async-ps");
    }

    #[test]
    fn illegal_stacks_fail_before_daemons_spawn() {
        let e = CommStack::builder(params(2), 2)
            .staleness(1)
            .build(CommScheme::Collective)
            .unwrap_err();
        assert!(e.to_string().contains("barrier-free"), "{e}");
        let e = CommStack::builder(params(2), 2)
            .staleness(1)
            .build(CommScheme::Hybrid)
            .unwrap_err();
        assert!(e.to_string().contains("requires the odc scheme"), "{e}");
        let e = CommStack::builder(params(2), 2)
            .staleness(1)
            .faults(FaultPlan::parse("drop=0.5,seed=1").unwrap(), RetryPolicy::default())
            .build(CommScheme::Odc)
            .unwrap_err();
        assert!(e.to_string().contains("fault plan"), "{e}");
        let e = CommStack::builder(params(2), 2)
            .membership(Arc::new(Membership::with_schedule(2, &[], &[(1, 1)]).unwrap()))
            .staleness(1)
            .build(CommScheme::Odc)
            .unwrap_err();
        assert!(e.to_string().contains("static membership"), "{e}");
        let e = CommStack::builder(params(2), 2)
            .transport(TransportKind::Shm)
            .build(CommScheme::Collective)
            .unwrap_err();
        assert!(e.to_string().contains("one-sided scheme"), "{e}");
        let e = CommStack::builder(params(2), 2).build(CommScheme::Hybrid).unwrap_err();
        assert!(e.to_string().contains(".groups("), "{e}");
        let e = CommStack::builder(params(2), 2).staleness(0).build_odc().unwrap_err();
        assert!(e.to_string().contains("build_async"), "{e}");
    }

    #[test]
    fn typed_terminals_hand_back_concrete_backends() {
        let odc = CommStack::builder(params(2), 2).build_odc().unwrap();
        let _ = odc.arena_stats(); // concrete OdcComm API
        let aps = CommStack::builder(params(2), 2).staleness(3).build_async().unwrap();
        assert_eq!(aps.staleness(), 3);
    }
}
