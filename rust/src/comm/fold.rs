//! FastFold — the shared weighted-accumulate kernels of the reduction
//! hot path, plus the wire-precision (bf16) payload codecs.
//!
//! Every one-sided backend folds buffered gradient pieces into an f32
//! master accumulator in a deterministic key order (micro asc, client
//! asc — see `comm/odc.rs` / `comm/hybrid.rs`). Before this module each
//! fold site carried its own scalar `for` loop and every payload crossed
//! the wire as full f32. This module centralizes:
//!
//! * **Fold kernels** — [`fold_pieces`] folds a sorted piece list into
//!   an accumulator either with one auto-vectorizable chunked scalar
//!   pass or chunk-parallel over [`crate::util::threadpool::scoped_map`].
//!   Parallelism splits the accumulator's ELEMENT RANGE into fixed
//!   [`CHUNK_ELEMS`]-aligned spans; every worker folds ALL pieces in the
//!   caller's order over its own span, so the per-element float
//!   bracketing is identical to the scalar pass at ANY thread count or
//!   chunk boundary — bit-identity is by construction, not by test.
//! * **Wire precision** — [`WireDtype`] selects the payload element
//!   encoding. `F32` round-trips bit-exactly; `Bf16` halves the bytes
//!   with round-to-nearest-even truncation and an optional per-shard
//!   error-feedback residual ([`encode_ef`]): the quantization error of
//!   each push is carried into the next push of the same shard, so
//!   compression error stays bounded instead of accumulating across
//!   steps (see `docs/wire_precision.md` for the math and the
//!   determinism scope table).
//! * **Bulk byte casts** — [`f32_from_le_bytes`] / [`f32_to_le_bytes`],
//!   the memcpy-shaped decode the manifest loader and the F32 wire
//!   encoding share (the seed's per-element `chunks_exact(4)` decode was
//!   a measurable startup cost on multi-MiB init blobs).

use crate::util::threadpool::scoped_map;
use std::fmt;

/// Payload element encoding on the wire (gradient pushes). Parameters
/// themselves are always exchanged as f32 values; only the PRICED byte
/// volume of gathers follows the dtype (the sim has always modeled bf16
/// parameter bytes — `WireDtype` makes that assumption explicit and
/// configurable).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WireDtype {
    /// 4 bytes/element, bit-exact round trip (the engine default: every
    /// equivalence suite stays bit-identical to the oracle).
    #[default]
    F32,
    /// 2 bytes/element, round-to-nearest-even truncation + error
    /// feedback (the sim's historical pricing assumption).
    Bf16,
}

impl WireDtype {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            WireDtype::F32 => 4,
            WireDtype::Bf16 => 2,
        }
    }

    /// Wire bytes for `elems` elements under this encoding.
    pub fn bytes_for(self, elems: usize) -> usize {
        elems * self.bytes_per_elem()
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(WireDtype::F32),
            "bf16" | "bfloat16" => Some(WireDtype::Bf16),
            _ => None,
        }
    }
}

impl fmt::Display for WireDtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", match self {
            WireDtype::F32 => "f32",
            WireDtype::Bf16 => "bf16",
        })
    }
}

/// Fixed chunk size (elements) of the parallel fold split. The value is
/// a constant — NOT derived from thread count — so the span boundaries
/// are deterministic; 8K f32 = 32 KiB per chunk keeps a span inside L1/L2
/// while amortizing the spawn cost.
pub const CHUNK_ELEMS: usize = 8192;

/// One gradient piece awaiting the fold, in whatever representation it
/// arrived: decoded f32 (a reconstituted per-sequence fold) or raw wire
/// bytes (the common case — decode happens fused into the accumulate,
/// never into a temporary).
#[derive(Clone, Copy)]
pub enum PieceData<'a> {
    F32(&'a [f32]),
    Wire(&'a [u8], WireDtype),
}

impl PieceData<'_> {
    pub fn elems(&self) -> usize {
        match self {
            PieceData::F32(v) => v.len(),
            PieceData::Wire(b, dt) => b.len() / dt.bytes_per_elem(),
        }
    }
}

/// A weighted piece for [`fold_pieces`].
#[derive(Clone, Copy)]
pub struct FoldPiece<'a> {
    pub weight: f32,
    pub data: PieceData<'a>,
}

/// The scalar inner kernel: `dst[i] += weight * src[i]`. Kept as a bare
/// slice loop with no bounds checks in the body so LLVM auto-vectorizes
/// it (the zip iterator erases the per-index checks).
#[inline]
pub fn axpy(dst: &mut [f32], src: &[f32], weight: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += weight * s;
    }
}

/// Decode-fused accumulate of LE f32 wire bytes: `dst[i] += w * le(src)`.
#[inline]
fn axpy_f32_bytes(dst: &mut [f32], src: &[u8], weight: f32) {
    debug_assert_eq!(dst.len() * 4, src.len());
    for (d, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
        *d += weight * f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
}

/// Decode-fused accumulate of LE bf16 wire bytes. bf16 is the upper 16
/// bits of the f32 pattern, so decode is a single shift — the loop stays
/// vectorizable.
#[inline]
fn axpy_bf16_bytes(dst: &mut [f32], src: &[u8], weight: f32) {
    debug_assert_eq!(dst.len() * 2, src.len());
    for (d, c) in dst.iter_mut().zip(src.chunks_exact(2)) {
        let bits = (u16::from_le_bytes([c[0], c[1]]) as u32) << 16;
        *d += weight * f32::from_bits(bits);
    }
}

/// Fold one piece's sub-range `[lo, lo + acc.len())` into `acc`. A piece
/// shorter than the accumulator (trailing-shard padding) contributes
/// only its overlap.
#[inline]
fn fold_piece_range(acc: &mut [f32], lo: usize, piece: &FoldPiece) {
    let n = piece.data.elems();
    if lo >= n {
        return;
    }
    let hi = (lo + acc.len()).min(n);
    let acc = &mut acc[..hi - lo];
    match piece.data {
        PieceData::F32(v) => axpy(acc, &v[lo..hi], piece.weight),
        PieceData::Wire(b, WireDtype::F32) => axpy_f32_bytes(acc, &b[lo * 4..hi * 4], piece.weight),
        PieceData::Wire(b, WireDtype::Bf16) => axpy_bf16_bytes(acc, &b[lo * 2..hi * 2], piece.weight),
    }
}

/// Fold `pieces` (already sorted in the caller's deterministic key
/// order) into `acc`, scalar or chunk-parallel.
///
/// `threads <= 1` — or a fold too small to amortize a spawn — runs the
/// single chunked scalar pass. Otherwise the accumulator is split into
/// `threads` contiguous spans aligned to [`CHUNK_ELEMS`]; each worker
/// folds EVERY piece, in order, over its own span. Per element the
/// accumulation sequence is identical to the scalar pass, so the result
/// is bit-identical at any thread count (asserted by
/// `tests/fold_prop.rs` across boundaries and counts).
pub fn fold_pieces(acc: &mut [f32], pieces: &[FoldPiece], threads: usize) {
    if pieces.is_empty() {
        return;
    }
    let len = acc.len();
    if threads <= 1 || len < 2 * CHUNK_ELEMS {
        for p in pieces {
            fold_piece_range(acc, 0, p);
        }
        return;
    }
    // Span length: ceil-even split, rounded UP to a chunk boundary so
    // span edges are independent of `threads`-vs-`len` remainders.
    let chunks = len.div_ceil(CHUNK_ELEMS);
    let span = chunks.div_ceil(threads) * CHUNK_ELEMS;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    for (i, sub) in acc.chunks_mut(span).enumerate() {
        let lo = i * span;
        jobs.push(Box::new(move || {
            for p in pieces {
                fold_piece_range(sub, lo, p);
            }
        }));
    }
    let workers = jobs.len();
    scoped_map(workers, jobs);
}

/// Round-to-nearest-even truncation of an f32 to the bf16 bit pattern
/// (upper 16 bits). NaN payload bits are forced non-zero so a NaN never
/// rounds into an infinity.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Exact bf16 → f32 widening (every bf16 value is representable).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode `src` into `dst` (appended) under `dtype`, WITHOUT error
/// feedback. `F32` is the exact LE byte image of the slice.
pub fn encode(dst: &mut Vec<u8>, src: &[f32], dtype: WireDtype) {
    match dtype {
        WireDtype::F32 => f32_to_le_bytes(dst, src),
        WireDtype::Bf16 => {
            dst.reserve(src.len() * 2);
            for &x in src {
                dst.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
            }
        }
    }
}

/// Encode `src` into `dst` (appended) with per-element error feedback:
/// each element is quantized as `q = enc(src[i] + residual[i])` and the
/// quantization error `(src[i] + residual[i]) - dec(q)` is written back
/// into `residual[i]` for the NEXT push of the same shard. Under `F32`
/// the encoding is exact, so the residual is untouched (it stays zero)
/// and the byte image equals [`encode`]'s.
pub fn encode_ef(dst: &mut Vec<u8>, src: &[f32], residual: &mut [f32], dtype: WireDtype) {
    match dtype {
        WireDtype::F32 => f32_to_le_bytes(dst, src),
        WireDtype::Bf16 => {
            debug_assert_eq!(src.len(), residual.len());
            dst.reserve(src.len() * 2);
            for (&x, r) in src.iter().zip(residual.iter_mut()) {
                let v = x + *r;
                let q = f32_to_bf16(v);
                *r = v - bf16_to_f32(q);
                dst.extend_from_slice(&q.to_le_bytes());
            }
        }
    }
}

/// Decode a wire payload back into f32s (tests and the per-sequence
/// fold's reconstitution path; the hot micro fold never materializes
/// this — it decodes fused into the accumulate).
pub fn decode(bytes: &[u8], dtype: WireDtype) -> Vec<f32> {
    match dtype {
        WireDtype::F32 => f32_from_le_bytes(bytes),
        WireDtype::Bf16 => bytes
            .chunks_exact(2)
            .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
    }
}

/// Bulk LE-byte → f32 decode: one `memcpy` into the target allocation
/// on little-endian hosts (a per-element byte-swap pass elsewhere),
/// replacing per-element `chunks_exact(4)` scalar decodes.
pub fn f32_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 4 == 0, "byte length {} not a multiple of 4", bytes.len());
    let n = bytes.len() / 4;
    let mut out = vec![0.0f32; n];
    // SAFETY: `out` owns n*4 writable bytes; f32 has no invalid bit
    // patterns; ranges cannot overlap (fresh allocation).
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
    #[cfg(target_endian = "big")]
    for x in &mut out {
        *x = f32::from_bits(x.to_bits().swap_bytes());
    }
    out
}

/// Bulk f32 → LE-byte append: the encode-side twin of
/// [`f32_from_le_bytes`].
pub fn f32_to_le_bytes(dst: &mut Vec<u8>, src: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: reading src as its own byte image; f32 and u8 have no
        // alignment conflict in this direction.
        let bytes =
            unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u8, src.len() * 4) };
        dst.extend_from_slice(bytes);
    }
    #[cfg(target_endian = "big")]
    for &x in src {
        dst.extend_from_slice(&x.to_le_bytes());
    }
}

/// Fold worker count: `ODC_FOLD_THREADS` when set (0/1 = scalar), else
/// a conservative share of the host's parallelism — every device daemon
/// folds concurrently at the minibatch flush, so each fold taking a
/// quarter of the cores keeps world-4 runs from oversubscribing.
pub fn default_fold_threads() -> usize {
    if let Ok(v) = std::env::var("ODC_FOLD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| (n.get() / 4).clamp(1, 4)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pieces_of(raw: &[(f32, Vec<f32>)]) -> Vec<(f32, Vec<u8>)> {
        raw.iter()
            .map(|(w, v)| {
                let mut b = Vec::new();
                encode(&mut b, v, WireDtype::F32);
                (*w, b)
            })
            .collect()
    }

    #[test]
    fn parallel_fold_bit_identical_to_scalar() {
        let n = 3 * CHUNK_ELEMS + 17; // deliberately chunk-misaligned
        let raw: Vec<(f32, Vec<f32>)> = (0..5)
            .map(|k| {
                let w = 1.0 + k as f32 * 0.25;
                let v: Vec<f32> =
                    (0..n).map(|i| ((i * 31 + k * 7) % 1000) as f32 * 1e-3 - 0.5).collect();
                (w, v)
            })
            .collect();
        let enc = pieces_of(&raw);
        let build = |threads: usize| {
            let pieces: Vec<FoldPiece> = enc
                .iter()
                .map(|(w, b)| FoldPiece { weight: *w, data: PieceData::Wire(b, WireDtype::F32) })
                .collect();
            let mut acc = vec![0.0f32; n];
            fold_pieces(&mut acc, &pieces, threads);
            acc
        };
        let scalar = build(1);
        for threads in [2, 3, 4, 7] {
            assert_eq!(build(threads), scalar, "threads={threads} must be bit-identical");
        }
    }

    #[test]
    fn fold_handles_short_pieces() {
        // A piece shorter than the accumulator (trailing-pad shard)
        // contributes only its overlap — in scalar and parallel alike.
        let n = 2 * CHUNK_ELEMS + 5;
        let short = vec![2.0f32; CHUNK_ELEMS + 3];
        let full = vec![1.0f32; n];
        let run = |threads| {
            let pieces = [
                FoldPiece { weight: 1.0, data: PieceData::F32(&full) },
                FoldPiece { weight: 0.5, data: PieceData::F32(&short) },
            ];
            let mut acc = vec![0.0f32; n];
            fold_pieces(&mut acc, &pieces, threads);
            acc
        };
        let a = run(1);
        assert_eq!(a[0], 2.0);
        assert_eq!(a[n - 1], 1.0);
        assert_eq!(run(4), a);
    }

    #[test]
    fn bf16_roundtrip_exact_for_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -2.5, 0.15625, 3.0e38, -1.0e-38] {
            let q = f32_to_bf16(x);
            let back = bf16_to_f32(q);
            assert_eq!(f32_to_bf16(back), q);
            if x.to_bits() & 0xFFFF == 0 {
                assert_eq!(back.to_bits(), x.to_bits(), "{x} is exactly representable");
            }
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly half-way between bf16(1.0) and the next
        // value up: RNE picks the even mantissa (1.0).
        let half_way = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(half_way)), 1.0);
        // one ULP above half-way rounds up
        let above = f32::from_bits(0x3F80_8001);
        assert!(bf16_to_f32(f32_to_bf16(above)) > 1.0);
        // NaN stays NaN (payload forced non-zero)
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn error_feedback_carries_quantization_error() {
        let src = vec![0.1f32; 64];
        let mut residual = vec![0.0f32; 64];
        let mut b1 = Vec::new();
        encode_ef(&mut b1, &src, &mut residual, WireDtype::Bf16);
        let q1 = decode(&b1, WireDtype::Bf16);
        // residual holds exactly what the wire lost
        for i in 0..64 {
            assert_eq!(residual[i], src[i] - q1[i]);
        }
        // the next push re-injects it: cumulative decoded sum tracks the
        // true sum to within one quantization step
        let mut sum = q1[0];
        for _ in 0..20 {
            let mut b = Vec::new();
            encode_ef(&mut b, &src, &mut residual, WireDtype::Bf16);
            sum += decode(&b, WireDtype::Bf16)[0];
        }
        let truth = 21.0 * 0.1;
        assert!((sum - truth).abs() / truth < 1e-2, "EF sum {sum} vs {truth}");
    }

    #[test]
    fn f32_wire_is_exact_and_residual_untouched() {
        let src = vec![0.1f32, -3.7e-5, 1.0e30, -0.0];
        let mut residual = vec![0.0f32; 4];
        let mut b = Vec::new();
        encode_ef(&mut b, &src, &mut residual, WireDtype::F32);
        assert_eq!(decode(&b, WireDtype::F32), src);
        assert_eq!(residual, vec![0.0; 4]);
        assert_eq!(b.len(), WireDtype::F32.bytes_for(4));
    }

    #[test]
    fn bulk_byte_cast_roundtrips() {
        let src: Vec<f32> = (0..1025).map(|i| (i as f32).sin()).collect();
        let mut bytes = Vec::new();
        f32_to_le_bytes(&mut bytes, &src);
        assert_eq!(bytes.len(), src.len() * 4);
        assert_eq!(f32_from_le_bytes(&bytes), src);
        // matches the scalar per-element decode bit-for-bit
        let scalar: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(f32_from_le_bytes(&bytes), scalar);
    }

    #[test]
    fn dtype_parse_roundtrip_and_sizes() {
        for dt in [WireDtype::F32, WireDtype::Bf16] {
            assert_eq!(WireDtype::parse(&dt.to_string()), Some(dt));
        }
        assert_eq!(WireDtype::parse("int8"), None);
        assert_eq!(WireDtype::F32.bytes_for(10), 40);
        assert_eq!(WireDtype::Bf16.bytes_for(10), 20);
        assert_eq!(WireDtype::default(), WireDtype::F32);
    }

    #[test]
    fn bf16_wire_halves_the_bytes() {
        let src = vec![1.0f32; 1000];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        encode(&mut a, &src, WireDtype::F32);
        encode(&mut b, &src, WireDtype::Bf16);
        assert_eq!(b.len() * 2, a.len());
    }
}
