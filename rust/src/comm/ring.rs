//! WireComm (1/2) — the same-host shared-memory ring transport.
//!
//! [`RingTransport`] moves every envelope as **bytes** through
//! fixed-capacity SPSC slot rings, one ring per directed link. This is
//! the first transport where "communication" is not a pointer handoff:
//! payloads are serialized through [`WireCodec`] into the
//! [`frame`] format, copied into ring slots, and decoded on the
//! receiver side — exactly the data movement a same-host worker pair
//! would pay over a POSIX shm segment, minus the `mmap` plumbing
//! (worker *threads* already share one address space; the OS-process
//! flavor lives in [`crate::comm::socket`]).
//!
//! # Ring memory layout
//!
//! Each link owns `slots × slot_bytes` of payload memory plus one
//! `AtomicU64` *turn counter* per slot (a seqlock-style publish stamp,
//! after Vyukov's bounded queue):
//!
//! ```text
//! slot[i].seq == p        → free, awaiting the producer's write #p
//! slot[i].seq == p + 1    → published: fragment #p readable
//! consumer frees: seq := p + slots   (the producer's next turn)
//! ```
//!
//! The producer claims position `p`, spins until `slot[p % slots].seq
//! == p`, writes the fragment, and publishes with a release-store of
//! `p + 1`; the consumer acquires-loads the stamp, copies the bytes
//! out, and release-stores `p + slots`. No locks are held across the
//! handoff — the per-link producer mutex only *enforces* the
//! single-producer contract (each `(src,dst)` link has exactly one
//! sending thread in this codebase, so it is uncontended).
//!
//! # Fragmentation
//!
//! A frame larger than a slot is split across consecutive slots: the
//! first fragment carries a `u32` total-length prefix, continuations
//! are raw bytes. SPSC FIFO makes a frame's fragments contiguous in
//! its ring, so reassembly is a per-link append buffer.
//!
//! # Delivery order: tickets
//!
//! The in-process mailbox delivers in global per-destination enqueue
//! order (one mpsc per rank) — the daemons' quorum counting and the
//! bit-identity of the fold depend on arrival order only through that
//! total order. Per-link rings alone would lose it, so every enqueue
//! claims a per-destination **ticket** (`fetch_add`) stamped into the
//! frame, and `recv` releases envelopes strictly in ticket order
//! (stashing early arrivals). Local-only messages (flush replies —
//! [`WireCodec::encode`] returns `false`) ride a ticketed local lane
//! and merge at the same sequencer, so the delivered stream is
//! indistinguishable from [`InProcTransport`]'s.
//!
//! # Waiting
//!
//! `recv` is busy/park hybrid: it spins through a bounded number of
//! drain passes (`SPIN_PASSES`), then parks on a per-destination
//! condvar with a short timeout; producers wake it only when the
//! parked flag is up, so the steady-state hot path stays wait-free.
//!
//! [`InProcTransport`]: crate::comm::transport::InProcTransport

use super::transport::{frame, Envelope, SendError, Transport, WireCodec};
use std::cell::UnsafeCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Default payload capacity per slot.
pub const SLOT_BYTES: usize = 16 * 1024;
/// Default slots per link ring (must stay ≥ 2).
pub const RING_SLOTS: usize = 64;
/// Producer spin iterations before yielding on a full ring.
const SPIN_LIMIT: u32 = 512;
/// Consumer drain passes before parking.
const SPIN_PASSES: u32 = 64;
/// Park timeout — bounds the wake race instead of a parked-flag dance
/// on every publish.
const PARK_US: u64 = 100;

/// One slot: a turn counter and a fixed payload buffer. The buffer is
/// only ever touched by the thread whose turn the counter grants, with
/// the acquire/release pair on `seq` ordering the accesses.
struct Slot {
    seq: AtomicU64,
    len: UnsafeCell<u32>,
    buf: UnsafeCell<Box<[u8]>>,
}

/// One directed link's ring: slots plus the producer cursor. The
/// consumer cursor lives with the destination's consumer state.
struct Ring {
    slots: Vec<Slot>,
    slot_bytes: usize,
    /// Producer position. A Mutex rather than an atomic: it *enforces*
    /// SPSC (uncontended in this codebase — one sending thread per
    /// link) and keeps a multi-fragment frame's slots contiguous.
    head: Mutex<u64>,
}

// SAFETY: `len`/`buf` are only accessed by the party whose turn
// `slots[i].seq` grants; the acquire load before access and the
// release store after form the happens-before edge for the handoff.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(slots: usize, slot_bytes: usize) -> Ring {
        assert!(slots >= 2 && slot_bytes > 4, "ring geometry");
        Ring {
            slots: (0..slots)
                .map(|i| Slot {
                    seq: AtomicU64::new(i as u64),
                    len: UnsafeCell::new(0),
                    buf: UnsafeCell::new(vec![0u8; slot_bytes].into_boxed_slice()),
                })
                .collect(),
            slot_bytes,
            head: Mutex::new(0),
        }
    }

    /// Spin until position `pos`'s slot is free for the producer.
    fn wait_slot(&self, pos: u64) -> &Slot {
        let slot = &self.slots[(pos % self.slots.len() as u64) as usize];
        let mut spins = 0u32;
        while slot.seq.load(Ordering::Acquire) != pos {
            spins = spins.wrapping_add(1);
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        slot
    }

    /// Write one frame as contiguous fragments (first carries the
    /// `u32` total-length prefix).
    fn push_frame(&self, frame_bytes: &[u8]) {
        let total = frame_bytes.len();
        let mut head = self.head.lock().unwrap();
        let mut offset = 0usize;
        let mut first = true;
        while first || offset < total {
            let pos = *head;
            let slot = self.wait_slot(pos);
            // SAFETY: the turn check in wait_slot grants exclusive
            // access to this slot's buffer until the release below.
            unsafe {
                let buf = &mut *slot.buf.get();
                let mut w = 0usize;
                if first {
                    buf[..4].copy_from_slice(&(total as u32).to_le_bytes());
                    w = 4;
                    first = false;
                }
                let take = (total - offset).min(self.slot_bytes - w);
                buf[w..w + take].copy_from_slice(&frame_bytes[offset..offset + take]);
                offset += take;
                w += take;
                *slot.len.get() = w as u32;
            }
            slot.seq.store(pos + 1, Ordering::Release);
            *head = pos + 1;
        }
    }

    /// Consume the fragment at consumer position `tail`, if published.
    fn try_frag(&self, tail: u64) -> Option<Vec<u8>> {
        let n = self.slots.len() as u64;
        let slot = &self.slots[(tail % n) as usize];
        if slot.seq.load(Ordering::Acquire) != tail + 1 {
            return None;
        }
        // SAFETY: the published stamp grants the consumer exclusive
        // access until the freeing release-store below.
        let out = unsafe {
            let len = *slot.len.get() as usize;
            let buf = &*slot.buf.get();
            buf[..len].to_vec()
        };
        slot.seq.store(tail + n, Ordering::Release);
        Some(out)
    }
}

/// Per-(dst, src) consumer cursor + fragment reassembly buffer.
struct LinkRecv {
    tail: u64,
    pending: Vec<u8>,
    /// Total frame bytes expected; 0 = the next fragment starts a frame.
    want: usize,
}

/// Per-destination consumer state (single consumer per rank).
struct ConsState<M> {
    links: Vec<LinkRecv>,
    /// Early arrivals, keyed by delivery ticket.
    stash: BTreeMap<u64, Envelope<M>>,
    next_ticket: u64,
}

struct ParkCell {
    parked: AtomicBool,
    m: Mutex<()>,
    cv: Condvar,
}

/// Lock-free shared-memory SPSC ring-buffer transport for same-host
/// workers — see the module docs for the memory layout and ordering
/// contract.
pub struct RingTransport<M: WireCodec> {
    world: usize,
    rings: Vec<Arc<Ring>>,
    /// Per-link wire sequence numbers ([`Transport::send`]).
    seq: Vec<AtomicU64>,
    /// Per-destination delivery tickets (global arrival order).
    tickets: Vec<AtomicU64>,
    /// Ticketed lane for local-only messages (flush replies).
    local: Vec<Mutex<Vec<(u64, Envelope<M>)>>>,
    cons: Vec<Mutex<ConsState<M>>>,
    park: Vec<ParkCell>,
    closed: AtomicBool,
}

impl<M: WireCodec> RingTransport<M> {
    pub fn new(world: usize) -> Self {
        RingTransport::with_geometry(world, RING_SLOTS, SLOT_BYTES)
    }

    /// Explicit ring geometry (tests shrink it to force fragmentation
    /// and full-ring backpressure).
    pub fn with_geometry(world: usize, slots: usize, slot_bytes: usize) -> Self {
        RingTransport {
            world,
            rings: (0..world * world).map(|_| Arc::new(Ring::new(slots, slot_bytes))).collect(),
            seq: (0..world * world).map(|_| AtomicU64::new(0)).collect(),
            tickets: (0..world).map(|_| AtomicU64::new(0)).collect(),
            local: (0..world).map(|_| Mutex::new(Vec::new())).collect(),
            cons: (0..world)
                .map(|_| {
                    Mutex::new(ConsState {
                        links: (0..world)
                            .map(|_| LinkRecv { tail: 0, pending: Vec::new(), want: 0 })
                            .collect(),
                        stash: BTreeMap::new(),
                        next_ticket: 0,
                    })
                })
                .collect(),
            park: (0..world)
                .map(|_| ParkCell { parked: AtomicBool::new(false), m: Mutex::new(()), cv: Condvar::new() })
                .collect(),
            closed: AtomicBool::new(false),
        }
    }

    /// Wake `dst`'s consumer if (and only if) it is parked.
    fn wake(&self, dst: usize) {
        let cell = &self.park[dst];
        if cell.parked.load(Ordering::Acquire) {
            let _g = cell.m.lock().unwrap();
            cell.cv.notify_all();
        }
    }

    /// Drain one ring's published fragments, assembling frames and
    /// stashing decoded envelopes by ticket.
    fn drain_ring(ring: &Ring, lr: &mut LinkRecv, stash: &mut BTreeMap<u64, Envelope<M>>) {
        while let Some(frag) = ring.try_frag(lr.tail) {
            lr.tail += 1;
            if lr.want == 0 {
                if frag.len() < 4 {
                    debug_assert!(false, "ring fragment shorter than the frame prefix");
                    continue;
                }
                lr.want = u32::from_le_bytes([frag[0], frag[1], frag[2], frag[3]]) as usize;
                lr.pending.clear();
                lr.pending.extend_from_slice(&frag[4..]);
            } else {
                lr.pending.extend_from_slice(&frag);
            }
            if lr.pending.len() >= lr.want {
                debug_assert_eq!(lr.pending.len(), lr.want, "fragments never straddle frames");
                let bytes = std::mem::take(&mut lr.pending);
                lr.want = 0;
                match frame::decode::<M>(&bytes) {
                    Some((ticket, env)) => {
                        stash.insert(ticket, env);
                    }
                    None => debug_assert!(false, "malformed ring frame"),
                }
            }
        }
    }

    /// Tear down for tests/benches: unblocks every parked consumer and
    /// makes `recv` return `None` once its stream is fully drained.
    /// Call only after senders are quiescent — the backends themselves
    /// terminate daemons with explicit Shutdown messages instead.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        for d in 0..self.world {
            let cell = &self.park[d];
            let _g = cell.m.lock().unwrap();
            cell.cv.notify_all();
        }
    }
}

impl<M: WireCodec> Transport<M> for RingTransport<M> {
    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, src: usize, dst: usize, micro: u64, msg: M) -> Result<(), SendError> {
        let seq = self.seq[src * self.world + dst].fetch_add(1, Ordering::Relaxed);
        self.send_env(dst, Envelope { src, seq, micro, msg });
        Ok(())
    }

    fn send_env(&self, dst: usize, env: Envelope<M>) {
        // the ticket is claimed at enqueue time, so delivery order ==
        // enqueue order == the in-process mailbox's arrival order
        let ticket = self.tickets[dst].fetch_add(1, Ordering::Relaxed);
        match frame::encode(ticket, &env) {
            Some(bytes) => self.rings[env.src * self.world + dst].push_frame(&bytes),
            None => self.local[dst].lock().unwrap().push((ticket, env)),
        }
        self.wake(dst);
    }

    fn recv(&self, dst: usize) -> Option<Envelope<M>> {
        let mut st = self.cons[dst].lock().unwrap();
        let mut passes = 0u32;
        loop {
            {
                let mut lane = self.local[dst].lock().unwrap();
                if !lane.is_empty() {
                    for (t, env) in lane.drain(..) {
                        st.stash.insert(t, env);
                    }
                }
            }
            let ConsState { links, stash, next_ticket } = &mut *st;
            for src in 0..self.world {
                Self::drain_ring(&self.rings[src * self.world + dst], &mut links[src], stash);
            }
            if let Some(env) = stash.remove(next_ticket) {
                *next_ticket += 1;
                return Some(env);
            }
            if self.closed.load(Ordering::Acquire) && stash.is_empty() {
                return None;
            }
            passes = passes.wrapping_add(1);
            if passes < SPIN_PASSES {
                std::hint::spin_loop();
                continue;
            }
            // park with a bounded timeout: a publish racing the parked
            // flag costs at most PARK_US, never a lost wakeup
            let cell = &self.park[dst];
            cell.parked.store(true, Ordering::Release);
            let g = cell.m.lock().unwrap();
            let _ = cell.cv.wait_timeout(g, Duration::from_micros(PARK_US)).unwrap();
            cell.parked.store(false, Ordering::Release);
            passes = 0;
        }
    }

    fn one_sided(&self, _src: usize, _dst: usize, _bytes: usize) -> Result<u32, SendError> {
        // gathers / replica refresh stay genuine shared-memory reads on
        // a same-host fleet; the socket transport is the priced path
        Ok(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::{FaultPlan, FaultyTransport, RetryPolicy, WireMsg};

    #[derive(Clone, Debug, PartialEq)]
    enum RMsg {
        Data(u64),
        Blob(Vec<u8>),
        Local(u64),
        Done,
    }

    impl WireMsg for RMsg {
        fn is_barrier(&self) -> bool {
            matches!(self, RMsg::Done)
        }
        fn payload_bytes(&self) -> usize {
            match self {
                RMsg::Blob(b) => b.len(),
                _ => 8,
            }
        }
    }

    impl WireCodec for RMsg {
        fn encode(&self, out: &mut Vec<u8>) -> bool {
            match self {
                RMsg::Data(v) => {
                    out.push(0);
                    frame::put_u64(out, *v);
                }
                RMsg::Blob(b) => {
                    out.push(1);
                    frame::put_bytes(out, b);
                }
                RMsg::Local(_) => return false,
                RMsg::Done => out.push(3),
            }
            true
        }
        fn decode(bytes: &[u8]) -> Option<RMsg> {
            let mut r = frame::Reader::new(bytes.get(1..)?);
            match bytes.first()? {
                0 => Some(RMsg::Data(r.u64()?)),
                1 => Some(RMsg::Blob(r.bytes()?)),
                3 => Some(RMsg::Done),
                _ => None,
            }
        }
    }

    #[test]
    fn delivers_in_order_across_threads() {
        let t = Arc::new(RingTransport::<RMsg>::new(2));
        let tx = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            for i in 0..500u64 {
                tx.send(0, 1, i, RMsg::Data(i)).unwrap();
            }
            tx.send(0, 1, 500, RMsg::Done).unwrap();
        });
        let mut got = Vec::new();
        loop {
            let env = t.recv(1).expect("open stream");
            assert_eq!(env.src, 0);
            match env.msg {
                RMsg::Data(v) => got.push(v),
                RMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        h.join().unwrap();
        assert_eq!(got, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn fragments_large_frames_through_a_tiny_ring() {
        // 4 slots × 64B forces heavy fragmentation AND full-ring
        // backpressure on a 10 KiB payload
        let t = Arc::new(RingTransport::<RMsg>::with_geometry(2, 4, 64));
        let blob: Vec<u8> = (0..10_240).map(|i| (i * 31 % 251) as u8).collect();
        let expect = blob.clone();
        let tx = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            tx.send(0, 1, 0, RMsg::Blob(blob)).unwrap();
            tx.send(0, 1, 1, RMsg::Done).unwrap();
        });
        let env = t.recv(1).expect("blob arrives");
        assert_eq!(env.msg, RMsg::Blob(expect));
        assert!(matches!(t.recv(1).expect("done arrives").msg, RMsg::Done));
        h.join().unwrap();
    }

    #[test]
    fn local_lane_merges_in_ticket_order() {
        // local-only messages interleaved with wire messages must be
        // delivered in exact global send order
        let t = RingTransport::<RMsg>::new(2);
        for i in 0..50u64 {
            if i % 3 == 0 {
                t.send(0, 1, i, RMsg::Local(i)).unwrap();
            } else {
                t.send(0, 1, i, RMsg::Data(i)).unwrap();
            }
        }
        t.send(0, 1, 50, RMsg::Done).unwrap();
        let mut got = Vec::new();
        loop {
            match t.recv(1).expect("open stream").msg {
                RMsg::Local(v) | RMsg::Data(v) => got.push(v),
                RMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn many_producers_one_consumer_total_order_per_link() {
        let world = 4;
        let t = Arc::new(RingTransport::<RMsg>::new(world));
        let mut handles = Vec::new();
        for src in 0..world {
            let tx = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    tx.send(src, 3, i, RMsg::Data(src as u64 * 1000 + i)).unwrap();
                }
                tx.send(src, 3, 200, RMsg::Done).unwrap();
            }));
        }
        let mut per_src: Vec<Vec<u64>> = vec![Vec::new(); world];
        let mut done = 0;
        while done < world {
            let env = t.recv(3).expect("open stream");
            match env.msg {
                RMsg::Data(v) => per_src[env.src].push(v - env.src as u64 * 1000),
                RMsg::Done => done += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        for (src, got) in per_src.iter().enumerate() {
            assert_eq!(got, &(0..200).collect::<Vec<_>>(), "link {src}→3 must stay FIFO");
        }
    }

    #[test]
    fn chaos_over_ring_reassembles_exactly_once_in_order() {
        // the ChaosComm wrapper layered on the byte-moving ring: the
        // wrapper owns seqs + reassembly, the ring owns delivery order
        let plan = FaultPlan {
            drop: 0.10,
            dup: 0.30,
            reorder: 0.30,
            delay: 0.20,
            seed: 0xFA15,
            partition: Vec::new(),
        };
        let inner = Arc::new(RingTransport::<RMsg>::new(2));
        let t = FaultyTransport::over(inner, plan, RetryPolicy::default());
        for i in 0..200u64 {
            t.send(0, 1, i, RMsg::Data(i)).expect("transient plan never loses a message");
        }
        t.send(0, 1, 200, RMsg::Done).expect("barrier delivered");
        let mut got = Vec::new();
        loop {
            match t.recv(1).expect("open stream").msg {
                RMsg::Data(v) => got.push(v),
                RMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..200).collect::<Vec<_>>(), "chaos over the ring must be invisible");
        assert!(t.stats().retries > 0);
        assert_eq!(t.buffered_envelopes(), 0);
    }

    #[test]
    fn close_unblocks_an_idle_consumer() {
        let t = Arc::new(RingTransport::<RMsg>::new(2));
        let rx = Arc::clone(&t);
        let h = std::thread::spawn(move || rx.recv(1));
        std::thread::sleep(Duration::from_millis(5));
        t.close();
        assert!(h.join().unwrap().is_none(), "recv must return None after close");
    }
}
