//! WireComm (2/2) — the UDS/TCP socket transport.
//!
//! [`SocketTransport`] moves every envelope through real kernel
//! sockets: each rank binds a Unix-domain listener (falling back to a
//! TCP loopback listener when the UDS bind fails — path-length limits,
//! exotic filesystems), advertises its address in a shared rendezvous
//! directory (`rank{d}.addr`), and peers connect one stream per
//! directed link on first use. An acceptor thread per hosted rank
//! spawns one reader thread per accepted connection; readers decode
//! frames and hand them to the destination's queue.
//!
//! # Wire format
//!
//! The stream carries length-prefixed **segments**:
//!
//! ```text
//! [len: u32 LE][last: u8][bytes…]      // one segment
//! ```
//!
//! A [`frame`]-encoded envelope ≤ `CHUNK_BYTES` travels as a single
//! `last=1` segment. Larger frames are **chunked** into `CHUNK_BYTES`
//! segments (`last=1` only on the final one) so a multi-megabyte push
//! never monopolizes a link buffer in one burst and the kernel can
//! pipeline the copy — the receiving reassembly is a per-connection
//! append (stream FIFO keeps a frame's chunks contiguous).
//!
//! # Fusion
//!
//! Consecutive small *data* frames on one link that share a microbatch
//! id are **fused**: their segments accumulate in a per-connection
//! buffer and flush as a single `write(2)` once the `FUSION_BUDGET` is
//! reached, a different microbatch arrives, or a barrier message comes
//! through (barriers — `Done`/`Flush`/`Shutdown` — always flush, so a
//! fused tail can never outlive its own minibatch epilogue; this is
//! the same discipline ChaosComm's limbo applies). Fusion only delays
//! the syscall, never the order: tickets are claimed at enqueue, and
//! the stream write order matches ticket order per link.
//!
//! # Two modes
//!
//! * **Hosted** ([`SocketTransport::bind_world`]) — one process hosts
//!   all ranks (the trainer: device threads + daemon threads). Every
//!   byte still crosses the kernel through a genuine socketpair, and a
//!   shared per-destination ticket counter restores the in-process
//!   mailbox's global arrival order, keeping backends bit-identical
//!   (see `comm/ring.rs` for the ticket argument).
//! * **Endpoint** ([`SocketTransport::endpoint`]) — one process per
//!   rank (the `runtime::spawn_world` harness). No shared counters
//!   exist across processes, so delivery is per-link FIFO with fair
//!   cross-link arrival order, and protocols over it must be
//!   order-tolerant (the harness's scatter-accumulate is).

use super::transport::{frame, Envelope, SendError, Transport, WireCodec};
use std::collections::{BTreeMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Frames larger than this are split into `last=0` segments.
pub const CHUNK_BYTES: usize = 256 * 1024;
/// Fused small-frame buffer flushes at this many bytes.
pub const FUSION_BUDGET: usize = 32 * 1024;
/// Segment header bytes (`u32` length + `u8` last flag).
const SEG_HDR: usize = 5;
/// How long connect/rendezvous waits for a peer's address file.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(20);

static DIR_COUNTER: AtomicUsize = AtomicUsize::new(0);

enum Listener {
    Uds(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Uds(l) => l.accept().map(|(s, _)| Stream::Uds(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

enum Stream {
    Uds(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn shutdown(&self) {
        let _ = match self {
            Stream::Uds(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Uds(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Uds(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Uds(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// One outbound link: the stream plus the fusion buffer.
struct Conn {
    stream: Stream,
    /// Pre-segmented fused bytes awaiting one `write(2)`.
    fused: Vec<u8>,
    /// Microbatch id the fused frames share.
    fused_micro: u64,
}

impl Conn {
    fn flush_fused(&mut self) -> io::Result<()> {
        if self.fused.is_empty() {
            return Ok(());
        }
        let buf = std::mem::take(&mut self.fused);
        self.stream.write_all(&buf)
    }
}

/// Per-destination delivery queue fed by reader threads and the local
/// lane. Ordered mode releases strictly by ticket; unordered mode
/// (endpoint) releases in arrival order.
struct DstQueue<M> {
    m: Mutex<QInner<M>>,
    cv: Condvar,
}

struct QInner<M> {
    ordered: bool,
    next_ticket: u64,
    stash: BTreeMap<u64, Envelope<M>>,
    fifo: VecDeque<Envelope<M>>,
    closed: bool,
}

impl<M> DstQueue<M> {
    fn new(ordered: bool) -> Self {
        DstQueue {
            m: Mutex::new(QInner {
                ordered,
                next_ticket: 0,
                stash: BTreeMap::new(),
                fifo: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, ticket: u64, env: Envelope<M>) {
        let mut q = self.m.lock().unwrap();
        if q.ordered {
            q.stash.insert(ticket, env);
        } else {
            q.fifo.push_back(env);
        }
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<Envelope<M>> {
        let mut q = self.m.lock().unwrap();
        loop {
            if q.ordered {
                let next = q.next_ticket;
                if let Some(env) = q.stash.remove(&next) {
                    q.next_ticket += 1;
                    return Some(env);
                }
            } else if let Some(env) = q.fifo.pop_front() {
                return Some(env);
            }
            if q.closed {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    fn close(&self) {
        self.m.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// UDS-with-TCP-fallback byte transport — see the module docs.
pub struct SocketTransport<M: WireCodec> {
    world: usize,
    /// `None` = hosted mode (all ranks in this process); `Some(r)` =
    /// endpoint mode (this process is rank `r` only).
    rank: Option<usize>,
    dir: PathBuf,
    owns_dir: bool,
    /// Hosted listener ranks (for the teardown dummy-connect).
    hosted: Vec<usize>,
    conns: Vec<Mutex<Option<Conn>>>,
    seq: Vec<AtomicU64>,
    tickets: Vec<AtomicU64>,
    queues: Vec<Arc<DstQueue<M>>>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    closed: Arc<AtomicBool>,
}

impl<M: WireCodec> SocketTransport<M> {
    /// Hosted mode: bind every rank's listener in this process (the
    /// trainer path — device threads keep sharing the `ParamStore`,
    /// while every mailbox byte crosses the kernel). Ticket-ordered:
    /// delivery matches the in-process mailbox exactly.
    pub fn bind_world(world: usize) -> io::Result<Self> {
        let dir = std::env::temp_dir().join(format!(
            "odc-wire-{}-{}",
            std::process::id(),
            DIR_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Self::build(world, None, dir, true, true)
    }

    /// Endpoint mode: this process hosts exactly `rank`, rendezvousing
    /// with its peers through the shared `dir`. Delivery is per-link
    /// FIFO only (no cross-process ticket counter exists).
    pub fn endpoint(rank: usize, world: usize, dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Self::build(world, Some(rank), dir, false, false)
    }

    fn build(
        world: usize,
        rank: Option<usize>,
        dir: PathBuf,
        owns_dir: bool,
        ordered: bool,
    ) -> io::Result<Self> {
        let hosted: Vec<usize> = match rank {
            Some(r) => vec![r],
            None => (0..world).collect(),
        };
        let queues: Vec<Arc<DstQueue<M>>> =
            (0..world).map(|_| Arc::new(DstQueue::new(ordered))).collect();
        let threads = Arc::new(Mutex::new(Vec::new()));
        let closed = Arc::new(AtomicBool::new(false));
        for &r in &hosted {
            let listener = Self::bind_rank(&dir, r)?;
            let q = Arc::clone(&queues[r]);
            let reg = Arc::clone(&threads);
            let stop = Arc::clone(&closed);
            let acceptor = std::thread::spawn(move || {
                loop {
                    let stream = match listener.accept() {
                        Ok(s) => s,
                        Err(_) => break,
                    };
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let q = Arc::clone(&q);
                    let reader = std::thread::spawn(move || reader_loop::<M>(stream, q));
                    reg.lock().unwrap().push(reader);
                }
            });
            threads.lock().unwrap().push(acceptor);
        }
        Ok(SocketTransport {
            world,
            rank,
            dir,
            owns_dir,
            hosted,
            conns: (0..world * world).map(|_| Mutex::new(None)).collect(),
            seq: (0..world * world).map(|_| AtomicU64::new(0)).collect(),
            tickets: (0..world).map(|_| AtomicU64::new(0)).collect(),
            queues,
            threads,
            closed,
        })
    }

    /// Bind rank `r`'s listener: UDS at `dir/rank{r}.sock`, falling
    /// back to a TCP loopback socket; advertise in `dir/rank{r}.addr`.
    fn bind_rank(dir: &Path, r: usize) -> io::Result<Listener> {
        let sock = dir.join(format!("rank{r}.sock"));
        let _ = std::fs::remove_file(&sock);
        let (listener, addr_line) = match UnixListener::bind(&sock) {
            Ok(l) => (Listener::Uds(l), format!("uds:{}", sock.display())),
            Err(_) => {
                let l = TcpListener::bind("127.0.0.1:0")?;
                let port = l.local_addr()?.port();
                (Listener::Tcp(l), format!("tcp:127.0.0.1:{port}"))
            }
        };
        // write-then-rename so peers never read a torn address file
        let tmp = dir.join(format!("rank{r}.addr.tmp"));
        std::fs::write(&tmp, format!("{addr_line}\n"))?;
        std::fs::rename(&tmp, dir.join(format!("rank{r}.addr")))?;
        Ok(listener)
    }

    /// Resolve + connect to `dst`, polling for its address file until
    /// the rendezvous timeout (peers may still be starting up).
    fn connect(dir: &Path, dst: usize) -> io::Result<Stream> {
        let addr_file = dir.join(format!("rank{dst}.addr"));
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        loop {
            match std::fs::read_to_string(&addr_file) {
                Ok(line) => {
                    let line = line.trim();
                    if let Some(path) = line.strip_prefix("uds:") {
                        match UnixStream::connect(path) {
                            Ok(s) => return Ok(Stream::Uds(s)),
                            Err(e) if Instant::now() >= deadline => return Err(e),
                            Err(_) => {}
                        }
                    } else if let Some(addr) = line.strip_prefix("tcp:") {
                        match TcpStream::connect(addr) {
                            Ok(s) => return Ok(Stream::Tcp(s)),
                            Err(e) if Instant::now() >= deadline => return Err(e),
                            Err(_) => {}
                        }
                    } else {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("malformed address file {}", addr_file.display()),
                        ));
                    }
                }
                Err(e) if Instant::now() >= deadline => {
                    return Err(io::Error::new(
                        e.kind(),
                        format!("rank{dst}.addr never appeared in {}", dir.display()),
                    ));
                }
                Err(_) => {}
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Put one encoded frame on link `src→dst`, fusing or chunking as
    /// the sizes dictate.
    fn write_wire(&self, src: usize, dst: usize, barrier: bool, micro: u64, bytes: Vec<u8>) -> io::Result<()> {
        let mut guard = self.conns[src * self.world + dst].lock().unwrap();
        if guard.is_none() {
            let stream = Self::connect(&self.dir, dst)?;
            *guard = Some(Conn { stream, fused: Vec::new(), fused_micro: 0 });
        }
        let conn = guard.as_mut().expect("just connected");
        let seg_len = SEG_HDR + bytes.len();
        let fusible = !barrier && seg_len <= FUSION_BUDGET;
        if !conn.fused.is_empty()
            && (!fusible || conn.fused_micro != micro || conn.fused.len() + seg_len > FUSION_BUDGET)
        {
            conn.flush_fused()?;
        }
        if fusible {
            if conn.fused.is_empty() {
                conn.fused_micro = micro;
            }
            push_segment(&mut conn.fused, &bytes, true);
            if conn.fused.len() >= FUSION_BUDGET {
                conn.flush_fused()?;
            }
            return Ok(());
        }
        // immediate path: barrier or large frame (chunked)
        let mut off = 0usize;
        loop {
            let take = (bytes.len() - off).min(CHUNK_BYTES);
            let last = off + take == bytes.len();
            let mut seg = Vec::with_capacity(SEG_HDR + take);
            push_segment_raw(&mut seg, &bytes[off..off + take], last);
            conn.stream.write_all(&seg)?;
            off += take;
            if last {
                break;
            }
        }
        Ok(())
    }

    /// Flush a link's fused buffer (barrier discipline for local-only
    /// messages, which bypass `write_wire`).
    fn flush_link(&self, src: usize, dst: usize) {
        if let Some(conn) = self.conns[src * self.world + dst].lock().unwrap().as_mut() {
            let _ = conn.flush_fused();
        }
    }
}

fn push_segment_raw(out: &mut Vec<u8>, seg: &[u8], last: bool) {
    out.extend_from_slice(&(seg.len() as u32).to_le_bytes());
    out.push(last as u8);
    out.extend_from_slice(seg);
}

fn push_segment(out: &mut Vec<u8>, whole_frame: &[u8], last: bool) {
    push_segment_raw(out, whole_frame, last)
}

/// Per-connection reader: reassemble segments into frames, decode,
/// enqueue. Exits on EOF / teardown.
fn reader_loop<M: WireCodec>(mut stream: Stream, q: Arc<DstQueue<M>>) {
    let mut pending: Vec<u8> = Vec::new();
    loop {
        let mut hdr = [0u8; SEG_HDR];
        if stream.read_exact(&mut hdr).is_err() {
            return; // EOF: peer closed or teardown
        }
        let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
        let last = hdr[4] == 1;
        let start = pending.len();
        pending.resize(start + len, 0);
        if stream.read_exact(&mut pending[start..]).is_err() {
            return;
        }
        if !last {
            continue;
        }
        let bytes = std::mem::take(&mut pending);
        match frame::decode::<M>(&bytes) {
            Some((ticket, env)) => q.push(ticket, env),
            None => debug_assert!(false, "malformed socket frame"),
        }
    }
}

impl<M: WireCodec> Transport<M> for SocketTransport<M> {
    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, src: usize, dst: usize, micro: u64, msg: M) -> Result<(), SendError> {
        debug_assert!(self.rank.is_none() || self.rank == Some(src), "endpoint sends as itself");
        let seq = self.seq[src * self.world + dst].fetch_add(1, Ordering::Relaxed);
        let env = Envelope { src, seq, micro, msg };
        let ticket = self.tickets[dst].fetch_add(1, Ordering::Relaxed);
        match frame::encode(ticket, &env) {
            Some(bytes) => {
                let barrier = env.msg.is_barrier();
                self.write_wire(src, dst, barrier, micro, bytes).map_err(|_| SendError::Unreachable)
            }
            None => {
                // local-only: barrier discipline, then the ticketed lane
                self.flush_link(src, dst);
                self.queues[dst].push(ticket, env);
                Ok(())
            }
        }
    }

    fn send_env(&self, dst: usize, env: Envelope<M>) {
        let ticket = self.tickets[dst].fetch_add(1, Ordering::Relaxed);
        match frame::encode(ticket, &env) {
            Some(bytes) => {
                let barrier = env.msg.is_barrier();
                let res = self.write_wire(env.src, dst, barrier, env.micro, bytes);
                debug_assert!(res.is_ok(), "socket send_env failed: {res:?}");
            }
            None => {
                self.flush_link(env.src, dst);
                self.queues[dst].push(ticket, env);
            }
        }
    }

    fn recv(&self, dst: usize) -> Option<Envelope<M>> {
        debug_assert!(self.rank.is_none() || self.rank == Some(dst), "endpoint receives as itself");
        self.queues[dst].pop()
    }

    fn one_sided(&self, _src: usize, _dst: usize, _bytes: usize) -> Result<u32, SendError> {
        // gathers / replica refresh stay shared-memory reads in hosted
        // mode; `benches/wire_calib.rs` prices the socket path itself
        Ok(0)
    }
}

impl<M: WireCodec> Drop for SocketTransport<M> {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::Release);
        // flush + shut down every outbound stream (EOFs the readers)
        for c in &self.conns {
            if let Some(mut conn) = c.lock().unwrap().take() {
                let _ = conn.flush_fused();
                conn.stream.shutdown();
            }
        }
        // pop each acceptor out of accept() with a throwaway connection
        for &r in &self.hosted {
            if let Ok(s) = Self::connect(&self.dir, r) {
                s.shutdown();
            }
        }
        for q in &self.queues {
            q.close();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        if self.owns_dir {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::WireMsg;

    #[derive(Clone, Debug, PartialEq)]
    enum SMsg {
        Data(u64),
        Blob(Vec<u8>),
        Local(u64),
        Done,
    }

    impl WireMsg for SMsg {
        fn is_barrier(&self) -> bool {
            matches!(self, SMsg::Done)
        }
        fn payload_bytes(&self) -> usize {
            match self {
                SMsg::Blob(b) => b.len(),
                _ => 8,
            }
        }
    }

    impl WireCodec for SMsg {
        fn encode(&self, out: &mut Vec<u8>) -> bool {
            match self {
                SMsg::Data(v) => {
                    out.push(0);
                    frame::put_u64(out, *v);
                }
                SMsg::Blob(b) => {
                    out.push(1);
                    frame::put_bytes(out, b);
                }
                SMsg::Local(_) => return false,
                SMsg::Done => out.push(3),
            }
            true
        }
        fn decode(bytes: &[u8]) -> Option<SMsg> {
            let mut r = frame::Reader::new(bytes.get(1..)?);
            match bytes.first()? {
                0 => Some(SMsg::Data(r.u64()?)),
                1 => Some(SMsg::Blob(r.bytes()?)),
                3 => Some(SMsg::Done),
                _ => None,
            }
        }
    }

    #[test]
    fn hosted_loopback_delivers_in_order() {
        let t = Arc::new(SocketTransport::<SMsg>::bind_world(2).expect("bind"));
        let tx = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            for i in 0..300u64 {
                tx.send(0, 1, i / 8, SMsg::Data(i)).unwrap();
            }
            tx.send(0, 1, 0, SMsg::Done).unwrap();
        });
        let mut got = Vec::new();
        loop {
            let env = t.recv(1).expect("open stream");
            match env.msg {
                SMsg::Data(v) => got.push(v),
                SMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        h.join().unwrap();
        assert_eq!(got, (0..300).collect::<Vec<_>>(), "fusion must not disturb order");
    }

    #[test]
    fn chunks_large_frames() {
        let t = Arc::new(SocketTransport::<SMsg>::bind_world(2).expect("bind"));
        // > CHUNK_BYTES forces the multi-segment path
        let blob: Vec<u8> = (0..CHUNK_BYTES + 12_345).map(|i| (i * 131 % 251) as u8).collect();
        let expect = blob.clone();
        let tx = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            tx.send(0, 1, 0, SMsg::Blob(blob)).unwrap();
            tx.send(0, 1, 0, SMsg::Done).unwrap();
        });
        let env = t.recv(1).expect("blob arrives");
        assert_eq!(env.msg, SMsg::Blob(expect));
        assert!(matches!(t.recv(1).expect("done").msg, SMsg::Done));
        h.join().unwrap();
    }

    #[test]
    fn local_lane_merges_in_ticket_order() {
        let t = SocketTransport::<SMsg>::bind_world(2).expect("bind");
        for i in 0..40u64 {
            if i % 4 == 0 {
                t.send(1, 1, 0, SMsg::Local(i)).unwrap();
            } else {
                t.send(1, 1, 0, SMsg::Data(i)).unwrap();
            }
        }
        t.send(1, 1, 0, SMsg::Done).unwrap();
        let mut got = Vec::new();
        loop {
            match t.recv(1).expect("open stream").msg {
                SMsg::Local(v) | SMsg::Data(v) => got.push(v),
                SMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn endpoint_pair_rendezvous_over_the_dir() {
        // two endpoint transports in one test process — the same path
        // spawn_world exercises across OS processes
        let dir = std::env::temp_dir().join(format!("odc-wire-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let a = SocketTransport::<SMsg>::endpoint(0, 2, &dir).expect("bind rank 0");
        let b = SocketTransport::<SMsg>::endpoint(1, 2, &dir).expect("bind rank 1");
        for i in 0..100u64 {
            a.send(0, 1, 0, SMsg::Data(i)).unwrap();
        }
        a.send(0, 1, 0, SMsg::Done).unwrap();
        let mut got = Vec::new();
        loop {
            let env = b.recv(1).expect("open stream");
            assert_eq!(env.src, 0);
            match env.msg {
                SMsg::Data(v) => got.push(v),
                SMsg::Done => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..100).collect::<Vec<_>>(), "per-link FIFO holds in endpoint mode");
        drop(b);
        drop(a);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
