//! Per-(server, client) payload arenas — the paper's preallocated
//! per-client RDMA buffers (Appendix B), landed in the real backend.
//!
//! The seed implementation pooled push payloads in ONE global
//! `Mutex<Vec<Vec<f32>>>`: every `reduce_grad` from every client took
//! the same lock and linearly scanned for a buffer of sufficient
//! capacity — O(pool) under a contended lock, on the hottest path in
//! the system. An arena instead belongs to exactly one (server, client)
//! pair, so:
//!
//! * a client's `acquire` only ever contends with the one daemon
//!   returning that client's own consumed buffers — never with other
//!   clients (the paper's point: per-client buffers make concurrent
//!   pushes independent);
//! * slots are preallocated per layer at that layer's `shard_range`
//!   WIRE length in bytes (plus one max-sized spare for daemon lag), so
//!   `acquire` is a best-fit pick over ~layers+1 uncontended entries +
//!   `extend_from_slice`, never a heap allocation in steady state.
//!   Arenas pool raw encoded bytes (`Vec<u8>`) rather than f32s: under
//!   `WireDtype::Bf16` the resident payload memory genuinely halves,
//!   and the pool is dtype-agnostic — callers size capacities via
//!   `WireDtype::bytes_for`;
//! * in-flight payloads per pair are bounded by one minibatch's pushes
//!   (`end_minibatch` fully drains every daemon before any device can
//!   start the next minibatch), so the arena stops growing after
//!   warm-up — asserted by the `comm_stress` integration tests via the
//!   [`ArenaStats`] counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cumulative counters over one arena (or summed over a matrix of them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Buffers handed out (one per gradient piece pushed).
    pub acquires: u64,
    /// Acquires that had to heap-allocate because the arena was empty.
    /// Steady state after warm-up: this stops increasing.
    pub fresh_allocs: u64,
    /// Buffers currently resident (preallocated + returned).
    pub resident: u64,
}

impl ArenaStats {
    pub fn merge(&mut self, other: ArenaStats) {
        self.acquires += other.acquires;
        self.fresh_allocs += other.fresh_allocs;
        self.resident += other.resident;
    }
}

/// A preallocated payload buffer pool owned by one (server, client) pair.
pub struct PayloadArena {
    /// Free buffers, heterogeneous capacities (one per layer + spares).
    slots: Mutex<Vec<Vec<u8>>>,
    acquires: AtomicU64,
    fresh_allocs: AtomicU64,
}

impl PayloadArena {
    /// Arena preallocating one empty buffer per entry of `caps` (BYTE
    /// capacities) — callers pass one encoded shard length per layer
    /// plus any headroom spares.
    pub fn new(caps: &[usize]) -> Self {
        PayloadArena {
            slots: Mutex::new(caps.iter().map(|&c| Vec::with_capacity(c)).collect()),
            acquires: AtomicU64::new(0),
            fresh_allocs: AtomicU64::new(0),
        }
    }

    /// Take an EMPTY buffer with capacity for at least `len` bytes —
    /// best fit, so a small request never consumes a large layer's slot
    /// — and let the caller fill it with `extend_from_slice` (no
    /// zero-fill, no reallocation). Falls back to a fresh allocation
    /// (counted) only when no slot fits.
    pub fn acquire(&self, len: usize) -> Vec<u8> {
        self.acquires.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.slots.lock().unwrap();
        let best = slots
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        if let Some(i) = best {
            let mut b = slots.swap_remove(i);
            drop(slots);
            b.clear();
            return b;
        }
        drop(slots);
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(len)
    }

    /// Return a consumed buffer (daemon side). Never shrinks; the arena
    /// grows to the historical in-flight maximum and then stays flat.
    pub fn release(&self, buf: Vec<u8>) {
        self.slots.lock().unwrap().push(buf);
    }

    /// Free every resident buffer: the pair's client is gone for good
    /// (ElasticWorld device failure), so its prealloc is dead weight.
    /// The counters keep their history; `resident` drops to 0. A retired
    /// arena still works if ever used again — acquires just fall through
    /// to fresh allocations.
    pub fn retire(&self) {
        self.slots.lock().unwrap().clear();
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            acquires: self.acquires.load(Ordering::Relaxed),
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            resident: self.slots.lock().unwrap().len() as u64,
        }
    }
}

/// A dense matrix of per-pair arenas, every entry preallocated with the
/// same capacity schedule.
///
/// One matrix describes one *communication level*: `OdcComm` owns a
/// (server × client) matrix of global-shard-sized arenas; the hybrid
/// two-level backend owns a (server × group-local-client) matrix for the
/// intra-group scatter-accumulate and an (owner × group) matrix for the
/// cross-group epilogue pieces. Rows belong to the receiving daemon
/// (which `release`s consumed payloads), columns to the sender (which
/// `acquire`s) — so every pair stays uncontended exactly as a single
/// [`PayloadArena`] does.
pub struct ArenaMatrix {
    rows: Vec<Vec<Arc<PayloadArena>>>,
}

impl ArenaMatrix {
    /// `rows × cols` arenas, each preallocating one buffer per entry of
    /// `caps` (callers pass one per-layer payload length plus headroom
    /// spares, exactly as for [`PayloadArena::new`]).
    pub fn new(rows: usize, cols: usize, caps: &[usize]) -> Self {
        ArenaMatrix {
            rows: (0..rows)
                .map(|_| (0..cols).map(|_| Arc::new(PayloadArena::new(caps))).collect())
                .collect(),
        }
    }

    /// The arena of one (receiver, sender) pair.
    #[inline]
    pub fn arena(&self, row: usize, col: usize) -> &PayloadArena {
        &self.rows[row][col]
    }

    /// Clones of one row's arenas, in column order — handed to the
    /// receiving daemon so it can release payloads without touching the
    /// matrix itself.
    pub fn row(&self, row: usize) -> Vec<Arc<PayloadArena>> {
        self.rows[row].iter().map(Arc::clone).collect()
    }

    /// Summed counters over every pair in the matrix.
    pub fn stats(&self) -> ArenaStats {
        let mut total = ArenaStats::default();
        for row in &self.rows {
            for a in row {
                total.merge(a.stats());
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_within_prealloc_never_allocates() {
        let a = PayloadArena::new(&[64, 64, 16]);
        for _ in 0..100 {
            let mut b1 = a.acquire(64);
            let b2 = a.acquire(16);
            b1.extend_from_slice(&[1u8; 64]);
            a.release(b1);
            a.release(b2);
        }
        let s = a.stats();
        assert_eq!(s.acquires, 200);
        assert_eq!(s.fresh_allocs, 0, "double-buffered use must stay inside the prealloc");
        assert_eq!(s.resident, 3);
    }

    #[test]
    fn overflow_allocates_then_stabilizes() {
        let a = PayloadArena::new(&[8, 8]);
        // burst of 5 in flight: 3 fresh allocations, once
        let held: Vec<_> = (0..5).map(|_| a.acquire(8)).collect();
        for b in held {
            a.release(b);
        }
        assert_eq!(a.stats().fresh_allocs, 3);
        assert_eq!(a.stats().resident, 5);
        // same burst again: the grown arena absorbs it, no new allocs
        let held: Vec<_> = (0..5).map(|_| a.acquire(8)).collect();
        for b in held {
            a.release(b);
        }
        assert_eq!(a.stats().fresh_allocs, 3, "arena must not grow after warm-up");
    }

    #[test]
    fn acquire_is_best_fit() {
        // a small request must not consume a large layer's slot
        let a = PayloadArena::new(&[4, 100]);
        let small = a.acquire(3);
        assert!(small.capacity() < 100, "small request took the large slot");
        let large = a.acquire(50);
        assert!(large.capacity() >= 100);
        assert_eq!(a.stats().fresh_allocs, 0);
        a.release(small);
        a.release(large);
    }

    #[test]
    fn matrix_pairs_are_independent() {
        let m = ArenaMatrix::new(2, 3, &[8, 8]);
        // draining one pair never touches a neighbour's prealloc
        let held: Vec<_> = (0..2).map(|_| m.arena(0, 0).acquire(8)).collect();
        assert_eq!(m.arena(0, 0).stats().resident, 0);
        assert_eq!(m.arena(0, 1).stats().resident, 2);
        assert_eq!(m.arena(1, 2).stats().fresh_allocs, 0);
        for b in held {
            m.arena(0, 0).release(b);
        }
        let s = m.stats();
        assert_eq!(s.resident, 2 * 3 * 2);
        assert_eq!(s.acquires, 2);
        assert_eq!(s.fresh_allocs, 0);
    }

    #[test]
    fn matrix_row_clones_release_into_matrix() {
        let m = ArenaMatrix::new(2, 2, &[4]);
        let row = m.row(1);
        let b = m.arena(1, 0).acquire(4);
        row[0].release(b); // the daemon-side clone is the same arena
        assert_eq!(m.arena(1, 0).stats().resident, 1);
    }

    #[test]
    fn acquired_buffers_are_empty_with_capacity() {
        let a = PayloadArena::new(&[32]);
        let mut b = a.acquire(10);
        assert!(b.is_empty());
        assert!(b.capacity() >= 32);
        b.extend_from_slice(&[2u8; 10]);
        let ptr = b.as_ptr();
        a.release(b);
        // round-trips reuse the same allocation
        let b2 = a.acquire(10);
        assert!(b2.is_empty());
        assert_eq!(b2.as_ptr(), ptr);
    }
}
