//! Communication layer: the paper's contribution.
//!
//! * [`topology`] — cluster model (NVSwitch intra-node, RoCE inter-node).
//! * [`volume`] — per-client communication volumes (Table 2) and the
//!   analytic time model the simulator uses.
//! * [`shared`] — shared-memory substrate standing in for CUDA-IPC /
//!   NVSHMEM one-sided windows: shard stores, push mailboxes, and the
//!   accumulation daemon.
//! * [`collective`] — baseline backend: all-gather / reduce-scatter with
//!   per-layer barriers.
//! * [`odc`] — the paper's backend: gather / scatter-accumulate with one
//!   barrier per minibatch.
//! * [`hybrid`] — §6.1 hybrid sharding as a REAL two-level backend:
//!   params/grads sharded within a topology group (one-sided gathers
//!   over per-group replicas, intra-group scatter-accumulate), optimizer
//!   shards across all devices with an ODC-style cross-group epilogue —
//!   cross-group synchronization only at `end_minibatch`/`end_step`.
//! * [`arena`] — preallocated per-(server, client) payload arenas (the
//!   paper's Appendix B per-client RDMA buffers) and the [`ArenaMatrix`]
//!   generalization the two-level backend indexes per (group, client):
//!   every push path is allocation-free and uncontended in steady state.
//! * [`gather_cache`] — minibatch-scoped parameter-gather cache (§6.2
//!   parameter caching) for one-sided backends: each layer is gathered
//!   once per minibatch and shared zero-copy from then on.
//! * [`fold`] — FastFold: the shared weighted-accumulate kernels
//!   (chunked scalar + deterministic chunk-parallel) every fold site
//!   drives, the [`WireDtype`] payload codecs (f32 exact / bf16 with
//!   error feedback), and the bulk f32↔LE-byte casts.
//! * [`transport`] — ChaosComm: the typed envelope transport under the
//!   mailboxes ([`InProcTransport`] reliable path, [`FaultyTransport`]
//!   deterministic drop/dup/reorder/delay injection per a declarative
//!   [`FaultPlan`]) with retransmit ladder, receiver-side reassembly,
//!   and suspicion-counter escalation into ElasticWorld.
//! * [`ring`] — WireComm: lock-free shared-memory SPSC ring-buffer
//!   transport (turn-counter slot publish, frame fragmentation,
//!   busy/park hybrid wait) — bytes leave the typed mailbox world.
//! * [`socket`] — WireComm: UDS-with-TCP-fallback transport (framed
//!   length-prefixed envelopes over kernel sockets, message fusion,
//!   chunking) with a per-OS-process endpoint mode driven by
//!   `runtime::spawn_world`.
//! * [`membership`] — ElasticWorld: fault-tolerant elastic membership
//!   for the one-sided backends (device crash mid-minibatch, join at a
//!   minibatch boundary, deterministic rendezvous shard takeover,
//!   replicated optimizer state) — the classical PS property collective
//!   FSDP structurally cannot offer.
//! * [`async_ps`] — AsyncPS: the bounded-staleness parameter-server
//!   tier — dedicated shard-server daemons buffering per-minibatch
//!   gradient buckets, free-running workers admission-gated on the
//!   versioned `ParamStore` clock (`k = 0` bit-identical to [`odc`]).
//! * [`stack`] — [`CommStack`], the single public builder every
//!   backend is constructed through (membership × wire × transport ×
//!   faults × staleness), holding the stack legality matrix.
//! * [`backend`] — the `CommBackend` trait the engine drives.
//! * [`primbench`] — the Fig 11 primitive bandwidth benchmark.

pub mod arena;
pub mod async_ps;
pub mod backend;
pub mod collective;
pub mod fold;
pub mod gather_cache;
pub mod hybrid;
pub mod membership;
pub mod odc;
pub mod primbench;
pub mod ring;
pub mod shared;
pub mod socket;
pub mod stack;
pub mod topology;
pub mod transport;
pub mod volume;

pub use arena::{ArenaMatrix, ArenaStats, PayloadArena};
pub use async_ps::AsyncPs;
pub use backend::{CommBackend, GatherPolicy, HotpathStats};
pub use collective::CollectiveComm;
pub use fold::{FoldPiece, PieceData, WireDtype};
pub use gather_cache::{CacheStats, GatherCache};
pub use hybrid::HybridComm;
pub use membership::{Membership, MembershipBarrier, OptReplica};
pub use odc::OdcComm;
pub use ring::RingTransport;
pub use socket::SocketTransport;
pub use stack::CommStack;
pub use topology::GroupMap;
pub use transport::{
    Envelope, FaultPlan, FaultStats, FaultyTransport, InProcTransport, RetryPolicy, SendError,
    Transport, TransportKind, WireCodec, WireMsg,
};
