//! Communication layer: the paper's contribution.
//!
//! * [`topology`] — cluster model (NVSwitch intra-node, RoCE inter-node).
//! * [`volume`] — per-client communication volumes (Table 2) and the
//!   analytic time model the simulator uses.
//! * [`shared`] — shared-memory substrate standing in for CUDA-IPC /
//!   NVSHMEM one-sided windows: shard stores, push mailboxes, and the
//!   accumulation daemon.
//! * [`collective`] — baseline backend: all-gather / reduce-scatter with
//!   per-layer barriers.
//! * [`odc`] — the paper's backend: gather / scatter-accumulate with one
//!   barrier per minibatch.
//! * [`backend`] — the `CommBackend` trait the engine drives.
//! * [`primbench`] — the Fig 11 primitive bandwidth benchmark.

pub mod backend;
pub mod collective;
pub mod odc;
pub mod primbench;
pub mod shared;
pub mod topology;
pub mod volume;

pub use backend::CommBackend;
pub use collective::CollectiveComm;
pub use odc::OdcComm;
