//! ODC backend: on-demand point-to-point communication (paper §3).
//!
//! * `gather_params` is a **one-sided read** of each owner's parameter
//!   window — no barrier, no participation of the owner (the CUDA-IPC /
//!   NVSHMEM `get_mem` analogue). Because it is one-sided and params are
//!   phase-immutable, gathers are also **cacheable** per minibatch
//!   ([`CommBackend::gathers_cacheable`] returns true; the engine's
//!   [`crate::comm::gather_cache::GatherCache`] exploits it — §6.2).
//! * `reduce_grad` is **scatter-accumulate**: the client splits its
//!   full-layer gradient by owner and pushes each piece into the owner's
//!   mailbox (the `put_mem` + notify analogue, Appendix B). A per-device
//!   **daemon thread** — the paper's "lightweight daemon" that polls for
//!   notifications without occupying compute — drains the mailbox and
//!   accumulates into the owned shard.
//! * The ONLY rendezvous is `end_minibatch`: a client broadcasts `Done`
//!   to every server; a server's gradients are complete once the step's
//!   live quorum of clients is done and its mailbox is drained. Devices
//!   therefore progress completely independently within a minibatch
//!   (Figure 2), including running *different microbatch counts*
//!   (LB-Mini) or pulling microbatches from a shared runtime queue
//!   ([`crate::balance::dispatch::WorkQueue`]).
//! * Under an elastic membership schedule
//!   ([`crate::comm::membership`]) the daemons double as persistent
//!   *shard servers*: a crashed worker's daemon keeps accumulating, the
//!   fold quorum and `end_step` barrier shrink to the live set, the
//!   dead client's arenas are retired at its fail-step fold, and the
//!   rendezvous successor adopts the orphaned shard via
//!   [`CommBackend::flush_shard`]. Collective has no counterpart — one
//!   dead rank deadlocks its per-layer barriers, which is exactly the
//!   PS-vs-collective contrast the elastic scenario measures.
//!
//! ## Determinism: the id-keyed fold
//!
//! The daemon does NOT accumulate in arrival order (float addition is
//! not associative, so arrival order would leak thread scheduling into
//! the training bytes). It buffers every piece with its **global
//! microbatch id** (`reduce_grad`'s `micro` argument) and folds at the
//! `end_minibatch` flush in (id, client) order — a pure function of the
//! plan, independent of placement and timing. Any dispatch interleaving
//! — static or work-stealing, uniform or straggling devices — is
//! therefore bit-identical to a single device replaying the
//! microbatches in id order (`tests/engine_equivalence.rs` pins this
//! against the oracle; `tests/comm_stress.rs` scrambles push order
//! directly). Buffering until the flush trades bounded memory (one
//! minibatch's pushes per pair, the bound the arenas already live with)
//! for exactness, the same trade [`super::hybrid`] documents.
//!
//! Buffering matches Appendix B exactly: each (server, client) pair owns
//! a preallocated [`PayloadArena`] sized by `shard_range` — the paper's
//! per-client RDMA buffers — so concurrent pushes from different clients
//! never alias, never contend on a shared lock, and never allocate in
//! steady state. The daemon returns each consumed payload to its pair's
//! arena at the fold; `end_minibatch` drains every daemon before any
//! device can advance, which bounds in-flight payloads per pair to one
//! minibatch's pushes and therefore bounds arena growth (see
//! `comm_stress`).

use super::arena::{ArenaMatrix, ArenaStats, PayloadArena};
use super::backend::{seq_micro_key, CommBackend, GatherPolicy, HotpathStats, ParamStore};
use super::fold::{self, FoldPiece, PieceData, WireDtype};
use super::membership::{Membership, MembershipBarrier};
use super::ring::RingTransport;
use super::socket::SocketTransport;
use super::transport::{
    frame, FaultPlan, FaultStats, FaultyTransport, InProcTransport, RetryPolicy, SendError,
    Transport, TransportKind, WireCodec, WireMsg,
};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone)]
enum Msg {
    /// One gradient piece for this server's shard of `layer`, pushed by
    /// `client` for global microbatch `micro`; buffered until the flush
    /// (the fold is keyed by `micro`, not arrival), then `data` returns
    /// to the (server, client) arena. `data` is the ENCODED wire image
    /// (the backend's [`WireDtype`]) — the daemon decodes fused into the
    /// f32 master accumulate at the fold.
    Accum { layer: usize, micro: u64, weight: f32, client: usize, data: Vec<u8> },
    /// One gradient piece of a SEQUENCE CHUNK (SeqSplit): chunk `chunk`
    /// of `count`, cut from parent sample `seq`, pushed by `client`.
    /// Buffered apart from the micro pieces; at the flush each
    /// sequence's chunks are partially reduced in chunk-index order
    /// FIRST, and the reconstituted gradient enters the micro fold under
    /// the synthetic key `seq_micro_key(seq)`.
    SeqAccum { layer: usize, seq: u64, chunk: u32, count: u32, weight: f32, client: usize, data: Vec<u8> },
    /// Discard the buffered piece of chunk (`seq`, `chunk`) from
    /// `client`, across all layers — the SeqSplit arm of the
    /// all-or-nothing crash-out compensation ([`Msg::Retract`]).
    SeqRetract { seq: u64, chunk: u32, client: usize },
    /// `client` has finished every microbatch of the current minibatch.
    /// Carrying the id lets the daemon count the quorum per-client, so
    /// a stray Done from a device the membership already excludes (the
    /// escalation path) can never overshoot the quorum.
    Done { client: usize },
    /// Discard every buffered piece of (`micro`, `client`), across all
    /// layers: the crash-out compensation that keeps pushes
    /// all-or-nothing per microbatch. A device that lost a piece of
    /// `micro` on a dead link retracts the siblings it did deliver, so
    /// the orphan re-run by a survivor cannot double-count.
    Retract { micro: u64, client: usize },
    /// The colocated worker asks for the completed accumulators; the
    /// daemon replies once the step's live quorum of clients is Done.
    Flush { reply: mpsc::Sender<Vec<Vec<f32>>> },
    Shutdown,
}

impl WireMsg for Msg {
    fn is_barrier(&self) -> bool {
        // control plane: never held in limbo, flushes limbo ahead
        !matches!(self, Msg::Accum { .. } | Msg::SeqAccum { .. })
    }
    fn payload_bytes(&self) -> usize {
        // payloads are already encoded wire bytes, so their length IS
        // the priced volume — bf16 halves it automatically
        match self {
            Msg::Accum { data, .. } | Msg::SeqAccum { data, .. } => data.len(),
            _ => 0,
        }
    }
}

impl WireCodec for Msg {
    fn encode(&self, out: &mut Vec<u8>) -> bool {
        match self {
            Msg::Accum { layer, micro, weight, client, data } => {
                out.push(0);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *micro);
                frame::put_f32(out, *weight);
                frame::put_u64(out, *client as u64);
                frame::put_bytes(out, data);
            }
            Msg::SeqAccum { layer, seq, chunk, count, weight, client, data } => {
                out.push(1);
                frame::put_u64(out, *layer as u64);
                frame::put_u64(out, *seq);
                frame::put_u32(out, *chunk);
                frame::put_u32(out, *count);
                frame::put_f32(out, *weight);
                frame::put_u64(out, *client as u64);
                frame::put_bytes(out, data);
            }
            Msg::SeqRetract { seq, chunk, client } => {
                out.push(2);
                frame::put_u64(out, *seq);
                frame::put_u32(out, *chunk);
                frame::put_u64(out, *client as u64);
            }
            Msg::Done { client } => {
                out.push(3);
                frame::put_u64(out, *client as u64);
            }
            Msg::Retract { micro, client } => {
                out.push(4);
                frame::put_u64(out, *micro);
                frame::put_u64(out, *client as u64);
            }
            // Flush carries an mpsc reply channel — a process-local
            // rendezvous by nature. It rides the transport's ticketed
            // local lane (it is only ever sent on a self-link).
            Msg::Flush { .. } => return false,
            Msg::Shutdown => out.push(5),
        }
        true
    }

    fn decode(bytes: &[u8]) -> Option<Msg> {
        let mut r = frame::Reader::new(bytes.get(1..)?);
        let msg = match bytes.first()? {
            0 => Msg::Accum {
                layer: r.u64()? as usize,
                micro: r.u64()?,
                weight: r.f32()?,
                client: r.u64()? as usize,
                data: r.bytes()?,
            },
            1 => Msg::SeqAccum {
                layer: r.u64()? as usize,
                seq: r.u64()?,
                chunk: r.u32()?,
                count: r.u32()?,
                weight: r.f32()?,
                client: r.u64()? as usize,
                data: r.bytes()?,
            },
            2 => Msg::SeqRetract { seq: r.u64()?, chunk: r.u32()?, client: r.u64()? as usize },
            3 => Msg::Done { client: r.u64()? as usize },
            4 => Msg::Retract { micro: r.u64()?, client: r.u64()? as usize },
            5 => Msg::Shutdown,
            _ => return None,
        };
        if !r.done() {
            return None;
        }
        Some(msg)
    }
}

pub struct OdcComm {
    world: usize,
    params: Arc<ParamStore>,
    /// The typed envelope transport carrying every mailbox message
    /// ([`crate::comm::transport`]): the reliable in-process path by
    /// default, or the deterministic lossy wrapper under a fault plan.
    transport: Arc<dyn Transport<Msg>>,
    /// Grads returned by the local daemon at the minibatch boundary
    /// (written by the owner's `end_minibatch`, or by a rendezvous
    /// successor's `flush_shard` when the owner is dead or dormant).
    taken: Vec<Mutex<Option<Vec<Vec<f32>>>>>,
    barrier: MembershipBarrier,
    membership: Arc<Membership>,
    daemons: Mutex<Vec<JoinHandle<()>>>,
    /// Payload arenas indexed `[server][client]` (Appendix B: one
    /// preallocated buffer set per client per server).
    arenas: ArenaMatrix,
    /// Per-device step counters gating step-scoped fault partitions.
    step_ctr: Vec<AtomicUsize>,
    /// Set for a device once one of its links was declared unreachable:
    /// the device must escalate into ElasticWorld (`report_failed`).
    escalated: Vec<AtomicBool>,
    /// Payload element encoding on the wire (FastFold). `F32` is
    /// bit-exact; `Bf16` halves push bytes with error feedback.
    wire: WireDtype,
    /// Error-feedback residuals, `[dev][layer]`, each the layer's full
    /// padded length (sliced per server range at the push). Empty under
    /// `F32` — the encoding is exact, there is no error to feed back.
    residuals: Vec<Vec<Mutex<Vec<f32>>>>,
    /// Total encoded gradient bytes pushed by clients (Accum + SeqAccum).
    wire_bytes: Arc<AtomicU64>,
    /// Total nanoseconds the daemons spent in flush folds.
    fold_ns: Arc<AtomicU64>,
}

impl OdcComm {
    pub(crate) fn new(params: Arc<ParamStore>, world: usize) -> Self {
        OdcComm::with_membership(params, Arc::new(Membership::all_live(world)))
    }

    /// ODC over an elastic membership schedule (see
    /// [`crate::comm::membership`]): daemons fold with the per-step
    /// live quorum, the step barrier shrinks and grows with it, and a
    /// dead client's payload arenas are released at its fail-step fold.
    /// With a static schedule this is exactly [`OdcComm::new`].
    pub(crate) fn with_membership(params: Arc<ParamStore>, membership: Arc<Membership>) -> Self {
        OdcComm::with_wire(params, membership, WireDtype::F32)
    }

    /// ODC with a configured wire encoding: `F32` stays bit-identical to
    /// the oracle; `Bf16` halves pushed gradient bytes (round-to-nearest
    /// -even + per-shard error feedback, f32 master accumulation
    /// server-side — tolerance-equivalent, see `docs/wire_precision.md`).
    pub(crate) fn with_wire(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        OdcComm::with_transport(params, membership, Arc::new(InProcTransport::new(world)), wire)
    }

    /// ODC over a lossy transport: every mailbox message crosses a
    /// [`FaultyTransport`] injecting the given plan. Transient faults
    /// are absorbed by the retransmit ladder + reassembly (bit-identical
    /// results); a partitioned link escalates the sender into the
    /// elastic machinery (see [`CommBackend::link_escalated`]).
    pub(crate) fn with_faults(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        plan: FaultPlan,
        policy: RetryPolicy,
    ) -> Self {
        OdcComm::with_faults_wire(params, membership, plan, policy, WireDtype::F32)
    }

    /// [`OdcComm::with_faults`] with a configured wire encoding — the
    /// retransmit ladder replays the SAME encoded payload, so fault
    /// tolerance and wire precision compose without interaction.
    pub(crate) fn with_faults_wire(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        plan: FaultPlan,
        policy: RetryPolicy,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        OdcComm::with_transport(
            params,
            membership,
            Arc::new(FaultyTransport::new(world, plan, policy)),
            wire,
        )
    }

    /// Build the full transport stack from a [`TransportKind`]: the
    /// byte-moving base (`inproc` mailbox, `shm` ring, or `uds`
    /// sockets), optionally wrapped in the chaos layer. This is the
    /// trainer's `--transport` entry point; delivery order — and
    /// therefore the training bytes under static dispatch — is
    /// identical across all three bases (ticket-sequenced, see
    /// `comm/ring.rs`).
    pub(crate) fn with_stack(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        wire: WireDtype,
        kind: TransportKind,
        faults: Option<(FaultPlan, RetryPolicy)>,
    ) -> std::io::Result<Self> {
        let world = membership.world();
        let base: Arc<dyn Transport<Msg>> = match kind {
            TransportKind::Inproc => Arc::new(InProcTransport::new(world)),
            TransportKind::Shm => Arc::new(RingTransport::new(world)),
            TransportKind::Uds => Arc::new(SocketTransport::bind_world(world)?),
        };
        let transport: Arc<dyn Transport<Msg>> = match faults {
            Some((plan, policy)) => Arc::new(FaultyTransport::over(base, plan, policy)),
            None => base,
        };
        Ok(OdcComm::with_transport(params, membership, transport, wire))
    }

    fn with_transport(
        params: Arc<ParamStore>,
        membership: Arc<Membership>,
        transport: Arc<dyn Transport<Msg>>,
        wire: WireDtype,
    ) -> Self {
        let world = membership.world();
        let shard_lens: Vec<usize> = params.layers.iter().map(|l| l.shard_len).collect();
        // One full microbatch of a client pushes one piece per layer to
        // each server, so prealloc one buffer per layer's ENCODED shard
        // length, plus a max-sized spare for the daemon lagging one
        // message. Byte-sized arenas: under bf16 the resident payload
        // memory genuinely halves.
        let mut caps: Vec<usize> = shard_lens.iter().map(|&l| wire.bytes_for(l)).collect();
        caps.push(caps.iter().copied().max().unwrap_or(0));
        let arenas = ArenaMatrix::new(world, world, &caps);
        let fold_threads = fold::default_fold_threads();
        let fold_ns = Arc::new(AtomicU64::new(0));
        let mut daemons = Vec::with_capacity(world);
        for server in 0..world {
            let lens = shard_lens.clone();
            let row = arenas.row(server);
            let members = Arc::clone(&membership);
            let link = Arc::clone(&transport);
            let ns = Arc::clone(&fold_ns);
            daemons.push(std::thread::spawn(move || {
                daemon_loop(server, link, lens, members, row, wire, fold_threads, ns)
            }));
        }
        let residuals = (0..world)
            .map(|_| {
                params
                    .layers
                    .iter()
                    .map(|l| {
                        Mutex::new(match wire {
                            WireDtype::F32 => Vec::new(),
                            WireDtype::Bf16 => vec![0.0; l.padded_len()],
                        })
                    })
                    .collect()
            })
            .collect();
        OdcComm {
            world,
            params,
            transport,
            taken: (0..world).map(|_| Mutex::new(None)).collect(),
            barrier: MembershipBarrier::new(Arc::clone(&membership), 1),
            membership,
            daemons: Mutex::new(daemons),
            arenas,
            step_ctr: (0..world).map(|_| AtomicUsize::new(0)).collect(),
            escalated: (0..world).map(|_| AtomicBool::new(false)).collect(),
            wire,
            residuals,
            wire_bytes: Arc::new(AtomicU64::new(0)),
            fold_ns,
        }
    }

    /// Send with escalation handling: a lost message is tolerated (the
    /// id-keyed fold and membership quorum absorb it — it only happens
    /// on a link already under suspicion), an unreachable link marks the
    /// sending device for ElasticWorld escalation.
    fn send(&self, src: usize, dst: usize, micro: u64, msg: Msg) {
        match self.transport.send(src, dst, micro, msg) {
            Ok(()) | Err(SendError::Lost { .. }) => {}
            Err(SendError::Unreachable) => self.escalated[src].store(true, Ordering::Relaxed),
        }
    }

    /// Summed payload-arena counters (tests / benches): proves the push
    /// path is allocation-free after warm-up.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arenas.stats()
    }
}

/// A buffered piece's payload: the encoded wire image as pushed (goes
/// home to its pusher's arena after the fold), or an already-decoded f32
/// gradient reconstituted by the SeqSplit per-sequence rendezvous
/// (plain heap — simply dropped after the fold).
enum Payload {
    Wire(Vec<u8>),
    Folded(Vec<f32>),
}

impl Payload {
    /// Borrow as a fold input under the backend's wire encoding.
    fn piece_data(&self, wire: WireDtype) -> PieceData<'_> {
        match self {
            Payload::Wire(b) => PieceData::Wire(b, wire),
            Payload::Folded(v) => PieceData::F32(v),
        }
    }
}

/// One buffered gradient piece awaiting the minibatch fold.
struct Piece {
    micro: u64,
    client: usize,
    weight: f32,
    data: Payload,
}

/// One buffered SEQUENCE-CHUNK piece (SeqSplit) awaiting its
/// per-sequence rendezvous at the flush.
struct SeqPiece {
    seq: u64,
    chunk: u32,
    count: u32,
    client: usize,
    weight: f32,
    data: Vec<u8>,
}

/// SeqSplit's per-sequence partial reduction: sort the layer's chunk
/// pieces by (seq, chunk, client) — chunk-index order within a
/// sequence, a pure function of the split rule, blind to which device
/// ran which chunk — then fold each sequence's chunks into a fresh f32
/// accumulator (decode fused into the accumulate; every chunk's wire
/// payload returns to its pusher's arena immediately). Each
/// reconstituted sequence gradient becomes one ordinary [`Piece`] keyed
/// `seq_micro_key(seq)` with weight 1 (the chunk weights already sum to
/// the sequence's aggregation weight), so the micro fold stays the
/// single ordering authority — and arena accounting stays exact: every
/// acquired buffer goes home here, the f32 accumulator is plain heap.
fn fold_seq_layer(
    seqs: &mut Vec<SeqPiece>,
    len: usize,
    arenas: &[Arc<PayloadArena>],
    wire: WireDtype,
) -> Vec<Piece> {
    seqs.sort_by_key(|p| (p.seq, p.chunk, p.client));
    let mut out: Vec<Piece> = Vec::new();
    for p in seqs.drain(..) {
        let key = seq_micro_key(p.seq);
        if !matches!(out.last(), Some(last) if last.micro == key) {
            debug_assert!(p.count >= 2);
            out.push(Piece {
                micro: key,
                client: p.client,
                weight: 1.0,
                data: Payload::Folded(vec![0.0; len]),
            });
        }
        let last = out.last_mut().expect("accumulator just ensured");
        let acc = match &mut last.data {
            Payload::Folded(v) => v,
            Payload::Wire(_) => unreachable!("seq accumulators are always Folded"),
        };
        let piece = FoldPiece { weight: p.weight, data: PieceData::Wire(&p.data, wire) };
        fold::fold_pieces(acc, std::slice::from_ref(&piece), 1);
        arenas[p.client].release(p.data);
    }
    out
}

/// Fold one layer's buffered pieces in (micro id asc, client asc) order
/// — a pure function of the plan, blind to arrival interleaving — and
/// release every wire payload to its (server, client) arena. The sort
/// is stable, so same-key pieces (possible only from one client's
/// sequential pushes) keep their channel-FIFO order. The accumulate
/// itself runs through [`fold::fold_pieces`] — chunk-parallel over
/// `threads` workers with per-element order identical to the scalar
/// pass, so the result is bit-identical at any thread count.
fn fold_layer(
    pieces: &mut Vec<Piece>,
    len: usize,
    arenas: &[Arc<PayloadArena>],
    wire: WireDtype,
    threads: usize,
) -> Vec<f32> {
    pieces.sort_by_key(|p| (p.micro, p.client));
    let mut acc = vec![0.0f32; len];
    let inputs: Vec<FoldPiece> = pieces
        .iter()
        .map(|p| FoldPiece { weight: p.weight, data: p.data.piece_data(wire) })
        .collect();
    fold::fold_pieces(&mut acc, &inputs, threads);
    drop(inputs);
    for p in pieces.drain(..) {
        if let Payload::Wire(b) = p.data {
            arenas[p.client].release(b);
        }
    }
    acc
}

/// The accumulation daemon: single-threaded state machine buffering the
/// minibatch's gradient pieces and folding them id-keyed at the flush.
/// `arenas` is this server's row of the pair matrix, indexed by client.
///
/// The daemon is the device's *shard server* and outlives the device's
/// worker thread (the PS fault model: server state survives a client
/// crash). It counts its own minibatch index and flushes when the
/// membership's per-step quorum of `Done`s has arrived — a crashed
/// client is simply no longer waited for, while its already-buffered
/// pieces (completed microbatches) stay in the fold for exactly-once
/// delivery. At the crash step's flush the dead client's payload
/// arenas are retired.
#[allow(clippy::too_many_arguments)]
fn daemon_loop(
    me: usize,
    transport: Arc<dyn Transport<Msg>>,
    shard_lens: Vec<usize>,
    membership: Arc<Membership>,
    arenas: Vec<Arc<PayloadArena>>,
    wire: WireDtype,
    fold_threads: usize,
    fold_ns: Arc<AtomicU64>,
) {
    let mut pending: Vec<Vec<Piece>> = shard_lens.iter().map(|_| Vec::new()).collect();
    let mut pending_seq: Vec<Vec<SeqPiece>> = shard_lens.iter().map(|_| Vec::new()).collect();
    let mut done = 0usize;
    let mut mb = 0usize;
    let mut flush: Option<mpsc::Sender<Vec<Vec<f32>>>> = None;
    loop {
        let msg = match transport.recv(me) {
            Some(env) => env.msg,
            None => return,
        };
        match msg {
            Msg::Accum { layer, micro, weight, client, data } => {
                // Idempotent delivery, belt and braces on top of the
                // transport's seq dedup: the fold key (micro, client)
                // identifies a push uniquely, so a replayed request is
                // recognized and its payload returns to the arena.
                if pending[layer].iter().any(|p| p.micro == micro && p.client == client) {
                    arenas[client].release(data);
                } else {
                    pending[layer].push(Piece { micro, client, weight, data: Payload::Wire(data) });
                }
            }
            // Count the quorum per-client so a stray Done from a device
            // the membership excludes at this minibatch (crash or
            // escalation mid-broadcast) can never overshoot it.
            Msg::Done { client } => {
                if membership.completes(client, mb) {
                    done += 1;
                }
            }
            Msg::SeqAccum { layer, seq, chunk, count, weight, client, data } => {
                // idempotent like Accum: (seq, chunk, client) is unique
                if pending_seq[layer]
                    .iter()
                    .any(|p| p.seq == seq && p.chunk == chunk && p.client == client)
                {
                    arenas[client].release(data);
                } else {
                    pending_seq[layer].push(SeqPiece { seq, chunk, count, client, weight, data });
                }
            }
            Msg::Retract { micro, client } => {
                for pieces in pending.iter_mut() {
                    if let Some(pos) =
                        pieces.iter().position(|p| p.micro == micro && p.client == client)
                    {
                        let p = pieces.swap_remove(pos);
                        if let Payload::Wire(b) = p.data {
                            arenas[p.client].release(b);
                        }
                    }
                }
            }
            Msg::SeqRetract { seq, chunk, client } => {
                for pieces in pending_seq.iter_mut() {
                    if let Some(pos) = pieces
                        .iter()
                        .position(|p| p.seq == seq && p.chunk == chunk && p.client == client)
                    {
                        let p = pieces.swap_remove(pos);
                        arenas[p.client].release(p.data);
                    }
                }
            }
            Msg::Flush { reply } => flush = Some(reply),
            Msg::Shutdown => return,
        }
        if done == membership.expected_done(mb) {
            if let Some(reply) = flush.take() {
                // SeqSplit rendezvous first: reconstituted sequence
                // gradients join the micro fold under their synthetic
                // keys, then everything folds id-ordered as usual.
                let t0 = Instant::now();
                for (layer, seqs) in pending_seq.iter_mut().enumerate() {
                    let folded = fold_seq_layer(seqs, shard_lens[layer], &arenas, wire);
                    pending[layer].extend(folded);
                }
                let out: Vec<Vec<f32>> = pending
                    .iter_mut()
                    .zip(&shard_lens)
                    .map(|(pieces, &len)| fold_layer(pieces, len, &arenas, wire, fold_threads))
                    .collect();
                fold_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                for (client, arena) in arenas.iter().enumerate() {
                    if membership.fails_during(client, mb) {
                        arena.retire();
                    }
                }
                done = 0;
                mb += 1;
                let _ = reply.send(out);
            }
        }
    }
}

impl CommBackend for OdcComm {
    fn world(&self) -> usize {
        self.world
    }

    fn gather_params(&self, dev: usize, layer: usize, out: &mut [f32]) {
        // One-sided read: parameters are immutable during the minibatch
        // (owners only write between end_minibatch and end_step), so no
        // synchronization is needed — the owner's compute is undisturbed.
        // Under a lossy transport each per-owner read runs the same
        // retry ladder as a push; the read itself always succeeds
        // in-process, so a dead link only marks the reader for
        // escalation.
        let p = &self.params.layers[layer];
        for server in 0..self.world {
            let bytes = self.wire.bytes_for(p.shard_range(server).len());
            if self.transport.one_sided(dev, server, bytes).is_err() {
                self.escalated[dev].store(true, Ordering::Relaxed);
            }
        }
        let n = p.padded_len().min(out.len());
        p.buf.read(0, &mut out[..n]);
    }

    fn gather_policy(&self) -> GatherPolicy {
        // One-sided + phase-immutable params: a gather at any point of
        // the minibatch returns identical bytes, and skipping one never
        // desynchronizes anything (there is nothing to rendezvous with).
        GatherPolicy::OneSided
    }

    fn reduce_grad(&self, dev: usize, layer: usize, grad: &[f32], weight: f32, micro: u64) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        if weight == 0.0 {
            return; // idle slot: ODC has nothing to send and nothing to wait for
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // a link is dead: the device is crashing out, stop pushing
        }
        let mut lost = false;
        let mut residual = self.residuals[dev][layer].lock().unwrap();
        for server in 0..self.world {
            let r = p.shard_range(server);
            let mut data = self.arenas.arena(server, dev).acquire(self.wire.bytes_for(r.len()));
            let src = &grad[r.clone()];
            match self.wire {
                WireDtype::F32 => fold::encode(&mut data, src, self.wire),
                WireDtype::Bf16 => fold::encode_ef(&mut data, src, &mut residual[r], self.wire),
            }
            self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
            let msg = Msg::Accum { layer, micro, weight, client: dev, data };
            if self.transport.send(dev, server, micro, msg).is_err() {
                lost = true;
            }
        }
        drop(residual);
        if lost {
            // All-or-nothing per microbatch: a piece of `micro` is gone,
            // so the micro must re-run on a survivor — land the held
            // pieces of COMPLETED micros, retract the delivered siblings
            // of this one, and crash out into ElasticWorld.
            self.escalated[dev].store(true, Ordering::Relaxed);
            self.transport.flush_links(dev);
            for server in 0..self.world {
                let _ = self.transport.send(dev, server, micro, Msg::Retract { micro, client: dev });
            }
        }
    }

    fn reduce_grad_seq(
        &self,
        dev: usize,
        layer: usize,
        grad: &[f32],
        weight: f32,
        seq: u64,
        chunk: u32,
        count: u32,
    ) {
        let p = &self.params.layers[layer];
        debug_assert_eq!(grad.len(), p.padded_len());
        if weight == 0.0 {
            return;
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // a link is dead: the device is crashing out, stop pushing
        }
        let mut lost = false;
        let mut residual = self.residuals[dev][layer].lock().unwrap();
        for server in 0..self.world {
            let r = p.shard_range(server);
            let mut data = self.arenas.arena(server, dev).acquire(self.wire.bytes_for(r.len()));
            let src = &grad[r.clone()];
            match self.wire {
                WireDtype::F32 => fold::encode(&mut data, src, self.wire),
                WireDtype::Bf16 => fold::encode_ef(&mut data, src, &mut residual[r], self.wire),
            }
            self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
            let msg = Msg::SeqAccum { layer, seq, chunk, count, weight, client: dev, data };
            if self.transport.send(dev, server, seq_micro_key(seq), msg).is_err() {
                lost = true;
            }
        }
        drop(residual);
        if lost {
            // all-or-nothing per chunk, mirroring `reduce_grad`
            self.escalated[dev].store(true, Ordering::Relaxed);
            self.transport.flush_links(dev);
            for server in 0..self.world {
                let _ = self.transport.send(
                    dev,
                    server,
                    seq_micro_key(seq),
                    Msg::SeqRetract { seq, chunk, client: dev },
                );
            }
        }
    }

    fn end_minibatch(&self, dev: usize) {
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // crashing out: no Done broadcast, no flush to wait on
        }
        // scatter-accumulate epilogue: tell every server this client is done
        for server in 0..self.world {
            self.send(dev, server, 0, Msg::Done { client: dev });
        }
        if self.escalated[dev].load(Ordering::Relaxed) {
            return; // link died mid-broadcast: daemons ignore the stray Dones
        }
        // then wait for the local daemon to see all clients done
        let (rtx, rrx) = mpsc::channel();
        self.send(dev, dev, 0, Msg::Flush { reply: rtx });
        let grads = rrx.recv().expect("daemon flush");
        *self.taken[dev].lock().unwrap() = Some(grads);
    }

    fn take_grad_shard(&self, dev: usize, layer: usize, out: &mut [f32]) {
        let slot = self.taken[dev].lock().unwrap();
        let grads = slot.as_ref().expect("take_grad_shard before end_minibatch");
        out.copy_from_slice(&grads[layer]);
    }

    fn end_step(&self, dev: usize) {
        // The single global barrier per step: params republished. The
        // quorum follows the membership schedule (a dead device is not
        // waited for; a joiner is counted from its join step).
        let next = self.step_ctr[dev].fetch_add(1, Ordering::Relaxed) + 1;
        self.barrier.wait();
        self.transport.note_step(dev, next);
    }

    fn flush_shard(&self, shard: usize) {
        // The rendezvous successor drives the orphaned shard server's
        // flush. Safe to call after the caller's own `end_minibatch`
        // returned: every live client has broadcast `Done` to ALL
        // daemons by then, so the orphan's quorum is (or will shortly
        // be) met and the reply cannot deadlock. The request travels
        // the shard's self-link (never partitioned — validated).
        let (tx, rx) = mpsc::channel();
        self.send(shard, shard, 0, Msg::Flush { reply: tx });
        let grads = rx.recv().expect("orphan daemon flush");
        *self.taken[shard].lock().unwrap() = Some(grads);
    }

    fn await_join(&self, dev: usize) {
        let join = self.membership.joins_at(dev);
        self.step_ctr[dev].store(join, Ordering::Relaxed);
        self.transport.note_step(dev, join);
        self.barrier.await_step_start(join);
    }

    fn link_escalated(&self, dev: usize) -> bool {
        self.escalated[dev].load(Ordering::Relaxed)
    }

    fn fault_stats(&self) -> FaultStats {
        self.transport.stats()
    }

    fn hotpath_stats(&self) -> HotpathStats {
        HotpathStats {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            fold_ns: self.fold_ns.load(Ordering::Relaxed),
        }
    }

    fn name(&self) -> &'static str {
        "odc"
    }
}

impl Drop for OdcComm {
    fn drop(&mut self) {
        for server in 0..self.world {
            let _ = self.transport.send(server, server, 0, Msg::Shutdown);
        }
        for d in self.daemons.lock().unwrap().drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_is_barrier_free_and_current() {
        // A single device can gather repeatedly with nobody else
        // participating — impossible under the collective backend.
        let params = Arc::new(ParamStore::new(&[8], 2));
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        params.layers[0].init_from(&vals);
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut out = vec![0.0; 8];
        for _ in 0..3 {
            comm.gather_params(0, 0, &mut out);
            assert_eq!(out, vals);
        }
        assert!(comm.gathers_cacheable());
    }

    #[test]
    fn scatter_accumulate_sums_across_clients() {
        let world = 3;
        let params = Arc::new(ParamStore::new(&[9], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    // device pushes (dev+1) twice with weight 1 — two microbatches
                    let grad = vec![(dev + 1) as f32; 9];
                    comm.reduce_grad(dev, 0, &grad, 1.0, (2 * dev) as u64);
                    comm.reduce_grad(dev, 0, &grad, 1.0, (2 * dev + 1) as u64);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 3];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    for &v in &shard {
                        assert_eq!(v, 12.0); // 2 * (1 + 2 + 3)
                    }
                    comm.end_step(dev);
                });
            }
        });
    }

    #[test]
    fn different_push_counts_per_device() {
        // The LB-Mini property: devices contribute different numbers of
        // microbatches and nothing deadlocks.
        let world = 2;
        let params = Arc::new(ParamStore::new(&[4], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let pushes = if dev == 0 { 3 } else { 1 };
                    for m in 0..pushes {
                        comm.reduce_grad(dev, 0, &[1.0; 4], 1.0, (4 * dev + m) as u64);
                    }
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 2];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    assert_eq!(shard, vec![4.0, 4.0]); // 3 + 1 pushes
                    comm.end_step(dev);
                });
            }
        });
    }

    #[test]
    fn two_minibatches_reset_cleanly() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[4], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for step in 1..=2 {
                        comm.reduce_grad(dev, 0, &[step as f32; 4], 1.0, dev as u64);
                        comm.end_minibatch(dev);
                        let mut shard = vec![0.0; 2];
                        comm.take_grad_shard(dev, 0, &mut shard);
                        assert_eq!(shard, vec![2.0 * step as f32; 2]);
                        comm.end_step(dev);
                    }
                });
            }
        });
    }

    #[test]
    fn weighted_pushes() {
        let world = 2;
        let params = Arc::new(ParamStore::new(&[2], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    comm.reduce_grad(dev, 0, &[1.0, 1.0], if dev == 0 { 0.5 } else { 2.0 }, dev as u64);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 1];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    assert!((shard[0] - 2.5).abs() < 1e-6);
                    comm.end_step(dev);
                });
            }
        });
    }

    #[test]
    fn arena_fully_drained_after_minibatch() {
        // After end_minibatch on every device, every pushed payload has
        // been accumulated and returned: resident == prealloc + fresh.
        let world = 2;
        let params = Arc::new(ParamStore::new(&[6, 10], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        let initial = comm.arena_stats().resident;
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    for l in 0..2 {
                        comm.reduce_grad(dev, l, &vec![1.0; params_padded(&comm, l)], 1.0, dev as u64);
                    }
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 5];
                    comm.take_grad_shard(dev, 1, &mut shard);
                    comm.end_step(dev);
                });
            }
        });
        let s = comm.arena_stats();
        assert_eq!(s.acquires, (world * world * 2) as u64);
        assert_eq!(s.resident, initial + s.fresh_allocs, "all payloads must return home");
    }

    fn params_padded(comm: &OdcComm, layer: usize) -> usize {
        comm.params.layers[layer].padded_len()
    }

    /// The fold is keyed by global microbatch id, not arrival: pushing
    /// the same (micro, grad) pieces in a scrambled order produces
    /// bit-identical shards. The values are chosen so an arrival-order
    /// fold WOULD differ: in f32, (1e8 + 1) - 1e8 = 0 but
    /// (-1e8 + 1e8) + 1 = 1.
    #[test]
    fn fold_keyed_by_micro_id_not_push_order() {
        let world = 2;
        let run = |push_order: &[(usize, u64, f32)]| -> Vec<Vec<f32>> {
            let params = Arc::new(ParamStore::new(&[4], world));
            let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
            // all pushes from this thread: arrival order == call order
            for &(client, micro, val) in push_order {
                comm.reduce_grad(client, 0, &[val; 4], 1.0, micro);
            }
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for dev in 0..world {
                    let comm = Arc::clone(&comm);
                    handles.push(s.spawn(move || {
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 2];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                        g
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        // micro 0 = 1e8 (client 0), micro 1 = 1.0 (client 1), micro 2 = -1e8 (client 0)
        let in_order = run(&[(0, 0, 1e8), (1, 1, 1.0), (0, 2, -1e8)]);
        let scrambled = run(&[(0, 2, -1e8), (0, 0, 1e8), (1, 1, 1.0)]);
        assert_eq!(in_order, scrambled, "push order must not change a bit");
        // id-order fold: (1e8 + 1.0) + (-1e8) == 0.0 in f32
        for shard in &in_order {
            assert_eq!(shard, &vec![0.0f32; 2]);
        }
    }

    /// SeqSplit rendezvous: chunk pieces fold in chunk-index order no
    /// matter which client pushed which chunk or in what order, and the
    /// reconstituted gradient joins the micro fold under its synthetic
    /// key. Values chosen so a wrong fold order would change bits.
    #[test]
    fn seq_fold_keyed_by_chunk_index_not_push_order() {
        let world = 2;
        let run = |push_order: &[(usize, u32, f32)]| -> Vec<Vec<f32>> {
            let params = Arc::new(ParamStore::new(&[4], world));
            let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
            // one regular micro plus a 3-chunk split sequence (seq 7)
            comm.reduce_grad(0, 0, &[2.0; 4], 1.0, 0);
            for &(client, chunk, val) in push_order {
                comm.reduce_grad_seq(client, 0, &[val; 4], 1.0, 7, chunk, 3);
            }
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for dev in 0..world {
                    let comm = Arc::clone(&comm);
                    handles.push(s.spawn(move || {
                        comm.end_minibatch(dev);
                        let mut g = vec![0.0f32; 2];
                        comm.take_grad_shard(dev, 0, &mut g);
                        comm.end_step(dev);
                        g
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        // chunk 0 = 1e8, chunk 1 = 1.0, chunk 2 = -1e8: index-order fold
        // gives (1e8 + 1.0) + (-1e8) == 0.0 in f32
        let in_order = run(&[(0, 0, 1e8), (1, 1, 1.0), (0, 2, -1e8)]);
        let scrambled = run(&[(1, 2, -1e8), (0, 1, 1.0), (1, 0, 1e8)]);
        assert_eq!(in_order, scrambled, "chunk placement/order must not change a bit");
        for shard in &in_order {
            assert_eq!(shard, &vec![2.0f32; 2], "micro 2.0 + seq fold 0.0");
        }
    }

    #[test]
    fn seq_chunk_weights_scale_each_chunk() {
        // weighted chunks: 0.25·4 + 0.75·8 = 7 on every element
        let world = 2;
        let params = Arc::new(ParamStore::new(&[4], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        comm.reduce_grad_seq(0, 0, &[4.0; 4], 0.25, 3, 0, 2);
        comm.reduce_grad_seq(1, 0, &[8.0; 4], 0.75, 3, 1, 2);
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    comm.end_minibatch(dev);
                    let mut g = vec![0.0f32; 2];
                    comm.take_grad_shard(dev, 0, &mut g);
                    comm.end_step(dev);
                    assert_eq!(g, vec![7.0f32; 2]);
                });
            }
        });
    }

    #[test]
    fn seq_pushes_keep_arena_accounting_exact() {
        // chunk payloads are acquired like micro payloads and every one
        // returns home at the fold — accumulator included.
        let world = 2;
        let params = Arc::new(ParamStore::new(&[6], world));
        let comm = Arc::new(OdcComm::new(Arc::clone(&params), world));
        let initial = comm.arena_stats().resident;
        std::thread::scope(|s| {
            for dev in 0..world {
                let comm = Arc::clone(&comm);
                s.spawn(move || {
                    let g = vec![1.0; params_padded(&comm, 0)];
                    comm.reduce_grad_seq(dev, 0, &g, 0.5, 11, dev as u32, 2);
                    comm.end_minibatch(dev);
                    let mut shard = vec![0.0; 3];
                    comm.take_grad_shard(dev, 0, &mut shard);
                    comm.end_step(dev);
                });
            }
        });
        let st = comm.arena_stats();
        assert_eq!(st.acquires, (world * world) as u64);
        assert_eq!(st.resident, initial + st.fresh_allocs, "all chunk payloads must return home");
    }
}
