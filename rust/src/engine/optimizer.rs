//! Sharded AdamW — the "server" half of the decentralized parameter
//! server: each device applies the update only to the shard it owns.
//!
//! The default path is this vectorizable Rust loop (it IS the server-side
//! op; the paper's daemon does the same on-GPU). The PJRT `adam_chunk`
//! artifact implements the identical math; `trainer::TrainerConfig::
//! pjrt_shard_ops` routes updates through it instead, and the unit tests
//! + python tests pin the two together.

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.01 }
    }
}

/// Per-shard Adam state.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u32,
}

impl AdamState {
    pub fn new(len: usize) -> Self {
        AdamState { m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    /// In-place AdamW step on `p` with gradient `g`.
    pub fn step(&mut self, cfg: &AdamConfig, p: &mut [f32], g: &[f32]) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let (b1, b2) = (cfg.beta1, cfg.beta2);
        for i in 0..p.len() {
            let gi = g[i];
            let m = b1 * self.m[i] + (1.0 - b1) * gi;
            let v = b2 * self.v[i] + (1.0 - b2) * gi * gi;
            self.m[i] = m;
            self.v[i] = v;
            let mhat = m / bc1;
            let vhat = v / bc2;
            p[i] -= cfg.lr * (mhat / (vhat.sqrt() + cfg.eps) + cfg.weight_decay * p[i]);
        }
    }

    /// Bias corrections for the PJRT adam_chunk hparam vector.
    pub fn bias_corrections(&self, cfg: &AdamConfig) -> (f32, f32) {
        (1.0 - cfg.beta1.powi(self.t as i32), 1.0 - cfg.beta2.powi(self.t as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_formula() {
        let cfg = AdamConfig { lr: 0.01, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.1 };
        let mut st = AdamState::new(1);
        let mut p = vec![1.0f32];
        st.step(&cfg, &mut p, &[0.5]);
        // t=1: m=0.05, v=0.00025 ; mhat=0.5, vhat=0.25
        let want = 1.0 - 0.01 * (0.5 / (0.25f32.sqrt() + 1e-8) + 0.1 * 1.0);
        assert!((p[0] - want).abs() < 1e-6, "{} vs {want}", p[0]);
    }

    #[test]
    fn descends_on_quadratic() {
        // minimize f(x) = x² — Adam should get close to 0
        let cfg = AdamConfig { lr: 0.05, weight_decay: 0.0, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut p = vec![3.0f32];
        for _ in 0..500 {
            let g = 2.0 * p[0];
            st.step(&cfg, &mut p, &[g]);
        }
        assert!(p[0].abs() < 0.05, "{}", p[0]);
    }

    #[test]
    fn zero_grad_only_decays() {
        let cfg = AdamConfig { lr: 0.1, weight_decay: 0.01, ..Default::default() };
        let mut st = AdamState::new(2);
        let mut p = vec![1.0f32, -2.0];
        st.step(&cfg, &mut p, &[0.0, 0.0]);
        assert!((p[0] - (1.0 - 0.1 * 0.01)).abs() < 1e-6);
        assert!((p[1] - (-2.0 + 0.1 * 0.01 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn step_counter_advances() {
        let cfg = AdamConfig::default();
        let mut st = AdamState::new(1);
        let mut p = vec![0.0f32];
        st.step(&cfg, &mut p, &[1.0]);
        st.step(&cfg, &mut p, &[1.0]);
        assert_eq!(st.t, 2);
        let (bc1, _) = st.bias_corrections(&cfg);
        assert!((bc1 - (1.0 - 0.9f32.powi(2))).abs() < 1e-7);
    }
}
