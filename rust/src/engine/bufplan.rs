//! Per-device buffer plan: every recurring allocation of the training
//! hot path, owned in one place and recycled across layers, microbatches
//! and minibatches.
//!
//! The seed `run_microbatch` made 16+ full-tensor `to_vec()`/`clone()`
//! calls per microbatch (gathered layers, activations, tokens, segment
//! ids, masks) because every PJRT call took owned `Vec`s. The plan
//! replaces all of them with `Arc<[T]>` buffers that are:
//!
//! * **shared** into PJRT calls via [`Input::F32Shared`]-style variants
//!   (refcount clone, no copy), and
//! * **recycled** once uniquely owned again (the compute service drops
//!   its clones before replying, see `runtime::service`), so the steady
//!   state performs no heap allocation at all.
//!
//! Contents:
//! * [`SlicePool`] — a free-list of `Arc<[T]>` buffers keyed by length;
//!   `adopt` moves fresh data into a recycled allocation, `recycle`
//!   returns a uniquely-owned buffer to the list.
//! * [`BufferPlan`] — the per-device bundle: the minibatch-scoped
//!   [`GatherCache`], gradient staging (`grad_pad`, `gshard`), and the
//!   activation / token pools plus the forward activation stack.

use crate::comm::backend::{GatherPolicy, ParamStore};
use crate::comm::GatherCache;
use std::sync::Arc;

/// Free-list of reusable `Arc<[T]>` buffers. Single-threaded (one per
/// device thread); `recycle` only accepts uniquely-owned buffers, so
/// `adopt` can safely overwrite list entries in place.
pub struct SlicePool<T> {
    free: Vec<Arc<[T]>>,
    cap: usize,
    allocs: u64,
    reuses: u64,
}

impl<T: Copy> SlicePool<T> {
    /// Pool retaining at most `cap` free buffers.
    pub fn new(cap: usize) -> Self {
        SlicePool { free: Vec::with_capacity(cap), cap, allocs: 0, reuses: 0 }
    }

    /// Move `v`'s contents into a shared buffer, reusing a free
    /// same-length allocation when available (copy, no alloc) and
    /// falling back to a fresh `Arc` (counted) otherwise.
    pub fn adopt(&mut self, v: Vec<T>) -> Arc<[T]> {
        if let Some(pos) =
            self.free.iter().position(|a| a.len() == v.len() && Arc::strong_count(a) == 1)
        {
            let mut a = self.free.swap_remove(pos);
            Arc::get_mut(&mut a).expect("uniquely owned free-list entry").copy_from_slice(&v);
            self.reuses += 1;
            return a;
        }
        self.allocs += 1;
        v.into()
    }

    /// Return a buffer to the pool. Drops the buffer when other clones
    /// are still outstanding; when the pool is full, evicts the OLDEST
    /// entry instead of rejecting the new one, so a shifting length
    /// working set (e.g. microbatches moving to a different sequence
    /// bucket) re-warms the pool rather than permanently bypassing it.
    pub fn recycle(&mut self, a: Arc<[T]>) {
        if Arc::strong_count(&a) != 1 || self.cap == 0 {
            return;
        }
        if self.free.len() == self.cap {
            self.free.remove(0);
        }
        self.free.push(a);
    }

    /// (fresh allocations, in-place reuses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.allocs, self.reuses)
    }
}

/// All recurring per-device buffers of the training loop.
pub struct BufferPlan {
    /// Minibatch-scoped parameter gathers, honouring the backend's
    /// per-level [`GatherPolicy`]: one-sided (ODC) and two-level intra
    /// (Hybrid) gathers cache per minibatch; rendezvous (Collective)
    /// gathers never do. Cross-group epilogue traffic lives inside the
    /// backend and bypasses this cache entirely.
    pub cache: GatherCache,
    /// Padded full-layer gradient staging (reduce_grad input).
    pub grad_pad: Vec<f32>,
    /// Owned-shard gradient staging (take_grad_shard target).
    pub gshard: Vec<f32>,
    /// Activation / mask buffers (f32), recycled across microbatches.
    pub f32_pool: SlicePool<f32>,
    /// Token / segment / target buffers (i32), recycled likewise.
    pub i32_pool: SlicePool<i32>,
    /// Forward activation stack of the microbatch in flight (block
    /// inputs saved for the backward recompute).
    pub acts: Vec<Arc<[f32]>>,
}

impl BufferPlan {
    /// `policy` is the backend's structural gather classification
    /// ([`crate::comm::CommBackend::gather_policy`]), downgraded to
    /// [`GatherPolicy::Rendezvous`] when the engine disables caching.
    pub fn new(params: &ParamStore, dev: usize, policy: GatherPolicy) -> Self {
        let max_padded = params.max_padded_len();
        let max_shard = params.layers.iter().map(|p| p.shard_len).max().unwrap_or(0);
        let n_layers = params.n_layers();
        // Live f32 buffers per microbatch: one activation per block, the
        // current x, the mask, plus slack for in-flight adoption.
        let f32_cap = 2 * (n_layers + 2);
        // Live i32 buffers: tokens, segments, targets (+ slack).
        let i32_cap = 2 * 3;
        BufferPlan {
            cache: GatherCache::for_policy(params, dev, policy),
            grad_pad: vec![0.0; max_padded],
            gshard: vec![0.0; max_shard],
            f32_pool: SlicePool::new(f32_cap),
            i32_pool: SlicePool::new(i32_cap),
            acts: Vec::with_capacity(n_layers),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommBackend, OdcComm};

    #[test]
    fn pool_reuses_same_length_buffers() {
        let mut pool: SlicePool<f32> = SlicePool::new(4);
        let a = pool.adopt(vec![1.0, 2.0, 3.0]);
        let ptr = a.as_ptr();
        pool.recycle(a);
        let b = pool.adopt(vec![4.0, 5.0, 6.0]);
        assert_eq!(b.as_ptr(), ptr, "same-length adopt must reuse the allocation");
        assert_eq!(&b[..], &[4.0, 5.0, 6.0]);
        assert_eq!(pool.stats(), (1, 1));
    }

    #[test]
    fn pool_allocates_on_length_mismatch() {
        let mut pool: SlicePool<i32> = SlicePool::new(4);
        let a = pool.adopt(vec![1, 2]);
        pool.recycle(a);
        let b = pool.adopt(vec![1, 2, 3]);
        assert_eq!(&b[..], &[1, 2, 3]);
        assert_eq!(pool.stats().0, 2);
    }

    #[test]
    fn pool_refuses_aliased_recycle() {
        let mut pool: SlicePool<f32> = SlicePool::new(4);
        let a = pool.adopt(vec![1.0; 8]);
        let alias = Arc::clone(&a);
        pool.recycle(a); // dropped, not pooled: alias outstanding
        let b = pool.adopt(vec![2.0; 8]);
        assert_ne!(b.as_ptr(), alias.as_ptr());
        assert_eq!(alias[0], 1.0, "outstanding clone untouched");
    }

    #[test]
    fn pool_bounds_retention() {
        let mut pool: SlicePool<f32> = SlicePool::new(2);
        for _ in 0..5 {
            let a = pool.adopt(vec![0.0; 4]);
            let b = pool.adopt(vec![0.0; 4]);
            let c = pool.adopt(vec![0.0; 4]);
            pool.recycle(a);
            pool.recycle(b);
            pool.recycle(c); // third drops: pool cap is 2
        }
        assert!(pool.free.len() <= 2);
    }

    #[test]
    fn full_pool_evicts_oldest_instead_of_seizing() {
        // Regression: a pool filled with stale lengths must adapt when
        // the working set's length changes, not allocate forever.
        let mut pool: SlicePool<f32> = SlicePool::new(2);
        for len in [3usize, 4] {
            let a = pool.adopt(vec![0.0; len]);
            pool.recycle(a);
        }
        // pool now full with lengths {3, 4}; switch the working set to 5
        for _ in 0..3 {
            let a = pool.adopt(vec![0.0; 5]);
            pool.recycle(a);
        }
        let allocs_before = pool.stats().0;
        let a = pool.adopt(vec![1.0; 5]);
        pool.recycle(a);
        assert_eq!(pool.stats().0, allocs_before, "len-5 entries must be served from the pool");
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let mut pool: SlicePool<f32> = SlicePool::new(8);
        // warm-up round allocates
        let warm: Vec<_> = (0..4).map(|_| pool.adopt(vec![0.0; 16])).collect();
        for a in warm {
            pool.recycle(a);
        }
        let (allocs_after_warmup, _) = pool.stats();
        // steady state: same working set, zero new allocations
        for _ in 0..50 {
            let round: Vec<_> = (0..4).map(|i| pool.adopt(vec![i as f32; 16])).collect();
            for a in round {
                pool.recycle(a);
            }
        }
        assert_eq!(pool.stats().0, allocs_after_warmup, "steady state must not allocate");
    }

    #[test]
    fn buffer_plan_shapes_match_store() {
        let params = Arc::new(ParamStore::new(&[10, 6, 6], 2));
        let comm = OdcComm::new(Arc::clone(&params), 2);
        let mut plan = BufferPlan::new(&params, 0, comm.gather_policy());
        assert_eq!(plan.grad_pad.len(), params.max_padded_len());
        assert_eq!(plan.gshard.len(), 5);
        assert!(plan.cache.enabled());
        let g = plan.cache.gather(&comm, 0);
        assert_eq!(g.len(), params.layers[0].padded_len());
    }

    #[test]
    fn buffer_plan_inherits_backend_policy_per_level() {
        let params = Arc::new(ParamStore::new(&[8, 8], 2));
        let hybrid = crate::comm::HybridComm::new(Arc::clone(&params), 2, 2);
        let plan = BufferPlan::new(&params, 0, hybrid.gather_policy());
        assert_eq!(plan.cache.policy(), GatherPolicy::TwoLevelIntra);
        assert!(plan.cache.enabled(), "intra-group gathers cache per minibatch");
        let disabled = BufferPlan::new(&params, 0, GatherPolicy::Rendezvous);
        assert!(!disabled.cache.enabled());
    }
}
